"""Hillclimb optimizations preserve exactness (§Perf changes)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke_config
from repro.models import get_model, make_batch, nn


def test_padded_heads_exact():
    """GQA head padding (zero o-rows, per-kv-group layout) is a no-op."""
    cfg = get_smoke_config("llama3.2-3b")  # 6 heads, kv=2
    cfgp = cfg.scaled(pad_heads_to=8)
    api = get_model(cfg)
    params, _ = nn.split(api.init(jax.random.PRNGKey(0), cfg))
    paramsp, _ = nn.split(api.init(jax.random.PRNGKey(1), cfgp))
    nkv, hd, d = cfg.n_kv_heads, cfg.head_dim, cfg.d_model
    g_real, g_pad = cfg.n_heads // nkv, cfgp.padded_heads // nkv
    L = params["blocks"]["attn"]["q"]["w"].shape[0]

    qs = np.asarray(params["blocks"]["attn"]["q"]["w"])
    qd = np.array(paramsp["blocks"]["attn"]["q"]["w"])
    qd4 = qd.reshape(L, d, nkv, g_pad, hd)
    qd4[:, :, :, :g_real] = qs.reshape(L, d, nkv, g_real, hd)
    paramsp["blocks"]["attn"]["q"]["w"] = jnp.asarray(qd4.reshape(L, d, -1))
    osrc = np.asarray(params["blocks"]["attn"]["o"]["w"]).reshape(
        L, nkv, g_real, hd, d)
    odst = np.zeros((L, nkv, g_pad, hd, d), np.float32)
    odst[:, :, :g_real] = osrc
    paramsp["blocks"]["attn"]["o"]["w"] = jnp.asarray(odst.reshape(L, -1, d))
    paramsp["blocks"]["attn"]["k"] = params["blocks"]["attn"]["k"]
    paramsp["blocks"]["attn"]["v"] = params["blocks"]["attn"]["v"]
    for k in ("ln_attn", "ln_mlp", "mlp"):
        paramsp["blocks"][k] = params["blocks"][k]
    for k in ("embed", "ln_f", "unembed"):
        paramsp[k] = params[k]
    batch = make_batch(cfg, 2, 16)
    l0, _ = api.forward(params, batch, cfg)
    l1, _ = api.forward(paramsp, batch, cfgp)
    np.testing.assert_allclose(np.asarray(l0), np.asarray(l1),
                               rtol=1e-5, atol=1e-5)


def test_explicit_tp_flags_are_noop_without_mesh():
    """explicit_tp / SP flags fall back exactly on a single device."""
    cfg = get_smoke_config("qwen3-8b")
    cfg2 = cfg.scaled(explicit_tp=True, fsdp_params=True,
                      seq_shard_activations=True)
    api = get_model(cfg)
    params, _ = nn.split(api.init(jax.random.PRNGKey(0), cfg))
    batch = make_batch(cfg, 2, 16)
    l0, _ = api.forward(params, batch, cfg)
    l1, _ = api.forward(params, batch, cfg2)
    np.testing.assert_array_equal(np.asarray(l0), np.asarray(l1))


def test_decode_bf16_cache_matches_f32():
    """bf16-storage decode attention matches f32 math within bf16 tolerance."""
    from repro.models.attention import decode_attention

    ks = jax.random.split(jax.random.PRNGKey(2), 4)
    B, S, Hq, Hkv, D = 2, 64, 4, 2, 16
    q = jax.random.normal(ks[0], (B, 1, Hq, D), jnp.bfloat16)
    kc = jax.random.normal(ks[1], (B, S, Hkv, D), jnp.bfloat16)
    vc = jax.random.normal(ks[2], (B, S, Hkv, D), jnp.bfloat16)
    lens = jnp.asarray([40, 64], jnp.int32)
    out = decode_attention(q, kc, vc, lens)
    ref = decode_attention(q.astype(jnp.float32), kc.astype(jnp.float32),
                           vc.astype(jnp.float32), lens)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=3e-2, atol=3e-2)
