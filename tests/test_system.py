"""End-to-end behaviour tests for the paper's system: the three workflow
classes of §II running through the full middleware stack."""
import threading
import time

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import (ExecutionPolicy, ResourceDescription,
                        ResourceRequirements, Rhapsody, ServiceDescription,
                        TaskDescription, TaskKind)
from repro.core.agent import AgentConfig, run_agent_population
from repro.core.coupling import make_store
from repro.serving.client import llm_service_factory
from repro.substrate.simulation import heat_stencil, noop, surrogate_eval


def demo_cfg():
    return get_config("rhapsody-demo").scaled(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, vocab=512)


def test_heterogeneous_campaign():
    """§II-A: concurrent serial/MPI/CPU/GPU tasks with dependencies."""
    rh = Rhapsody(ResourceDescription(nodes=4, cores_per_node=8,
                                      gpus_per_node=2), n_workers=4)
    try:
        descs = []
        for i in range(6):
            sim = TaskDescription(
                kind=TaskKind.EXECUTABLE, fn=heat_stencil,
                kwargs={"n": 32, "steps": 4, "seed": i},
                requirements=ResourceRequirements(ranks=2, cores_per_rank=2),
                task_type="mpi_sim")
            score = TaskDescription(
                fn=surrogate_eval, kwargs={"dim": 16, "hidden": 32, "seed": i},
                requirements=ResourceRequirements(gpus_per_rank=1),
                task_type="gpu_score", dependencies=[sim.uid])
            descs.extend([sim, score])
        uids = rh.submit(descs)
        assert rh.wait(uids, timeout=60)
        assert rh.events.peak_hw() >= 2  # genuinely overlapped types
    finally:
        rh.close()


def test_inference_at_scale_roundtrip():
    """§II-B: persistent service + concurrent clients."""
    rh = Rhapsody(ResourceDescription(nodes=1, cores_per_node=8), n_workers=2)
    try:
        rh.add_service(ServiceDescription(
            name="llm", factory=llm_service_factory(
                demo_cfg(), max_num_seqs=4, max_len=64,
                prefill_buckets=(16,))))
        ep = rh.get_service("llm")
        futs = [ep.request({"prompt": [i + 1] * 6, "max_new_tokens": 3})
                for i in range(6)]
        outs = [f.result(timeout=300) for f in futs]
        assert all(len(o["tokens"]) == 3 for o in outs)
        inst = rh.services.instances["llm"]
        assert inst.servicer.stats.utilization > 0
    finally:
        rh.close()


@pytest.mark.parametrize("kind", ["memory", "filesystem"])
def test_coupled_simulation_inference(kind):
    """§II-C: sim -> store -> inference pairs with real array payloads."""
    rh = Rhapsody(ResourceDescription(nodes=1, cores_per_node=8), n_workers=2)
    store = make_store(kind)
    try:
        def sim(key, seed):
            rng = np.random.RandomState(seed)
            store.put(key, rng.normal(size=256).astype(np.float32))
            return True

        def infer(key):
            data = store.get(key, timeout=10)
            return float(np.mean(data))

        descs = []
        for i in range(8):
            s = TaskDescription(kind=TaskKind.COUPLED, fn=sim,
                                args=(f"k{i}", i), task_type="sim")
            f = TaskDescription(kind=TaskKind.COUPLED, fn=infer,
                                args=(f"k{i}",), dependencies=[s.uid],
                                task_type="infer")
            descs.extend([s, f])
        uids = rh.submit(descs)
        assert rh.wait(uids, timeout=60)
        st = store.stats.summary()
        assert st["puts"] == 8 and st["gets"] == 8
    finally:
        store.close()
        rh.close()


def test_agentic_control_loop():
    """§II-C agentic: decisions realized as HPC tasks with bounded lag."""
    rh = Rhapsody(ResourceDescription(nodes=2, cores_per_node=8), n_workers=2)
    try:
        rh.add_service(ServiceDescription(
            name="llm", factory=llm_service_factory(
                demo_cfg(), max_num_seqs=4, max_len=64,
                prefill_buckets=(16,))))
        cfgs = [AgentConfig(name=f"a{k}", service="llm", n_decisions=2,
                            tasks_per_decision=2,
                            decision_payload=lambda i: {
                                "prompt": [3, 1, 4, 1, 5],
                                "max_new_tokens": 2})
                for k in range(2)]
        out = run_agent_population(rh, cfgs)
        assert out["decisions"] == 4
        assert out["tasks"] == 8
        assert not out["errors"]
        lags = rh.events.realization_lag()
        assert lags and max(lags) < 30.0
    finally:
        rh.close()


def test_oversubscription_backfill():
    """Logical oversubscription: big blocked task doesn't starve small ones."""
    rh = Rhapsody(ResourceDescription(nodes=1, cores_per_node=4),
                  policy=ExecutionPolicy(backfill=True), n_workers=2)
    try:
        gate = threading.Event()

        def hold():
            gate.wait(5)
            return "held"

        big1 = TaskDescription(fn=hold, requirements=ResourceRequirements(
            ranks=1, cores_per_rank=3), task_type="big")
        big2 = TaskDescription(fn=hold, requirements=ResourceRequirements(
            ranks=1, cores_per_rank=3), task_type="big")
        smalls = [TaskDescription(fn=noop, task_type="small")
                  for _ in range(10)]
        rh.submit([big1, big2] + smalls)  # big2 blocks; smalls backfill
        assert rh.wait([d.uid for d in smalls], timeout=5), \
            "small tasks must backfill around the blocked large task"
        gate.set()
        assert rh.wait([big1.uid, big2.uid], timeout=10)
    finally:
        rh.close()
