"""Prefix-affinity (sticky-session) routing end to end: INFERENCE tasks
dispatched through Rhapsody pin same-prefix sessions to one replica, hit
counters land in ReplicaSet.stats(), and spill keeps affinity from
defeating load balance."""
import threading
import time

import pytest

from repro.core import (ExecutionPolicy, InferenceRequest,
                        ResourceDescription, Rhapsody, ServiceDescription,
                        TaskDescription, TaskKind)


class Echo:
    def handle(self, payload):
        time.sleep(0.001)
        return ("ok", payload)


def make_rh(**policy_kw):
    policy_kw.setdefault("routing", "prefix_affinity")
    return Rhapsody(ResourceDescription(nodes=2, cores_per_node=16),
                    policy=ExecutionPolicy(**policy_kw), n_workers=2)


def _session_task(base: int, turn: int):
    # turn t prompt = 40-token session prefix + growing tail (chat shape)
    prompt = [base] * 40 + list(range(turn + 1))
    return TaskDescription(kind=TaskKind.INFERENCE, service="svc",
                           payload={"prompt": prompt},
                           task_type="inference")


def test_sticky_dispatch_pins_sessions_and_spreads_load():
    """Acceptance: two interleaved sessions through the middleware land on
    one replica each (all but the first request of a session is a prefix
    hit) while both replicas carry traffic."""
    turns = 8
    rh = make_rh(affinity_spill_factor=50.0)  # tiny echo load: never spill
    try:
        rs = rh.add_service(ServiceDescription(name="svc", factory=Echo,
                                               replicas=2))
        descs = []
        for t in range(turns):  # interleave the two sessions turn by turn
            descs.append(_session_task(1, t))
            descs.append(_session_task(2, t))
        uids = rh.submit(descs)
        assert rh.wait(uids, timeout=30)
        stats = rs.stats()
        per = stats["per_replica"]
        # both replicas serve exactly one session's worth of requests
        assert [p["requests"] for p in per] == [turns, turns]
        # every request after a session's first sticks to its home replica
        assert [p["prefix_hits"] for p in per] == [turns - 1, turns - 1]
        assert stats["prefix_misses"] == 2  # one first-contact per session
        assert stats["completed"] == 2 * turns
    finally:
        rh.close()


def test_direct_request_surface_is_sticky_too():
    """ReplicaSet.request() (the non-task client path) computes the same
    affinity signature as the dispatcher."""
    rh = make_rh(affinity_spill_factor=50.0)
    try:
        rs = rh.add_service(ServiceDescription(name="svc", factory=Echo,
                                               replicas=3))
        futs = [rs.request({"prompt": [9] * 40 + [t]}) for t in range(6)]
        for f in futs:
            f.result(10.0)
        per = [p["requests"] for p in rs.stats()["per_replica"]]
        assert sorted(per) == [0, 0, 6]  # one replica owns the session
        assert rs.stats()["prefix_hits"] == 5
    finally:
        rh.close()


def test_unkeyed_payloads_route_without_affinity_accounting():
    rh = make_rh()
    try:
        rs = rh.add_service(ServiceDescription(name="svc", factory=Echo,
                                               replicas=2))
        # ints have no prompt to key on -> signature None -> no affinity
        futs = [rs.request(1000 + i) for i in range(4)]
        for f in futs:
            f.result(10.0)
        stats = rs.stats()
        assert stats["prefix_hits"] == 0
        assert stats["prefix_misses"] == 0
        assert stats["completed"] == 4
    finally:
        rh.close()


def test_spill_rehomes_session_under_load():
    """A sticky replica that backs up past the spill factor sheds the
    session to a less-loaded sibling instead of queueing behind itself."""

    class Gated:
        def __init__(self):
            self.gate = GATE

        def handle(self, payload):
            # the session's home replica blocks while the gate is held,
            # building observable queue depth
            if payload.get("block") and not self.gate.is_set():
                self.gate.wait(10.0)
            return "ok"

    GATE = threading.Event()
    rh = make_rh(affinity_spill_factor=1.0, inference_timeout_s=30.0)
    try:
        rs = rh.add_service(ServiceDescription(name="svc", factory=Gated,
                                               replicas=2))
        key_payload = {"prompt": [5] * 40, "block": True}
        home = rs.route(InferenceRequest(payload=key_payload), rh.router,
                        cost=40.0)
        # pile blocked requests onto the sticky home
        futs = [home.request(dict(key_payload)) for _ in range(6)]
        for f in futs:  # depth builds: 6 outstanding on home, 0 elsewhere
            assert not f.done()
        spilled = rs.route(InferenceRequest(payload=key_payload), rh.router,
                           cost=40.0)
        assert spilled is not home
        GATE.set()
        for f in futs:
            assert f.result(15.0) == "ok"
        assert rs.stats()["prefix_misses"] >= 1  # the spill was accounted
    finally:
        GATE.set()
        rh.close()


@pytest.mark.parametrize("routing", ["prefix_affinity", "radix_affinity"])
def test_assignments_carry_across_autoscale_membership_change(routing):
    """Acceptance: after a forced mid-stream scale event, sessions homed
    on SURVIVING replicas keep their sticky replica; only sessions homed
    on the departed replica re-home.  (Before the stable-member-identity
    refactor, ANY membership change re-homed every session.)"""
    rh = make_rh(routing=routing, affinity_spill_factor=0.0)  # never spill
    try:
        rs = rh.add_service(ServiceDescription(name="svc", factory=Echo,
                                               replicas=3))
        payloads = [{"prompt": [s] * 40 + list(range(s + 1))}
                    for s in range(6)]

        def route_home(p):
            return rs.route(InferenceRequest(payload=p), rh.router,
                            cost=40.0).replica_idx

        home = {s: route_home(p) for s, p in enumerate(payloads)}
        assert set(home.values()) == {0, 1, 2}  # first contacts spread
        rs.scale_to(2)  # forced scale-down removes replica_idx 2
        survivors = {ep.replica_idx for ep in rs.endpoints}
        assert survivors == {0, 1}
        for s, p in enumerate(payloads):
            idx = route_home(p)
            if home[s] in survivors:
                assert idx == home[s], "surviving session lost its home"
            else:
                assert idx in survivors
                home[s] = idx  # re-homed exactly once
        rs.scale_to(3)  # grow back: the new replica gets a FRESH identity
        assert {ep.replica_idx for ep in rs.endpoints} == {0, 1, 3}
        for s, p in enumerate(payloads):
            assert route_home(p) == home[s], "grow-back re-homed a session"
    finally:
        rh.close()


def test_radix_dispatch_sticks_through_branching_sessions():
    """End to end through the middleware: two agents share a 40-token stem
    (identical hashed signature, so PR 2's router could not tell them
    apart) and diverge after it.  Under load the stem stampede spills the
    second agent to its own replica; every later turn then follows each
    agent's OWN transcript — radix longest-match stickiness."""
    turns = 6
    rh = make_rh(routing="radix_affinity", affinity_spill_factor=2.0)
    try:
        rs = rh.add_service(ServiceDescription(name="svc", factory=Echo,
                                               replicas=2))
        stem = [7] * 40
        grown = {1: list(stem) + [1], 2: list(stem) + [2]}
        # first contacts: agent 1 homes somewhere; a simulated backlog on
        # that replica makes agent 2's stem-only match spill to the other
        rs.request({"prompt": list(grown[1])}).result(10.0)
        home1 = next(ep for ep in rs.endpoints if ep.stats["requests"])
        home1.bump("requests", 50)  # fake queue depth -> overloaded
        rs.request({"prompt": list(grown[2])}).result(10.0)
        home1.bump("requests", -50)
        per = [p["requests"] for p in rs.stats()["per_replica"]]
        assert sorted(per) == [1, 1]  # the agents separated
        # turns 2..N through the task dispatch path: each agent's growing
        # transcript matches DEEPER on its own replica than the shared
        # stem does anywhere else, so stickiness is per-agent
        descs = []
        for t in range(1, turns):
            for agent in (1, 2):
                grown[agent] += [agent * 10 + t]
                descs.append(TaskDescription(
                    kind=TaskKind.INFERENCE, service="svc",
                    payload={"prompt": list(grown[agent])},
                    task_type="inference"))
        uids = rh.submit(descs)
        assert rh.wait(uids, timeout=30)
        stats = rs.stats()
        assert [p["requests"] for p in stats["per_replica"]] == \
            [turns, turns]
        # one true miss (agent 1's first contact), one spill (agent 2's),
        # everything after follows the per-agent transcript
        assert stats["prefix_hits"] == 2 * (turns - 1)
        assert stats["prefix_misses"] == 2
    finally:
        rh.close()


def test_relaunch_clears_stale_gossiped_residency():
    """A crashed-and-relaunched replica restarts with an EMPTY cache: its
    pre-crash gossiped residency must be dropped from the router so
    prefix matches don't chase a cache that no longer exists (the sibling
    replica's gossip stays)."""

    class CrashyResident:
        def __init__(self):
            self.jobs = {}
            self.uid = 0

        def submit(self, payload):
            if payload == "boom":
                raise SystemError("preempted")
            self.uid += 1
            self.jobs[self.uid] = payload
            return self.uid

        def step(self):
            out = [(u, "ok") for u in self.jobs]
            self.jobs.clear()
            return out

        def residency_summary(self, max_len=128):
            return [[1, 2, 3, 4, 5, 6, 7, 8][:max_len]]

    rh = make_rh(routing="radix_affinity", restart_failed_services=True,
                 restart_backoff_s=0.01, restart_backoff_max_s=0.02,
                 restart_max_attempts=10)
    try:
        rs = rh.add_service(ServiceDescription(name="svc",
                                               factory=CrashyResident,
                                               replicas=2))
        rs.stats()  # gossip tick: both replicas' residency lands
        res = rh.router._affinity[("svc", rs._uid, "default")]["residency"]
        assert res.values() == {ep.replica_idx for ep in rs.endpoints}
        victim = rs.endpoints[0]
        with pytest.raises((SystemError, RuntimeError)):
            victim.request("boom").result(10.0)  # crash -> relaunch
        deadline = time.perf_counter() + 10
        while time.perf_counter() < deadline and \
                victim.replica_idx in res.values():
            time.sleep(0.01)
        # the relaunched replica's stale residency is gone; its sibling's
        # survives untouched
        assert victim.replica_idx not in res.values()
        assert rs.endpoints[1].replica_idx in res.values()
        assert victim.request("fine").result(10.0) == "ok"
    finally:
        rh.close()


def test_degraded_replica_does_not_strand_sessions():
    """When a session's home replica dies (restarts disabled), the sticky
    map re-homes the session to a live replica instead of raising."""

    class DiesOnBoom:
        def __init__(self):
            self.jobs = {}
            self.uid = 0

        def submit(self, payload):
            if isinstance(payload, dict) and payload.get("boom"):
                raise SystemError("replica down")
            self.uid += 1
            self.jobs[self.uid] = payload
            return self.uid

        def step(self):
            out = [(u, "ok") for u in self.jobs]
            self.jobs.clear()
            return out

    rh = make_rh(restart_failed_services=False, max_retries=0)
    try:
        rs = rh.add_service(ServiceDescription(name="svc", factory=DiesOnBoom,
                                               replicas=2))
        payload = {"prompt": [4] * 40}
        home = rs.route(InferenceRequest(payload=payload), rh.router,
                        cost=40.0)
        with pytest.raises((SystemError, RuntimeError)):
            home.request({"prompt": [4] * 40, "boom": True}).result(10.0)
        deadline = time.perf_counter() + 5
        idx = rs.endpoints.index(home)
        while time.perf_counter() < deadline and \
                rs.instances[idx].error is None:
            time.sleep(0.01)
        # sticky key re-homes to the surviving replica (fresh router group:
        # membership changed, so the dead endpoint is no longer a candidate)
        assert rs.request(payload).result(10.0) == "ok"
    finally:
        rh.close()
