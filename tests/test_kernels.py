"""Pallas kernel validation: shape/dtype sweeps, allclose vs ref oracles
(interpret mode on CPU; TPU is the target)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.decode_attention.ops import (decode_attention,
                                                paged_decode_attention)
from repro.kernels.decode_attention.ref import (decode_ref, gather_kv,
                                                paged_decode_ref)
from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.mamba2.ops import ssd
from repro.kernels.mamba2.ref import ssd_ref
from repro.kernels.rwkv6.ops import wkv
from repro.kernels.rwkv6.ref import wkv_ref


@pytest.mark.parametrize("B,S,Hq,Hkv,D,bq,bk", [
    (2, 128, 4, 2, 32, 32, 32),
    (1, 256, 2, 2, 64, 64, 128),
    (2, 64, 8, 2, 16, 64, 32),
    (1, 128, 4, 1, 32, 128, 64),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention(B, S, Hq, Hkv, D, bq, bk, dtype):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, S, Hq, D), dtype)
    k = jax.random.normal(ks[1], (B, S, Hkv, D), dtype)
    v = jax.random.normal(ks[2], (B, S, Hkv, D), dtype)
    out = flash_attention(q, k, v, causal=True, block_q=bq, block_k=bk,
                          interpret=True)
    kr = jnp.repeat(k, Hq // Hkv, 2)
    vr = jnp.repeat(v, Hq // Hkv, 2)
    qf = jnp.transpose(q, (0, 2, 1, 3)).reshape(B * Hq, S, D)
    kf = jnp.transpose(kr, (0, 2, 1, 3)).reshape(B * Hq, S, D)
    vf = jnp.transpose(vr, (0, 2, 1, 3)).reshape(B * Hq, S, D)
    ref = jnp.transpose(attention_ref(qf, kf, vf, causal=True)
                        .reshape(B, Hq, S, D), (0, 2, 1, 3))
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("B,S,Hq,Hkv,D,bk", [
    (2, 256, 4, 2, 32, 64),
    (3, 128, 8, 4, 16, 128),
    (1, 512, 2, 1, 64, 256),
])
def test_decode_attention(B, S, Hq, Hkv, D, bk):
    ks = jax.random.split(jax.random.PRNGKey(1), 4)
    q = jax.random.normal(ks[0], (B, 1, Hq, D))
    kc = jax.random.normal(ks[1], (B, S, Hkv, D))
    vc = jax.random.normal(ks[2], (B, S, Hkv, D))
    lens = jax.random.randint(ks[3], (B,), 1, S + 1)
    out = decode_attention(q, kc, vc, lens, block_k=bk, interpret=True)
    ref = decode_ref(q[:, 0].reshape(B, Hkv, Hq // Hkv, D), kc, vc,
                     lens).reshape(B, 1, Hq, D)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


def _paged_setup(key, B, num_blocks, bs, mb, Hq, Hkv, D, *, permute=True):
    """Random paged stores + per-sequence block tables with DISTINCT,
    permuted physical blocks and ragged lengths (including lengths not a
    multiple of block_size)."""
    ks = jax.random.split(key, 5)
    q = jax.random.normal(ks[0], (B, 1, Hq, D))
    k_store = jax.random.normal(ks[1], (num_blocks, bs, Hkv, D))
    v_store = jax.random.normal(ks[2], (num_blocks, bs, Hkv, D))
    # physical blocks 1..num_blocks-1 dealt without repeats (block 0 is
    # the null block), shuffled so tables are non-contiguous
    perm = np.arange(1, num_blocks)
    if permute:
        perm = np.asarray(jax.random.permutation(ks[3], perm))
    bt = np.zeros((B, mb), np.int32)
    flat = perm[:B * mb]
    bt[:, :] = flat.reshape(B, mb)
    lens = np.asarray(jax.random.randint(ks[4], (B,), 1, mb * bs + 1),
                      np.int32)
    # logical blocks past each length point at the null block, as the
    # engine guarantees
    for b in range(B):
        used = -(-int(lens[b]) // bs)
        bt[b, used:] = 0
    return q, k_store, v_store, jnp.asarray(bt), jnp.asarray(lens)


@pytest.mark.parametrize("B,num_blocks,bs,mb,Hq,Hkv,D", [
    (2, 17, 16, 4, 4, 2, 32),     # ragged lens, permuted tables
    (3, 32, 8, 6, 8, 4, 16),      # small blocks, more heads
    (1, 9, 32, 8, 2, 1, 64),      # single sequence, MHA-degenerate
])
def test_paged_decode_attention(B, num_blocks, bs, mb, Hq, Hkv, D):
    """Paged kernel vs the gather-then-dense oracle."""
    q, ks_, vs_, bt, lens = _paged_setup(
        jax.random.PRNGKey(5), B, num_blocks, bs, mb, Hq, Hkv, D)
    out = paged_decode_attention(q, ks_, vs_, bt, lens, interpret=True)
    ref = paged_decode_ref(q[:, 0].reshape(B, Hkv, Hq // Hkv, D),
                           ks_, vs_, bt, lens).reshape(B, 1, Hq, D)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


def test_paged_matches_contiguous_kernel():
    """The paged kernel on a blocked store equals the contiguous kernel on
    the gathered caches — the two engine paths agree bit-for-bit up to
    float tolerance, whatever the block-table permutation."""
    B, num_blocks, bs, mb, Hq, Hkv, D = 2, 13, 16, 3, 4, 2, 32
    q, ks_, vs_, bt, lens = _paged_setup(
        jax.random.PRNGKey(6), B, num_blocks, bs, mb, Hq, Hkv, D)
    paged = paged_decode_attention(q, ks_, vs_, bt, lens, interpret=True)
    kc, vc = gather_kv(ks_, bt), gather_kv(vs_, bt)
    contig = decode_attention(q, kc, vc, lens, block_k=bs, interpret=True)
    np.testing.assert_allclose(np.asarray(paged), np.asarray(contig),
                               rtol=2e-6, atol=2e-6)


def test_paged_decode_block_size_edges():
    """Lengths straddling block boundaries: 1, block_size-1, block_size,
    block_size+1, and full capacity all mask correctly."""
    num_blocks, bs, mb, Hq, Hkv, D = 23, 8, 4, 4, 2, 16
    edge_lens = [1, bs - 1, bs, bs + 1, mb * bs]
    B = len(edge_lens)
    q, ks_, vs_, _, _ = _paged_setup(
        jax.random.PRNGKey(7), B, num_blocks, bs, mb, Hq, Hkv, D)
    lens = jnp.asarray(edge_lens, jnp.int32)
    # deal fresh full tables (distinct shuffled physical blocks), then
    # null exactly the logical blocks past each edge length
    perm = np.asarray(jax.random.permutation(jax.random.PRNGKey(17),
                                             np.arange(1, num_blocks)))
    bt_np = perm[:B * mb].reshape(B, mb).astype(np.int32).copy()
    for b in range(B):
        bt_np[b, -(-edge_lens[b] // bs):] = 0
    bt = jnp.asarray(bt_np)
    out = paged_decode_attention(q, ks_, vs_, bt, lens, interpret=True)
    ref = paged_decode_ref(q[:, 0].reshape(B, Hkv, Hq // Hkv, D),
                           ks_, vs_, bt, lens).reshape(B, 1, Hq, D)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


def test_paged_table_permutation_invariance():
    """Physically relocating blocks (and rewriting the tables to match)
    must not change the output: attention depends only on the logical
    sequence the table reconstructs."""
    B, num_blocks, bs, mb, Hq, Hkv, D = 2, 11, 8, 4, 4, 2, 16
    q, ks_, vs_, bt, lens = _paged_setup(
        jax.random.PRNGKey(8), B, num_blocks, bs, mb, Hq, Hkv, D,
        permute=False)
    out1 = paged_decode_attention(q, ks_, vs_, bt, lens, interpret=True)
    # relocate: physical block p -> perm[p], stores shuffled to match
    perm = np.concatenate([[0], 1 + np.asarray(
        jax.random.permutation(jax.random.PRNGKey(9), num_blocks - 1))])
    inv = np.argsort(perm)
    ks2 = jnp.asarray(np.asarray(ks_)[inv])
    vs2 = jnp.asarray(np.asarray(vs_)[inv])
    bt2 = jnp.asarray(perm[np.asarray(bt)])
    out2 = paged_decode_attention(q, ks2, vs2, bt2, lens, interpret=True)
    np.testing.assert_allclose(np.asarray(out1), np.asarray(out2),
                               rtol=0, atol=0)


@pytest.mark.parametrize("B,T,H,hd,chunk", [
    (2, 64, 3, 16, 16),
    (1, 96, 2, 32, 32),
    (2, 128, 4, 8, 32),
])
def test_rwkv6_wkv(B, T, H, hd, chunk):
    ks = jax.random.split(jax.random.PRNGKey(2), 5)
    r, k, v = (jax.random.normal(ks[i], (B, T, H, hd)) for i in range(3))
    lw = -jnp.exp(jax.random.normal(ks[3], (B, T, H, hd)) - 1.0)
    u = jax.random.normal(ks[4], (H, hd)) * 0.1
    out = wkv(r, k, v, lw, u, chunk=chunk, interpret=True)
    ref = wkv_ref(r, k, v, lw, u)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-4, atol=1e-4)


@pytest.mark.parametrize("B,T,H,P,N,chunk", [
    (2, 64, 3, 8, 4, 16),
    (1, 128, 4, 16, 8, 32),
    (2, 96, 2, 32, 16, 32),
])
def test_mamba2_ssd(B, T, H, P, N, chunk):
    ks = jax.random.split(jax.random.PRNGKey(3), 5)
    x = jax.random.normal(ks[0], (B, T, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, T, H)))
    A = -jnp.exp(jax.random.normal(ks[2], (H,)))
    Bm = jax.random.normal(ks[3], (B, T, N))
    Cm = jax.random.normal(ks[4], (B, T, N))
    out = ssd(x, dt, A, Bm, Cm, chunk=chunk, interpret=True)
    ref = ssd_ref(x, dt, A, Bm, Cm)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-4, atol=1e-4)


def test_chunked_matches_recurrent_models():
    """The model-internal chunked paths match their recurrent oracles."""
    from repro.models.mamba2 import ssd_chunked, ssd_recurrent
    from repro.models.rwkv6 import wkv_chunked, wkv_recurrent

    ks = jax.random.split(jax.random.PRNGKey(4), 6)
    B, T, H, hd = 2, 50, 2, 8
    r, k, v = (jax.random.normal(ks[i], (B, T, H, hd)) for i in range(3))
    lw = -jnp.exp(jax.random.normal(ks[3], (B, T, H, hd)))
    u = jax.random.normal(ks[4], (H, hd)) * 0.1
    y1, s1 = wkv_chunked(r, k, v, lw, u, 16)
    y2, s2 = wkv_recurrent(r, k, v, lw, u)
    np.testing.assert_allclose(y1, y2, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(s1, s2, rtol=1e-4, atol=1e-4)
