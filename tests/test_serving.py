"""Serving engine: continuous batching correctness + slot management."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import get_model, nn
from repro.serving.engine import InferenceEngine
from repro.serving.kvcache import CachePool


@pytest.fixture(scope="module")
def small_lm():
    cfg = get_config("rhapsody-demo").scaled(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, vocab=512)
    api = get_model(cfg)
    params, _ = nn.split(api.init(jax.random.PRNGKey(0), cfg))
    return cfg, api, params


def _ref_generate(api, params, cfg, prompt, steps):
    cache, logits = api.prefill(
        params, {"tokens": jnp.asarray([prompt], jnp.int32)}, cfg, max_len=128)
    out = [int(jnp.argmax(logits[0]))]
    for _ in range(steps - 1):
        cache, lg = api.decode(params, cache,
                               jnp.asarray([out[-1]], jnp.int32), cfg)
        out.append(int(jnp.argmax(lg[0])))
    return out


def test_continuous_batching_matches_sequential(small_lm):
    cfg, api, params = small_lm
    eng = InferenceEngine(cfg, params, max_num_seqs=4,
                          max_num_batched_tokens=256, max_len=128,
                          prefill_buckets=(16, 32, 64))
    rng = np.random.RandomState(0)
    prompts = [list(rng.randint(0, 512, size=n)) for n in (5, 12, 17, 30)]
    uids = [eng.submit(p, max_new_tokens=6) for p in prompts]
    done = eng.run()
    for uid, p in zip(uids, prompts):
        assert done[uid].output == _ref_generate(api, params, cfg, p, 6)


def test_slot_reuse_more_requests_than_slots(small_lm):
    cfg, _, params = small_lm
    eng = InferenceEngine(cfg, params, max_num_seqs=2,
                          max_num_batched_tokens=64, max_len=64,
                          prefill_buckets=(16,))
    uids = [eng.submit([1, 2, 3], max_new_tokens=3) for _ in range(7)]
    done = eng.run()
    assert len(done) == 7
    assert eng.pool.n_free == 2  # all slots returned


def test_admission_respects_token_budget(small_lm):
    cfg, _, params = small_lm
    eng = InferenceEngine(cfg, params, max_num_seqs=8,
                          max_num_batched_tokens=16, max_len=64,
                          prefill_buckets=(16,))
    for _ in range(4):
        eng.submit([1] * 10, max_new_tokens=2)
    eng._admit()
    # with a 16-token budget and 16-token buckets only one admit per step
    assert len(eng.running) == 1


def test_eos_stops_generation(small_lm):
    cfg, api, params = small_lm
    ref = _ref_generate(api, params, cfg, [5, 6, 7], 8)
    eos = ref[2]
    eng = InferenceEngine(cfg, params, max_num_seqs=2, max_len=64,
                          prefill_buckets=(16,))
    uid = eng.submit([5, 6, 7], max_new_tokens=8, eos_id=eos)
    done = eng.run()
    assert done[uid].output[-1] == eos
    assert len(done[uid].output) == 3


def test_cache_pool_set_len(small_lm):
    cfg, _, _ = small_lm
    pool = CachePool(cfg, max_seqs=2, max_len=32)
    pool.set_len(1, 7)
    lens = pool.cache["scan"]["len"]
    assert int(lens[0, 1]) == 7
    assert int(lens[0, 0]) == 0


# ---------------------------------------------------------------------------
# Prefix-reuse fast path: a prompt extending a resident slot's tokens skips
# prefill for the cached prefix and still generates identically
# ---------------------------------------------------------------------------


def test_prefix_reuse_matches_from_scratch(small_lm):
    cfg, api, params = small_lm
    eng = InferenceEngine(cfg, params, max_num_seqs=4,
                          max_num_batched_tokens=256, max_len=128,
                          prefill_buckets=(16, 32, 64))
    rng = np.random.RandomState(0)
    p1 = list(rng.randint(0, 512, size=12))
    u1 = eng.submit(p1, max_new_tokens=5)
    turn1 = eng.run()[u1].output
    # turn 2 extends turn 1's transcript (prompt + reply + new user tokens)
    p2 = p1 + turn1 + list(rng.randint(0, 512, size=7))
    u2 = eng.submit(p2, max_new_tokens=5)
    out2 = eng.run()[u2].output
    assert eng.stats.prefix_reuse_hits == 1
    # resident sequence covers p1 + turn1 minus the never-fed last token
    assert eng.stats.prefix_cached_tokens == len(p1) + len(turn1) - 1
    assert out2 == _ref_generate(api, params, cfg, p2, 5)


def test_prefix_reuse_multi_turn_chain(small_lm):
    """Three chained turns: each resumes the previous one's slot."""
    cfg, api, params = small_lm
    eng = InferenceEngine(cfg, params, max_num_seqs=2,
                          max_num_batched_tokens=256, max_len=128,
                          prefill_buckets=(16, 32, 64))
    prompt = [11, 12, 13, 14, 15]
    for turn in range(3):
        uid = eng.submit(list(prompt), max_new_tokens=4)
        out = eng.run()[uid].output
        assert out == _ref_generate(api, params, cfg, prompt, 4)
        prompt = prompt + out + [100 + turn, 101 + turn]
    assert eng.stats.prefix_reuse_hits == 2
    assert eng.pool.n_free == 2  # all slots returned


def test_unrelated_prompt_does_not_resume(small_lm):
    cfg, api, params = small_lm
    eng = InferenceEngine(cfg, params, max_num_seqs=2,
                          max_num_batched_tokens=256, max_len=128,
                          prefill_buckets=(16, 32))
    u1 = eng.submit([1, 2, 3, 4, 5, 6], max_new_tokens=3)
    eng.run()
    u2 = eng.submit([9, 8, 7, 6, 5, 4], max_new_tokens=3)
    out = eng.run()[u2].output
    assert eng.stats.prefix_reuse_hits == 0
    assert out == _ref_generate(api, params, cfg, [9, 8, 7, 6, 5, 4], 3)


def test_prefix_reuse_can_be_disabled(small_lm):
    cfg, _, params = small_lm
    eng = InferenceEngine(cfg, params, max_num_seqs=2, max_len=64,
                          prefill_buckets=(16,), enable_prefix_reuse=False)
    u1 = eng.submit([1, 2, 3, 4], max_new_tokens=3)
    out1 = eng.run()[u1].output
    u2 = eng.submit([1, 2, 3, 4] + out1 + [5], max_new_tokens=3)
    eng.run()
    assert eng.stats.prefix_reuse_hits == 0
    assert len(eng._prefix_index) == 0
    assert not eng._resident_len


def test_partial_prefix_resume_matches_from_scratch(small_lm):
    """A branching turn — shares a stem with a resident transcript but
    diverges mid-sequence — rewinds to the divergence point and still
    generates token-identically to a from-scratch prefill."""
    cfg, api, params = small_lm
    eng = InferenceEngine(cfg, params, max_num_seqs=4,
                          max_num_batched_tokens=256, max_len=128,
                          prefill_buckets=(16, 32, 64))
    rng = np.random.RandomState(3)
    p1 = list(rng.randint(0, 512, size=24))
    u1 = eng.submit(p1, max_new_tokens=4)
    eng.run()
    # branch: keep the first 20 tokens of turn 1's prompt, diverge after
    p2 = p1[:20] + list(rng.randint(0, 512, size=10))
    assert p2[:20] == p1[:20] and p2[20] != p1[20]
    u2 = eng.submit(p2, max_new_tokens=4)
    out2 = eng.run()[u2].output
    assert eng.stats.prefix_reuse_hits == 1
    assert eng.stats.prefix_partial_hits == 1
    assert eng.stats.prefix_cached_tokens == 20  # rewound to the divergence
    assert out2 == _ref_generate(api, params, cfg, p2, 4)


def test_partial_resume_prompt_inside_resident_sequence(small_lm):
    """A prompt that is a strict PREFIX of a resident transcript resumes
    too: rewind to len(prompt) - 1, no suffix feeds, identical output."""
    cfg, api, params = small_lm
    eng = InferenceEngine(cfg, params, max_num_seqs=2,
                          max_num_batched_tokens=256, max_len=128,
                          prefill_buckets=(16, 32, 64))
    rng = np.random.RandomState(4)
    p1 = list(rng.randint(0, 512, size=30))
    eng.submit(p1, max_new_tokens=4)
    eng.run()
    p2 = p1[:22]  # rewound replay of a shorter turn
    u2 = eng.submit(p2, max_new_tokens=4)
    out2 = eng.run()[u2].output
    assert eng.stats.prefix_reuse_hits == 1
    # a replay never DIVERGES from the resident transcript: it must not
    # count as a partial (divergence) hit
    assert eng.stats.prefix_partial_hits == 0
    assert eng.stats.prefix_cached_tokens == len(p2) - 1
    assert out2 == _ref_generate(api, params, cfg, p2, 4)


def test_deepest_resident_match_wins(small_lm):
    """With several resident slots sharing a stem, admission resumes the
    slot with the deepest usable common prefix (radix longest-match, not
    first-fit)."""
    cfg, api, params = small_lm
    eng = InferenceEngine(cfg, params, max_num_seqs=4,
                          max_num_batched_tokens=512, max_len=128,
                          prefill_buckets=(16, 32, 64))
    rng = np.random.RandomState(5)
    stem = list(rng.randint(0, 512, size=16))
    shallow = stem + list(rng.randint(0, 512, size=4))
    deep = stem + list(rng.randint(0, 512, size=14))
    for p in (shallow, deep):
        eng.submit(p, max_new_tokens=3)
        eng.run()
    probe = deep + list(rng.randint(0, 512, size=4))
    u = eng.submit(probe, max_new_tokens=3)
    out = eng.run()[u].output
    # cached >= len(deep) - 1 proves the deeper slot was chosen (the
    # shallow one could cover at most len(shallow) + its output)
    assert eng.stats.prefix_cached_tokens >= len(deep) - 1
    assert out == _ref_generate(api, params, cfg, probe, 3)


def test_allocator_prefers_blank_slots_over_resident(small_lm):
    """Fresh admissions must not evict reusable resident KV while a
    never-used blank slot is free."""
    cfg, api, params = small_lm
    eng = InferenceEngine(cfg, params, max_num_seqs=2,
                          max_num_batched_tokens=256, max_len=128,
                          prefill_buckets=(16, 32))
    p1 = [1, 2, 3, 4, 5, 6, 7, 8]
    u1 = eng.submit(p1, max_new_tokens=3)
    out1 = eng.run()[u1].output  # slot 0 now resident
    assert eng.pool.n_free_blank == 1
    eng.submit([9] * 10, max_new_tokens=3)  # unrelated: takes the blank
    eng.run()
    assert len(eng._prefix_index) >= 1  # turn 1's residency survived
    p3 = p1 + out1 + [6]
    u3 = eng.submit(p3, max_new_tokens=3)
    out3 = eng.run()[u3].output
    assert eng.stats.prefix_reuse_hits == 1  # ... and was still resumable
    assert out3 == _ref_generate(api, params, cfg, p3, 3)


def test_cache_pool_allocate_blank_first(small_lm):
    cfg, _, _ = small_lm
    pool = CachePool(cfg, max_seqs=3, max_len=32)
    a = pool.allocate()
    pool.free(a, resident=True)
    assert pool.n_free == 3 and pool.n_free_blank == 2
    # blank slots pop first even though the resident one is older in FIFO
    assert pool.allocate() != a
    assert pool.allocate() != a
    # only the resident slot left: allocate evicts it and clears the mark
    assert pool.allocate() == a
    assert pool.n_free == 0
    pool.free(a)
    assert pool.n_free_blank == 1


def test_prefix_reuse_slot_contention(small_lm):
    """A resident slot claimed by a fresh prefill (normal allocation) is
    no longer resumable; the engine stays correct either way."""
    cfg, api, params = small_lm
    eng = InferenceEngine(cfg, params, max_num_seqs=1,
                          max_num_batched_tokens=256, max_len=128,
                          prefill_buckets=(16, 32))
    u1 = eng.submit([1, 2, 3, 4, 5], max_new_tokens=3)
    out1 = eng.run()[u1].output
    # unrelated request recycles the only slot -> residency dropped
    u2 = eng.submit([7, 7, 7, 7], max_new_tokens=3)
    eng.run()
    p3 = [1, 2, 3, 4, 5] + out1 + [6]
    u3 = eng.submit(p3, max_new_tokens=3)
    out3 = eng.run()[u3].output
    assert out3 == _ref_generate(api, params, cfg, p3, 3)


def test_cache_pool_allocation_order_regression(small_lm):
    """The O(1) two-deque free list must preserve the exact allocation
    order of the old linear scan: blank slots FIFO first, then resident
    slots in least-recently-retired (coldest-eviction) order."""
    cfg, _, _ = small_lm
    pool = CachePool(cfg, max_seqs=5, max_len=32)
    slots = [pool.allocate() for _ in range(5)]
    assert pool.allocate() is None
    # retire in a known order: 2 and 4 resident (2 is colder), rest blank
    pool.free(slots[2], resident=True)
    pool.free(slots[0])
    pool.free(slots[4], resident=True)
    pool.free(slots[1])
    assert pool.n_free == 4 and pool.n_free_blank == 2
    # blanks pop in FIFO retirement order...
    assert pool.allocate() == slots[0]
    assert pool.allocate() == slots[1]
    # ...then residents, coldest (earliest-retired) first
    assert pool.allocate() == slots[2]
    assert pool.allocate() == slots[4]
    assert pool.allocate() is None


def test_cache_pool_take_specific_slot(small_lm):
    """take() claims a specific slot from either free queue (the prefix-
    resume path) and refuses busy slots."""
    cfg, _, _ = small_lm
    pool = CachePool(cfg, max_seqs=3, max_len=32)
    a, b, c = (pool.allocate() for _ in range(3))
    pool.free(a, resident=True)
    pool.free(b)
    assert pool.take(c) is False          # busy: not in any free queue
    assert pool.take(a) is True           # resident queue
    assert pool.take(a) is False          # no double-take
    assert pool.take(b) is True           # blank queue
    assert pool.n_free == 0
    # a re-freed taken slot goes back to the blank queue unless re-marked
    pool.free(a)
    assert pool.n_free_blank == 1
