"""Cross-group speculative decoding: sampling/acceptance unit rules, the
draft-propose / target-verify session (greedy token-for-token equivalence
across pool layouts and acceptance regimes, including the adaptive
disable path), servicer threading, per-group spec telemetry, and the
acceptance-driven draft entitlements of the weighted_capacity autoscaler.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (ExecutionPolicy, ModelGroup, ResourceDescription,
                        ResourceRequirements, Rhapsody, ServiceDescription,
                        WeightedCapacityAutoscaler)
from repro.models.config import ModelConfig
from repro.serving.client import LLMServicer, llm_model_group
from repro.serving.engine import SpecDecodeSession, make_engine_from_scratch
from repro.serving.sampling import sample, speculative_accept

# ---------------------------------------------------------------------------
# sampling: greedy / temperature / top-k / top-p boundaries
# ---------------------------------------------------------------------------


def _logits(rows):
    return jnp.asarray(rows, jnp.float32)


def test_sample_greedy_is_argmax_and_ignores_key():
    lg = _logits([[0.1, 2.0, -1.0, 0.5], [3.0, 0.0, 0.0, 0.0]])
    t1 = sample(lg, jax.random.PRNGKey(0), temperature=0.0)
    t2 = sample(lg, jax.random.PRNGKey(7), temperature=-1.0)
    assert t1.tolist() == [1, 0]
    assert t2.tolist() == [1, 0]  # non-positive temperature => greedy
    assert t1.dtype == jnp.int32


def test_sample_seeded_determinism_under_temperature():
    lg = _logits(np.random.RandomState(0).randn(4, 16))
    a = sample(lg, jax.random.PRNGKey(3), temperature=0.8)
    b = sample(lg, jax.random.PRNGKey(3), temperature=0.8)
    assert a.tolist() == b.tolist()  # same key, same pick
    # across many keys a hot temperature must visit >1 token
    seen = {tuple(sample(lg, jax.random.PRNGKey(k), temperature=5.0).tolist())
            for k in range(32)}
    assert len(seen) > 1


def test_sample_temperature_scales_concentration():
    lg = _logits([[0.0, 1.0, 0.0, 0.0]] * 64)
    cold = sample(lg, jax.random.PRNGKey(1), temperature=0.05)
    hot = sample(lg, jax.random.PRNGKey(1), temperature=50.0)
    # near-zero temperature concentrates on the argmax...
    assert np.mean(np.asarray(cold) == 1) > 0.95
    # ...while a very hot one spreads over the vocabulary
    assert len(set(np.asarray(hot).tolist())) > 1


def test_sample_top_k_one_is_greedy():
    lg = _logits(np.random.RandomState(1).randn(8, 32))
    greedy = jnp.argmax(lg, axis=-1)
    for key in range(8):
        got = sample(lg, jax.random.PRNGKey(key), temperature=1.7, top_k=1)
        assert got.tolist() == greedy.tolist()


def test_sample_top_p_zero_is_greedy_top_p_one_unrestricted():
    lg = _logits(np.random.RandomState(2).randn(8, 32))
    greedy = jnp.argmax(lg, axis=-1)
    for key in range(8):
        got = sample(lg, jax.random.PRNGKey(key), temperature=2.0, top_p=0.0)
        assert got.tolist() == greedy.tolist()  # only the mode survives
    # top_p=1.0 must not filter: identical to the plain categorical
    a = sample(lg, jax.random.PRNGKey(5), temperature=1.0, top_p=1.0)
    b = jax.random.categorical(jax.random.PRNGKey(5), lg, axis=-1)
    assert a.tolist() == b.tolist()


def test_sample_top_p_keeps_nucleus_only():
    # one token holds ~99% of the mass: any p in (0, .99] keeps just it
    lg = _logits([[10.0, 0.0, 0.0, 0.0]] * 16)
    got = sample(lg, jax.random.PRNGKey(9), temperature=1.0, top_p=0.5)
    assert set(np.asarray(got).tolist()) == {0}


# ---------------------------------------------------------------------------
# speculative_accept: the leftover-token acceptance rule
# ---------------------------------------------------------------------------


def test_speculative_accept_longest_matching_prefix():
    proposed = [[5, 6, 7],  # all accepted
                [5, 9, 7],  # diverges at position 1
                [9, 6, 7],  # diverges immediately
                [5, 6, 9]]  # diverges at the last proposal
    target = [[5, 6, 7, 8]] * 4
    n = speculative_accept(jnp.asarray(proposed), jnp.asarray(target))
    assert n.tolist() == [3, 1, 0, 2]


def test_speculative_accept_ignores_matches_after_divergence():
    # positions 1..2 match again but position 0 diverged: nothing counts
    n = speculative_accept(jnp.asarray([[1, 6, 7]]),
                           jnp.asarray([[5, 6, 7, 8]]))
    assert n.tolist() == [0]


def test_speculative_accept_emitted_tokens_are_target_picks():
    proposed = jnp.asarray([[5, 9, 7]])
    target = jnp.asarray([[5, 6, 7, 8]])
    a = int(speculative_accept(proposed, target)[0])
    emitted = target[0, :a + 1].tolist()
    # the accepted proposal EQUALS the target pick; the leftover token is
    # the target's own pick at the divergence — greedy equivalence
    assert emitted == [5, 6]


def test_speculative_accept_shape_validation():
    with pytest.raises(ValueError):
        speculative_accept(jnp.zeros((2, 3)), jnp.zeros((2, 3)))
    with pytest.raises(ValueError):
        speculative_accept(jnp.zeros((3,)), jnp.zeros((4,)))


# ---------------------------------------------------------------------------
# SpecDecodeSession: greedy equivalence across pools / families / regimes
# ---------------------------------------------------------------------------

_KW = dict(max_num_seqs=4, max_len=128)


def _mk_cfg(family="dense", n_layers=2, **kw):
    moe = dict(n_experts=4, top_k=2) if family == "moe" else {}
    return ModelConfig(family=family, vocab=64, d_model=32,
                       n_layers=n_layers, n_heads=4, **moe, **kw)


def _prompts(seed=0, lens=(5, 9, 3, 7)):
    rng = np.random.default_rng(seed)
    return [list(map(int, rng.integers(1, 64, size=n))) for n in lens]


def _vanilla(cfg, prompts, paged, max_new=10):
    eng = make_engine_from_scratch(cfg, seed=1, paged=paged, **_KW)
    uids = [eng.submit(p, max_new_tokens=max_new) for p in prompts]
    done = eng.run()
    return [done[u].output for u in uids]


def _spec(tcfg, dcfg, prompts, paged_t, paged_d, max_new=10, dseed=2,
          perturb=0.0, **sess_kw):
    tgt = make_engine_from_scratch(tcfg, seed=1, paged=paged_t, **_KW)
    drf = make_engine_from_scratch(dcfg, seed=dseed, paged=paged_d, **_KW)
    if perturb:
        leaves, treedef = jax.tree_util.tree_flatten(drf.params)
        keys = jax.random.split(jax.random.PRNGKey(9), len(leaves))
        leaves = [l + perturb * jax.random.normal(k, l.shape, l.dtype)
                  for l, k in zip(leaves, keys)]
        drf.params = jax.tree_util.tree_unflatten(treedef, leaves)
    sess = SpecDecodeSession(tgt, drf, k=sess_kw.pop("k", 3), **sess_kw)
    uids = [sess.submit(p, max_new_tokens=max_new) for p in prompts]
    done = sess.run()
    return [done[u].output for u in uids], sess


@pytest.mark.parametrize("paged_t,paged_d", [(False, False), (True, True),
                                             (True, False)])
def test_spec_greedy_equivalence_dense(paged_t, paged_d):
    tcfg, dcfg = _mk_cfg(), _mk_cfg(n_layers=1)
    prompts = _prompts()
    ref = _vanilla(tcfg, prompts, paged_t)
    got, sess = _spec(tcfg, dcfg, prompts, paged_t, paged_d)
    assert got == ref  # token-for-token, ragged prompt lengths
    ss = sess.spec_stats()
    assert ss["proposed"] > 0 and ss["rounds"] > 0 and ss["enabled"]
    assert 0.0 <= ss["acceptance_rate"] <= 1.0


def test_spec_greedy_equivalence_moe_target():
    tcfg, dcfg = _mk_cfg("moe"), _mk_cfg(n_layers=1)
    prompts = _prompts()
    ref = _vanilla(tcfg, prompts, True)
    got, _ = _spec(tcfg, dcfg, prompts, True, True)
    assert got == ref


@pytest.mark.parametrize("paged", [False, True])
def test_spec_same_model_full_acceptance(paged):
    """Draft == target: every proposal accepted (exercises the a==k bonus
    path and the two-token draft_pending resume)."""
    cfg = _mk_cfg()
    prompts = _prompts(seed=1)
    ref = _vanilla(cfg, prompts, paged)
    got, sess = _spec(cfg, cfg, prompts, paged, paged, dseed=1)
    assert got == ref
    assert sess.spec_stats()["acceptance_rate"] == 1.0


@pytest.mark.parametrize("paged", [False, True])
def test_spec_perturbed_draft_ragged_acceptance(paged):
    """Slightly-off draft: acceptance is ragged per round (0 < rate < 1),
    which walks the partial-rewind paths — output must stay identical."""
    cfg = _mk_cfg()
    prompts = _prompts(seed=1)
    ref = _vanilla(cfg, prompts, paged)
    got, sess = _spec(cfg, cfg, prompts, paged, paged, dseed=1, perturb=0.02)
    assert got == ref
    assert 0.0 < sess.spec_stats()["acceptance_rate"] < 1.0


def test_spec_adaptive_disable_still_matches_vanilla():
    """A hopeless draft trips the acceptance floor after the probe window:
    the session permanently falls back to target-only stepping and the
    transcript still equals vanilla greedy decode."""
    tcfg, dcfg = _mk_cfg(), _mk_cfg(n_layers=1)
    prompts = _prompts()
    ref = _vanilla(tcfg, prompts, True, max_new=16)
    got, sess = _spec(tcfg, dcfg, prompts, True, True, max_new=16,
                      min_acceptance=0.9, probe_proposals=8)
    assert got == ref
    assert sess.spec_stats()["enabled"] is False


def test_spec_session_rejects_sampling_and_validates_k():
    tcfg, dcfg = _mk_cfg(), _mk_cfg(n_layers=1)
    tgt = make_engine_from_scratch(tcfg, seed=1, paged=True, **_KW)
    drf = make_engine_from_scratch(dcfg, seed=2, paged=True, **_KW)
    with pytest.raises(ValueError):
        SpecDecodeSession(tgt, drf, k=0)
    sess = SpecDecodeSession(tgt, drf, k=2)
    with pytest.raises(ValueError):
        sess.submit([1, 2, 3], max_new_tokens=4, temperature=0.7)


def test_servicer_draft_group_threading_matches_plain():
    """LLMServicer(draft_group=ModelGroup) resolves the draft through the
    group's factory and serves greedy requests identically."""
    tcfg, dcfg = _mk_cfg(), _mk_cfg(n_layers=1)
    dg = llm_model_group("draft", dcfg, role="draft", paired_with="chat",
                         min_replicas=0, **_KW)
    assert (dg.role, dg.paired_with, dg.min_replicas) == ("draft", "chat", 0)
    plain = LLMServicer(tcfg, seed=1, **_KW)
    spec = LLMServicer(tcfg, seed=1, draft_group=dg, spec_k=3, **_KW)
    assert plain.spec_stats() is None

    def drive(sv):
        uids = [sv.submit({"prompt": p, "max_new_tokens": 8})
                for p in _prompts()]
        out = {}
        for _ in range(400):
            for uid, res in sv.step():
                out[uid] = res["tokens"]
            if len(out) == len(uids):
                return [out[u] for u in uids]
        raise AssertionError("servicer did not finish")

    assert drive(plain) == drive(spec)
    assert spec.spec_stats()["proposed"] > 0


# ---------------------------------------------------------------------------
# replica set: per-group spec telemetry + per-group scaling bounds
# ---------------------------------------------------------------------------


class SpecTagged:
    """Sync servicer faking a spec session's counters (the target group's
    servicers host the sessions; plain replicas report None)."""

    def __init__(self, tag, proposed=None, accepted=0):
        self.tag, self.proposed, self.accepted = tag, proposed, accepted

    def handle(self, payload):
        return {"served_by": self.tag}

    def spec_stats(self):
        if self.proposed is None:
            return None
        return {"k": 4, "proposed": self.proposed, "accepted": self.accepted,
                "acceptance_rate": self.accepted / max(1, self.proposed),
                "rounds": 1, "enabled": True}


def _spec_pair_rh(**policy_kw):
    rh = Rhapsody(ResourceDescription(nodes=1, cores_per_node=8),
                  policy=ExecutionPolicy(**policy_kw), n_workers=1)
    rs = rh.add_service(ServiceDescription(
        name="llm",
        requirements=ResourceRequirements(ranks=1, cores_per_rank=1),
        models=[ModelGroup(name="chat",
                           factory=lambda: SpecTagged("chat", 100, 70),
                           replicas=2),
                ModelGroup(name="draft",
                           factory=lambda: SpecTagged("draft"),
                           role="draft", paired_with="chat",
                           min_replicas=0, max_replicas=2, replicas=1)]))
    return rh, rs


def test_per_group_stats_carry_spec_counters_and_roles():
    rh, rs = _spec_pair_rh()
    try:
        assert rs.spec_totals() == (200, 140)  # 2 chat replicas x (100, 70)
        pg = rs.stats()["per_group"]
        assert pg["chat"]["role"] == "serve"
        assert (pg["chat"]["proposed"], pg["chat"]["accepted"]) == (200, 140)
        assert pg["chat"]["acceptance_rate"] == pytest.approx(0.7)
        # the draft group runs no sessions itself but mirrors the
        # set-wide acceptance so the entitlement signal is observable
        assert pg["draft"]["role"] == "draft"
        assert pg["draft"]["proposed"] == 0
        assert pg["draft"]["acceptance_rate"] == pytest.approx(0.7)
    finally:
        rh.close()


def test_group_bounds_and_scale_groups_clamping():
    rh, rs = _spec_pair_rh()
    try:
        assert rs.group_bounds("chat") == (1, None)
        assert rs.group_bounds("draft") == (0, 2)
        # draft may scale to zero; chat is clamped to its implicit floor
        rs.scale_groups({"chat": 0, "draft": 0})
        assert rs.group_counts() == {"chat": 1, "draft": 0}
        # ...and the draft's ceiling caps a greedy target
        rs.scale_groups({"chat": 1, "draft": 5})
        assert rs.group_counts() == {"chat": 1, "draft": 2}
        # requests still route correctly on the scaled set
        assert rs.request({"prompt": [1], "model": "chat"}
                          ).result(10.0)["served_by"] == "chat"
    finally:
        rh.close()


def test_draft_affinity_aliases_to_target_group():
    rh, rs = _spec_pair_rh()
    try:
        assert rs._affinity_alias("draft") == "chat"
        assert rs._affinity_alias("chat") == "chat"
    finally:
        rh.close()


# ---------------------------------------------------------------------------
# weighted_capacity: acceptance-driven draft entitlements (unit, fake rs)
# ---------------------------------------------------------------------------


class SpecGroupRS:
    """The group surface desired_groups() consumes, plus the spec-decode
    extensions (roles / per-group bounds / set-wide counters)."""

    multi_model = True

    def __init__(self, counts, p95_s, depths, headroom=None, weights=None,
                 roles=None, bounds=None, spec=(0, 0)):
        self._counts = dict(counts)
        self._p95 = dict(p95_s)
        self._depths = dict(depths)
        self._headroom = headroom
        self._weights = weights or {g: 1.0 for g in counts}
        self._roles = roles or {}
        self._bounds = bounds or {}
        self._spec = spec
        self.denied = 0

    def group_counts(self):
        return dict(self._counts)

    def group_weight(self, g):
        return self._weights[g]

    def group_slo_ms(self, g):
        return 100.0

    def group_role(self, g):
        return self._roles.get(g, "serve")

    def group_bounds(self, g):
        return self._bounds.get(g, (1, None))

    def spec_totals(self):
        return self._spec

    def latency_p95(self, window_s=None, started_after=None, group=None):
        return self._p95[group]

    def mean_depth(self, group=None):
        return self._depths[group]

    def capacity_headroom(self, group=None):
        return self._headroom

    def _note_admission_denied(self, where, once_per_episode=False):
        self.denied += 1


def spec_scaler(**kw):
    kw.setdefault("autoscaler", "weighted_capacity")
    kw.setdefault("autoscale_sustain_up", 1)
    kw.setdefault("autoscale_sustain_down", 1)
    kw.setdefault("autoscale_max_replicas", 8)
    kw.setdefault("autoscale_low_depth", 0.5)
    kw.setdefault("slo_p95_ms", 100.0)
    return WeightedCapacityAutoscaler(ExecutionPolicy(**kw))


def test_low_acceptance_force_shrinks_draft_without_sustain():
    a = spec_scaler(autoscale_sustain_down=5, spec_min_acceptance=0.3,
                    spec_min_proposed=100)
    rs = SpecGroupRS({"chat": 2, "draft": 2},
                     {"chat": 0.06, "draft": 0.02},
                     {"chat": 1.0, "draft": 1.0}, headroom=2,
                     roles={"draft": "draft"},
                     bounds={"draft": (0, None)},
                     spec=(500, 50))  # 10% acceptance: below the floor
    # forced shrink bypasses the 5-tick sustain — one replica per tick
    assert a.desired_groups("s", rs) == {"chat": 2, "draft": 1}
    rs._counts["draft"] = 1
    assert a.desired_groups("s", rs) == {"chat": 2, "draft": 0}
    rs._counts["draft"] = 0
    assert a.desired_groups("s", rs) is None  # at its explicit floor


def test_low_acceptance_respects_default_floor():
    a = spec_scaler(spec_min_acceptance=0.3, spec_min_proposed=100)
    rs = SpecGroupRS({"chat": 2, "draft": 1},
                     {"chat": 0.06, "draft": 0.02},
                     {"chat": 1.0, "draft": 1.0}, headroom=2,
                     roles={"draft": "draft"}, spec=(500, 0))
    assert a.desired_groups("s", rs) is None  # min_replicas defaults to 1


def test_acceptance_below_probe_threshold_is_not_judged():
    a = spec_scaler(spec_min_acceptance=0.3, spec_min_proposed=1000)
    rs = SpecGroupRS({"chat": 2, "draft": 2},
                     {"chat": 0.06, "draft": 0.02},
                     {"chat": 1.0, "draft": 5.0}, headroom=2,
                     roles={"draft": "draft"},
                     bounds={"draft": (0, None)}, spec=(500, 0))
    # 500 < 1000 proposals observed: acceptance signal not yet trusted,
    # and a paying draft is not idle overhead (no depth-based shrink)
    assert a.desired_groups("s", rs) is None


def test_acceptance_scales_draft_weight_making_it_the_donor():
    a = spec_scaler(autoscale_max_replicas=4, spec_min_acceptance=0.1,
                    spec_min_proposed=100)
    # chat violates its SLO at set capacity; draft is mid-band but its
    # acceptance-scaled weight (1.0 * 0.2) makes it the over-entitled
    # donor even though raw weights are equal
    rs = SpecGroupRS({"chat": 2, "draft": 2},
                     {"chat": 0.2, "draft": 0.05},
                     {"chat": 5.0, "draft": 1.0}, headroom=0,
                     roles={"draft": "draft"},
                     bounds={"draft": (0, None)}, spec=(1000, 200))
    assert a.desired_groups("s", rs) == {"chat": 3, "draft": 1}


def test_grower_pinned_by_per_group_max_replicas():
    a = spec_scaler()
    rs = SpecGroupRS({"chat": 2, "draft": 1},
                     {"chat": 0.2, "draft": 0.06},
                     {"chat": 5.0, "draft": 1.0}, headroom=3,
                     bounds={"chat": (1, 2)})
    assert a.desired_groups("s", rs) is None  # ceiling holds despite SLO


def test_donor_respects_explicit_zero_floor():
    a = spec_scaler(autoscale_max_replicas=3)
    # chat needs a replica, set is at max; draft holds 1 but its floor is
    # 0, so it can donate its last replica
    rs = SpecGroupRS({"chat": 2, "draft": 1},
                     {"chat": 0.2, "draft": None},
                     {"chat": 5.0, "draft": 0.0}, headroom=0,
                     roles={"draft": "draft"},
                     bounds={"draft": (0, None)})
    assert a.desired_groups("s", rs) == {"chat": 3, "draft": 0}
