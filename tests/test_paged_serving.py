"""Block-paged engine: exact greedy equivalence with the slot-pool engine
and the from-scratch oracle, block-table sharing / copy-on-write behavior,
chunked-prefill interleaving, and block conservation."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, get_smoke_config
from repro.models import get_model, nn
from repro.serving.engine import InferenceEngine
from repro.serving.kvcache import BlockAllocator, PagedCachePool


def _build(name):
    if name == "dense":
        cfg = get_config("rhapsody-demo").scaled(
            n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
            d_ff=128, vocab=512)
    else:
        cfg = get_smoke_config("deepseek-moe-16b")
    api = get_model(cfg)
    params, _ = nn.split(api.init(jax.random.PRNGKey(0), cfg))
    return cfg, api, params


@pytest.fixture(scope="module")
def dense_lm():
    return _build("dense")


@pytest.fixture(scope="module")
def moe_lm():
    return _build("moe")


def _ref_generate(api, params, cfg, prompt, steps):
    cache, logits = api.prefill(
        params, {"tokens": jnp.asarray([prompt], jnp.int32)}, cfg,
        max_len=128)
    out = [int(jnp.argmax(logits[0]))]
    for _ in range(steps - 1):
        cache, lg = api.decode(params, cache,
                               jnp.asarray([out[-1]], jnp.int32), cfg)
        out.append(int(jnp.argmax(lg[0])))
    return out


def _drive(eng, prompts, new_tokens, *, uids=None):
    uids = uids or [eng.submit(p, max_new_tokens=new_tokens)
                    for p in prompts]
    done = {}
    for _ in range(100000):
        if not eng.has_work():
            break
        eng.step()
        for r in eng.collect_finished():
            done[r.uid] = r
    return [done[u].output for u in uids]


ENGINE_KW = dict(max_num_seqs=4, max_num_batched_tokens=256, max_len=64,
                 prefill_buckets=(16, 32), seed=0)


@pytest.mark.parametrize("family", ["dense", "moe"])
def test_paged_matches_monolithic_and_ref(family, dense_lm, moe_lm):
    """Greedy outputs are token-for-token identical across the paged
    engine, the slot-pool engine, and the from-scratch incremental oracle
    — mixed prompt lengths spanning chunk and block boundaries."""
    cfg, api, params = dense_lm if family == "dense" else moe_lm
    rng = np.random.RandomState(1)
    prompts = [list(rng.randint(1, cfg.vocab, size=n))
               for n in (3, 8, 9, 17, 30)]
    mono = InferenceEngine(cfg, params, **ENGINE_KW)
    paged = InferenceEngine(cfg, params, **ENGINE_KW, paged=True,
                            block_size=8)
    out_m = _drive(mono, prompts, 6)
    out_p = _drive(paged, prompts, 6)
    assert out_p == out_m
    for p, o in zip(prompts, out_p):
        assert o == _ref_generate(api, params, cfg, p, 6)


def test_paged_prefix_resume_chain(dense_lm):
    """Multi-turn chain: each turn extends the previous transcript, so
    every turn after the first forks resident blocks — outputs still match
    the from-scratch oracle exactly."""
    cfg, api, params = dense_lm
    eng = InferenceEngine(cfg, params, **ENGINE_KW, paged=True,
                          block_size=4)
    prompt = [11, 12, 13, 14, 15, 16]
    for _ in range(3):
        uid = eng.submit(prompt, max_new_tokens=4)
        out = _drive(eng, [], 0, uids=[uid])[0]
        assert out == _ref_generate(api, params, cfg, prompt, 4)
        prompt = prompt + out + [9]
    assert eng.stats.prefix_reuse_hits >= 2
    assert eng.stats.prefix_cached_tokens > 0


def test_paged_divergence_rewind_cow(dense_lm):
    """Branch prompts sharing a stem with a resident transcript but
    diverging mid-way: the resume forks the shared blocks (PARTIAL hit)
    and the divergent write triggers copy-on-write — and each branch's
    output still matches the from-scratch oracle."""
    cfg, api, params = dense_lm
    eng = InferenceEngine(cfg, params, **ENGINE_KW, paged=True,
                          block_size=4)
    stem = [5, 4, 3, 2, 1, 2, 3, 4, 5, 6, 7, 8]
    u = eng.submit(stem, max_new_tokens=4)
    _drive(eng, [], 0, uids=[u])
    branches = [stem[:9] + [100 + i, 101, 102] for i in range(3)]
    outs = _drive(eng, branches, 4)
    for p, o in zip(branches, outs):
        assert o == _ref_generate(api, params, cfg, p, 4)
    assert eng.stats.prefix_partial_hits >= 1
    assert eng.stats.cow_copies >= 1
    assert eng.stats.shared_block_peak > 0


def test_paged_concurrency_exceeds_slot_ceiling(dense_lm):
    """At memory parity (default num_blocks = the slot pool's KV cells),
    short sequences no longer pin whole max_len slots: the paged engine
    admits well past max_num_seqs, with identical outputs."""
    cfg, api, params = dense_lm
    rng = np.random.RandomState(2)
    prompts = [list(rng.randint(1, cfg.vocab, size=6)) for _ in range(10)]
    mono = InferenceEngine(cfg, params, **ENGINE_KW)
    paged = InferenceEngine(cfg, params, **ENGINE_KW, paged=True,
                            block_size=8)
    # parity: 4 slots * 64 positions == 32 blocks of 8 (+ null block)
    assert paged.num_blocks == 33
    out_m = _drive(mono, prompts, 4)
    out_p = _drive(paged, prompts, 4)
    assert out_p == out_m
    assert paged.stats.peak_running > ENGINE_KW["max_num_seqs"]


def test_paged_chunked_prefill_interleaves_decode(dense_lm):
    """A long prompt prefills in chunks without stalling decode: a short
    request submitted alongside finishes BEFORE the long prompt emits its
    first token, and both match the oracle."""
    cfg, api, params = dense_lm
    eng = InferenceEngine(cfg, params, max_num_seqs=4,
                          max_num_batched_tokens=8, max_len=64,
                          prefill_buckets=(16, 32), seed=0, paged=True,
                          block_size=8, prefill_chunk=8)
    rng = np.random.RandomState(3)
    short = list(rng.randint(1, cfg.vocab, size=5))
    long = list(rng.randint(1, cfg.vocab, size=40))
    u_short = eng.submit(short, max_new_tokens=4)
    u_long = eng.submit(long, max_new_tokens=4)
    first_emit = {}
    done = {}
    for step in range(10000):
        if not eng.has_work():
            break
        for uid, _ in eng.step():
            first_emit.setdefault(uid, step)
        for r in eng.collect_finished():
            done[r.uid] = (r.output, step)
    out_s, t_short_done = done[u_short]
    out_l, _ = done[u_long]
    assert out_s == _ref_generate(api, params, cfg, short, 4)
    assert out_l == _ref_generate(api, params, cfg, long, 4)
    # the 40-token prompt needs 5 chunk steps at budget 8; the short
    # request decoded to completion inside that window
    assert t_short_done < first_emit[u_long]


def test_paged_residency_eviction(dense_lm):
    """When free blocks run out, the coldest residency is evicted at
    block granularity and the drop listener fires — and evicted prefixes
    simply miss (fresh prefill), never corrupt."""
    cfg, api, params = dense_lm
    eng = InferenceEngine(cfg, params, max_num_seqs=2,
                          max_num_batched_tokens=128, max_len=32,
                          prefill_buckets=(16, 32), seed=0, paged=True,
                          block_size=8, num_blocks=9)  # capacity: 8 blocks
    drops = []
    eng.on_residency_drop = lambda: drops.append(1)
    rng = np.random.RandomState(4)
    prompts = [list(rng.randint(1, cfg.vocab, size=20)) for _ in range(3)]
    for p in prompts:
        u = eng.submit(p, max_new_tokens=4)
        out = _drive(eng, [], 0, uids=[u])[0]
        assert out == _ref_generate(api, params, cfg, p, 4)
    # 3 retired sequences x 3 blocks each > 8-block capacity: the first
    # residency must have been evicted to admit the third sequence
    assert eng.stats.evicted_residencies >= 1
    assert drops
    assert len(eng._residency) < 3


def test_paged_prefix_reuse_disabled_frees_blocks(dense_lm):
    """With reuse off, retirement frees every block immediately — the
    allocator returns to full capacity after each drain."""
    cfg, api, params = dense_lm
    eng = InferenceEngine(cfg, params, **ENGINE_KW, paged=True,
                          block_size=8, enable_prefix_reuse=False)
    rng = np.random.RandomState(5)
    prompts = [list(rng.randint(1, cfg.vocab, size=10)) for _ in range(3)]
    outs = _drive(eng, prompts, 4)
    for p, o in zip(prompts, outs):
        assert o == _ref_generate(api, params, cfg, p, 4)
    assert eng.stats.prefix_reuse_hits == 0
    assert eng.pool.alloc.n_free == eng.pool.alloc.capacity
    assert eng._reserved == 0


def test_paged_block_conservation_after_drain(dense_lm):
    """After serving a branching load and force-evicting every residency,
    all blocks return to the free list and no reservation leaks."""
    cfg, _, params = dense_lm
    eng = InferenceEngine(cfg, params, **ENGINE_KW, paged=True,
                          block_size=8)
    stem = [7, 6, 5, 4, 3, 2, 1, 2, 3]
    _drive(eng, [stem], 4)
    _drive(eng, [stem + [10 + i] for i in range(5)], 4)
    assert eng.stats.shared_block_peak > 0
    while eng._residency:
        eng._evict_residency()
    assert eng.pool.alloc.n_free == eng.pool.alloc.capacity
    assert eng.pool.block_savings() == 0
    assert eng._reserved == 0
    assert eng._res_holds == {}


def test_paged_rejects_state_carrying_families():
    """ssm/hybrid have no per-position KV: paged mode must refuse."""
    cfg = get_smoke_config("rwkv6-1.6b")
    with pytest.raises(ValueError, match="paged"):
        PagedCachePool(cfg, num_blocks=8, block_size=4, max_len=16)
    api = get_model(cfg)
    params, _ = nn.split(api.init(jax.random.PRNGKey(0), cfg))
    with pytest.raises(ValueError):
        InferenceEngine(cfg, params, paged=True, max_len=16,
                        prefill_buckets=(16,))


def test_block_allocator_error_paths():
    """The allocator enforces the invariants CoW safety rests on."""
    alloc = BlockAllocator(4)
    with pytest.raises(ValueError):
        BlockAllocator(1)  # no room for the null block + one real block
    b = alloc.allocate()
    alloc.free(b)
    with pytest.raises(ValueError):
        alloc.free(b)  # double free
    with pytest.raises(ValueError):
        alloc.fork(b)  # fork of an unallocated block
    with pytest.raises(ValueError):
        alloc.fork(0)  # the null block is never refcounted
    with pytest.raises(ValueError):
        alloc.free(99)  # out of range


def test_paged_pool_rejects_undersized_budget(dense_lm):
    """A pool that cannot hold even one max_len sequence is a config
    error, not a runtime deadlock."""
    cfg, _, _ = dense_lm
    with pytest.raises(ValueError, match="num_blocks"):
        PagedCachePool(cfg, num_blocks=4, block_size=8, max_len=64)


def test_paged_sampling_smoke(dense_lm):
    """temperature > 0 runs through the paged prefill/decode sampling
    paths and terminates (no equivalence claim — key streams differ)."""
    cfg, _, params = dense_lm
    eng = InferenceEngine(cfg, params, **ENGINE_KW, paged=True,
                          block_size=8)
    u = eng.submit([3, 1, 4, 1, 5, 9], max_new_tokens=5, temperature=0.8)
    out = _drive(eng, [], 0, uids=[u])[0]
    assert len(out) == 5
    assert all(0 <= t < cfg.vocab for t in out)


# ---------------------------------------------------------------------------
# Direct paged decode (the kernel-on-the-block-store path)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("family", ["dense", "moe"])
def test_paged_decode_modes_equivalent(family, dense_lm, moe_lm):
    """Direct paged decode (K/V written straight into the tail block,
    attention through the block table) is token-identical to the legacy
    gather round-trip AND the slot pool — ragged lengths straddling block
    boundaries (block_size 8: 7/8/9 and 15/16/17)."""
    cfg, api, params = dense_lm if family == "dense" else moe_lm
    rng = np.random.RandomState(7)
    prompts = [list(rng.randint(1, cfg.vocab, size=n))
               for n in (7, 8, 9, 15, 16, 17)]
    outs = {}
    for mode in ("slot", "direct", "gather"):
        kw = dict(ENGINE_KW)
        if mode != "slot":
            kw.update(paged=True, block_size=8, paged_decode_mode=mode)
        outs[mode] = _drive(InferenceEngine(cfg, params, **kw), prompts, 6)
    assert outs["direct"] == outs["gather"] == outs["slot"]


def test_paged_decode_modes_agree_on_divergence(dense_lm):
    """Partial-hit resume plus copy-on-write divergence produce identical
    greedy tokens under the direct kernel and the gather round-trip, and
    both match the from-scratch oracle."""
    cfg, api, params = dense_lm
    stem = [5, 4, 3, 2, 1, 2, 3, 4, 5, 6, 7, 8]
    branches = [stem[:9] + [100 + i, 101, 102] for i in range(3)]
    outs = {}
    for mode in ("direct", "gather"):
        eng = InferenceEngine(cfg, params, **ENGINE_KW, paged=True,
                              block_size=4, paged_decode_mode=mode)
        _drive(eng, [stem], 4)
        outs[mode] = _drive(eng, branches, 4)
        assert eng.stats.prefix_partial_hits >= 1
        assert eng.stats.cow_copies >= 1
    assert outs["direct"] == outs["gather"]
    for p, o in zip(branches, outs["direct"]):
        assert o == _ref_generate(api, params, cfg, p, 4)


def test_direct_decode_never_gathers(dense_lm, monkeypatch):
    """The tentpole invariant: in direct mode the decode step NEVER
    reassembles a contiguous view — ``gather_block_view`` is extend-only.
    The gather-mode engine run through the same spy proves the spy sees
    decode-phase gathers when they happen."""
    import repro.serving.engine as engine_mod
    cfg, _, params = dense_lm
    in_decode = []
    decode_gathers = {"direct": 0, "gather": 0}
    real_gather = engine_mod.gather_block_view
    current = ["direct"]

    def spy(*a, **k):
        if in_decode:
            decode_gathers[current[0]] += 1
        return real_gather(*a, **k)

    monkeypatch.setattr(engine_mod, "gather_block_view", spy)
    rng = np.random.RandomState(8)
    prompts = [list(rng.randint(1, cfg.vocab, size=n)) for n in (5, 11, 19)]
    for mode in ("direct", "gather"):
        current[0] = mode
        eng = InferenceEngine(cfg, params, **ENGINE_KW, paged=True,
                              block_size=8, paged_decode_mode=mode)
        real_decode = eng._paged_decode

        def wrapped(*a, __real=real_decode, **k):
            in_decode.append(1)
            try:
                return __real(*a, **k)
            finally:
                in_decode.pop()

        eng._paged_decode = wrapped
        _drive(eng, prompts, 6)
    assert decode_gathers["direct"] == 0
    assert decode_gathers["gather"] > 0


def test_paged_rejects_unknown_decode_mode(dense_lm):
    cfg, _, params = dense_lm
    with pytest.raises(ValueError, match="paged_decode_mode"):
        InferenceEngine(cfg, params, **ENGINE_KW, paged=True,
                        block_size=8, paged_decode_mode="telepathy")


# ---------------------------------------------------------------------------
# Chunk-budget accounting (bugfix: charge the padded bucket, not T)
# ---------------------------------------------------------------------------


def test_paged_chunk_budget_charges_padded_bucket(dense_lm):
    """Regression: the prefill scheduler must charge the PADDED bucket
    that actually runs, so one step's batched prefill tokens never exceed
    ``max_num_batched_tokens`` under ragged chunk mixes.  (The old code
    charged the real token count: three 9-token chunks padded to bucket 16
    fit a 24-token budget on paper while running 48.)"""
    cfg, api, params = dense_lm
    eng = InferenceEngine(cfg, params, max_num_seqs=8,
                          max_num_batched_tokens=24, max_len=64,
                          prefill_buckets=(8, 16), seed=0, paged=True,
                          block_size=8)
    real = eng._paged_extend
    widths = []

    def spy(params, store, bt, lens, tokens, wphys, woff):
        widths.append(int(tokens.shape[1]))
        return real(params, store, bt, lens, tokens, wphys, woff)

    eng._paged_extend = spy
    rng = np.random.RandomState(9)
    prompts = [list(rng.randint(1, cfg.vocab, size=n))
               for n in (9, 9, 9, 13, 21, 30)]
    uids = [eng.submit(p, max_new_tokens=4) for p in prompts]
    done = {}
    per_step = []
    for _ in range(100000):
        if not eng.has_work():
            break
        widths.clear()
        eng.step()
        per_step.append(sum(widths))
        for r in eng.collect_finished():
            done[r.uid] = r
    assert max(per_step) <= 24
    # splitting a chunk to fit the remaining budget stays correct
    for p, u in zip(prompts, uids):
        assert done[u].output == _ref_generate(api, params, cfg, p, 4)


# ---------------------------------------------------------------------------
# Live pool gauges + telemetry + servicer paged default
# ---------------------------------------------------------------------------


def test_paged_live_gauges_and_telemetry(dense_lm):
    """free/reserved gauges track the pool every step (not just peaks),
    and block_telemetry() bundles the router-facing numbers."""
    cfg, _, params = dense_lm
    eng = InferenceEngine(cfg, params, **ENGINE_KW, paged=True,
                          block_size=8)
    assert eng.stats.free_blocks == eng.pool.n_free
    _drive(eng, [[1, 2, 3, 4, 5], [1, 2, 3, 9, 9, 9, 9]], 4)
    assert eng.stats.free_blocks == eng.pool.n_free
    assert eng.stats.reserved_blocks == eng._reserved == 0
    tel = eng.block_telemetry()
    assert tel["free_blocks"] == eng.pool.n_free
    assert tel["total_blocks"] == eng.pool.alloc.capacity
    assert {"reserved_blocks", "shared_blocks", "cow_copies",
            "evicted_residencies"} <= set(tel)
    # slot-pool engines report no block telemetry
    mono = InferenceEngine(cfg, params, **ENGINE_KW)
    assert mono.block_telemetry() is None


def test_llm_servicer_paged_auto_default(dense_lm):
    """LLMServicer defaults dense/moe replicas to the paged engine
    (direct decode); explicit paged=False forces the slot pool; families
    without per-position KV auto-resolve to the slot pool with the
    paged-only knobs stripped."""
    from repro.serving.client import LLMServicer
    cfg, _, params = dense_lm
    s = LLMServicer(cfg, params, max_num_seqs=2, max_len=32,
                    prefill_buckets=(16,))
    assert s.engine.paged
    assert s.engine.paged_decode_mode == "direct"
    assert s.block_telemetry()["total_blocks"] > 0
    s = LLMServicer(cfg, params, max_num_seqs=2, max_len=32,
                    prefill_buckets=(16,), paged=False, block_size=8)
    assert not s.engine.paged
    assert s.block_telemetry() is None
    ssm = get_smoke_config("rwkv6-1.6b")
    sapi = get_model(ssm)
    sparams, _ = nn.split(sapi.init(jax.random.PRNGKey(0), ssm))
    s = LLMServicer(ssm, sparams, max_num_seqs=2, max_len=16,
                    prefill_buckets=(16,), block_size=8, num_blocks=16)
    assert not s.engine.paged
    assert s.block_telemetry() is None
