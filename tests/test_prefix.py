"""RadixIndex (repro.core.prefix) unit tests: the API semantics the
engine admission path and the radix router rely on.  The hypothesis
property tests (brute-force agreement under random interleavings) live in
``test_prefix_properties.py`` so this module runs even without the
optional dependency."""
from repro.core.prefix import RadixIndex


# ---------------------------------------------------------------------------
# Unit tests: API semantics the engine/router rely on
# ---------------------------------------------------------------------------


def test_insert_and_longest_match_basic():
    idx = RadixIndex()
    assert idx.insert((1, 2, 3, 4), "a")
    assert idx.insert((1, 2, 9, 9), "b")
    assert idx.longest_match((1, 2, 3, 4, 5)) == (4, "a")
    assert idx.longest_match((1, 2, 9)) == (3, "b")
    assert idx.longest_match((1, 2)) in ((2, "a"), (2, "b"))
    assert idx.longest_match((7, 7)) == (0, None)
    assert idx.longest_match(()) == (0, None)
    assert not idx.insert((), "x")  # empty sequences are rejected


def test_match_lengths_reports_every_value():
    idx = RadixIndex()
    idx.insert((1, 2, 3), "a")
    idx.insert((1, 2, 3, 4, 5), "b")
    idx.insert((9,), "c")
    assert idx.match_lengths((1, 2, 3, 4, 9)) == {"a": 3, "b": 4, "c": 0}
    assert idx.match_lengths((1, 2)) == {"a": 2, "b": 2, "c": 0}


def test_same_value_longer_sequence_compacts_prefix():
    """A growing session replaces its earlier, shorter entry (compaction),
    so the index stays one-entry-per-live-transcript."""
    idx = RadixIndex()
    idx.insert((1, 2), "s")
    idx.insert((1, 2, 3, 4), "s")  # extends the first -> subsumes it
    assert len(idx) == 1
    assert idx.longest_match((1, 2, 3, 4)) == (4, "s")
    # a DIFFERENT value's prefix entry is not compacted away
    idx.insert((1, 2), "t")
    idx.insert((1, 2, 3, 4, 5), "u")
    assert len(idx) == 3


def test_remove_value_drops_all_entries():
    idx = RadixIndex()
    idx.insert((1, 2, 3), "a")
    idx.insert((5, 6), "a")
    idx.insert((1, 9), "b")
    assert idx.remove_value("a") == 2
    assert "a" not in idx
    assert idx.longest_match((1, 2, 3)) == (1, "b")
    assert idx.remove_value("missing") == 0


def test_lru_eviction_order_and_capacity():
    idx = RadixIndex(capacity=2)
    idx.insert((1, 1), "a")
    idx.insert((2, 2), "b")
    idx.insert((1, 1), "a")  # refresh: 'a' is now the most recent
    idx.insert((3, 3), "c")  # capacity 2 -> evicts 'b' (oldest)
    assert idx.values() == {"a", "c"}
    assert len(idx) == 2
    seq, value = idx.evict_lru()
    assert (tuple(seq), value) == ((1, 1), "a")
    assert len(idx) == 1


def test_summary_newest_first_truncated():
    idx = RadixIndex()
    idx.insert(tuple(range(10)), "a")
    idx.insert((7, 7, 7), "b")
    s = idx.summary(max_entries=8, max_len=4)
    assert s[0] == [7, 7, 7]
    assert s[1] == [0, 1, 2, 3]
    assert idx.summary(max_entries=1) == [[7, 7, 7]]


def test_remove_exact_entry():
    idx = RadixIndex()
    idx.insert((1, 2, 3), "a")
    idx.insert((1, 2), "b")
    assert idx.remove((1, 2, 3), "a")
    assert not idx.remove((1, 2, 3), "a")  # already gone
    assert idx.longest_match((1, 2, 3)) == (2, "b")


def test_clear_resets_everything():
    idx = RadixIndex()
    idx.insert((1, 2), "a")
    idx.clear()
    assert len(idx) == 0
    assert idx.longest_match((1, 2)) == (0, None)
    idx.insert((1, 2), "a")  # still usable after clear
    assert idx.longest_match((1, 2)) == (2, "a")


def test_string_tokens_work():
    """The router keys sessions by raw char tuples for string prompts."""
    idx = RadixIndex()
    idx.insert(tuple("hello world"), 0)
    d, v = idx.longest_match(tuple("hello there"))
    assert (d, v) == (len("hello "), 0)
