"""Training substrate: loss decreases, grad-accum equivalence, optimizer
variants, checkpoint fault tolerance."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import get_model, make_batch, nn
from repro.training.checkpoint import Checkpointer
from repro.training.optim import OptimizerConfig, adamw_init, adamw_update
from repro.training.train import (TrainConfig, init_state, make_train_step,
                                  train_loop)


@pytest.fixture(scope="module")
def tiny_cfg():
    return get_config("rhapsody-demo").scaled(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, vocab=128)


def _data_iter(cfg, batch, seq, seed=1):
    k = jax.random.PRNGKey(seed)
    while True:
        k, s = jax.random.split(k)
        yield make_batch(cfg, batch, seq, s)


def test_loss_decreases(tiny_cfg):
    api = get_model(tiny_cfg)
    tcfg = TrainConfig(global_batch=8, seq_len=32,
                       optimizer=OptimizerConfig(lr=1e-2, warmup_steps=2,
                                                 decay_steps=100))
    _, hist = train_loop(api, tiny_cfg, tcfg, steps=15,
                         data_iter=_data_iter(tiny_cfg, 8, 32), log_every=14)
    assert hist[-1]["loss"] < hist[0]["loss"]


def test_grad_accum_equivalence(tiny_cfg):
    """n_micro=1 and n_micro=4 produce (nearly) identical updates."""
    api = get_model(tiny_cfg)
    opt = OptimizerConfig(lr=1e-3, warmup_steps=1, decay_steps=10)
    state1, _ = init_state(jax.random.PRNGKey(0), api, tiny_cfg, opt)
    state2 = jax.tree.map(lambda x: x, state1)
    batch = make_batch(tiny_cfg, 8, 16)
    s1 = make_train_step(api, tiny_cfg,
                         TrainConfig(microbatches=1, optimizer=opt),
                         donate=False)
    s4 = make_train_step(api, tiny_cfg,
                         TrainConfig(microbatches=4, optimizer=opt),
                         donate=False)
    out1, m1 = s1(state1, batch)
    out4, m4 = s4(state2, batch)
    # loss definitions average over different token groups; allow small tol
    for a, b in zip(jax.tree.leaves(out1["params"]),
                    jax.tree.leaves(out4["params"])):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-3, atol=2e-5)


def test_quantized_adam_matches_fp32_direction(tiny_cfg):
    api = get_model(tiny_cfg)
    params, _ = nn.split(api.init(jax.random.PRNGKey(0), tiny_cfg))
    g = jax.tree.map(lambda p: jnp.ones_like(p) * 0.01, params)
    for quant in (False, True):
        opt_cfg = OptimizerConfig(lr=1e-3, quantize_states=quant,
                                  weight_decay=0.0)
        st = adamw_init(params, opt_cfg)
        new_p, st, _ = adamw_update(g, st, params, opt_cfg)
        delta = jax.tree.map(lambda a, b: np.asarray(b - a), params, new_p)
        for d in jax.tree.leaves(delta):
            assert (d <= 1e-9).all()  # positive grads -> params decrease


def test_checkpoint_restart_resumes(tiny_cfg):
    api = get_model(tiny_cfg)
    opt = OptimizerConfig(lr=1e-3, warmup_steps=1, decay_steps=10)
    state, _ = init_state(jax.random.PRNGKey(0), api, tiny_cfg, opt)
    with tempfile.TemporaryDirectory() as d:
        ck = Checkpointer(d, keep=2)
        ck.save(state, 10)
        ck.save(state, 20)
        ck.save(state, 30)
        assert ck.steps() == [20, 30]  # keep=2 GC'd step 10
        restored, step = ck.restore_latest(state)
        assert step == 30
        for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_skips_corrupt(tiny_cfg):
    api = get_model(tiny_cfg)
    opt = OptimizerConfig()
    state, _ = init_state(jax.random.PRNGKey(0), api, tiny_cfg, opt)
    with tempfile.TemporaryDirectory() as d:
        ck = Checkpointer(d, keep=5)
        ck.save(state, 1)
        ck.save(state, 2)
        # corrupt the newest payload
        with open(os.path.join(d, "step_00000002.npz"), "r+b") as f:
            f.seek(10)
            f.write(b"\xde\xad\xbe\xef")
        restored, step = ck.restore_latest(state)
        assert step == 1  # fell back to the last valid checkpoint


def test_lr_schedule():
    from repro.training.optim import lr_at

    cfg = OptimizerConfig(lr=1.0, warmup_steps=10, decay_steps=100,
                          min_lr_ratio=0.1)
    assert float(lr_at(jnp.asarray(0), cfg)) < 0.2
    assert float(lr_at(jnp.asarray(9), cfg)) == pytest.approx(1.0, abs=0.01)
    assert float(lr_at(jnp.asarray(1000), cfg)) == pytest.approx(0.1, abs=0.01)
