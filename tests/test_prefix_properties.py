"""RadixIndex property tests: agreement with a brute-force reference
under random insert / evict / remove interleavings (LRU eviction order,
same-value prefix compaction, capacity bounds, refcount/pruning
invariants).

``hypothesis`` is an optional dev dependency: skip the whole module
(rather than dying at collection) when it isn't installed, matching
``test_properties.py``.
"""
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core.prefix import RadixIndex  # noqa: E402


def _lcp(a, b):
    n = min(len(a), len(b))
    k = 0
    while k < n and a[k] == b[k]:
        k += 1
    return k


_ops = st.lists(
    st.one_of(
        # insert: (0, seq, value)
        st.tuples(st.just(0),
                  st.lists(st.integers(0, 3), min_size=1, max_size=10),
                  st.integers(0, 4)),
        # evict_lru: (1, None, None)
        st.tuples(st.just(1), st.none(), st.none()),
        # remove_value: (2, None, value)
        st.tuples(st.just(2), st.none(), st.integers(0, 4)),
    ),
    min_size=1, max_size=60)


class _BruteRef:
    """Mirror of RadixIndex semantics on a plain recency-ordered list."""

    def __init__(self, capacity):
        self.capacity = capacity
        self.items: list = []  # (seq, value), oldest first

    def insert(self, seq, value):
        # compaction: same-value strict prefixes of seq are subsumed
        self.items = [(s, v) for s, v in self.items
                      if not (v == value and len(s) < len(seq)
                              and seq[:len(s)] == s)]
        if (seq, value) in self.items:
            self.items.remove((seq, value))
        self.items.append((seq, value))
        while self.capacity and len(self.items) > self.capacity:
            if self.items[0] == (seq, value):
                break
            self.items.pop(0)

    def best(self, q):
        return max((_lcp(q, s) for s, _ in self.items), default=0)

    def match_lengths(self, q):
        out: dict = {}
        for s, v in self.items:
            out[v] = max(out.get(v, 0), _lcp(q, s))
        return out


@settings(max_examples=60, deadline=None)
@given(ops=_ops, capacity=st.sampled_from([0, 3, 8]),
       probe=st.lists(st.integers(0, 3), max_size=12))
def test_radix_agrees_with_brute_force(ops, capacity, probe):
    idx = RadixIndex(capacity=capacity)
    ref = _BruteRef(capacity)
    for op, seq, value in ops:
        if op == 0:
            seq = tuple(seq)
            idx.insert(seq, value)
            ref.insert(seq, value)
        elif op == 1:
            ev = idx.evict_lru()
            if ref.items:
                assert ev is not None
                assert (tuple(ev[0]), ev[1]) == ref.items.pop(0)
            else:
                assert ev is None
        else:
            n = idx.remove_value(value)
            assert n == sum(1 for _, v in ref.items if v == value)
            ref.items = [(s, v) for s, v in ref.items if v != value]
        assert len(idx) == len(ref.items)
    probe = tuple(probe)
    d, v = idx.longest_match(probe)
    assert d == ref.best(probe)
    if d > 0:  # the returned value must itself achieve the best depth
        assert max(_lcp(probe, s)
                   for s, vv in ref.items if vv == v) == d
    assert idx.match_lengths(probe) == ref.match_lengths(probe)


@settings(max_examples=40, deadline=None)
@given(seqs=st.lists(
    st.lists(st.integers(0, 2), min_size=1, max_size=8), min_size=1,
    max_size=20))
def test_radix_insert_remove_roundtrip_leaves_empty(seqs):
    """Inserting distinct-valued sequences then removing every value leaves
    a structurally empty index (refcounts and pruning are consistent)."""
    idx = RadixIndex()
    for i, s in enumerate(seqs):
        idx.insert(tuple(s), i)
    for i in range(len(seqs)):
        idx.remove_value(i)
    assert len(idx) == 0
    assert idx.values() == set()
    assert not idx.root.edges  # tree fully pruned
    assert not idx.root.vals


@settings(max_examples=40, deadline=None)
@given(
    sessions=st.lists(st.integers(0, 5), min_size=2, max_size=50),
    n=st.integers(2, 6),
)
def test_radix_router_sticky_while_membership_stable(sessions, n):
    """With a stable replica count and no spill pressure, every repeat of
    a session's (growing) prompt re-picks the replica that served it
    first — the radix analogue of the hashed-LRU sticky property."""
    from repro.core.router import make_router

    r = make_router("radix_affinity", spill_factor=0.0, min_match=4)
    grown: dict = {}
    home: dict = {}
    for s in sessions:
        # session s's prompt grows turn over turn from a unique base
        grown[s] = grown.get(s, tuple([s] * 8)) + (s, len(grown.get(s, ())))
        key = r.signature({"prompt": list(grown[s])})
        idx = r.pick(1.0, n_instances=n, group="g", affinity_key=key)
        assert 0 <= idx < n
        if s in home:
            assert idx == home[s], "radix sticky violated on stable set"
        else:
            home[s] = idx
