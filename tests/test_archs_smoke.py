"""Per-architecture smoke tests: reduced configs, one forward/train step on
CPU, output shapes + finite values (assignment requirement f)."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_smoke_config, list_archs
from repro.models import get_model, make_batch, nn
from repro.training.optim import OptimizerConfig
from repro.training.train import TrainConfig, make_train_step, init_state

ARCHS = list_archs()


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_shapes_and_finite(arch):
    cfg = get_smoke_config(arch)
    api = get_model(cfg)
    params, axes = nn.split(api.init(jax.random.PRNGKey(0), cfg))
    B, S = 2, 16
    batch = make_batch(cfg, B, S)
    logits, aux = api.forward(params, batch, cfg)
    assert logits.shape == (B, S, cfg.vocab)
    assert bool(jnp.isfinite(logits).all())
    assert bool(jnp.isfinite(aux))


@pytest.mark.parametrize("arch", ARCHS)
def test_one_train_step(arch):
    cfg = get_smoke_config(arch)
    api = get_model(cfg)
    tcfg = TrainConfig(global_batch=4, seq_len=16, microbatches=2,
                       optimizer=OptimizerConfig(lr=1e-3, warmup_steps=1,
                                                 decay_steps=10))
    state, axes = init_state(jax.random.PRNGKey(0), api, cfg, tcfg.optimizer)
    step = make_train_step(api, cfg, tcfg, donate=False)
    batch = make_batch(cfg, 4, 16)
    state2, metrics = step(state, batch)
    assert bool(jnp.isfinite(metrics["loss"]))
    assert bool(jnp.isfinite(metrics["grad_norm"]))
    # params actually changed
    a0 = jax.tree.leaves(state["params"])[0] if False else None
    changed = jax.tree.map(
        lambda a, b: bool(jnp.any(a != b)), state["params"], state2["params"])
    assert any(jax.tree.leaves(changed))


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_decode_parity(arch):
    over = {"attention_impl": "full"}
    cfg0 = get_smoke_config(arch)
    if cfg0.is_moe:  # capacity drops differ between paths; lift the cap
        over.update(capacity_factor=8.0, decode_capacity_factor=8.0)
    cfg = cfg0.scaled(**over)
    api = get_model(cfg)
    params, _ = nn.split(api.init(jax.random.PRNGKey(0), cfg))
    B, S = 2, 12
    batch = make_batch(cfg, B, S)
    logits_full, _ = api.forward(params, batch, cfg)
    pre = dict(batch)
    pre["tokens"] = batch["tokens"][:, :8]
    kw = {"max_len": 32} if cfg.family != "ssm" else {}
    cache, lg = api.prefill(params, pre, cfg, **kw)
    assert float(jnp.max(jnp.abs(lg - logits_full[:, 7]))) < 5e-3
    for t in range(8, 12):
        cache, lg = api.decode(params, cache, batch["tokens"][:, t], cfg)
        err = float(jnp.max(jnp.abs(lg - logits_full[:, t])))
        assert err < 5e-3, (arch, t, err)
