"""Replicated services: router-driven dispatch spread, aggregate stats,
per-replica restart, scaling, and endpoint lifecycle."""
import threading
import time

import pytest

from repro.core import (ExecutionPolicy, ResourceDescription, Rhapsody,
                        ServiceDescription, TaskDescription, TaskKind)


class Echo:
    def handle(self, payload):
        time.sleep(0.001)
        return ("ok", payload)


def make_rh(**policy_kw):
    policy = ExecutionPolicy(**policy_kw)
    return Rhapsody(ResourceDescription(nodes=2, cores_per_node=16),
                    policy=policy, n_workers=2)


# ---------------------------------------------------------------------------
# Dispatch path: INFERENCE tasks route through Rhapsody.router
# ---------------------------------------------------------------------------


def test_inference_tasks_spread_across_replicas():
    """Acceptance: under round_robin with >= 2x replicas requests, every
    replica receives traffic — proves _dispatch_inference goes through the
    router, not a fixed endpoint."""
    rh = make_rh(routing="round_robin")
    try:
        rs = rh.add_service(ServiceDescription(name="svc", factory=Echo,
                                               replicas=3))
        descs = [TaskDescription(kind=TaskKind.INFERENCE, service="svc",
                                 payload={"prompt": [1] * (i + 1)},
                                 task_type="inference")
                 for i in range(12)]
        uids = rh.submit(descs)
        assert rh.wait(uids, timeout=20)
        stats = rs.stats()
        per = [p["requests"] for p in stats["per_replica"]]
        assert len(per) == 3
        assert all(c > 0 for c in per), per
        assert stats["requests"] == 12
        assert stats["completed"] == 12
        assert stats["errors"] == 0
    finally:
        rh.close()


def test_balanced_routing_spreads_token_load():
    rh = make_rh(routing="balanced")
    try:
        rs = rh.add_service(ServiceDescription(name="svc", factory=Echo,
                                               replicas=2))
        # one huge prompt + many small ones: token-aware routing must not
        # pile the small ones onto the replica holding the huge prompt
        descs = [TaskDescription(kind=TaskKind.INFERENCE, service="svc",
                                 payload={"prompt": [0] * 500})]
        descs += [TaskDescription(kind=TaskKind.INFERENCE, service="svc",
                                  payload={"prompt": [0] * 5})
                  for _ in range(10)]
        uids = rh.submit(descs)
        assert rh.wait(uids, timeout=20)
        per = [p["requests"] for p in rs.stats()["per_replica"]]
        assert min(per) >= 1
        assert max(per) - min(per) >= 5  # small ones went to the other side
    finally:
        rh.close()


def test_direct_request_also_routes():
    """ReplicaSet.request() (the legacy endpoint surface) load-balances."""
    rh = make_rh(routing="round_robin")
    try:
        rs = rh.add_service(ServiceDescription(name="svc", factory=Echo,
                                               replicas=2))
        futs = [rs.request({"prompt": [1]}) for _ in range(8)]
        for f in futs:
            f.result(10.0)
        per = [p["requests"] for p in rs.stats()["per_replica"]]
        assert per == [4, 4]
    finally:
        rh.close()


def test_unknown_service_fails_task():
    rh = make_rh()
    try:
        t = TaskDescription(kind=TaskKind.INFERENCE, service="nope",
                            payload={})
        rh.submit(t)
        rh.wait([t.uid], timeout=10)
        with pytest.raises(KeyError):
            rh.result(t.uid)
    finally:
        rh.close()


# ---------------------------------------------------------------------------
# Lifecycle: per-replica restart, scaling, stop
# ---------------------------------------------------------------------------


class CrashOnceEngine:
    """Pumped servicer whose first 'boom' submission kills its replica."""
    crashed = None  # set per-test to a shared dict

    def __init__(self):
        self.jobs = {}
        self.uid = 0

    def submit(self, payload):
        if payload == "boom" and not CrashOnceEngine.crashed["n"]:
            CrashOnceEngine.crashed["n"] += 1
            raise SystemError("replica preempted")
        self.uid += 1
        self.jobs[self.uid] = payload
        return self.uid

    def step(self):
        out = [(u, ("done", p)) for u, p in self.jobs.items()]
        self.jobs.clear()
        return out


def test_single_replica_crash_restarts_only_that_replica():
    CrashOnceEngine.crashed = {"n": 0}
    rh = make_rh(routing="round_robin", restart_failed_services=True)
    try:
        rs = rh.add_service(ServiceDescription(name="eng",
                                               factory=CrashOnceEngine,
                                               replicas=2))
        before = list(rs.instances)
        assert rs.request("fine").result(10.0) == ("done", "fine")
        # crash one replica; its in-flight request replays after restart
        assert rs.request("boom").result(15.0) == ("done", "boom")
        deadline = time.perf_counter() + 10
        while time.perf_counter() < deadline and not rs.ready():
            time.sleep(0.01)
        assert rs.n_replicas == 2
        assert rs.ready()
        after = list(rs.instances)
        # exactly one replica was replaced; its sibling was untouched
        assert len(set(before) & set(after)) == 1
        assert len(set(after) - set(before)) == 1
        # the set keeps serving on both replicas
        futs = [rs.request(f"r{i}") for i in range(4)]
        for f in futs:
            f.result(10.0)
        per = [p["requests"] for p in rs.stats()["per_replica"]]
        assert all(c > 0 for c in per)
    finally:
        rh.close()


def test_dead_service_without_restart_raises_instead_of_hanging():
    """When every replica has crashed and restarts are disabled, route()
    must fail fast, not queue onto a dead endpoint forever."""

    class DiesImmediately:
        def submit(self, payload):
            raise SystemError("dead on arrival")

        def step(self):
            return []

    rh = make_rh(restart_failed_services=False)
    try:
        rs = rh.add_service(ServiceDescription(name="doomed",
                                               factory=DiesImmediately))
        rs.request("boom")  # kills the only replica
        deadline = time.perf_counter() + 5
        while time.perf_counter() < deadline and rs.instances[0].error is None:
            time.sleep(0.01)
        assert rs.instances[0].error is not None
        with pytest.raises(KeyError):
            rs.request("after-death")
    finally:
        rh.close()


def test_scale_up_and_down_reroutes_work():
    rh = make_rh(routing="round_robin")
    try:
        rs = rh.add_service(ServiceDescription(name="svc", factory=Echo,
                                               replicas=1))
        rs.scale_to(3)
        assert rs.n_replicas == 3
        futs = [rs.request(i) for i in range(9)]
        for f in futs:
            f.result(10.0)
        assert all(p["requests"] > 0
                   for p in rs.stats()["per_replica"])
        rs.scale_to(1)
        assert rs.n_replicas == 1
        assert rs.request("still-up").result(10.0) == ("ok", "still-up")
        # aggregate stats survive the shrink: retired replicas' counters
        # are folded in rather than dropped
        stats = rs.stats()
        assert stats["requests"] == 10
        assert stats["completed"] == 10
    finally:
        rh.close()


def test_scale_up_with_unready_replica_degrades_gracefully():
    """A replica whose factory hangs past the ready timeout must not stay
    in the routing set (requests to it would sit unadmitted)."""
    calls = {"n": 0}

    class SecondOneHangs:
        def __init__(self):
            calls["n"] += 1
            if calls["n"] > 1:
                time.sleep(30)

        def handle(self, payload):
            return "h"

    rh = make_rh(routing="round_robin")
    try:
        rs = rh.add_service(ServiceDescription(name="svc",
                                               factory=SecondOneHangs))
        rs.scale_to(2, ready_timeout=0.2)
        assert rs.n_replicas == 1  # grow aborted, set stays consistent
        futs = [rs.request(i) for i in range(4)]
        assert all(f.result(10.0) == "h" for f in futs)
    finally:
        rh.close()


def test_stop_removes_endpoint_and_get_raises():
    """Regression: stop() used to leave a dead endpoint registered, so
    get() handed out a handle whose requests hung until timeout."""
    rh = make_rh()
    try:
        rh.add_service(ServiceDescription(name="svc", factory=Echo))
        assert rh.get_service("svc").request("x").result(10.0) == ("ok", "x")
        rh.services.stop("svc")
        with pytest.raises(KeyError):
            rh.get_service("svc")
        with pytest.raises(KeyError):
            rh.services.get("svc")
    finally:
        rh.close()


def test_sync_servicer_not_passed_private_metadata():
    """Regression: internal keys (_straggler_twin, _replays, ...) must be
    stripped before handle(), like the pumped submit path already does —
    otherwise a straggler twin of an INFERENCE task TypeErrors."""
    seen = []

    class Strict:
        def handle(self, payload, **kw):
            seen.append(kw)
            return "ok"

    rh = make_rh()
    try:
        rs = rh.add_service(ServiceDescription(name="svc", factory=Strict))
        assert rs.request("x", _straggler_twin=True,
                          visible=1).result(10.0) == "ok"
        assert seen == [{"visible": 1}]
    finally:
        rh.close()


def test_relaunch_same_name_serves_outstanding_requests():
    """Regression: re-launching a live service name must hand queued
    requests to the new replicas instead of abandoning their futures."""
    rh = make_rh(routing="round_robin")
    try:
        rh.add_service(ServiceDescription(name="svc", factory=Slow))
        old = rh.get_service("svc")
        futs = [old.request(i) for i in range(30)]
        new = rh.add_service(ServiceDescription(name="svc", factory=Echo,
                                                replicas=2))
        assert new is not old
        results = {f.result(30.0) for f in futs}
        # early requests served by Slow ('z'), drained ones by Echo
        assert results <= {"z", ("ok", 0)} | {("ok", i) for i in range(30)}
        assert new.request("after").result(10.0) == ("ok", "after")
    finally:
        rh.close()


def test_crash_exhausted_replays_count_as_errors():
    """Regression: futures failed after the replay budget must bump the
    errors stat, or depth() stays inflated and biases routing forever."""

    class AlwaysCrash:
        def __init__(self):
            pass

        def submit(self, payload):
            raise SystemError("dead on arrival")

        def step(self):
            return []

    rh = make_rh(restart_failed_services=True)
    try:
        rs = rh.add_service(ServiceDescription(name="bad",
                                               factory=AlwaysCrash))
        fut = rs.request("x")
        with pytest.raises(SystemError):
            fut.result(20.0)
        deadline = time.perf_counter() + 5
        ep = rs.endpoints[0]
        while time.perf_counter() < deadline and ep.depth() > 0:
            time.sleep(0.01)
        assert ep.depth() == 0, ep.stats
    finally:
        rh.close()


def test_policy_default_replicas():
    rh = make_rh(replicas=2)
    try:
        rs = rh.add_service(ServiceDescription(name="svc", factory=Echo))
        assert rs.n_replicas == 2  # picked up from ExecutionPolicy.replicas
    finally:
        rh.close()


# ---------------------------------------------------------------------------
# Autoscaling: queue depth grows the set, idleness shrinks it
# ---------------------------------------------------------------------------


class Slow:
    def handle(self, payload):
        time.sleep(0.01)
        return "z"


# ---------------------------------------------------------------------------
# Restart backoff: a persistently crashing replica must not hot-loop
# ---------------------------------------------------------------------------


def test_persistent_crasher_stops_hot_looping_and_degrades():
    """The first construction serves; every relaunch crashes in setup().
    Exponential backoff + restart_max_attempts must bound the relaunch
    count, declare the replica dead, and leave the set degraded (the
    healthy sibling keeps serving)."""
    built = {"n": 0}

    class CrashLoop:
        def __init__(self):
            built["n"] += 1
            self.first = built["n"] <= 2  # one healthy boot per replica
            self.jobs = {}
            self.uid = 0

        def setup(self):
            if not self.first:
                raise SystemError("still broken")

        def submit(self, payload):
            if payload == "boom":
                raise SystemError("boom")
            self.uid += 1
            self.jobs[self.uid] = payload
            return self.uid

        def step(self):
            out = [(u, "ok") for u in self.jobs]
            self.jobs.clear()
            return out

    rh = make_rh(routing="round_robin", restart_failed_services=True,
                 restart_backoff_s=0.01, restart_backoff_max_s=0.05,
                 restart_max_attempts=3)
    try:
        rs = rh.add_service(ServiceDescription(name="svc", factory=CrashLoop,
                                               replicas=2, ready_timeout=5.0))
        assert built["n"] == 2
        # kill one replica; every relaunch crashes in setup -> crash loop.
        # The replayed in-flight request fails once the budget runs out.
        with pytest.raises((SystemError, RuntimeError)):
            rs.request("boom").result(10.0)
        deadline = time.perf_counter() + 10
        while time.perf_counter() < deadline:
            if rh.services.list()["svc"] == "degraded":
                break
            time.sleep(0.02)
        assert rh.services.list()["svc"] == "degraded"
        # bounded: initial 2 boots + (1 + max_attempts) relaunch tries max
        n_after_give_up = built["n"]
        assert n_after_give_up <= 2 + 1 + 3, built
        time.sleep(0.3)  # several backoff ceilings: no further relaunches
        assert built["n"] == n_after_give_up
        # the surviving replica still serves
        assert rs.request("fine").result(10.0) == "ok"
    finally:
        rh.close()


def test_backoff_delays_relaunch_but_recovers():
    """A transient double-crash still recovers — backoff delays, it does
    not give up below the attempt cap — and the crash budget resets after
    a healthy stretch."""
    crashes = {"n": 0}

    class CrashTwice:
        def __init__(self):
            self.jobs = {}
            self.uid = 0

        def submit(self, payload):
            if payload == "boom" and crashes["n"] < 2:
                crashes["n"] += 1
                raise SystemError("transient")
            self.uid += 1
            self.jobs[self.uid] = payload
            return self.uid

        def step(self):
            out = [(u, "ok") for u in self.jobs]
            self.jobs.clear()
            return out

    rh = make_rh(restart_failed_services=True, restart_backoff_s=0.01,
                 restart_backoff_max_s=0.05, restart_max_attempts=3)
    try:
        rs = rh.add_service(ServiceDescription(name="svc",
                                               factory=CrashTwice))
        # both crashes replay the in-flight request on the relaunched
        # replica; the third attempt serves it
        assert rs.request("boom").result(15.0) == "ok"
        assert crashes["n"] == 2
        assert rs.request("fine").result(10.0) == "ok"
        hist = rs._crash_history[rs.endpoints[0].replica_idx]
        assert hist["attempts"] == 2
    finally:
        rh.close()


def test_exhausted_replica_folds_after_grace():
    """A replica that burns its restart budget is removed from the set
    after the grace period with its stats merged into the aggregate, and
    stats()/list(verbose=True) expose an operator-visible dead-replica
    count — no retired-in-place corpse lingers."""

    class BoomOnDemand:
        def __init__(self):
            self.jobs = {}
            self.uid = 0

        def submit(self, payload):
            if payload == "boom":
                raise SystemError("persistent fault")
            self.uid += 1
            self.jobs[self.uid] = payload
            return self.uid

        def step(self):
            out = [(u, "ok") for u in self.jobs]
            self.jobs.clear()
            return out

    rh = make_rh(routing="round_robin", restart_failed_services=True,
                 restart_backoff_s=0.01, restart_backoff_max_s=0.02,
                 restart_max_attempts=1, dead_replica_grace_s=0.15)
    try:
        rs = rh.add_service(ServiceDescription(name="svc",
                                               factory=BoomOnDemand,
                                               replicas=2))
        assert rs.request("warm").result(10.0) == "ok"
        # the replayed boom crashes the relaunched replica too -> budget
        # (1 attempt) exhausted -> declared dead
        with pytest.raises((SystemError, RuntimeError)):
            rs.request("boom").result(10.0)
        deadline = time.perf_counter() + 10
        while time.perf_counter() < deadline and rs.n_replicas > 1:
            time.sleep(0.02)
        assert rs.n_replicas == 1, "dead replica was not folded"
        stats = rs.stats()
        assert stats["dead_replicas"] == 1
        # the folded replica's served/errored requests stay in the
        # aggregate (merged, not dropped)
        assert stats["requests"] >= 2
        assert stats["errors"] >= 1
        # the set is healthy again from the operator's point of view
        assert rh.services.list()["svc"] == "ready"
        verbose = rh.services.list(verbose=True)["svc"]
        assert verbose["status"] == "ready"
        assert verbose["replicas"] == 1
        assert verbose["dead_replicas"] == 1
        # ... and keeps serving on the survivor
        assert rs.request("fine").result(10.0) == "ok"
    finally:
        rh.close()


def test_negative_grace_keeps_dead_replica_visible():
    """Operators can opt out of folding: a negative grace keeps the
    degraded corpse in the set (the pre-fold behavior)."""

    class DiesOnBoom:
        def submit(self, payload):
            if payload == "boom":
                raise SystemError("dead")
            return 1

        def step(self):
            return [(1, "ok")]

    rh = make_rh(restart_failed_services=False, max_retries=0,
                 dead_replica_grace_s=-1.0)
    try:
        rs = rh.add_service(ServiceDescription(name="svc",
                                               factory=DiesOnBoom,
                                               replicas=2))
        with pytest.raises((SystemError, RuntimeError)):
            rs.request("boom").result(10.0)
        deadline = time.perf_counter() + 5
        while time.perf_counter() < deadline and \
                all(i.error is None for i in rs.instances):
            time.sleep(0.01)
        time.sleep(0.3)  # several grace periods' worth: nothing folds
        assert rs.n_replicas == 2  # corpse stays visible (degraded)
        assert rs.n_live == 1
        assert rs.stats()["dead_replicas"] == 1
        assert rh.services.list()["svc"] == "degraded"
    finally:
        rh.close()


# ---------------------------------------------------------------------------
# Concurrency stress: clients hammer route()+request() during scaling
# ---------------------------------------------------------------------------


def test_concurrent_requests_during_scaling_conserve_futures():
    """N client threads vs a scaler thread bouncing the replica count:
    every future resolves exactly once with its own payload, and the
    aggregate stats stay conserved (requests == completed, no errors,
    nothing lost or double-counted across retire/reroute races)."""
    rh = make_rh(routing="least_loaded")
    n_threads, per_thread = 6, 40
    try:
        rs = rh.add_service(ServiceDescription(name="svc", factory=Echo,
                                               replicas=1))
        stop = threading.Event()

        def scaler():
            n = 3
            while not stop.is_set():
                rs.scale_to(n)
                n = 1 if n == 3 else 3
                time.sleep(0.005)

        results: list = [None] * n_threads
        errors: list = [None] * n_threads

        def client(tid):
            got = []
            try:
                futs = [(i, rs.request({"prompt": [tid, i] * 4}))
                        for i in range(per_thread)]
                for i, f in enumerate(futs):
                    got.append((i, f[1].result(30.0)))
            except BaseException as e:  # noqa: BLE001
                errors[tid] = e
            results[tid] = got

        scale_thread = threading.Thread(target=scaler, daemon=True)
        scale_thread.start()
        clients = [threading.Thread(target=client, args=(t,))
                   for t in range(n_threads)]
        for c in clients:
            c.start()
        for c in clients:
            c.join(timeout=60)
        stop.set()
        scale_thread.join(timeout=10)
        assert all(e is None for e in errors), errors
        # exactly-once, with the right payload: no lost or cross-resolved
        # future anywhere
        for tid, got in enumerate(results):
            assert len(got) == per_thread
            for i, res in got:
                assert res == ("ok", {"prompt": [tid, i] * 4})
        # settle any late drains, then check conservation
        deadline = time.perf_counter() + 10
        total = n_threads * per_thread
        while time.perf_counter() < deadline:
            stats = rs.stats()
            if stats["completed"] + stats["errors"] >= total:
                break
            time.sleep(0.02)
        stats = rs.stats()
        assert stats["errors"] == 0
        assert stats["completed"] == total
        assert stats["requests"] == total
    finally:
        rh.close()


def test_autoscale_replaces_replica_dead_in_place():
    """A replica retired in place (restart budget exhausted) must not
    consume autoscale capacity: with max_replicas == configured replicas,
    the set still grows a substitute when the survivor backs up."""
    rh = make_rh(routing="least_loaded", autoscale=True,
                 autoscale_min_replicas=1, autoscale_max_replicas=2,
                 autoscale_high_depth=2.0, autoscale_low_depth=0.5,
                 autoscale_interval_s=0.02, autoscale_sustain=2)
    try:
        rs = rh.add_service(ServiceDescription(name="slow", factory=Slow,
                                               replicas=2))
        # simulate the _handle_exit give-up outcome: dead in place
        dead = rs.endpoints[0]
        dead.ready.clear()
        dead.retired = True
        assert rs.n_live == 1
        futs = [rs.request(i) for i in range(150)]
        deadline = time.perf_counter() + 15
        while rs.n_live < 2 and time.perf_counter() < deadline:
            time.sleep(0.02)
        assert rs.n_live == 2, "dead replica blocked the replacement"
        assert dead in rs.endpoints  # degraded signal stays visible
        for f in futs:
            f.result(30.0)
    finally:
        rh.close()


def test_autoscale_grows_and_shrinks():
    rh = make_rh(routing="least_loaded", autoscale=True,
                 autoscale_min_replicas=1, autoscale_max_replicas=3,
                 autoscale_high_depth=2.0, autoscale_low_depth=0.5,
                 autoscale_interval_s=0.02, autoscale_sustain=2)
    try:
        rs = rh.add_service(ServiceDescription(name="slow", factory=Slow))
        assert rs.n_replicas == 1
        futs = [rs.request(i) for i in range(150)]
        deadline = time.perf_counter() + 15
        while rs.n_replicas < 2 and time.perf_counter() < deadline:
            time.sleep(0.02)
        assert rs.n_replicas >= 2, "sustained queue depth must scale up"
        assert rs.n_replicas <= 3, "bounded by autoscale_max_replicas"
        for f in futs:
            f.result(30.0)
        deadline = time.perf_counter() + 15
        while rs.n_replicas > 1 and time.perf_counter() < deadline:
            time.sleep(0.02)
        assert rs.n_replicas == 1, "idle set must shrink to the minimum"
        # still serving after all that churn
        assert rs.request("tail").result(10.0) == "z"
    finally:
        rh.close()
