"""The CI bench-JSON validator (benchmarks/check_bench_json.py) is a
committed, tested script — these feed it canned good/bad rows so the
heredoc-era assertions can no longer rot silently inside ci.yml."""
import copy
import json
import sys
from pathlib import Path

import pytest

sys.path.insert(0, str(Path(__file__).resolve().parents[1]))
from benchmarks.check_bench_json import (CheckFailed, check_affinity,  # noqa: E402
                                         check_autoscale, check_multimodel,
                                         check_paged, check_qos,
                                         check_specdecode, main)


def affinity_rows():
    rows = []
    for pol in ("least_loaded", "prefix_affinity", "radix_affinity"):
        for stream in ("sessioned", "branching", "uniform"):
            rows.append({"policy": pol, "stream": stream, "replicas": 4,
                         "requests": 32, "req_per_s": 100.0,
                         "hit_rate": 0.0 if pol == "least_loaded" else 0.5})
    return rows


def autoscale_rows():
    rows = []
    for pol in ("queue_depth", "latency_slo"):
        for sc in ("step", "saturate"):
            rows.append({
                "autoscaler": pol, "scenario": sc, "capacity": 4,
                "final_replicas": 4 if sc == "saturate" else 3,
                "service_replicas": 4 if sc == "saturate" else 3,
                "service_cores": 4 if sc == "saturate" else 3,
                "requests": 100, "converged": True,
                "admission_denied": 5 if sc == "saturate" else 0,
                "slo_p95_ms": 120.0, "p95_ms": 80.0,
            })
    return rows


def multimodel_rows():
    return [
        {"scenario": "multi_model", "group": "alpha", "weight": 1.0,
         "hot": False, "capacity": 4, "requests": 40, "wrong_route": 0,
         "replicas_start": 2, "replicas_final": 1, "p95_ms": None,
         "slo_p95_ms": 60.0, "service_cores": 1,
         "ledger_service_cores": 4, "admission_denied": 0},
        {"scenario": "multi_model", "group": "beta", "weight": 1.0,
         "hot": True, "capacity": 4, "requests": 500, "wrong_route": 0,
         "replicas_start": 2, "replicas_final": 3, "p95_ms": 80.0,
         "slo_p95_ms": 60.0, "service_cores": 3,
         "ledger_service_cores": 4, "admission_denied": 0},
    ]


def paged_rows():
    return [
        {"scenario": "paged_compare", "engine": "monolithic",
         "decode_mode": None, "max_num_seqs": 4, "max_len": 64,
         "block_size": None, "num_blocks": None, "requests": 13,
         "peak_concurrent": 4, "prefix_reuse_hits": 9,
         "prefix_cached_tokens": 108, "shared_block_peak": 0,
         "cow_copies": 0, "decode_tokens_per_s": 2100.0,
         "free_blocks": None, "reserved_blocks": None,
         "tokens_match": True},
        {"scenario": "paged_compare", "engine": "paged_gather",
         "decode_mode": "gather", "max_num_seqs": 4, "max_len": 64,
         "block_size": 8, "num_blocks": 33, "requests": 13,
         "peak_concurrent": 12, "prefix_reuse_hits": 12,
         "prefix_cached_tokens": 144, "shared_block_peak": 12,
         "cow_copies": 12, "decode_tokens_per_s": 2000.0,
         "free_blocks": 1, "reserved_blocks": 0, "tokens_match": True},
        {"scenario": "paged_compare", "engine": "paged",
         "decode_mode": "direct", "max_num_seqs": 4, "max_len": 64,
         "block_size": 8, "num_blocks": 33, "requests": 13,
         "peak_concurrent": 12, "prefix_reuse_hits": 12,
         "prefix_cached_tokens": 144, "shared_block_peak": 12,
         "cow_copies": 12, "decode_tokens_per_s": 2300.0,
         "free_blocks": 1, "reserved_blocks": 0, "tokens_match": True},
        {"scenario": "paged_service", "group": "default", "replicas": 2,
         "requests": 8,
         "block_telemetry": {"free_blocks": 40, "total_blocks": 64,
                             "reserved_blocks": 0, "shared_blocks": 0,
                             "cow_copies": 0, "evicted_residencies": 0,
                             "reporting_replicas": 2}},
    ]


def specdecode_rows():
    base = {"scenario": "speculative", "k": 4, "target_layers": 12,
            "draft_layers": 1, "new_tokens": 40, "tokens_match": True}
    return [
        {**base, "stream": "vanilla", "decode_tokens_per_s": 80.0,
         "acceptance_rate": None, "proposed": 0, "accepted": 0,
         "enabled": None, "speedup_vs_vanilla": 1.0},
        {**base, "stream": "high_acceptance", "decode_tokens_per_s": 160.0,
         "acceptance_rate": 1.0, "proposed": 512, "accepted": 512,
         "enabled": True, "speedup_vs_vanilla": 2.0},
        {**base, "stream": "low_acceptance", "decode_tokens_per_s": 78.0,
         "acceptance_rate": 0.0, "proposed": 32, "accepted": 0,
         "enabled": False, "speedup_vs_vanilla": 0.975},
    ]


def qos_rows():
    def tenant(req, done, err=0):
        return {"requests": req, "completed": done, "errors": err}

    base = {"scenario": "qos_campaign", "decision_errors": 0,
            "agent_errors": [], "batch_tasks": 16, "batch_completed": 16,
            "high_decisions": 48}
    return [
        {**base, "phase": "baseline_high", "qos": True,
         "high_p95_s": 0.08, "low_p95_s": None, "low_decisions": 0,
         "low_throughput_per_s": None,
         "per_tenant": {"interactive": tenant(52, 52)},
         "qos_counters": {"preempted": 0, "engine_preemptions": 0,
                          "engine_preempt_resumes": 0,
                          "reporting_replicas": 1},
         "expected_tenants": ["interactive"]},
        {**base, "phase": "no_qos", "qos": False,
         "high_p95_s": 0.30, "low_p95_s": 0.25, "low_decisions": 48,
         "low_throughput_per_s": 5.0,
         "per_tenant": {"interactive": tenant(52, 52),
                        "batch": tenant(56, 56)},
         "qos_counters": None,
         "expected_tenants": ["batch", "interactive"]},
        {**base, "phase": "qos", "qos": True,
         "high_p95_s": 0.09, "low_p95_s": 0.40, "low_decisions": 48,
         "low_throughput_per_s": 4.6,
         "per_tenant": {"interactive": tenant(52, 52),
                        "batch": tenant(56, 56)},
         "qos_counters": {"preempted": 3, "engine_preemptions": 3,
                          "engine_preempt_resumes": 3,
                          "reporting_replicas": 1},
         "expected_tenants": ["batch", "interactive"]},
    ]


def test_good_rows_pass():
    check_affinity(affinity_rows())
    check_autoscale(autoscale_rows())
    check_multimodel(multimodel_rows())
    check_paged(paged_rows())
    check_specdecode(specdecode_rows())
    check_qos(qos_rows())


def test_affinity_catches_missing_policy_and_dead_hits():
    rows = [r for r in affinity_rows() if r["policy"] != "radix_affinity"]
    with pytest.raises(CheckFailed):
        check_affinity(rows)
    rows = affinity_rows()
    for r in rows:
        if r["policy"] == "prefix_affinity" and r["stream"] == "sessioned":
            r["hit_rate"] = 0.0  # sticky policy that never sticks
    with pytest.raises(CheckFailed):
        check_affinity(rows)


def test_autoscale_catches_ledger_drift_and_unpunished_saturate():
    rows = autoscale_rows()
    rows[0]["service_cores"] += 1  # claim not matching live replicas
    with pytest.raises(CheckFailed):
        check_autoscale(rows)
    rows = autoscale_rows()
    for r in rows:
        if r["scenario"] == "saturate":
            r["admission_denied"] = 0  # overload never denied: overbooked
    with pytest.raises(CheckFailed):
        check_autoscale(rows)


def test_multimodel_catches_wrong_route_and_missing_rebalance():
    rows = multimodel_rows()
    rows[1]["wrong_route"] = 1  # a request hit a wrong-model replica
    with pytest.raises(CheckFailed):
        check_multimodel(rows)
    rows = multimodel_rows()
    rows[0]["replicas_final"] = rows[0]["replicas_start"]  # idle held on
    with pytest.raises(CheckFailed):
        check_multimodel(rows)
    rows = multimodel_rows()
    rows[1]["service_cores"] = 2  # groups no longer sum to the ledger
    with pytest.raises(CheckFailed):
        check_multimodel(rows)


def test_paged_catches_mismatch_and_unshared_blocks():
    rows = paged_rows()
    rows[2]["tokens_match"] = False  # paged output diverged
    with pytest.raises(CheckFailed):
        check_paged(rows)
    rows = paged_rows()
    rows[2]["peak_concurrent"] = 4  # never admitted past the slot ceiling
    with pytest.raises(CheckFailed):
        check_paged(rows)
    rows = paged_rows()
    rows[2]["shared_block_peak"] = 0  # no physical sharing observed
    with pytest.raises(CheckFailed):
        check_paged(rows)
    rows = paged_rows()
    rows[2]["cow_copies"] = 0  # divergence never copy-on-wrote
    with pytest.raises(CheckFailed):
        check_paged(rows)
    with pytest.raises(CheckFailed):
        check_paged(paged_rows()[:2])  # an engine's row is missing


def test_paged_catches_decode_regression_and_missing_telemetry():
    rows = paged_rows()
    # the direct kernel must not be slower than the gather round-trip
    # (beyond the 10% CI-noise allowance)
    rows[2]["decode_tokens_per_s"] = 0.5 * rows[1]["decode_tokens_per_s"]
    with pytest.raises(CheckFailed):
        check_paged(rows)
    rows = paged_rows()
    rows[2]["decode_mode"] = "gather"  # direct row mislabeled
    with pytest.raises(CheckFailed):
        check_paged(rows)
    rows = paged_rows()
    rows[2]["free_blocks"] = None  # live gauge never surfaced
    with pytest.raises(CheckFailed):
        check_paged(rows)
    rows = paged_rows()
    rows[2]["reserved_blocks"] = 3  # reserve leak at quiescence
    with pytest.raises(CheckFailed):
        check_paged(rows)
    with pytest.raises(CheckFailed):
        check_paged(paged_rows()[:3])  # service telemetry rows missing
    rows = paged_rows()
    del rows[3]["block_telemetry"]["shared_blocks"]
    with pytest.raises(CheckFailed):
        check_paged(rows)
    rows = paged_rows()
    rows[3]["block_telemetry"] = None  # group aggregated nothing
    with pytest.raises(CheckFailed):
        check_paged(rows)
    rows = paged_rows()
    rows[3]["block_telemetry"]["reporting_replicas"] = 0
    with pytest.raises(CheckFailed):
        check_paged(rows)


def test_specdecode_catches_divergence_and_missing_speedup():
    rows = specdecode_rows()
    rows[1]["tokens_match"] = False  # spec transcript diverged from target
    with pytest.raises(CheckFailed):
        check_specdecode(rows)
    rows = specdecode_rows()
    rows[1]["speedup_vs_vanilla"] = 1.1  # draft cost ate the win
    with pytest.raises(CheckFailed):
        check_specdecode(rows)
    rows = specdecode_rows()
    rows[1]["acceptance_rate"] = 0.4  # identity padding broken
    with pytest.raises(CheckFailed):
        check_specdecode(rows)
    with pytest.raises(CheckFailed):
        check_specdecode(specdecode_rows()[:2])  # a stream is missing


def test_specdecode_catches_floor_and_fallback_failures():
    rows = specdecode_rows()
    rows[2]["enabled"] = True  # acceptance floor never tripped
    with pytest.raises(CheckFailed):
        check_specdecode(rows)
    rows = specdecode_rows()
    rows[2]["speedup_vs_vanilla"] = 0.6  # disabled session still dragging
    with pytest.raises(CheckFailed):
        check_specdecode(rows)
    rows = specdecode_rows()
    rows[0]["proposed"] = 16  # baseline contaminated by speculation
    with pytest.raises(CheckFailed):
        check_specdecode(rows)
    rows = specdecode_rows()
    rows[1]["enabled"] = False  # high-acceptance session shut down
    with pytest.raises(CheckFailed):
        check_specdecode(rows)


def test_qos_catches_blown_isolation_and_starvation():
    rows = qos_rows()
    rows[2]["high_p95_s"] = 2.0 * rows[0]["high_p95_s"]  # isolation lost
    with pytest.raises(CheckFailed):
        check_qos(rows)
    rows = qos_rows()
    rows[2]["low_throughput_per_s"] = 0.5 * rows[1]["low_throughput_per_s"]
    with pytest.raises(CheckFailed):
        check_qos(rows)  # fairness collapsed into starvation
    rows = qos_rows()
    rows[1]["low_decisions"] = 0  # contention never materialized
    with pytest.raises(CheckFailed):
        check_qos(rows)
    with pytest.raises(CheckFailed):
        check_qos(qos_rows()[:2])  # a phase is missing


def test_qos_catches_tenant_bleed_and_lost_work():
    rows = qos_rows()
    # the unloaded baseline saw a tenant that never ran: cross-tenant
    rows[0]["per_tenant"]["batch"] = {"requests": 1, "completed": 1,
                                      "errors": 0}
    with pytest.raises(CheckFailed):
        check_qos(rows)
    rows = qos_rows()
    rows[2]["per_tenant"]["batch"]["completed"] -= 1  # ledger leak
    with pytest.raises(CheckFailed):
        check_qos(rows)
    rows = qos_rows()
    rows[2]["batch_completed"] = 15  # HPC leg starved off the ledger
    with pytest.raises(CheckFailed):
        check_qos(rows)
    rows = qos_rows()
    rows[2]["qos_counters"]["engine_preempt_resumes"] = 2  # lost a victim
    with pytest.raises(CheckFailed):
        check_qos(rows)
    rows = qos_rows()
    rows[1]["qos_counters"] = rows[2]["qos_counters"]  # QoS-off not off
    with pytest.raises(CheckFailed):
        check_qos(rows)
    rows = qos_rows()
    rows[2]["decision_errors"] = 1  # a decision was dropped
    with pytest.raises(CheckFailed):
        check_qos(rows)


def test_main_exit_codes(tmp_path):
    good = tmp_path / "good.json"
    good.write_text(json.dumps(multimodel_rows()))
    assert main(["multimodel", str(good)]) == 0
    bad_rows = copy.deepcopy(multimodel_rows())
    bad_rows[1]["wrong_route"] = 3
    bad = tmp_path / "bad.json"
    bad.write_text(json.dumps(bad_rows))
    assert main(["multimodel", str(bad)]) == 1
