"""Multi-model replica sets: per-model routing groups, weighted capacity,
model-aware rebalancing (weighted_capacity autoscaler), per-group stats /
claims on the shared ledger, and the zero-footprint INFERENCE-task fix.
"""
import threading
import time

import pytest

from repro.core import (ExecutionPolicy, ModelGroup, ResourceDescription,
                        ResourceRequirements, Rhapsody, ServiceDescription,
                        TaskDescription, TaskKind, WeightedCapacityAutoscaler,
                        weighted_split)


class Tagged:
    """Sync RPC servicer that tags results with the model group serving
    them — wrong-model routing becomes directly observable."""

    def __init__(self, tag, delay_s: float = 0.0):
        self.tag = tag
        self.delay_s = delay_s

    def handle(self, payload):
        if self.delay_s:
            time.sleep(self.delay_s)
        return {"served_by": self.tag}


def tagged_factory(tag, delay_s: float = 0.0):
    return lambda: Tagged(tag, delay_s)


def two_model_rh(nodes=1, cores=8, replicas_a=2, replicas_b=2,
                 weight_a=1.0, weight_b=1.0, **policy_kw):
    rh = Rhapsody(ResourceDescription(nodes=nodes, cores_per_node=cores),
                  policy=ExecutionPolicy(**policy_kw), n_workers=1)
    rs = rh.add_service(ServiceDescription(
        name="llm",
        requirements=ResourceRequirements(ranks=1, cores_per_rank=1),
        models=[ModelGroup(name="a", factory=tagged_factory("a"),
                           weight=weight_a, replicas=replicas_a),
                ModelGroup(name="b", factory=tagged_factory("b"),
                           weight=weight_b, replicas=replicas_b)]))
    return rh, rs


# ---------------------------------------------------------------------------
# Weighted initial split
# ---------------------------------------------------------------------------


def test_weighted_split_proportional_with_floor():
    assert weighted_split(6, {"a": 2.0, "b": 1.0}) == {"a": 4, "b": 2}
    assert weighted_split(4, {"a": 3.0, "b": 1.0}) == {"a": 3, "b": 1}
    # never below one replica per group, even when total is too small
    assert weighted_split(2, {"a": 1, "b": 1, "c": 1}) == \
        {"a": 1, "b": 1, "c": 1}
    # zero/negative weights degrade to an even split, not a crash
    assert sum(weighted_split(4, {"a": 0.0, "b": 0.0}).values()) == 4


def test_initial_group_counts_explicit_weighted_and_mixed():
    rh = Rhapsody(ResourceDescription(nodes=1, cores_per_node=16),
                  policy=ExecutionPolicy(), n_workers=1)
    try:
        # weights split the ServiceDescription total
        rs = rh.add_service(ServiceDescription(
            name="w", replicas=6,
            requirements=ResourceRequirements(ranks=1, cores_per_rank=1),
            models=[ModelGroup(name="a", factory=tagged_factory("a"),
                               weight=2.0),
                    ModelGroup(name="b", factory=tagged_factory("b"),
                               weight=1.0)]))
        assert rs.group_counts() == {"a": 4, "b": 2}
        # explicit per-group replicas win; the rest split the remainder
        rs2 = rh.add_service(ServiceDescription(
            name="m", replicas=4,
            requirements=ResourceRequirements(ranks=1, cores_per_rank=1),
            models=[ModelGroup(name="a", factory=tagged_factory("a"),
                               replicas=1),
                    ModelGroup(name="b", factory=tagged_factory("b"))]))
        assert rs2.group_counts() == {"a": 1, "b": 3}
    finally:
        rh.close()


# ---------------------------------------------------------------------------
# Model-addressed routing
# ---------------------------------------------------------------------------


def test_requests_route_only_within_their_model_group():
    rh, rs = two_model_rh()
    try:
        for _ in range(6):
            assert rs.request({"prompt": [1, 2, 3], "model": "a"}
                              ).result(10.0)["served_by"] == "a"
            assert rs.request({"prompt": [1, 2, 3]}, model="b"
                              ).result(10.0)["served_by"] == "b"
        stats = rs.stats()
        per_group = stats["per_group"]
        assert per_group["a"]["requests"] == 6
        assert per_group["b"]["requests"] == 6
        assert per_group["a"]["completed"] == 6
        assert per_group["b"]["completed"] == 6
        # replicas are tagged and disjoint across groups
        assert set(per_group["a"]["endpoints"]).isdisjoint(
            per_group["b"]["endpoints"])
        assert all(p["group"] in ("a", "b") for p in stats["per_replica"])
    finally:
        rh.close()


def test_untagged_requests_go_to_the_first_declared_group():
    rh, rs = two_model_rh()
    try:
        assert rs.request("plain").result(10.0)["served_by"] == "a"
        assert rs.stats()["per_group"]["a"]["requests"] == 1
    finally:
        rh.close()


def test_unknown_model_raises_not_misroutes():
    rh, rs = two_model_rh()
    try:
        with pytest.raises(KeyError):
            rs.request({"prompt": [1], "model": "zzz"})
    finally:
        rh.close()


def test_inference_task_payload_model_is_honored_and_unknown_fails():
    rh, rs = two_model_rh()
    try:
        uids = rh.submit([
            TaskDescription(kind=TaskKind.INFERENCE, service="llm",
                            payload={"prompt": [1], "model": "b"},
                            task_type="inference"),
            TaskDescription(kind=TaskKind.INFERENCE, service="llm",
                            payload={"prompt": [1], "model": "nope"},
                            task_type="inference", max_retries=0),
        ])
        assert rh.wait(uids, timeout=30)
        assert rh.result(uids[0])["served_by"] == "b"
        with pytest.raises(KeyError):
            rh.result(uids[1])
    finally:
        rh.close()


def test_single_model_sets_ignore_payload_model_tags():
    """Back-compat: a payload carrying {"model": "llama-7b"} routed fine
    before model groups existed (the key just passed through) — a
    single-model set must keep serving it, not KeyError."""
    rh = Rhapsody(ResourceDescription(nodes=1, cores_per_node=8),
                  policy=ExecutionPolicy(), n_workers=1)
    try:
        rs = rh.add_service(ServiceDescription(
            name="svc", factory=tagged_factory("solo"), replicas=2))
        assert rs.request({"prompt": [1], "model": "llama-7b"}
                          ).result(10.0)["served_by"] == "solo"
        uid = rh.submit(TaskDescription(
            kind=TaskKind.INFERENCE, service="svc",
            payload={"prompt": [1], "model": "llama-7b"},
            task_type="inference"))
        assert rh.wait(uid, timeout=30)
        assert rh.result(uid[0])["served_by"] == "solo"
    finally:
        rh.close()


def test_single_model_sets_keep_the_old_surface():
    """A plain description gets one implicit 'default' group: request()
    without a model, scale_to() without a group, per_group in stats."""
    rh = Rhapsody(ResourceDescription(nodes=1, cores_per_node=8),
                  policy=ExecutionPolicy(), n_workers=1)
    try:
        rs = rh.add_service(ServiceDescription(
            name="svc", factory=tagged_factory("solo"), replicas=2))
        assert not rs.multi_model
        assert rs.request("x").result(10.0)["served_by"] == "solo"
        rs.scale_to(3)
        assert rs.n_replicas == 3
        assert rs.stats()["per_group"]["default"]["replicas"] == 3
    finally:
        rh.close()


def test_per_group_affinity_is_isolated_across_models():
    """Two models sharing the SAME prompt prefix each stick within their
    own group: sticky state is keyed per model, so affinity can never
    cross a group boundary."""
    rh, rs = two_model_rh(routing="prefix_affinity")
    try:
        for m in ("a", "b"):
            for _ in range(4):
                assert rs.request({"prompt": [7] * 40, "model": m}
                                  ).result(10.0)["served_by"] == m
        per_group = rs.stats()["per_group"]
        for m in ("a", "b"):
            assert per_group[m]["prefix_hits"] == 3  # first contact misses
            assert per_group[m]["prefix_misses"] == 1
    finally:
        rh.close()


# ---------------------------------------------------------------------------
# Per-group scaling + claims on the shared ledger
# ---------------------------------------------------------------------------


def test_scale_to_requires_group_on_multi_model_sets():
    rh, rs = two_model_rh()
    try:
        with pytest.raises(ValueError):
            rs.scale_to(3)
        with pytest.raises(KeyError):
            rs.scale_to(3, group="zzz")
        rs.scale_to(3, group="a")
        assert rs.group_counts() == {"a": 3, "b": 2}
        rs.scale_to(1, group="b")
        assert rs.group_counts() == {"a": 3, "b": 1}
    finally:
        rh.close()


def test_per_group_claims_sum_to_the_ledger_total():
    rh, rs = two_model_rh(nodes=1, cores=8)
    try:
        util = rh.utilization()["default"]
        assert util["service_cores"] == 4
        by_group = rs.claimed_by_group()
        assert by_group["a"]["cores"] + by_group["b"]["cores"] == 4
        assert util["service_models"]["a"]["cores"] == 2
        assert util["service_models"]["b"]["replicas"] == 2
        per_group = rs.stats()["per_group"]
        assert per_group["a"]["cores"] == 2 and per_group["b"]["cores"] == 2
    finally:
        rh.close()


def test_scale_groups_rebalances_inside_a_full_partition():
    """Shrink-before-grow: with ZERO free cores, moving a replica from one
    group to another must succeed on the donor's freed claim."""
    rh, rs = two_model_rh(nodes=3, cores=1, replicas_a=2, replicas_b=1)
    try:
        assert rh.utilization()["default"]["free"]["cores"] == 0
        rs.scale_groups({"a": 1, "b": 2})
        assert rs.group_counts() == {"a": 1, "b": 2}
        util = rh.utilization()["default"]
        assert util["service_cores"] == 3  # capacity-neutral move
        assert util["service_models"]["b"]["cores"] == 2
        # the moved-to group actually serves
        assert rs.request({"model": "b"}).result(10.0)["served_by"] == "b"
    finally:
        rh.close()


def test_scale_groups_targets_count_live_replicas_despite_a_corpse():
    """A replica declared dead stays visible in the set through its grace
    window (here: forever, grace < 0) — a live-count target must still
    spawn its replacement instead of silently no-opping on the corpse."""

    class CrashOnBoom:  # pumped servicer: a submit crash kills the thread
        def __init__(self, tag):
            self.tag = tag

        def submit(self, payload):
            if payload == "boom":
                raise SystemError("dead")
            return 1

        def step(self):
            return [(1, {"served_by": self.tag})]

    rh = Rhapsody(ResourceDescription(nodes=1, cores_per_node=8),
                  policy=ExecutionPolicy(restart_failed_services=False,
                                         dead_replica_grace_s=-1.0),
                  n_workers=1)
    try:
        rs = rh.add_service(ServiceDescription(
            name="llm",
            requirements=ResourceRequirements(ranks=1, cores_per_rank=1),
            models=[ModelGroup(name="a",
                               factory=lambda: CrashOnBoom("a"),
                               replicas=2),
                    ModelGroup(name="b",
                               factory=lambda: CrashOnBoom("b"),
                               replicas=1)]))
        # untagged -> first declared group ("a"): kill one of its replicas
        with pytest.raises((SystemError, RuntimeError)):
            rs.request("boom").result(10.0)
        deadline = time.perf_counter() + 10
        while time.perf_counter() < deadline and rs.n_live_group("a") > 1:
            time.sleep(0.01)
        assert rs.n_live_group("a") == 1  # corpse retired in place
        rs.scale_groups({"a": 2, "b": 1})  # live target, corpse present
        assert rs.n_live_group("a") == 2, \
            "replacement grow no-opped on the dead-in-place replica"
        assert rs.request({"prompt": [1], "model": "a"}
                          ).result(10.0)["served_by"] == "a"
    finally:
        rh.close()


# ---------------------------------------------------------------------------
# WeightedCapacityAutoscaler policy logic (unit, no threads)
# ---------------------------------------------------------------------------


class FakeGroupRS:
    """Just the group surface desired_groups() consumes."""

    multi_model = True

    def __init__(self, counts, p95_s, depths, headroom=None, weights=None,
                 slos=None):
        self._counts = dict(counts)
        self._p95 = dict(p95_s)  # group -> seconds or None
        self._depths = dict(depths)
        self._headroom = headroom
        self._weights = weights or {g: 1.0 for g in counts}
        self._slos = slos or {}
        self.denied = 0

    def group_counts(self):
        return dict(self._counts)

    def group_weight(self, g):
        return self._weights[g]

    def group_slo_ms(self, g):
        return self._slos.get(g, 100.0)

    def latency_p95(self, window_s=None, started_after=None, group=None):
        return self._p95[group]

    def mean_depth(self, group=None):
        return self._depths[group]

    def capacity_headroom(self, group=None):
        return self._headroom

    def _note_admission_denied(self, where, once_per_episode=False):
        self.denied += 1


def make_scaler(**kw):
    kw.setdefault("autoscaler", "weighted_capacity")
    kw.setdefault("autoscale_sustain_up", 1)
    kw.setdefault("autoscale_sustain_down", 1)
    kw.setdefault("autoscale_max_replicas", 4)
    kw.setdefault("autoscale_low_depth", 0.5)
    kw.setdefault("slo_p95_ms", 100.0)
    return WeightedCapacityAutoscaler(ExecutionPolicy(**kw))


def test_weighted_scaler_grows_violating_group_with_headroom():
    a = make_scaler(autoscale_max_replicas=8)
    # b violates its SLO; a is mid-band (no shrink signal)
    rs = FakeGroupRS({"a": 2, "b": 2}, {"a": 0.06, "b": 0.2},
                     {"a": 1.0, "b": 5.0}, headroom=2)
    assert a.desired_groups("s", rs) == {"a": 2, "b": 3}


def test_weighted_scaler_rebalances_at_capacity():
    a = make_scaler()
    # set at max (4) and no headroom: the idle group donates
    rs = FakeGroupRS({"a": 2, "b": 2}, {"a": None, "b": 0.2},
                     {"a": 0.0, "b": 5.0}, headroom=0)
    assert a.desired_groups("s", rs) == {"a": 1, "b": 3}


def test_weighted_scaler_donor_prefers_over_entitled_group():
    a = make_scaler(autoscale_max_replicas=5)
    # c violates; a and b both quiet, but a holds MORE than its weighted
    # share (weight 1 vs b's 2) — a donates
    rs = FakeGroupRS({"a": 2, "b": 2, "c": 1},
                     {"a": 0.06, "b": 0.06, "c": 0.3},
                     {"a": 1.0, "b": 1.0, "c": 6.0}, headroom=0,
                     weights={"a": 1.0, "b": 2.0, "c": 1.0})
    assert a.desired_groups("s", rs) == {"a": 1, "b": 2, "c": 2}


def test_weighted_scaler_no_donor_notes_denial_and_holds():
    a = make_scaler(autoscale_max_replicas=2)
    # every other group is at its 1-replica floor: nothing can donate
    rs = FakeGroupRS({"a": 1, "b": 1}, {"a": None, "b": 0.2},
                     {"a": 0.0, "b": 5.0}, headroom=0)
    assert a.desired_groups("s", rs) is None
    assert rs.denied == 1


def test_weighted_scaler_shrinks_idle_group_but_keeps_one_replica():
    a = make_scaler()
    rs = FakeGroupRS({"a": 2, "b": 1}, {"a": None, "b": 0.06},
                     {"a": 0.0, "b": 1.0}, headroom=1)
    assert a.desired_groups("s", rs) == {"a": 1, "b": 1}
    rs2 = FakeGroupRS({"a": 1, "b": 1}, {"a": None, "b": 0.06},
                      {"a": 0.0, "b": 1.0}, headroom=1)
    assert a.desired_groups("s", rs2) is None  # floor: never to zero


def test_weighted_scaler_honors_set_level_min_replicas():
    """autoscale_min_replicas bounds the SET total, same as the per-set
    policies: an idle multi-model set must not shrink below it."""
    a = make_scaler(autoscale_min_replicas=3)
    rs = FakeGroupRS({"a": 2, "b": 1}, {"a": None, "b": None},
                     {"a": 0.0, "b": 0.0}, headroom=1)
    assert a.desired_groups("s", rs) is None  # total 3 == floor: hold
    rs2 = FakeGroupRS({"a": 3, "b": 1}, {"a": None, "b": None},
                      {"a": 0.0, "b": 0.0}, headroom=1)
    assert a.desired_groups("s", rs2) == {"a": 2, "b": 1}  # 4 -> 3 only


def test_weighted_scaler_sustain_damps_single_tick_signal():
    a = make_scaler(autoscale_sustain_up=2, autoscale_max_replicas=8)
    rs = FakeGroupRS({"a": 2, "b": 2}, {"a": 0.06, "b": 0.2},
                     {"a": 1.0, "b": 5.0}, headroom=2)
    assert a.desired_groups("s", rs) is None  # 1st hot tick
    assert a.desired_groups("s", rs) == {"a": 2, "b": 3}  # 2nd: sustained
    a.note_scaled("s")
    assert a.desired_groups("s", rs) is None  # hysteresis restarted


# ---------------------------------------------------------------------------
# Rebalancing e2e + concurrency stress (clients race the autoscaler)
# ---------------------------------------------------------------------------


def test_multimodel_stress_futures_exactly_once_no_cross_group():
    """Clients on two model groups race a rebalancing weighted-capacity
    autoscaler: every future resolves exactly once with a result served
    by ITS model's replicas, and per-group stats stay conserved."""
    rh = Rhapsody(ResourceDescription(nodes=4, cores_per_node=1),
                  policy=ExecutionPolicy(
                      routing="least_loaded", autoscale=True,
                      autoscaler="weighted_capacity",
                      autoscale_min_replicas=1, autoscale_max_replicas=4,
                      autoscale_interval_s=0.02, autoscale_sustain=1,
                      slo_p95_ms=20.0, slo_window_s=0.5,
                      autoscale_low_depth=0.5),
                  n_workers=1)
    n_threads, per_thread = 4, 30
    try:
        rs = rh.add_service(ServiceDescription(
            name="llm",
            requirements=ResourceRequirements(ranks=1, cores_per_rank=1),
            models=[ModelGroup(name="a",
                               factory=tagged_factory("a", 0.004)),
                    ModelGroup(name="b",
                               factory=tagged_factory("b", 0.004))]))
        errors: list = [None] * n_threads
        results: list = [None] * n_threads

        def client(tid):
            model = "a" if tid % 2 == 0 else "b"
            got = []
            try:
                futs = [rs.request({"prompt": [tid, i], "model": model})
                        for i in range(per_thread)]
                got = [(model, f.result(30.0)) for f in futs]
            except BaseException as e:  # noqa: BLE001
                errors[tid] = e
            results[tid] = got

        threads = [threading.Thread(target=client, args=(t,))
                   for t in range(n_threads)]
        for t in threads:
            t.start()
        for t in threads:
            t.join(timeout=60)
        assert all(e is None for e in errors), errors
        # exactly once, never cross-group
        for got in results:
            assert len(got) == per_thread
            assert all(r["served_by"] == m for m, r in got)
        stats = rs.stats()
        per_group = stats["per_group"]
        total = n_threads // 2 * per_thread
        for g in ("a", "b"):
            assert per_group[g]["requests"] == total, per_group
            assert per_group[g]["completed"] + per_group[g]["errors"] == \
                total, per_group
        assert stats["requests"] == 2 * total
        # the ledger never overbooked while the scaler bounced groups
        util = rh.utilization()["default"]
        assert util["service_cores"] <= 4
    finally:
        rh.close()


# ---------------------------------------------------------------------------
# INFERENCE tasks are zero-footprint (service-charged) on the ledger
# ---------------------------------------------------------------------------


def test_inference_tasks_dispatch_on_a_fully_claimed_partition():
    """Regression (ROADMAP): replicas holding EVERY core used to starve
    their own clients — each INFERENCE task mapped 1 core just to wait on
    the service.  Inference is service-charged now: the replica's claim
    already accounts for the compute, so the task maps nothing."""
    rh = Rhapsody(ResourceDescription(nodes=1, cores_per_node=1),
                  policy=ExecutionPolicy(routing="least_loaded"),
                  n_workers=1)
    try:
        rh.add_service(ServiceDescription(
            name="svc", factory=tagged_factory("solo"), replicas=1,
            requirements=ResourceRequirements(ranks=1, cores_per_rank=1)))
        assert rh.utilization()["default"]["free"]["cores"] == 0
        uids = rh.submit([TaskDescription(
            kind=TaskKind.INFERENCE, service="svc",
            payload={"prompt": [1, 2]}, task_type="inference")
            for _ in range(4)])
        assert rh.wait(uids, timeout=30), \
            "INFERENCE tasks starved by their own service's claims"
        assert all(rh.result(u)["served_by"] == "solo" for u in uids)
        # control: a FUNCTION task still needs a core and stays blocked —
        # admission control for real compute is untouched
        fuid = rh.submit(TaskDescription(fn=lambda: 1))
        assert not rh.wait(fuid, timeout=0.3)
    finally:
        rh.close()
