"""Middleware behaviour: dependencies, resources, retries, services,
stragglers, elasticity, failure recovery."""
import threading
import time

import pytest

from repro.backends.local import PoolBackend
from repro.core import (ExecutionPolicy, ResourceDescription,
                        ResourceRequirements, Rhapsody, ServiceDescription,
                        TaskDescription, TaskKind, TaskState)
from repro.core.resources import Allocation, partition
from repro.substrate.simulation import noop


@pytest.fixture
def rh():
    r = Rhapsody(ResourceDescription(nodes=2, cores_per_node=8), n_workers=2)
    yield r
    r.close()


def test_submit_and_wait(rh):
    uids = rh.submit([TaskDescription(fn=lambda: 7) for _ in range(20)])
    assert rh.wait(uids, timeout=10)
    assert all(rh.result(u) == 7 for u in uids)


def test_dependency_ordering(rh):
    order = []
    lock = threading.Lock()

    def record(x):
        with lock:
            order.append(x)
        return x

    a = TaskDescription(fn=record, args=("a",))
    b = TaskDescription(fn=record, args=("b",), dependencies=[a.uid])
    c = TaskDescription(fn=record, args=("c",), dependencies=[b.uid])
    rh.submit([a, b, c])
    rh.wait([c.uid], timeout=10)
    assert order == ["a", "b", "c"]


def test_diamond_dependencies(rh):
    a = TaskDescription(fn=lambda: 1)
    b = TaskDescription(fn=lambda: 2, dependencies=[a.uid])
    c = TaskDescription(fn=lambda: 3, dependencies=[a.uid])
    d = TaskDescription(fn=lambda: 4, dependencies=[b.uid, c.uid])
    rh.submit([a, b, c, d])
    assert rh.wait([d.uid], timeout=10)
    assert rh.state(d.uid) == TaskState.DONE


def test_failure_and_retry(rh):
    calls = {"n": 0}

    def flaky():
        calls["n"] += 1
        if calls["n"] < 3:
            raise RuntimeError("boom")
        return "ok"

    t = TaskDescription(fn=flaky, max_retries=5)
    rh.submit(t)
    rh.wait([t.uid], timeout=10)
    assert rh.result(t.uid) == "ok"
    assert calls["n"] == 3


def test_failure_exhausts_retries(rh):
    t = TaskDescription(fn=lambda: 1 / 0, max_retries=1)
    rh.submit(t)
    rh.wait([t.uid], timeout=10)
    assert rh.state(t.uid) == TaskState.FAILED
    with pytest.raises(ZeroDivisionError):
        rh.result(t.uid)


def test_resource_mapping_respects_capacity():
    alloc = Allocation(ResourceDescription(nodes=2, cores_per_node=4))
    p1 = alloc.try_map(ranks=2, cores_per_rank=2, gpus_per_rank=0)
    assert p1 is not None
    p2 = alloc.try_map(ranks=1, cores_per_rank=4, gpus_per_rank=0)
    assert p2 is not None
    assert alloc.try_map(ranks=1, cores_per_rank=2, gpus_per_rank=0) is None
    alloc.release(p1)
    assert alloc.try_map(ranks=1, cores_per_rank=2, gpus_per_rank=0)


def test_partitioning():
    parts = partition(ResourceDescription(nodes=8, cores_per_node=4),
                      {"mpi": 6, "fn": 2})
    assert len(parts["mpi"].nodes) == 6
    assert len(parts["fn"].nodes) == 2
    assert set(parts["mpi"].nodes).isdisjoint(parts["fn"].nodes)


def test_elastic_add_and_drain():
    alloc = Allocation(ResourceDescription(nodes=1, cores_per_node=2))
    p = alloc.try_map(2, 1, 0)
    assert alloc.try_map(1, 1, 0) is None
    alloc.add_nodes(1)
    assert alloc.try_map(1, 1, 0) is not None
    assert not alloc.drain_node(0)  # busy
    alloc.release(p)
    assert alloc.drain_node(0)


def test_worker_failure_recovery():
    rh = Rhapsody(ResourceDescription(nodes=1, cores_per_node=8), n_workers=3)
    try:
        backend = rh.backends["pool"]
        gate = threading.Event()

        def slowish():
            gate.wait(2.0)
            return "done"

        uids = rh.submit([TaskDescription(fn=slowish, max_retries=2)
                          for _ in range(6)])
        stranded = backend.kill_worker(0)
        for t in stranded:  # middleware re-queues stranded work
            backend.submit(t)
        gate.set()
        assert rh.wait(uids, timeout=15)
        assert all(rh.result(u) == "done" for u in uids)
    finally:
        rh.close()


def test_straggler_duplication():
    policy = ExecutionPolicy(straggler_factor=3.0, straggler_min_samples=5)
    rh = Rhapsody(ResourceDescription(nodes=1, cores_per_node=8),
                  policy=policy, n_workers=4)
    try:
        fast = [TaskDescription(fn=lambda: time.sleep(0.01),
                                task_type="work") for _ in range(10)]
        rh.submit(fast)
        rh.wait([d.uid for d in fast], timeout=10)
        hang = threading.Event()

        def straggler():
            if not hang.is_set():
                hang.set()
                time.sleep(1.0)  # 100x median
            return "s"

        s = TaskDescription(fn=straggler, task_type="work")
        rh.submit(s)
        rh.wait([s.uid], timeout=10)
        dup_events = [e for e in rh.events.events if e[2] == "DUPLICATED"]
        assert dup_events, "straggler should have been duplicated"
        assert rh.result(s.uid) == "s"
    finally:
        rh.close()


def test_straggler_twin_preserves_full_description():
    """Regression: the straggler twin used to drop partition/service/
    payload/max_retries, so a twin could run on the wrong partition or
    lose its inference target."""
    policy = ExecutionPolicy(straggler_factor=3.0, straggler_min_samples=5)
    rh = Rhapsody(ResourceDescription(nodes=2, cores_per_node=8),
                  policy=policy, partitions={"p0": 1, "p1": 1}, n_workers=4)
    try:
        fast = [TaskDescription(fn=lambda: time.sleep(0.01),
                                task_type="work", partition="p1")
                for _ in range(10)]
        rh.submit(fast)
        rh.wait([d.uid for d in fast], timeout=10)
        hang = threading.Event()

        def straggler():
            if not hang.is_set():
                hang.set()
                time.sleep(1.0)  # 100x median
            return "s"

        s = TaskDescription(fn=straggler, task_type="work", partition="p1",
                            max_retries=3, payload={"x": 1})
        rh.submit(s)
        rh.wait([s.uid], timeout=10)
        twins = [t for t in rh.tasks.values()
                 if t.desc.metadata.get("_straggler_twin")]
        assert twins, "straggler should have been duplicated"
        twin = twins[0]
        # the twin must land on the same partition with the same retry
        # budget and payload as the original
        assert twin.desc.partition == "p1"
        assert twin.desc.max_retries == 3
        assert twin.desc.payload == {"x": 1}
        assert twin.desc.service == s.service
        assert rh.result(s.uid) == "s"
    finally:
        rh.close()


def test_service_lifecycle_and_restart():
    class Crashy:
        crashes = {"n": 0}

        def handle(self, payload):
            if payload == "crash" and Crashy.crashes["n"] == 0:
                Crashy.crashes["n"] += 1
                raise SystemError("service died")
            return ("ok", payload)

    rh = Rhapsody(ResourceDescription(nodes=1, cores_per_node=4), n_workers=1)
    try:
        ep = rh.add_service(ServiceDescription(name="svc", factory=Crashy))
        assert ep.request("hello").result(5.0) == ("ok", "hello")
        # sync-servicer errors surface per-request without killing the service
        with pytest.raises(SystemError):
            ep.request("crash").result(5.0)
        assert ep.request("again").result(5.0) == ("ok", "again")
        assert rh.services.list()["svc"] == "ready"
    finally:
        rh.close()


def test_heterogeneity_width_metric(rh):
    evs = rh.events
    evs.clear()
    evs.emit("t1", "RUNNING", "typeA")
    evs.emit("t2", "RUNNING", "typeB")
    evs.emit("t1", "DONE", "typeA")
    evs.emit("t3", "RUNNING", "typeB")
    evs.emit("t2", "DONE", "typeB")
    evs.emit("t3", "DONE", "typeB")
    assert evs.peak_hw() == 2  # typeA+typeB overlapped; B alone later


def test_preemption_safe_service_replay():
    """A crashing pumped service replays in-flight requests after restart."""
    class CrashyEngine:
        crashed = {"n": 0}

        def __init__(self):
            self.jobs = {}
            self.uid = 0

        def submit(self, payload):
            if payload == "boom" and CrashyEngine.crashed["n"] == 0:
                CrashyEngine.crashed["n"] += 1
                raise SystemError("preempted")
            self.uid += 1
            self.jobs[self.uid] = payload
            return self.uid

        def step(self):
            out = [(u, ("done", p)) for u, p in self.jobs.items()]
            self.jobs.clear()
            return out

    from repro.core.policy import ExecutionPolicy

    rh = Rhapsody(ResourceDescription(nodes=1, cores_per_node=4),
                  policy=ExecutionPolicy(restart_failed_services=True),
                  n_workers=1)
    try:
        ep = rh.add_service(ServiceDescription(name="eng",
                                               factory=CrashyEngine))
        ok = ep.request("fine")
        assert ok.result(10.0) == ("done", "fine")
        crash = ep.request("boom")  # kills instance; replayed after restart
        assert crash.result(15.0) == ("done", "boom")
        assert CrashyEngine.crashed["n"] == 1
    finally:
        rh.close()


def test_multi_backend_composition():
    """Paper's central claim: heterogeneous backends coexist in one
    allocation, each serving its partition."""
    import jax.numpy as jnp

    from repro.backends.jaxrt import JaxBackend
    from repro.backends.local import PoolBackend

    backends = {"pool": PoolBackend(n_workers=2), "jax": JaxBackend()}
    rh = Rhapsody(ResourceDescription(nodes=4, cores_per_node=8),
                  backends=backends,
                  partitions={"pool": 2, "jax": 2})
    try:
        def compute(x):
            return (x * x + 1.0).sum()

        jax_tasks = [TaskDescription(fn=compute,
                                     args=(jnp.arange(16.0) + i,),
                                     partition="jax", task_type="jax_compute")
                     for i in range(4)]
        py_tasks = [TaskDescription(fn=lambda i=i: i * 2, partition="pool",
                                    task_type="py_fn") for i in range(4)]
        uids = rh.submit(jax_tasks + py_tasks)
        assert rh.wait(uids, timeout=30)
        assert float(rh.result(jax_tasks[0].uid)) == float(
            ((jnp.arange(16.0)) ** 2 + 1.0).sum())
        assert rh.result(py_tasks[3].uid) == 6
        assert backends["jax"].stats()["executed"] == 4
        assert backends["pool"].stats()["executed"] == 4
    finally:
        rh.close()
