"""Resource-claimed replica placement + SLO-driven, capacity-bounded
autoscaling: the claim API, admission control, pluggable autoscaler
policies, latency windows, replica warm-up, partition() hardening, and the
residency gossip push channel."""
import threading
import time

import pytest

from repro.core import (Allocation, ExecutionPolicy, LatencySLOAutoscaler,
                        LatencyWindow, QueueDepthAutoscaler,
                        ResourceDescription, ResourceRequirements, Rhapsody,
                        ServiceDescription, partition)
from repro.core.autoscale import autoscaler_from_policy, percentile


class Echo:
    def handle(self, payload):
        time.sleep(0.001)
        return ("ok", payload)


def tiny_rh(cores=2, nodes=1, **policy_kw):
    """2-core default: capacity-pressure scenarios fit in one test."""
    return Rhapsody(ResourceDescription(nodes=nodes, cores_per_node=cores),
                    policy=ExecutionPolicy(**policy_kw), n_workers=1)


def events_with(rh, state):
    return [e for e in rh.events.events if e[2] == state]


# ---------------------------------------------------------------------------
# Claim API: book / release / free_capacity / fits / packing strategies
# ---------------------------------------------------------------------------


def test_claim_books_and_release_is_idempotent():
    alloc = Allocation(ResourceDescription(nodes=1, cores_per_node=4))
    c = alloc.claim(ResourceRequirements(ranks=1, cores_per_rank=3),
                    owner="svc")
    assert c is not None and c.n_cores == 3
    assert alloc.used_cores == 3
    assert alloc.free_capacity()["cores"] == 1
    denied = alloc.claim(ResourceRequirements(ranks=1, cores_per_rank=2))
    assert denied is None
    assert alloc.used_cores == 3  # failed claim rolled back fully
    assert c.release() is True
    assert c.release() is False  # second release is a no-op
    assert alloc.used_cores == 0
    assert c.n_cores == 0  # released claims report no held resources


def test_fits_counts_additional_placements():
    alloc = Allocation(ResourceDescription(nodes=2, cores_per_node=4))
    assert alloc.fits(1, 1, 0) == 8
    assert alloc.fits(1, 3, 0) == 2  # node-local: one 3-core rank per node
    assert alloc.fits(2, 2, 0) == 2
    assert alloc.fits(1, 5, 0) == 0  # no node has 5 cores
    c = alloc.claim(ResourceRequirements(ranks=1, cores_per_rank=3))
    assert alloc.fits(1, 3, 0) == 1
    c.release()
    assert alloc.fits(1, 3, 0) == 2


def test_best_fit_preserves_whole_nodes_where_first_fit_fragments():
    def seeded(strategy):
        alloc = Allocation(ResourceDescription(nodes=2, cores_per_node=4),
                           strategy=strategy)
        big = alloc.try_map(1, 4, 0)  # fills node 0
        alloc.try_map(1, 2, 0)  # node 1 -> 2 free
        alloc.release(big)  # node 0 whole again: free = {n0: 4, n1: 2}
        return alloc

    ff = seeded("first_fit")
    ff.try_map(1, 2, 0)  # lands on node 0, fragmenting the whole node
    assert ff.try_map(1, 4, 0) is None

    bf = seeded("best_fit")
    bf.try_map(1, 2, 0)  # tightest fit: node 1, leaving node 0 whole
    assert bf.try_map(1, 4, 0) is not None


def test_gpu_only_and_zero_footprint_claims_conserve_ledger():
    """Regression: cores_per_rank=0 used to slice [-0:] and silently book
    a node's ENTIRE free-core list while accounting 0."""
    alloc = Allocation(ResourceDescription(nodes=1, cores_per_node=4,
                                           gpus_per_node=2))
    c = alloc.claim(ResourceRequirements(ranks=1, cores_per_rank=0,
                                         gpus_per_rank=1))
    assert c is not None and c.n_cores == 0 and c.n_gpus == 1
    free = alloc.free_capacity()
    assert free["cores"] == 4  # cores untouched by a gpu-only claim
    assert free["gpus"] == 1
    assert alloc.fits(1, 0, 1) == 1  # fits agrees with claimability
    c.release()
    assert alloc.free_capacity()["gpus"] == 2
    # zero-footprint shape: claimable, and never bounds admission
    z = alloc.claim(ResourceRequirements(ranks=1, cores_per_rank=0,
                                         gpus_per_rank=0))
    assert z is not None and z.n_cores == 0
    assert alloc.fits(1, 0, 0) > 1_000_000
    assert alloc.free_capacity()["cores"] == 4


def test_unknown_strategy_rejected():
    with pytest.raises(ValueError):
        Allocation(ResourceDescription(), strategy="worst_fit")


# ---------------------------------------------------------------------------
# partition(): duplicates, overlap, explicit ids, "*" remainder
# ---------------------------------------------------------------------------


def test_partition_star_absorbs_leftover_nodes():
    parts = partition(ResourceDescription(nodes=8, cores_per_node=2),
                      {"mpi": 5, "*": None})
    assert sorted(parts["mpi"].nodes) == [0, 1, 2, 3, 4]
    assert sorted(parts["*"].nodes) == [5, 6, 7]  # nothing stranded


def test_partition_rejects_duplicate_names():
    with pytest.raises(ValueError, match="duplicate"):
        partition(ResourceDescription(nodes=4),
                  [("a", 1), ("a", 2)])


def test_partition_rejects_overlapping_explicit_ids():
    with pytest.raises(ValueError, match="overlap"):
        partition(ResourceDescription(nodes=4),
                  {"a": [0, 1], "b": [1, 2]})


def test_partition_rejects_out_of_range_and_repeated_ids():
    with pytest.raises(ValueError, match="outside"):
        partition(ResourceDescription(nodes=2), {"a": [0, 5]})
    with pytest.raises(ValueError, match="repeats"):
        partition(ResourceDescription(nodes=4), {"a": [1, 1]})


def test_partition_explicit_ids_and_counts_mix():
    parts = partition(ResourceDescription(nodes=6), {"pin": [4, 5],
                                                     "bulk": 3, "*": None})
    assert sorted(parts["pin"].nodes) == [4, 5]
    assert sorted(parts["bulk"].nodes) == [0, 1, 2]
    assert sorted(parts["*"].nodes) == [3]


def test_partition_empty_star_raises():
    with pytest.raises(ValueError, match="empty"):
        partition(ResourceDescription(nodes=2), {"a": 2, "*": None})


def test_partition_oversubscription_raises():
    with pytest.raises(ValueError, match="remain"):
        partition(ResourceDescription(nodes=4), {"a": 3, "b": 2})


# ---------------------------------------------------------------------------
# LatencyWindow
# ---------------------------------------------------------------------------


def test_latency_window_percentiles_and_filters():
    w = LatencyWindow()
    now = 1000.0
    for i, dt in enumerate([0.010, 0.020, 0.030, 0.500]):
        w.observe(dt, now=now + i)
    assert percentile(w.samples(now=now + 10), 0.95) == 0.500
    # wall-clock window: only the last two observations are recent
    recent = w.samples(window_s=2.5, now=now + 4)
    assert recent == [0.030, 0.500]
    # started_after: the 0.5s sample completed at t=1003 but STARTED at
    # 1002.5, so a cutoff of 1002.8 excludes it
    fresh = w.samples(started_after=now + 2.8, now=now + 10)
    assert fresh == []
    hist = w.histogram()
    assert sum(hist.values()) == 4
    assert w.count == 4


def test_percentile_nearest_rank():
    assert percentile([], 0.95) is None
    assert percentile([1.0], 0.95) == 1.0
    xs = list(range(1, 101))
    assert percentile(xs, 0.95) == 95
    assert percentile(xs, 0.50) == 50


# ---------------------------------------------------------------------------
# Autoscaler policies (unit, against a fake replica set)
# ---------------------------------------------------------------------------


class FakeRS:
    def __init__(self, n=1, depth=0.0, p95_all=None, p95_fresh=None):
        self.n_replicas = n
        self.depth = depth
        self.p95_all = p95_all  # windowed p95, any sample
        self.p95_fresh = p95_fresh  # p95 of post-action samples

    @property
    def n_live(self):
        return self.n_replicas

    def mean_depth(self):
        return self.depth

    def latency_p95(self, window_s=None, started_after=None):
        return self.p95_all if started_after is None else self.p95_fresh


def test_queue_depth_autoscaler_sustain_and_bounds():
    pol = ExecutionPolicy(autoscale_high_depth=4.0, autoscale_low_depth=0.5,
                          autoscale_sustain=2, autoscale_max_replicas=3)
    a = QueueDepthAutoscaler(pol)
    hot = FakeRS(n=1, depth=10.0)
    assert a.desired("s", hot) is None  # 1st hot tick: sustain not met
    assert a.desired("s", hot) == 2  # 2nd: grow
    hot.n_replicas = 3
    assert a.desired("s", hot) is None  # bounded by max_replicas
    assert a.desired("s", hot) is None
    cold = FakeRS(n=2, depth=0.0)
    assert a.desired("c", cold) is None
    assert a.desired("c", cold) == 1
    # a neutral tick resets the sustain counters
    a2 = QueueDepthAutoscaler(pol)
    assert a2.desired("s", hot := FakeRS(n=1, depth=10.0)) is None
    hot.depth = 1.0  # back in band
    assert a2.desired("s", hot) is None
    hot.depth = 10.0
    assert a2.desired("s", hot) is None  # counter restarted


def test_latency_slo_autoscaler_fast_up_slow_down():
    pol = ExecutionPolicy(autoscaler="latency_slo", slo_p95_ms=100.0,
                          autoscale_sustain=2, autoscale_max_replicas=4,
                          autoscale_low_depth=1.0)
    a = autoscaler_from_policy(pol)
    assert isinstance(a, LatencySLOAutoscaler)
    # breach scales up on the FIRST tick (sustain_up defaults to 1)
    rs = FakeRS(n=1, depth=5.0, p95_all=0.3, p95_fresh=0.3)
    assert a.desired("s", rs) == 2
    a.note_scaled("s")
    # stale signal only (no samples started since the action): hold
    rs.p95_fresh = None
    assert a.desired("s", rs) is None
    # comfortable p95 + shallow queues: shrink only after 3x sustain ticks
    rs = FakeRS(n=2, depth=0.1, p95_all=0.02, p95_fresh=0.02)
    for _ in range(5):
        assert a.desired("d", rs) is None
    assert a.desired("d", rs) == 1  # 6th tick (3 * autoscale_sustain)
    # fully idle set (nothing completed recently) also cools down
    idle = FakeRS(n=3, depth=0.0, p95_all=None, p95_fresh=None)
    for _ in range(5):
        assert a.desired("i", idle) is None
    assert a.desired("i", idle) == 2


def test_autoscaler_from_policy_rejects_unknown():
    with pytest.raises(ValueError, match="unknown autoscaler"):
        autoscaler_from_policy(ExecutionPolicy(autoscaler="vibes"))


# ---------------------------------------------------------------------------
# Admission control: replicas claim from the shared ledger
# ---------------------------------------------------------------------------


def test_scale_past_capacity_denied_with_event_not_exception():
    rh = tiny_rh(cores=2)
    try:
        rs = rh.add_service(ServiceDescription(name="svc", factory=Echo,
                                               replicas=1))
        assert rs.allocation is rh.allocations["default"]
        rs.scale_to(5)  # only 2 one-core replicas physically fit
        assert rs.n_replicas == 2
        stats = rs.stats()
        assert stats["admission_denied"] >= 3
        assert events_with(rh, "SCALE_DENIED"), "denial must be evented"
        assert rs.allocation.free_capacity()["cores"] == 0
        # the degraded set still serves
        assert rs.request("x").result(10.0) == ("ok", "x")
    finally:
        rh.close()


def test_utilization_reflects_live_service_claims():
    rh = tiny_rh(cores=4)
    try:
        rs = rh.add_service(ServiceDescription(name="svc", factory=Echo,
                                               replicas=3))
        util = rh.utilization()["default"]
        assert util["service_cores"] == 3
        assert util["service_replicas"] == 3
        assert util["cores"] == 3 / 4
        assert util["free"]["cores"] == 1
        rs.scale_to(1)  # shrink hands claims back
        util = rh.utilization()["default"]
        assert util["service_cores"] == 1
        assert util["service_replicas"] == 1
        assert rh.allocations["default"].used_cores == 1
        rh.services.stop("svc")  # stop releases the last claim
        assert rh.allocations["default"].used_cores == 0
        assert rh.utilization()["default"]["service_replicas"] == 0
    finally:
        rh.close()


def test_launch_degrades_to_admitted_replicas():
    rh = tiny_rh(cores=2)
    try:
        rs = rh.add_service(ServiceDescription(name="svc", factory=Echo,
                                               replicas=4))
        assert rs.n_replicas == 2  # admitted what fits, evented the rest
        assert rs.stats()["admission_denied"] == 2
    finally:
        rh.close()


def test_launch_with_no_admissible_replica_raises():
    rh = tiny_rh(cores=2)
    try:
        with pytest.raises(RuntimeError, match="no replica admitted"):
            rh.add_service(ServiceDescription(
                name="fat", factory=Echo,
                requirements=ResourceRequirements(ranks=1, cores_per_rank=8)))
        assert rh.allocations["default"].used_cores == 0
    finally:
        rh.close()


def test_tasks_and_services_share_one_ledger():
    """A service's claims reduce what tasks can map, and vice versa —
    the §III-C co-scheduling premise."""
    rh = tiny_rh(cores=3)
    try:
        rh.add_service(ServiceDescription(name="svc", factory=Echo,
                                          replicas=2))
        alloc = rh.allocations["default"]
        assert alloc.used_cores == 2
        p = alloc.try_map(1, 1, 0)  # a task takes the last core
        assert p is not None
        # now even a 1-core replica is denied
        rs = rh.get_service("svc")
        rs.scale_to(3)
        assert rs.n_replicas == 2
        assert rs.stats()["admission_denied"] >= 1
        alloc.release(p)  # task finishes -> the replica fits again
        rs.scale_to(3)
        assert rs.n_replicas == 3
    finally:
        rh.close()


def test_dead_replica_releases_its_claim_for_replacement():
    class BoomOnDemand:
        def submit(self, payload):
            if payload == "boom":
                raise SystemError("persistent fault")
            return 1

        def step(self):
            return [(1, "ok")]

    rh = tiny_rh(cores=2, restart_failed_services=True,
                 restart_backoff_s=0.01, restart_backoff_max_s=0.02,
                 restart_max_attempts=1, dead_replica_grace_s=0.1)
    try:
        rs = rh.add_service(ServiceDescription(name="svc",
                                               factory=BoomOnDemand,
                                               replicas=2))
        assert rh.allocations["default"].used_cores == 2
        with pytest.raises((SystemError, RuntimeError)):
            rs.request("boom").result(10.0)
        deadline = time.perf_counter() + 10
        while time.perf_counter() < deadline and \
                rh.allocations["default"].used_cores > 1:
            time.sleep(0.02)
        # the dead replica's cores are back on the ledger (released at
        # declare time, before the grace-period fold even runs)
        assert rh.allocations["default"].used_cores == 1
        while time.perf_counter() < deadline and rs.n_replicas > 1:
            time.sleep(0.02)
        assert rs.n_replicas == 1
        rs.scale_to(2)  # the freed core admits a substitute
        assert rs.n_replicas == 2
    finally:
        rh.close()


def test_autoscaler_bounded_by_free_capacity():
    """Sustained pressure with a full partition: the autoscaler denies the
    grow (event + stat) instead of raising or overbooking."""

    class Slow:
        def handle(self, payload):
            time.sleep(0.01)
            return "z"

    rh = tiny_rh(cores=2, routing="least_loaded", autoscale=True,
                 autoscale_min_replicas=1, autoscale_max_replicas=6,
                 autoscale_high_depth=1.0, autoscale_low_depth=0.2,
                 autoscale_interval_s=0.02, autoscale_sustain=2)
    try:
        rs = rh.add_service(ServiceDescription(name="slow", factory=Slow,
                                               replicas=1))
        futs = [rs.request(i) for i in range(200)]
        deadline = time.perf_counter() + 15
        while time.perf_counter() < deadline:
            if rs.stats()["admission_denied"] > 0 and rs.n_replicas == 2:
                break
            time.sleep(0.02)
        assert rs.n_replicas == 2, "should grow to physical capacity"
        assert rs.stats()["admission_denied"] > 0
        assert events_with(rh, "SCALE_DENIED")
        assert rh.allocations["default"].used_cores == 2
        for f in futs:
            f.result(30.0)
    finally:
        rh.close()


def test_relaunch_live_name_on_full_partition_succeeds():
    """Regression: a blue/green re-launch of a live service name must not
    be denied by the predecessor's own claims — the old set hands its
    claims back so the successor is admitted on the same capacity."""
    rh = tiny_rh(cores=2)
    try:
        old = rh.add_service(ServiceDescription(name="svc", factory=Echo,
                                                replicas=2))
        assert rh.allocations["default"].used_cores == 2
        new = rh.add_service(ServiceDescription(name="svc", factory=Echo,
                                                replicas=2))
        assert new is not old
        assert new.n_replicas == 2, "relaunch silently downsized"
        assert new.request("x").result(10.0) == ("ok", "x")
        # once the old set drains, the ledger books exactly the new claims
        deadline = time.perf_counter() + 10
        while time.perf_counter() < deadline and \
                rh.allocations["default"].used_cores != 2:
            time.sleep(0.02)
        assert rh.allocations["default"].used_cores == 2
        assert rh.utilization()["default"]["service_replicas"] == 2
    finally:
        rh.close()


def test_failed_relaunch_rebooks_the_predecessors_claims():
    """Regression: the claims lent to a failed blue/green successor must
    return to the still-serving predecessor, or admission control lapses
    for its cores."""

    class Broken:
        def __init__(self):
            raise SystemError("bad build")

    rh = tiny_rh(cores=2)
    try:
        old = rh.add_service(ServiceDescription(name="svc", factory=Echo,
                                                replicas=2))
        with pytest.raises(TimeoutError):
            rh.add_service(ServiceDescription(name="svc", factory=Broken,
                                              replicas=2,
                                              ready_timeout=1.0))
        assert rh.get_service("svc") is old  # predecessor still serving
        assert old.request("x").result(10.0) == ("ok", "x")
        assert rh.allocations["default"].used_cores == 2, \
            "predecessor left claim-less after failed relaunch"
        assert rh.utilization()["default"]["service_replicas"] == 2
    finally:
        rh.close()


# ---------------------------------------------------------------------------
# Regression: denied grow racing a scale-down must not wedge the set
# ---------------------------------------------------------------------------


def test_denied_grow_racing_scale_down_leaves_consistent_state():
    rh = tiny_rh(cores=2)
    try:
        rs = rh.add_service(ServiceDescription(name="svc", factory=Echo,
                                               replicas=2))
        for _ in range(5):
            # manager-path grow (sets _scaling) targeting past capacity,
            # racing a client scale-down
            rh.services._scale_async("svc", rs, rs.n_replicas, 4,
                                     "SCALE_UP")
            rs.scale_to(1)
            deadline = time.perf_counter() + 10
            while time.perf_counter() < deadline and rs._scaling:
                time.sleep(0.005)
            assert rs._scaling is False, "_scaling wedged after denial"
            rs.scale_to(2)
        # conserved ledger: booked cores == live replicas, nothing leaked
        assert rh.allocations["default"].used_cores == rs.n_replicas
        # no retired endpoint strands queued work in the drain list
        deadline = time.perf_counter() + 5
        while time.perf_counter() < deadline and \
                any(ep.depth() > 0 for ep in rs._retired):
            time.sleep(0.02)
        assert all(ep.depth() == 0 for ep in rs._retired)
        assert rs.request("after").result(10.0) == ("ok", "after")
    finally:
        rh.close()


# ---------------------------------------------------------------------------
# Warm-up: a new replica primes before the router may see it
# ---------------------------------------------------------------------------


def test_warmup_completes_before_replica_becomes_routable():
    order = []
    gate = threading.Event()

    class Warm:
        def __init__(self):
            order.append("init")

        def warmup(self):
            order.append("warmup")
            gate.wait(10.0)

        def handle(self, payload):
            order.append("handle")
            return "ok"

    rh = tiny_rh(cores=4, warmup=True)
    try:
        out = []
        t = threading.Thread(
            target=lambda: out.append(rh.add_service(
                ServiceDescription(name="svc", factory=Warm))),
            daemon=True)
        t.start()
        deadline = time.perf_counter() + 5
        while time.perf_counter() < deadline and "warmup" not in order:
            time.sleep(0.01)
        assert order == ["init", "warmup"]
        # still warming: the service is not registered, nothing can route
        assert "svc" not in rh.services.replica_sets
        gate.set()
        t.join(timeout=10)
        rs = out[0]
        assert rs.request("x").result(10.0) == "ok"
        assert order[:2] == ["init", "warmup"] and "handle" in order
    finally:
        gate.set()
        rh.close()


def test_warmup_runs_per_scaled_up_replica_and_is_opt_in():
    warmed = {"n": 0}

    class Warm:
        def warmup(self):
            warmed["n"] += 1

        def handle(self, payload):
            return "ok"

    rh = tiny_rh(cores=4, warmup=True)
    try:
        rs = rh.add_service(ServiceDescription(name="svc", factory=Warm))
        assert warmed["n"] == 1
        rs.scale_to(3)
        assert warmed["n"] == 3
    finally:
        rh.close()
    warmed["n"] = 0
    rh = tiny_rh(cores=4)  # warmup defaults off
    try:
        rh.add_service(ServiceDescription(name="svc", factory=Warm))
        assert warmed["n"] == 0
    finally:
        rh.close()


# ---------------------------------------------------------------------------
# Latency accounting feeds stats()
# ---------------------------------------------------------------------------


def test_stats_carry_latency_percentiles_and_histograms():
    rh = tiny_rh(cores=4)
    try:
        rs = rh.add_service(ServiceDescription(name="svc", factory=Echo,
                                               replicas=2))
        futs = [rs.request(i) for i in range(10)]
        for f in futs:
            f.result(10.0)
        stats = rs.stats()
        assert stats["latency_p95_ms"] is not None
        assert stats["latency_p95_ms"] > 0
        assert all(p["latency_p95_ms"] is not None
                   for p in stats["per_replica"]
                   if p["completed"])
        hist = stats["per_replica"][0]["latency_histogram"]
        assert sum(hist.values()) == stats["per_replica"][0]["completed"]
        assert rs.latency_p95() is not None
    finally:
        rh.close()


# ---------------------------------------------------------------------------
# Residency gossip push: eviction refreshes the router immediately
# ---------------------------------------------------------------------------


class GossipServicer:
    """Sync servicer faking an engine's residency surface."""

    def __init__(self):
        self.seqs = [tuple(range(100, 120))]
        self.listener = None

    def set_residency_listener(self, cb):
        self.listener = cb

    def residency_summary(self, max_len=128):
        return [s[:max_len] for s in self.seqs]

    def handle(self, payload):
        return "ok"


def test_eviction_push_refreshes_router_between_pull_ticks():
    rh = tiny_rh(cores=2, routing="radix_affinity",
                 residency_sync_every=0)  # periodic pull disabled
    try:
        rs = rh.add_service(ServiceDescription(name="svc",
                                               factory=GossipServicer))
        servicer = rs.instances[0].servicer
        assert servicer.listener is not None, "listener must be wired"
        rs.stats()  # one explicit pull seeds the router's residency view
        router = rh.router
        # sticky/residency state is keyed per (service, set uid, MODEL
        # group) — single-model sets live under the implicit "default"
        group = (rs.name, rs._uid, "default")

        def resident_members():
            astate = router._affinity.get(group)
            if astate is None:
                return {}
            return astate["residency"].match_lengths(tuple(range(100, 120)))

        deadline = time.perf_counter() + 5
        while time.perf_counter() < deadline and not resident_members():
            time.sleep(0.01)
        assert resident_members(), "pull should have seeded residency"
        # the engine evicts: push channel must refresh the router without
        # any stats()/route() tick happening
        servicer.seqs = []
        servicer.listener()
        deadline = time.perf_counter() + 5
        while time.perf_counter() < deadline and resident_members():
            time.sleep(0.01)
        assert not resident_members(), \
            "eviction push did not reach Router.update_residency"
    finally:
        rh.close()


def test_engine_drop_residency_fires_listener_only_on_real_drop():
    jax = pytest.importorskip("jax")  # noqa: F841
    from repro.configs import get_config
    from repro.serving.engine import make_engine_from_scratch

    cfg = get_config("rhapsody-demo").scaled(
        n_layers=1, d_model=32, n_heads=2, n_kv_heads=1, head_dim=16,
        d_ff=64, vocab=128)
    eng = make_engine_from_scratch(cfg, max_num_seqs=2, max_len=32,
                                   prefill_buckets=(16,))
    fired = []
    eng.on_residency_drop = lambda: fired.append(1)
    eng._prefix_index.insert((1, 2, 3), 0)
    eng._resident_len[0] = 3
    eng._drop_residency(0)
    assert fired == [1]
    eng._drop_residency(1)  # nothing resident on slot 1: no push
    assert fired == [1]
    # a take-for-resume (prefix-reuse HIT) must not push either: the
    # consuming request is already routed to this replica
    eng._prefix_index.insert((5, 6, 7), 1)
    eng._resident_len[1] = 3
    eng._drop_residency(1, notify=False)
    assert fired == [1]
