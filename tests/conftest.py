import os

# Smoke tests and benches must see the single real device (the dry-run sets
# its own 512-device flag as the very first import in its own process).
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax
import pytest


@pytest.fixture(scope="session")
def rng():
    return jax.random.PRNGKey(0)
