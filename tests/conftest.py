import os

# Smoke tests and benches must see the single real device (the dry-run sets
# its own 512-device flag as the very first import in its own process).
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax
import pytest

try:  # optional dev dependency (property tests importorskip it per-module)
    from hypothesis import settings
except ImportError:
    pass
else:
    # CI runs the DERANDOMIZED profile (HYPOTHESIS_PROFILE=ci in the
    # workflow): example generation is a pure function of the test, so a
    # property-test failure in a workflow log reproduces locally with
    #   HYPOTHESIS_PROFILE=ci pytest tests/test_... -k <name>
    # (or by passing the seed printed by --hypothesis-seed).  The default
    # "dev" profile keeps randomized exploration but always prints the
    # reproduction blob.
    settings.register_profile("ci", derandomize=True, print_blob=True)
    settings.register_profile("dev", print_blob=True)
    settings.load_profile(os.environ.get("HYPOTHESIS_PROFILE", "dev"))


@pytest.fixture(scope="session")
def rng():
    return jax.random.PRNGKey(0)
