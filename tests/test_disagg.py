"""Disaggregated prefill/decode serving: paged-KV export/import handoff
(token identity, slot/pool rejection, recompute-on-miss fallback),
streaming TTFT stamping, phase-pure latency windows, borrow-limited
donation, and warm-handoff rebalance ordering."""
import time

import jax
import numpy as np
import pytest

from repro.configs import get_config, get_smoke_config
from repro.core import (ExecutionPolicy, ModelGroup, ResourceDescription,
                        ResourceRequirements, Rhapsody, ServiceDescription,
                        WeightedCapacityAutoscaler)
from repro.core.request import InferenceRequest
from repro.core.service import _Future
from repro.models import get_model, nn
from repro.serving.client import LLMServicer, llm_model_group
from repro.serving.engine import InferenceEngine


def _build(name):
    if name == "dense":
        cfg = get_config("rhapsody-demo").scaled(
            n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
            d_ff=128, vocab=512)
    else:
        cfg = get_smoke_config("deepseek-moe-16b")
    api = get_model(cfg)
    params, _ = nn.split(api.init(jax.random.PRNGKey(0), cfg))
    return cfg, api, params


@pytest.fixture(scope="module")
def dense_lm():
    return _build("dense")


@pytest.fixture(scope="module")
def moe_lm():
    return _build("moe")


ENGINE_KW = dict(max_num_seqs=4, max_num_batched_tokens=256, max_len=64,
                 prefill_buckets=(16, 32), seed=0, paged=True, block_size=8)


def _prefill_export_all(pre, n, max_steps=200):
    """Pump a prefill-role paged engine until ``n`` sequences exported."""
    payloads = {}
    for _ in range(max_steps):
        if len(payloads) >= n:
            break
        pre.step_prefill_only()
        for uid in pre.exportable():
            payloads[uid] = pre.export_sequence(uid)
    assert len(payloads) == n, "prefill engine never exported every seq"
    return payloads


# ---------------------------------------------------------------------------
# Engine-level export/import round trip
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("family", ["dense", "moe"])
def test_export_import_round_trip_token_identity(family, dense_lm, moe_lm):
    """Greedy outputs survive the prefill->decode migration bit-for-bit:
    prefill on engine A, export, import into engine B, finish there —
    token-identical to the same prompts decoded on one unified engine."""
    cfg, api, params = dense_lm if family == "dense" else moe_lm
    rng = np.random.RandomState(0)
    prompts = [list(map(int, rng.randint(1, cfg.vocab, size=n)))
               for n in (5, 12, 23)]
    pre = InferenceEngine(cfg, params, **ENGINE_KW)
    dec = InferenceEngine(cfg, params, **ENGINE_KW)
    uids = [pre.submit(p, max_new_tokens=6) for p in prompts]
    payloads = _prefill_export_all(pre, len(prompts))
    assert not pre.running  # exports retire on the prefill side
    moved = {}
    for uid, pay in payloads.items():
        nuid = dec.import_sequence(pay)
        assert nuid is not None
        moved[uid] = (nuid, pay)
    done = dec.run()
    ref = InferenceEngine(cfg, params, **ENGINE_KW)
    ref_uids = [ref.submit(p, max_new_tokens=6) for p in prompts]
    ref_done = ref.run()
    for uid, ruid in zip(uids, ref_uids):
        nuid, pay = moved[uid]
        out = done[nuid].output
        assert out == ref_done[ruid].output
        # the prefill-side tokens are the prefix of the final output and
        # the original submit stamp survives the migration
        assert out[:len(pay["output"])] == pay["output"]
        assert done[nuid].submitted_at == pay["submitted_at"]


def test_import_refused_on_full_slots_then_lands_elsewhere(dense_lm):
    """A decode engine at its max_running cap refuses the import (None,
    no reservation leak); the untouched payload still imports cleanly
    into a roomier engine and finishes token-identically."""
    cfg, api, params = dense_lm
    pre = InferenceEngine(cfg, params, **ENGINE_KW)
    tight = InferenceEngine(cfg, params, **ENGINE_KW, max_running=1)
    tight.submit([3] * 10, max_new_tokens=30)
    tight.step()  # occupant admitted: running == max_num_seqs
    prompt = [5, 6, 7, 8, 9]
    pre.submit(prompt, max_new_tokens=4)
    pay = list(_prefill_export_all(pre, 1).values())[0]
    free0, res0 = tight.pool.n_free, tight._reserved
    assert tight.import_sequence(pay) is None
    assert (tight.pool.n_free, tight._reserved) == (free0, res0)
    roomy = InferenceEngine(cfg, params, **ENGINE_KW)
    nuid = roomy.import_sequence(pay)
    assert nuid is not None
    out = roomy.run()[nuid].output
    ref = InferenceEngine(cfg, params, **ENGINE_KW)
    ruid = ref.submit(prompt, max_new_tokens=4)
    assert out == ref.run()[ruid].output


def test_import_refused_on_exhausted_block_pool(dense_lm):
    """Admission-gated import: with the whole pool reserved by a live
    occupant, import_sequence refuses instead of over-committing —
    and leaves the free/reserved gauges untouched."""
    cfg, api, params = dense_lm
    pre = InferenceEngine(cfg, params, **ENGINE_KW)
    # num_blocks=9: one blank + 8 usable == exactly one max_len sequence
    dec = InferenceEngine(cfg, params, **{**ENGINE_KW, "num_blocks": 9})
    dec.submit([3] * 30, max_new_tokens=30)  # reserves all 8 blocks
    dec.step()
    pre.submit([7, 8, 9, 10, 11], max_new_tokens=4)
    pay = list(_prefill_export_all(pre, 1).values())[0]
    free0, res0 = dec.pool.n_free, dec._reserved
    assert dec.import_sequence(pay) is None
    assert (dec.pool.n_free, dec._reserved) == (free0, res0)


# ---------------------------------------------------------------------------
# Servicer-level handoff: counters and recompute fallback
# ---------------------------------------------------------------------------

SV_KW = dict(max_num_seqs=4, max_num_batched_tokens=256, max_len=64,
             paged=True, block_size=8, num_blocks=64,
             prefill_buckets=(16, 32))


def test_servicer_recompute_fallback_token_identity(dense_lm):
    """Every handoff denied by a block-exhausted decode pool degrades to
    a recompute on the decode replica — counted, flagged in the result,
    and still token-identical to a unified reference engine."""
    cfg, api, params = dense_lm
    pre = LLMServicer(cfg, params, phase="prefill", **SV_KW)
    dec = LLMServicer(cfg, params, phase="decode",
                      **{**SV_KW, "max_num_batched_tokens": 64,
                         "num_blocks": 9})
    dec.engine.submit([3] * 30, max_new_tokens=30)  # pins the pool
    dec.engine.step()
    prompts = [[7, 8, 9, 10, 11], [1, 2, 3], [4] * 9]
    for p in prompts:
        pre.submit({"prompt": p, "max_new_tokens": 5})
    handoffs = []
    for _ in range(200):
        if len(handoffs) == len(prompts):
            break
        for _uid, res in pre.step():
            assert res.get("role") == "prefill"
            assert res.get("handoff_export") is not None
            handoffs.append(res["handoff_export"])
    assert pre.handoff_stats() == {"role": "prefill",
                                   "exports": len(prompts),
                                   "imports": 0, "recomputes": 0}
    new_uids = [dec.submit({"prompt": list(pay["prompt"])},
                           envelope=InferenceRequest(
                               payload={"prompt": list(pay["prompt"])},
                               handoff=pay))
                for pay in handoffs]
    hs = dec.handoff_stats()
    assert hs["imports"] == 0 and hs["recomputes"] == len(prompts)
    results = {}
    for _ in range(2000):
        if len(results) == len(prompts) + 1:  # + the occupant
            break
        for uid, res in dec.step():
            results[uid] = res
    ref = InferenceEngine(cfg, params, **ENGINE_KW)
    ref_uids = [ref.submit(p, max_new_tokens=5) for p in prompts]
    ref_done = ref.run()
    for pay, nuid, ruid in zip(handoffs, new_uids, ref_uids):
        res = results[nuid]
        assert res.get("handoff") is True and res.get("recompute") is True
        assert res.get("role") == "decode"
        assert res["tokens"] == ref_done[ruid].output
        # end-to-end latency still spans the whole migration
        assert res["latency_s"] >= 0 and res["ttft_s"] is not None


# ---------------------------------------------------------------------------
# generate_stream / ttft_s
# ---------------------------------------------------------------------------


def test_generate_stream_tokens_then_final(dense_lm):
    """Tokens stream in generation order; the final event repeats them
    with the step()-shaped latency keys, matching a non-streamed run."""
    cfg, api, params = dense_lm
    sv = LLMServicer(cfg, params, **SV_KW)
    events = list(sv.generate_stream({"prompt": [5, 6, 7],
                                      "max_new_tokens": 6}))
    toks = [e["token"] for e in events[:-1]]
    final = events[-1]
    assert final["done"] is True
    assert final["tokens"] == toks and len(toks) == 6
    assert final["ttft_s"] is not None and final["ttft_s"] > 0
    assert final["itl_s"] is not None and final["latency_s"] > 0
    ref = InferenceEngine(cfg, params, **ENGINE_KW)
    u = ref.submit([5, 6, 7], max_new_tokens=6)
    assert ref.run()[u].output == toks


def test_generate_stream_empty_generation_has_no_ttft(dense_lm):
    """max_new_tokens<=0 yields only the final event with ttft_s None —
    an empty generation has no first token to stamp."""
    cfg, api, params = dense_lm
    sv = LLMServicer(cfg, params, **SV_KW)
    events = list(sv.generate_stream({"prompt": [5, 6],
                                      "max_new_tokens": 0}))
    assert len(events) == 1
    assert events[0]["done"] is True
    assert events[0]["tokens"] == [] and events[0]["ttft_s"] is None


def test_generate_stream_resumed_sequence_stamps_ttft(dense_lm):
    """A follow-up turn resuming resident prefix KV skips prefill
    entirely — its first token must still stamp ttft_s (the stamp lives
    on first-token emission, not on the prefill path)."""
    cfg, api, params = dense_lm
    sv = LLMServicer(cfg, params, **SV_KW)
    prompt = [11, 12, 13, 14, 15, 16]
    out1 = list(sv.generate_stream({"prompt": prompt,
                                    "max_new_tokens": 4}))[-1]
    prompt2 = prompt + out1["tokens"] + [9]
    out2 = list(sv.generate_stream({"prompt": prompt2,
                                    "max_new_tokens": 4}))[-1]
    assert sv.engine.stats.prefix_reuse_hits >= 1
    assert out2["ttft_s"] is not None and out2["ttft_s"] > 0
    ref = InferenceEngine(cfg, params, **ENGINE_KW)
    u = ref.submit(prompt2, max_new_tokens=4)
    assert out2["tokens"] == ref.run()[u].output


def test_generate_stream_refused_on_prefill_replicas(dense_lm):
    cfg, api, params = dense_lm
    sv = LLMServicer(cfg, params, phase="prefill", **SV_KW)
    with pytest.raises(ValueError, match="prefill"):
        next(sv.generate_stream({"prompt": [1, 2], "max_new_tokens": 2}))


# ---------------------------------------------------------------------------
# _Future.add_done_callback
# ---------------------------------------------------------------------------


def test_future_add_done_callback_orders_and_errors():
    f = _Future()
    seen = []
    f.add_done_callback(lambda fut: seen.append(fut.result(0)))
    f.add_done_callback(lambda fut: 1 / 0)  # callback errors swallowed
    f.set_result(42)
    assert seen == [42]
    f.add_done_callback(lambda fut: seen.append("late"))
    assert seen == [42, "late"]  # already-done future fires immediately
    g = _Future()
    errs = []

    def chain(fut):
        try:
            fut.result(0)
        except RuntimeError as e:
            errs.append(str(e))

    g.add_done_callback(chain)
    g.set_error(RuntimeError("boom"))
    assert errs == ["boom"]


# ---------------------------------------------------------------------------
# WeightedCapacityAutoscaler: borrow_limit floor + per-phase directions
# ---------------------------------------------------------------------------


class FakeGroupRS:
    """Just the group surface desired_groups() consumes, plus the
    optional borrow/role hooks the scaler probes with getattr."""

    multi_model = True

    def __init__(self, counts, p95_s, depths, headroom=None, weights=None,
                 borrows=None, roles=None):
        self._counts = dict(counts)
        self._p95 = dict(p95_s)  # group (or (group, phase)) -> seconds
        self._depths = dict(depths)
        self._headroom = headroom
        self._weights = weights or {g: 1.0 for g in counts}
        self._borrows = borrows
        self._roles = roles
        self.denied = 0
        self.phase_calls = []
        if borrows is not None:
            self.group_borrow_limit = lambda g: self._borrows.get(g)
        if roles is not None:
            self.group_role = lambda g: self._roles.get(g, "serve")

    def group_counts(self):
        return dict(self._counts)

    def group_weight(self, g):
        return self._weights[g]

    def group_slo_ms(self, g):
        return 100.0

    def latency_p95(self, window_s=None, started_after=None, group=None,
                    phase=None):
        self.phase_calls.append((group, phase))
        key = (group, phase) if (group, phase) in self._p95 else group
        return self._p95[key]

    def mean_depth(self, group=None):
        return self._depths[group]

    def capacity_headroom(self, group=None):
        return self._headroom

    def _note_admission_denied(self, where, once_per_episode=False):
        self.denied += 1


def _scaler(**kw):
    kw.setdefault("autoscaler", "weighted_capacity")
    kw.setdefault("autoscale_sustain_up", 1)
    kw.setdefault("autoscale_sustain_down", 1)
    kw.setdefault("autoscale_max_replicas", 4)
    kw.setdefault("autoscale_low_depth", 0.5)
    kw.setdefault("slo_p95_ms", 100.0)
    return WeightedCapacityAutoscaler(ExecutionPolicy(**kw))


def test_borrow_limit_floors_the_donor():
    """borrow_limit=0 pins the donor at its weight-anchored entitlement
    (ceil(2.0) - 0 = 2): the burst group cannot borrow, the scaler holds
    and notes the denial; borrow_limit=1 releases one replica."""
    a = _scaler()
    # "a" is mid-band (no idle-shrink signal of its own): the ONLY way
    # it loses a replica is being picked as b's donor
    rs = FakeGroupRS({"a": 2, "b": 2}, {"a": 0.06, "b": 0.2},
                     {"a": 1.0, "b": 5.0}, headroom=0,
                     borrows={"a": 0, "b": None})
    assert a.desired_groups("s", rs) is None
    assert rs.denied == 1
    a2 = _scaler()
    rs2 = FakeGroupRS({"a": 2, "b": 2}, {"a": 0.06, "b": 0.2},
                      {"a": 1.0, "b": 5.0}, headroom=0,
                      borrows={"a": 1, "b": None})
    assert a2.desired_groups("s", rs2) == {"a": 1, "b": 3}


def test_per_phase_directions_grow_prefill_on_ttft_violation():
    """A prefill-role group is judged on its TTFT window and a decode
    group on its ITL window: TTFT breach grows prefill at the quiet
    decode group's expense, even though no unified p95 is hot."""
    a = _scaler()
    rs = FakeGroupRS({"pre": 1, "dec": 2},
                     {("pre", "ttft"): 0.3, ("dec", "itl"): None},
                     {"pre": 4.0, "dec": 0.0}, headroom=0,
                     roles={"pre": "prefill", "dec": "decode"})
    assert a.desired_groups("s", rs) == {"pre": 2, "dec": 1}
    assert ("pre", "ttft") in rs.phase_calls
    assert ("dec", "itl") in rs.phase_calls


# ---------------------------------------------------------------------------
# scale_groups warm-handoff ordering (grow-first with headroom)
# ---------------------------------------------------------------------------


class _Tagged:
    def __init__(self, tag):
        self.tag = tag

    def handle(self, payload):
        return {"served_by": self.tag}


def _two_group_rh(nodes, replicas_a=2, replicas_b=1):
    rh = Rhapsody(ResourceDescription(nodes=nodes, cores_per_node=1),
                  policy=ExecutionPolicy(), n_workers=1)
    rs = rh.add_service(ServiceDescription(
        name="llm",
        requirements=ResourceRequirements(ranks=1, cores_per_rank=1),
        models=[ModelGroup(name="a", factory=lambda: _Tagged("a"),
                           replicas=replicas_a, borrow_limit=1),
                ModelGroup(name="b", factory=lambda: _Tagged("b"),
                           replicas=replicas_b)]))
    return rh, rs


def _record_scale_order(rs):
    order = []
    orig = rs._scale_group_locked

    def wrapped(g, n, t):
        order.append(g)
        return orig(g, n, t)

    rs._scale_group_locked = wrapped
    return order


def test_scale_groups_grow_first_when_headroom_admits_the_grow():
    """Warm handoff: one free core covers the single grow, so the
    growing group spawns (and warms) BEFORE the donor drains."""
    rh, rs = _two_group_rh(nodes=4)  # 3 claimed, 1 free core
    try:
        assert rs.group_borrow_limit("a") == 1  # ModelGroup passthrough
        assert rs.group_borrow_limit("b") is None
        order = _record_scale_order(rs)
        rs.scale_groups({"a": 1, "b": 2})
        assert order == ["b", "a"]  # grow first, then the shrink
        assert rs.group_counts() == {"a": 1, "b": 2}
        assert rs.request({"model": "b"}).result(10.0)["served_by"] == "b"
    finally:
        rh.close()


def test_scale_groups_shrink_first_in_a_full_partition():
    """Zero free cores: the grow could not be admitted before the donor
    releases its claim, so the order stays shrink-first."""
    rh, rs = _two_group_rh(nodes=3)  # 3 claimed, 0 free
    try:
        order = _record_scale_order(rs)
        rs.scale_groups({"a": 1, "b": 2})
        assert order == ["a", "b"]  # shrink frees the claim the grow uses
        assert rs.group_counts() == {"a": 1, "b": 2}
    finally:
        rh.close()


# ---------------------------------------------------------------------------
# End-to-end: disagg pair behind one ReplicaSet, phase-pure stats
# ---------------------------------------------------------------------------


def test_disagg_service_handoff_and_phase_pure_stats(dense_lm):
    """Prompts addressed to the prefill group come back decoded by the
    decode group, token-identical to a unified engine; TTFT samples land
    only in the prefill group's window and ITL only in the decode
    group's, and the handoff counters reconcile."""
    cfg, api, params = dense_lm
    engine_kw = dict(max_num_seqs=4, max_len=64, paged=True, block_size=8,
                     num_blocks=64, prefill_buckets=(16, 32))
    rh = Rhapsody(ResourceDescription(nodes=1, cores_per_node=8),
                  policy=ExecutionPolicy(routing="radix_affinity"),
                  n_workers=1)
    try:
        rs = rh.add_service(ServiceDescription(
            name="llm", replicas=2,
            requirements=ResourceRequirements(ranks=1, cores_per_rank=1),
            models=[
                llm_model_group("pre", cfg, params, role="prefill",
                                paired_with="dec", replicas=1,
                                max_num_batched_tokens=256, **engine_kw),
                llm_model_group("dec", cfg, params, role="decode",
                                replicas=1, max_num_batched_tokens=64,
                                **engine_kw),
            ]))
        assert rs.group_role("pre") == "prefill"
        rng = np.random.RandomState(0)
        prompts = [list(map(int, rng.randint(1, cfg.vocab, size=n)))
                   for n in (20, 12, 33)]
        futs = [rs.request({"prompt": p, "max_new_tokens": 6,
                            "model": "pre"}) for p in prompts]
        results = [f.result(60.0) for f in futs]
        ref = InferenceEngine(cfg, params, max_num_batched_tokens=256,
                              **engine_kw)
        ref_uids = [ref.submit(p, max_new_tokens=6) for p in prompts]
        ref_done = ref.run()
        for res, ruid in zip(results, ref_uids):
            assert res["tokens"] == ref_done[ruid].output
            assert res.get("handoff") is True
            assert res.get("role") == "decode"
            assert res["ttft_s"] is not None and res["itl_s"] is not None
        deadline = time.perf_counter() + 10
        while time.perf_counter() < deadline:
            tot = rs.handoff_totals()
            if tot["imports"] + tot["recomputes"] >= len(prompts):
                break
            time.sleep(0.05)
        tot = rs.handoff_totals()
        assert tot["exports"] == len(prompts)
        assert tot["imports"] + tot["recomputes"] == len(prompts)
        pg = rs.stats()["per_group"]
        assert pg["pre"]["role"] == "prefill"
        assert pg["pre"]["handoff_exports"] == len(prompts)
        assert pg["pre"]["ttft_p95_ms"] is not None
        assert pg["pre"]["itl_p95_ms"] is None  # never decodes
        assert pg["dec"]["itl_p95_ms"] is not None
        assert pg["dec"]["ttft_p95_ms"] is None  # phase-pure windows
        assert rs.latency_p95(group="pre", phase="ttft") is not None
        with pytest.raises(ValueError):
            rs.latency_p95(group="pre", phase="nope")
    finally:
        rh.close()
