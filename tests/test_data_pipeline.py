"""Data pipeline: determinism, sharding, checkpoint-resume."""
import numpy as np

from repro.substrate.data import DataConfig, DataPipeline, SyntheticCorpus


def test_deterministic():
    a = DataPipeline(DataConfig(seed=7))
    b = DataPipeline(DataConfig(seed=7))
    for _ in range(3):
        ba, bb = a.next_batch(), b.next_batch()
        np.testing.assert_array_equal(np.asarray(ba["tokens"]),
                                      np.asarray(bb["tokens"]))


def test_targets_are_shifted_tokens():
    p = DataPipeline(DataConfig())
    b = p.next_batch()
    np.testing.assert_array_equal(np.asarray(b["tokens"])[:, 1:],
                                  np.asarray(b["targets"])[:, :-1])


def test_dp_sharding_disjoint_and_complete():
    cfg = DataConfig(global_batch=8, dp_size=4)
    full = DataPipeline(DataConfig(global_batch=8))
    shards = [DataPipeline(DataConfig(global_batch=8, dp_size=4, dp_rank=r))
              for r in range(4)]
    fb = np.asarray(full.next_batch()["tokens"])
    got = np.concatenate([np.asarray(s.next_batch()["tokens"])
                          for s in shards])
    np.testing.assert_array_equal(fb, got)


def test_checkpoint_resume_cursor():
    a = DataPipeline(DataConfig(seed=3))
    for _ in range(5):
        a.next_batch()
    saved = a.state()
    want = np.asarray(a.next_batch()["tokens"])
    b = DataPipeline(DataConfig(seed=3))
    b.restore(saved)
    got = np.asarray(b.next_batch()["tokens"])
    np.testing.assert_array_equal(want, got)


def test_corpus_has_learnable_structure():
    c = SyntheticCorpus(DataConfig(seed=0))
    # motifs repeat within documents -> corpus is compressible
    toks = c.tokens[: 384 * 4]
    _, counts = np.unique(toks, return_counts=True)
    assert counts.max() >= 8  # repeated motifs present
