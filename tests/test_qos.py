"""Multi-tenant QoS: the InferenceRequest envelope adapter, per-tenant
token-bucket admission, weighted-fair queueing with decode preemption
(token-identical resume), per-tenant/per-class service accounting, and the
protected-class autoscaler signal."""
import time

import jax
import pytest

from repro.configs import get_config
from repro.core import (DEFAULT_CLASS_WEIGHTS, AdmissionDenied,
                        ExecutionPolicy, InferenceRequest,
                        ResourceDescription, Rhapsody, RouteContext,
                        ServiceDescription)
from repro.core.router import make_router, router_from_policy
from repro.models import get_model, nn
from repro.serving.engine import InferenceEngine
from repro.serving.qos import WFQScheduler


# ---------------------------------------------------------------------------
# InferenceRequest.wrap: the one normalization adapter
# ---------------------------------------------------------------------------


def test_wrap_lifts_model_tag_and_keeps_it_in_payload():
    env = InferenceRequest.wrap({"prompt": [1, 2], "model": "chat"})
    assert env.model == "chat"
    assert env.payload["model"] == "chat"  # single-model servicers saw it


def test_wrap_lifts_qos_meta_keys_off_servicer_kwargs():
    env = InferenceRequest.wrap({"prompt": [1]},
                                meta={"tenant": "acme", "priority": "high",
                                      "deadline_s": 1.5, "echo": True})
    assert (env.tenant, env.priority, env.deadline_s) == ("acme", "high", 1.5)
    assert env.servicer_kwargs() == {"echo": True}  # lifted keys are gone


def test_wrap_explicit_kwargs_win_over_meta_and_payload():
    env = InferenceRequest.wrap({"prompt": [1], "model": "a"},
                                model="b", tenant="t2", priority="low",
                                meta={"tenant": "t1", "priority": "high"})
    assert env.model == "b"
    assert (env.tenant, env.priority) == ("t2", "low")


def test_wrap_existing_envelope_is_merged_not_rebuilt():
    env = InferenceRequest(payload=[1, 2], tenant="a")
    t0 = env.submitted_at
    out = InferenceRequest.wrap(env, priority="high", meta={"k": 1})
    assert out is env
    assert out.submitted_at == t0  # latency stamp survives re-wrapping
    assert out.priority == "high" and out.meta["k"] == 1


def test_envelope_defaults_priority_and_stamps_submitted_at():
    env = InferenceRequest(payload=[1])
    assert env.priority == "normal"
    assert env.submitted_at is not None
    assert env.servicer_kwargs() == {}
    env2 = InferenceRequest(payload=[1], meta={"_private": 1, "pub": 2})
    assert env2.servicer_kwargs() == {"pub": 2}


# ---------------------------------------------------------------------------
# TenantThrottle: token-bucket admission at the router
# ---------------------------------------------------------------------------


def _env(tenant=None, cost_tokens=1):
    return InferenceRequest(payload=[0] * cost_tokens, tenant=tenant)


def test_unarmed_router_admits_everything():
    r = make_router("round_robin")
    assert r.admit(_env("anyone"), cost=1e9)
    assert r.admission_denials() == {}


def test_token_bucket_rate_limits_and_refills():
    now = [0.0]
    r = make_router("round_robin")
    r.configure_tenants(rate=10.0, burst_s=1.0, clock=lambda: now[0])
    # bucket depth = 10: ten unit-cost admits, then denial
    assert all(r.admit(_env("t"), cost=1.0) for _ in range(10))
    assert not r.admit(_env("t"), cost=1.0)
    now[0] += 0.5  # refills 5 tokens
    assert all(r.admit(_env("t"), cost=1.0) for _ in range(5))
    assert not r.admit(_env("t"), cost=1.0)
    assert r.admission_denials() == {"t": 2}


def test_tenant_overrides_and_hard_off_switch():
    now = [0.0]
    r = make_router("round_robin")
    r.configure_tenants(rate=None, rates={"slow": 1.0, "off": 0.0},
                        burst_s=1.0, clock=lambda: now[0])
    assert r.admit(_env("unlisted"), cost=1e6)  # default None: unlimited
    assert r.admit(_env(None), cost=1e6)  # untenanted: never throttled
    assert r.admit(_env("slow"), cost=1.0)
    assert not r.admit(_env("slow"), cost=1.0)
    assert not r.admit(_env("off"), cost=0.001)  # rate<=0 denies all
    assert r.admission_denials() == {"slow": 1, "off": 1}


def test_oversized_request_admits_at_full_bucket_not_never():
    """cost > bucket depth is clamped: a single huge request drains the
    full bucket instead of starving its tenant forever."""
    now = [0.0]
    r = make_router("round_robin")
    r.configure_tenants(rate=10.0, burst_s=1.0, clock=lambda: now[0])
    assert r.admit(_env("t"), cost=500.0)  # clamped to depth 10
    assert not r.admit(_env("t"), cost=1.0)  # bucket drained
    now[0] += 1.0
    assert r.admit(_env("t"), cost=500.0)  # refilled: admits again


def test_router_from_policy_arms_tenant_throttle():
    pol = ExecutionPolicy(tenant_rate=5.0, tenant_burst_s=1.0,
                          tenant_rates={"vip": None})
    r = router_from_policy(pol)
    assert r._throttle is not None
    assert r._throttle.rate_for("anyone") == 5.0
    assert r._throttle.rate_for("vip") is None
    assert router_from_policy(ExecutionPolicy())._throttle is None


# ---------------------------------------------------------------------------
# WFQScheduler: virtual-finish ordering (stub engine)
# ---------------------------------------------------------------------------


class _Req:
    def __init__(self, uid, qos_class, tenant="t", n=10):
        self.uid = uid
        self.qos_class = qos_class
        self.tenant = tenant
        self.prompt = [0] * n
        self.max_new_tokens = 0
        self.output = []
        self.done = False
        self.pending_tokens = []
        self.truncated = False


class _StubEngine:
    paged = False

    def __init__(self):
        self.queue = []
        self.running = {}


def test_wfq_orders_heavier_classes_ahead_under_contention():
    sched = WFQScheduler()
    eng = _StubEngine()
    reqs = [_Req(1, "low"), _Req(2, "high"), _Req(3, "normal"),
            _Req(4, "high")]
    for r in reqs:
        eng.queue.append(r)
        sched.on_submit(r)
    sched.schedule(eng)
    # equal cost 10 across weights 4/2/1: high finishes at 2.5, its
    # SECOND request at 5.0 (ties normal's first, stable order holds),
    # and both still beat low's first at 10.0
    assert [r.uid for r in eng.queue] == [2, 3, 4, 1]


def test_wfq_idle_flow_banks_no_credit():
    """A flow that slept does not return with an ancient virtual clock:
    its start time is pulled up to the global virtual time (the WFQ
    start-time rule), so sleeping earns no retroactive share."""
    sched = WFQScheduler()
    eng = _StubEngine()
    # the busy flow advances the global virtual clock
    for uid in range(1, 8):
        r = _Req(uid, "normal", tenant="busy", n=100)
        eng.queue.append(r)
        sched.on_submit(r)
    for _ in range(7):  # each schedule() pass advances V to the head
        sched.schedule(eng)
        sched.on_finish(eng.queue.pop(0).uid)
    v = sched.stats()["virtual_clock"]
    assert v > 0
    # a long-idle flow submits: its stamp starts AT the global clock,
    # not at its own zero — cost/weight past V, not past 0
    idle = _Req(100, "low", tenant="idle", n=10)
    eng.queue.append(idle)
    sched.on_submit(idle)
    assert sched._finish[100] == pytest.approx(v + 10 / 1.0)


def test_wfq_weights_fall_back_for_unknown_classes():
    sched = WFQScheduler()
    assert sched.weight_of("high") == DEFAULT_CLASS_WEIGHTS["high"]
    assert sched.weight_of("no-such-class") == 1.0


# ---------------------------------------------------------------------------
# Engine preemption: retire to residency, resume token-identically
# ---------------------------------------------------------------------------

ENGINE_KW = dict(max_num_seqs=4, max_num_batched_tokens=64, max_len=64,
                 paged=True, block_size=8, num_blocks=32,
                 prefill_buckets=(16, 32))


@pytest.fixture(scope="module")
def dense_lm():
    cfg = get_config("rhapsody-demo").scaled(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, vocab=512)
    api = get_model(cfg)
    params, _ = nn.split(api.init(jax.random.PRNGKey(0), cfg))
    return cfg, api, params


def test_preempt_resume_token_identity(dense_lm):
    cfg, api, params = dense_lm
    prompts = [[5] * 12, [9] * 7]
    ref = InferenceEngine(cfg, params, **ENGINE_KW)
    ref_uids = [ref.submit(p, max_new_tokens=10) for p in prompts]
    ref_done = ref.run()

    eng = InferenceEngine(cfg, params, **ENGINE_KW)
    uids = [eng.submit(p, max_new_tokens=10, tenant="a", qos_class="low")
            for p in prompts]
    # decode until the first request has emitted a few tokens, then
    # preempt it mid-generation (KV retires to residency)
    for _ in range(100):
        eng.step()
        eng.collect_finished()
        req = eng.running.get(uids[0])
        if req is not None and len(req.output) >= 3:
            break
    else:
        pytest.fail("first request never reached mid-decode")
    first_token_at = eng.running[uids[0]].first_token_at
    assert eng.preempt_sequence(uids[0])
    assert uids[0] not in eng.running
    assert eng.stats.preemptions == 1
    done = dict(ref_done)  # shape check below uses same keys
    done = {}
    for _ in range(2000):
        if not eng.has_work():
            break
        eng.step()
        for req in eng.collect_finished():
            done[req.uid] = req
    assert set(done) == set(uids)
    assert eng.stats.preempt_resumes == 1
    for uid, ruid in zip(uids, ref_uids):
        assert done[uid].output == ref_done[ruid].output
    # the original TTFT stamp survives the preempt/resume round trip
    assert done[uids[0]].first_token_at == first_token_at


def test_preempt_refuses_non_decode_phases(dense_lm):
    cfg, api, params = dense_lm
    eng = InferenceEngine(cfg, params, **ENGINE_KW)
    uid = eng.submit([3] * 12, max_new_tokens=4)
    assert not eng.preempt_sequence(uid)  # still queued, nothing to retire
    done = eng.run()
    assert not eng.preempt_sequence(uid)  # finished: nothing to preempt
    assert done[uid].output


def test_wfq_preempts_lighter_decode_for_blocked_high_head(dense_lm):
    """The full QoS squeeze: low-class decodes hold the whole pool; a
    high-class arrival cannot be admitted; the scheduler preempts the
    lightest victim, the head admits, and every transcript stays
    token-identical to an uncontended reference."""
    cfg, api, params = dense_lm
    kw = {**ENGINE_KW, "num_blocks": 7, "max_len": 32, "max_num_seqs": 2}
    prompts = {"low1": [5] * 12, "low2": [7] * 12, "high": [9] * 12}
    ref = InferenceEngine(cfg, params, **kw)
    ref_uids = {k: ref.submit(p, max_new_tokens=8)
                for k, p in prompts.items()}
    ref_done = {}
    for k in prompts:  # one at a time: no contention in the reference
        while ref_uids[k] not in ref_done:
            ref.step()
            for r in ref.collect_finished():
                ref_done[r.uid] = r

    eng = InferenceEngine(cfg, params, **kw)
    sched = WFQScheduler()
    uids = {}
    for k in ("low1", "low2"):
        uids[k] = eng.submit(prompts[k], max_new_tokens=8,
                             tenant="batch", qos_class="low")
        sched.on_submit(next(r for r in eng.queue if r.uid == uids[k]))
    # let the low requests occupy the pool and start decoding
    for _ in range(100):
        sched.schedule(eng)
        eng.step()
        if all(u in eng.running and eng.running[u].output
               and not eng.running[u].pending_tokens
               for u in uids.values()):
            break
    else:
        pytest.fail("low-class requests never reached decode")
    uids["high"] = eng.submit(prompts["high"], max_new_tokens=8,
                              tenant="agent", qos_class="high")
    sched.on_submit(next(r for r in eng.queue
                         if r.uid == uids["high"]))
    done = {}
    for _ in range(2000):
        if not eng.has_work():
            break
        sched.schedule(eng)
        eng.step()
        for r in eng.collect_finished():
            done[r.uid] = r
    assert sched.preempted >= 1
    assert eng.stats.preemptions >= 1
    assert eng.stats.preemptions == eng.stats.preempt_resumes
    for k in prompts:
        assert done[uids[k]].output == ref_done[ref_uids[k]].output, k


# ---------------------------------------------------------------------------
# Service layer: per-tenant accounting + admission denial end to end
# ---------------------------------------------------------------------------


class Echo:
    def handle(self, payload):
        time.sleep(0.001)
        return ("ok", payload)


def _rh(**policy_kw):
    return Rhapsody(ResourceDescription(nodes=1, cores_per_node=8),
                    policy=ExecutionPolicy(**policy_kw), n_workers=2)


def test_per_tenant_stats_conservation():
    rh = _rh(routing="round_robin")
    try:
        rs = rh.add_service(ServiceDescription(name="svc", factory=Echo,
                                               replicas=2))
        futs = [rs.request({"prompt": [1] * 4}, tenant=t, priority=p)
                for t, p in [("acme", "high")] * 3 + [("bulk", "low")] * 5
                + [(None, None)] * 2]
        for f in futs:
            f.result(timeout=20)
        stats = rs.stats()
        pt = stats["per_tenant"]
        assert pt["acme"] == {"requests": 3, "completed": 3, "errors": 0}
        assert pt["bulk"] == {"requests": 5, "completed": 5, "errors": 0}
        assert None not in pt  # untenanted traffic has no tenant row
        assert stats["requests"] == 10  # ... but counts in the aggregate
        # tenants also roll up onto the shared-ledger view
        tu = rh.utilization()["default"]["tenants"]
        assert tu["acme"]["completed"] == 3
    finally:
        rh.close()


def test_admission_denied_surfaces_to_client_and_stats():
    rh = _rh(routing="round_robin", tenant_rate=2.0, tenant_burst_s=1.0)
    try:
        rs = rh.add_service(ServiceDescription(name="svc", factory=Echo,
                                               replicas=1))
        # unit costs: bucket depth 2 -> two admits, then denial
        ok = [rs.request([1], tenant="t") for _ in range(2)]
        denied = rs.request([1], tenant="t")
        with pytest.raises(AdmissionDenied) as ei:
            denied.result(timeout=5)
        assert ei.value.tenant == "t"
        for f in ok:
            f.result(timeout=20)
        pt = rs.stats()["per_tenant"]
        assert pt["t"]["admission_denied"] == 1
        assert pt["t"]["requests"] == 2  # denied request never counted in
        assert rh.router.admission_denials() == {"t": 1}
    finally:
        rh.close()


def test_class_latency_windows_feed_protected_class_p95():
    rh = _rh(routing="round_robin")
    try:
        rs = rh.add_service(ServiceDescription(name="svc", factory=Echo,
                                               replicas=1))
        for p in ("high", "high", "low"):
            rs.request([1], tenant="x", priority=p).result(timeout=20)
        # per-class windows only hold their own class's samples
        assert rs.latency_p95(tenant_class="high") is not None
        assert rs.latency_p95(tenant_class="low") is not None
        assert rs.latency_p95(tenant_class="nobody") is None
        with pytest.raises(ValueError):
            rs.latency_p95(tenant_class="high", phase="ttft")
    finally:
        rh.close()
