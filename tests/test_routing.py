"""Router unit tests: balance invariants, determinism, incremental pick()
API, the queue-depth-aware least-loaded policy, and prefix-affinity
(sticky-session) routing."""
import pytest

from repro.core.router import (ROUTERS, LeastLoadedRouter,
                               PrefixAffinityRouter, RandomRouter,
                               RoundRobinRouter, TokenAwareBalancedRouter,
                               default_cost, make_router,
                               request_signature, router_from_policy)


def _requests(lens):
    return [[0] * L for L in lens]


LENS = [3, 50, 7, 120, 1, 44, 9, 80, 80, 2, 17, 61]


# ---------------------------------------------------------------------------
# Batch assign(): exact cover + balance invariants
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("kind", sorted(ROUTERS))
@pytest.mark.parametrize("n", [1, 2, 3, 5])
def test_assign_exact_cover(kind, n):
    reqs = _requests(LENS)
    assign = make_router(kind).assign(reqs, n, cost=len)
    assert len(assign) == n
    flat = sorted(i for a in assign for i in a)
    assert flat == list(range(len(reqs)))


@pytest.mark.parametrize("kind", sorted(ROUTERS))
def test_assign_empty_requests(kind):
    assign = make_router(kind).assign([], 3)
    assert assign == [[], [], []]


@pytest.mark.parametrize("kind", sorted(ROUTERS))
def test_assign_single_instance(kind):
    reqs = _requests(LENS)
    assign = make_router(kind).assign(reqs, 1, cost=len)
    assert len(assign) == 1
    assert sorted(assign[0]) == list(range(len(reqs)))


def test_round_robin_request_count_spread():
    for n in (2, 3, 4):
        assign = make_router("round_robin").assign(_requests(LENS), n)
        counts = [len(a) for a in assign]
        assert max(counts) - min(counts) <= 1


@pytest.mark.parametrize("kind", ["balanced", "least_loaded"])
def test_balanced_token_load_spread(kind):
    reqs = _requests(LENS)
    n = 3
    assign = make_router(kind).assign(reqs, n, cost=len)
    loads = [sum(LENS[i] for i in a) for a in assign]
    counts = [len(a) for a in assign]
    # LPT guarantee: spread bounded by the single largest item; every
    # instance gets work when there are enough requests
    assert max(loads) - min(loads) <= max(LENS)
    assert min(counts) >= 1


def test_random_router_deterministic_under_seed():
    a = make_router("random", seed=7).assign(_requests(LENS), 4, cost=len)
    b = make_router("random", seed=7).assign(_requests(LENS), 4, cost=len)
    c = make_router("random", seed=8).assign(_requests(LENS), 4, cost=len)
    assert a == b
    assert a != c  # overwhelmingly likely for 12 requests over 4 instances


# ---------------------------------------------------------------------------
# Incremental pick(): the middleware dispatch API
# ---------------------------------------------------------------------------


def test_pick_round_robin_cycles():
    r = RoundRobinRouter()
    picks = [r.pick(n_instances=3, group="g") for _ in range(7)]
    assert picks == [0, 1, 2, 0, 1, 2, 0]


def test_pick_single_instance_is_zero():
    for kind in sorted(ROUTERS):
        assert make_router(kind).pick(5.0, n_instances=1) == 0


def test_pick_rejects_bad_n():
    with pytest.raises(ValueError):
        RoundRobinRouter().pick(n_instances=0)


def test_pick_groups_are_independent():
    r = RoundRobinRouter()
    assert r.pick(n_instances=2, group="a") == 0
    assert r.pick(n_instances=2, group="b") == 0
    assert r.pick(n_instances=2, group="a") == 1
    assert r.pick(n_instances=2, group="b") == 1


def test_pick_balanced_tracks_cumulative_load():
    r = TokenAwareBalancedRouter()
    first = r.pick(100.0, n_instances=2, group="g")
    second = r.pick(1.0, n_instances=2, group="g")
    assert second != first  # heavy request loads one side; next goes other
    third = r.pick(1.0, n_instances=2, group="g")
    assert third == second  # still lighter than the 100-token side


def test_pick_resizes_when_replica_count_changes():
    r = TokenAwareBalancedRouter()
    for _ in range(6):
        assert r.pick(1.0, n_instances=2, group="g") in (0, 1)
    # autoscale grows the set: new replicas must receive traffic
    picks = [r.pick(1.0, n_instances=4, group="g") for _ in range(8)]
    assert set(picks) & {2, 3}
    # ... and shrinking stays in range
    picks = [r.pick(1.0, n_instances=2, group="g") for _ in range(4)]
    assert set(picks) <= {0, 1}


def test_least_loaded_prefers_shallow_queue():
    r = LeastLoadedRouter()
    idx = r.pick(1.0, n_instances=3, group="g", queue_depths=[5, 0, 9])
    assert idx == 1
    idx = r.pick(1.0, n_instances=3, group="g", queue_depths=[0, 4, 4])
    assert idx == 0


def test_least_loaded_falls_back_without_depths():
    r = LeastLoadedRouter()
    picks = {r.pick(1.0, n_instances=2, group="g") for _ in range(4)}
    assert picks == {0, 1}  # balanced fallback spreads


def test_default_cost_estimates_tokens():
    assert default_cost({"prompt": [1, 2, 3]}) == 3.0
    assert default_cost([1] * 7) == 7.0
    assert default_cost(42) == 1.0
    assert default_cost({"no_prompt": 1, "two_keys": 2}) == 1.0


# ---------------------------------------------------------------------------
# Prefix affinity: request signatures + sticky pick()
# ---------------------------------------------------------------------------


def test_request_signature_keys_on_bounded_prefix():
    base = {"prompt": [7] * 40}
    same_prefix = {"prompt": [7] * 40 + [1, 2, 3]}
    other = {"prompt": [8] * 40}
    assert request_signature(base) == request_signature(same_prefix)
    assert request_signature(base) != request_signature(other)
    # bounded: tokens past prefix_len don't matter, tokens within do
    assert request_signature({"prompt": [1, 2, 3]}, prefix_len=2) == \
        request_signature({"prompt": [1, 2, 9]}, prefix_len=2)
    assert request_signature({"prompt": [1, 2]}, prefix_len=2) != \
        request_signature({"prompt": [1, 9]}, prefix_len=2)
    # strings work too (tokenizer-free callers)
    assert request_signature("hello world", prefix_len=5) == \
        request_signature("hellooooo", prefix_len=5)


def test_request_signature_canonicalizes_integer_types():
    """Value-equal token ids must key identically whether they arrive as
    python ints or numpy scalars (one session's turns can mix both)."""
    import numpy as np

    plain = {"prompt": [1, 2, 3] * 20}
    npy = {"prompt": list(np.asarray([1, 2, 3] * 20))}
    assert request_signature(plain) == request_signature(npy)
    # floats are NOT coerced (lossy): they key by their own repr
    assert request_signature({"prompt": [1.5] * 40}) != \
        request_signature({"prompt": [1] * 40})


def test_request_signature_none_for_unkeyable_payloads():
    assert request_signature({"no_prompt": 1}) is None
    assert request_signature(42) is None
    assert request_signature(None) is None
    assert request_signature({"prompt": [1]}, prefix_len=0) is None


def test_signature_method_only_on_affinity_router():
    payload = {"prompt": [1] * 8}
    assert make_router("least_loaded").signature(payload) is None
    assert make_router("prefix_affinity").signature(payload) is not None
    assert PrefixAffinityRouter.uses_affinity
    assert not LeastLoadedRouter.uses_affinity


def test_prefix_affinity_sticks_same_key_to_same_replica():
    r = make_router("prefix_affinity")
    k = request_signature({"prompt": [3] * 40})
    first = r.pick(1.0, n_instances=4, group="g", affinity_key=k)
    for _ in range(10):
        assert r.pick(1.0, n_instances=4, group="g", affinity_key=k) == first


def test_prefix_affinity_reports_hit_miss_via_info():
    r = make_router("prefix_affinity")
    k = request_signature({"prompt": [3] * 40})
    info = {}
    r.pick(1.0, n_instances=4, group="g", affinity_key=k, info=info)
    assert info["affinity"] == "miss"
    info = {}
    r.pick(1.0, n_instances=4, group="g", affinity_key=k, info=info)
    assert info["affinity"] == "hit"
    info = {}
    r.pick(1.0, n_instances=4, group="g", info=info)  # unkeyed: no report
    assert "affinity" not in info


def test_prefix_affinity_distinct_sessions_spread():
    """First-seen keys fall through to least-loaded, so distinct sessions
    land on distinct replicas instead of piling up."""
    r = make_router("prefix_affinity")
    homes = [r.pick(10.0, n_instances=4, group="g",
                    affinity_key=request_signature({"prompt": [s] * 40}))
             for s in range(4)]
    assert sorted(homes) == [0, 1, 2, 3]


def test_prefix_affinity_spills_when_sticky_replica_backed_up():
    r = make_router("prefix_affinity", spill_factor=2.0)
    k = request_signature({"prompt": [1] * 40})
    home = r.pick(1.0, n_instances=3, group="g", affinity_key=k)
    depths = [0.0] * 3
    depths[home] = 50.0  # way past spill_factor * (min + 1)
    info = {}
    spilled = r.pick(1.0, n_instances=3, group="g", affinity_key=k,
                     queue_depths=depths, info=info)
    assert spilled != home
    assert info["affinity"] == "spill"
    # the session re-homed: next pick (no pressure) sticks to the new home
    info = {}
    assert r.pick(1.0, n_instances=3, group="g", affinity_key=k,
                  info=info) == spilled
    assert info["affinity"] == "hit"


def test_prefix_affinity_spill_disabled_by_nonpositive_factor():
    r = make_router("prefix_affinity", spill_factor=0.0)
    k = request_signature({"prompt": [1] * 40})
    home = r.pick(1.0, n_instances=3, group="g", affinity_key=k)
    depths = [0.0] * 3
    depths[home] = 1e9
    assert r.pick(1.0, n_instances=3, group="g", affinity_key=k,
                  queue_depths=depths) == home


def test_prefix_affinity_resize_keeps_surviving_homes():
    r = make_router("prefix_affinity")
    keys = [request_signature({"prompt": [s] * 40}) for s in range(4)]
    homes = {k: r.pick(1.0, n_instances=4, group="g", affinity_key=k)
             for k in keys}
    # shrink to 2: sessions homed on replicas 0/1 keep them, the rest
    # re-home in range; grow back keeps everything in range
    for n in (2, 4, 3):
        for k in keys:
            idx = r.pick(1.0, n_instances=n, group="g", affinity_key=k)
            assert 0 <= idx < n
            if homes[k] < n <= 2:  # surviving home after the first shrink
                assert idx == homes[k]


def test_prefix_affinity_map_is_lru_bounded():
    r = make_router("prefix_affinity", map_capacity=8)
    for s in range(50):
        r.pick(1.0, n_instances=2, group="g",
               affinity_key=request_signature({"prompt": [s, s + 1] * 20}))
    assert len(r._groups["g"]["amap"]) <= 8


def test_prefix_affinity_single_instance_miss_then_hit():
    """Even at one replica, first contact is a miss and repeats are hits,
    so hit rates mean the same thing at every replica count."""
    r = make_router("prefix_affinity")
    info = {}
    assert r.pick(1.0, n_instances=1, group="g",
                  affinity_key=1234, info=info) == 0
    assert info["affinity"] == "miss"
    info = {}
    assert r.pick(1.0, n_instances=1, group="g",
                  affinity_key=1234, info=info) == 0
    assert info["affinity"] == "hit"


def test_router_from_policy_threads_affinity_knobs():
    class P:
        routing = "prefix_affinity"
        affinity_prefix_len = 7
        affinity_spill_factor = 5.5

    r = router_from_policy(P())
    assert isinstance(r, PrefixAffinityRouter)
    assert r.prefix_len == 7
    assert r.spill_factor == 5.5
    assert router_from_policy(None).__class__ is RoundRobinRouter
