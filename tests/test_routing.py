"""Router unit tests: balance invariants, determinism, incremental pick()
API, and the queue-depth-aware least-loaded policy."""
import pytest

from repro.core.router import (ROUTERS, LeastLoadedRouter, RandomRouter,
                               RoundRobinRouter, TokenAwareBalancedRouter,
                               default_cost, make_router)


def _requests(lens):
    return [[0] * L for L in lens]


LENS = [3, 50, 7, 120, 1, 44, 9, 80, 80, 2, 17, 61]


# ---------------------------------------------------------------------------
# Batch assign(): exact cover + balance invariants
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("kind", sorted(ROUTERS))
@pytest.mark.parametrize("n", [1, 2, 3, 5])
def test_assign_exact_cover(kind, n):
    reqs = _requests(LENS)
    assign = make_router(kind).assign(reqs, n, cost=len)
    assert len(assign) == n
    flat = sorted(i for a in assign for i in a)
    assert flat == list(range(len(reqs)))


@pytest.mark.parametrize("kind", sorted(ROUTERS))
def test_assign_empty_requests(kind):
    assign = make_router(kind).assign([], 3)
    assert assign == [[], [], []]


@pytest.mark.parametrize("kind", sorted(ROUTERS))
def test_assign_single_instance(kind):
    reqs = _requests(LENS)
    assign = make_router(kind).assign(reqs, 1, cost=len)
    assert len(assign) == 1
    assert sorted(assign[0]) == list(range(len(reqs)))


def test_round_robin_request_count_spread():
    for n in (2, 3, 4):
        assign = make_router("round_robin").assign(_requests(LENS), n)
        counts = [len(a) for a in assign]
        assert max(counts) - min(counts) <= 1


@pytest.mark.parametrize("kind", ["balanced", "least_loaded"])
def test_balanced_token_load_spread(kind):
    reqs = _requests(LENS)
    n = 3
    assign = make_router(kind).assign(reqs, n, cost=len)
    loads = [sum(LENS[i] for i in a) for a in assign]
    counts = [len(a) for a in assign]
    # LPT guarantee: spread bounded by the single largest item; every
    # instance gets work when there are enough requests
    assert max(loads) - min(loads) <= max(LENS)
    assert min(counts) >= 1


def test_random_router_deterministic_under_seed():
    a = make_router("random", seed=7).assign(_requests(LENS), 4, cost=len)
    b = make_router("random", seed=7).assign(_requests(LENS), 4, cost=len)
    c = make_router("random", seed=8).assign(_requests(LENS), 4, cost=len)
    assert a == b
    assert a != c  # overwhelmingly likely for 12 requests over 4 instances


# ---------------------------------------------------------------------------
# Incremental pick(): the middleware dispatch API
# ---------------------------------------------------------------------------


def test_pick_round_robin_cycles():
    r = RoundRobinRouter()
    picks = [r.pick(n_instances=3, group="g") for _ in range(7)]
    assert picks == [0, 1, 2, 0, 1, 2, 0]


def test_pick_single_instance_is_zero():
    for kind in sorted(ROUTERS):
        assert make_router(kind).pick(5.0, n_instances=1) == 0


def test_pick_rejects_bad_n():
    with pytest.raises(ValueError):
        RoundRobinRouter().pick(n_instances=0)


def test_pick_groups_are_independent():
    r = RoundRobinRouter()
    assert r.pick(n_instances=2, group="a") == 0
    assert r.pick(n_instances=2, group="b") == 0
    assert r.pick(n_instances=2, group="a") == 1
    assert r.pick(n_instances=2, group="b") == 1


def test_pick_balanced_tracks_cumulative_load():
    r = TokenAwareBalancedRouter()
    first = r.pick(100.0, n_instances=2, group="g")
    second = r.pick(1.0, n_instances=2, group="g")
    assert second != first  # heavy request loads one side; next goes other
    third = r.pick(1.0, n_instances=2, group="g")
    assert third == second  # still lighter than the 100-token side


def test_pick_resizes_when_replica_count_changes():
    r = TokenAwareBalancedRouter()
    for _ in range(6):
        assert r.pick(1.0, n_instances=2, group="g") in (0, 1)
    # autoscale grows the set: new replicas must receive traffic
    picks = [r.pick(1.0, n_instances=4, group="g") for _ in range(8)]
    assert set(picks) & {2, 3}
    # ... and shrinking stays in range
    picks = [r.pick(1.0, n_instances=2, group="g") for _ in range(4)]
    assert set(picks) <= {0, 1}


def test_least_loaded_prefers_shallow_queue():
    r = LeastLoadedRouter()
    idx = r.pick(1.0, n_instances=3, group="g", queue_depths=[5, 0, 9])
    assert idx == 1
    idx = r.pick(1.0, n_instances=3, group="g", queue_depths=[0, 4, 4])
    assert idx == 0


def test_least_loaded_falls_back_without_depths():
    r = LeastLoadedRouter()
    picks = {r.pick(1.0, n_instances=2, group="g") for _ in range(4)}
    assert picks == {0, 1}  # balanced fallback spreads


def test_default_cost_estimates_tokens():
    assert default_cost({"prompt": [1, 2, 3]}) == 3.0
    assert default_cost([1] * 7) == 7.0
    assert default_cost(42) == 1.0
    assert default_cost({"no_prompt": 1, "two_keys": 2}) == 1.0
