"""Router unit tests: balance invariants, determinism, the incremental
route() API (envelope + RouteContext), the queue-depth-aware least-loaded
policy, prefix-affinity (sticky-session) routing, radix longest-prefix-
match routing, the legacy pick() shim, and per-tenant admission."""
import pytest

from repro.core.request import InferenceRequest, RouteContext
from repro.core.router import (ROUTERS, LeastLoadedRouter,
                               PrefixAffinityRouter, RadixAffinityRouter,
                               RandomRouter, RoundRobinRouter,
                               TokenAwareBalancedRouter, default_cost,
                               make_router, request_prefix,
                               request_signature, router_from_policy)


def _requests(lens):
    return [[0] * L for L in lens]


LENS = [3, 50, 7, 120, 1, 44, 9, 80, 80, 2, 17, 61]


def pick(r, cost=1.0, *, n_instances, group="default", queue_depths=None,
         affinity_key=None, info=None, members=None, affinity_group=None,
         payload=None):
    """route() through the primary envelope surface with pick()-shaped
    arguments — the whole suite exercises the new API while reading like
    the routing decisions it checks."""
    env = InferenceRequest(payload=payload, affinity=affinity_key)
    ctx = RouteContext(n_instances=n_instances, group=group,
                       queue_depths=queue_depths, members=members,
                       affinity_group=affinity_group, info=info)
    return r.route(env, ctx, cost=cost)


# ---------------------------------------------------------------------------
# Batch assign(): exact cover + balance invariants
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("kind", sorted(ROUTERS))
@pytest.mark.parametrize("n", [1, 2, 3, 5])
def test_assign_exact_cover(kind, n):
    reqs = _requests(LENS)
    assign = make_router(kind).assign(reqs, n, cost=len)
    assert len(assign) == n
    flat = sorted(i for a in assign for i in a)
    assert flat == list(range(len(reqs)))


@pytest.mark.parametrize("kind", sorted(ROUTERS))
def test_assign_empty_requests(kind):
    assign = make_router(kind).assign([], 3)
    assert assign == [[], [], []]


@pytest.mark.parametrize("kind", sorted(ROUTERS))
def test_assign_single_instance(kind):
    reqs = _requests(LENS)
    assign = make_router(kind).assign(reqs, 1, cost=len)
    assert len(assign) == 1
    assert sorted(assign[0]) == list(range(len(reqs)))


def test_round_robin_request_count_spread():
    for n in (2, 3, 4):
        assign = make_router("round_robin").assign(_requests(LENS), n)
        counts = [len(a) for a in assign]
        assert max(counts) - min(counts) <= 1


@pytest.mark.parametrize("kind", ["balanced", "least_loaded"])
def test_balanced_token_load_spread(kind):
    reqs = _requests(LENS)
    n = 3
    assign = make_router(kind).assign(reqs, n, cost=len)
    loads = [sum(LENS[i] for i in a) for a in assign]
    counts = [len(a) for a in assign]
    # LPT guarantee: spread bounded by the single largest item; every
    # instance gets work when there are enough requests
    assert max(loads) - min(loads) <= max(LENS)
    assert min(counts) >= 1


def test_random_router_deterministic_under_seed():
    a = make_router("random", seed=7).assign(_requests(LENS), 4, cost=len)
    b = make_router("random", seed=7).assign(_requests(LENS), 4, cost=len)
    c = make_router("random", seed=8).assign(_requests(LENS), 4, cost=len)
    assert a == b
    assert a != c  # overwhelmingly likely for 12 requests over 4 instances


# ---------------------------------------------------------------------------
# Incremental pick(): the middleware dispatch API
# ---------------------------------------------------------------------------


def test_pick_round_robin_cycles():
    r = RoundRobinRouter()
    picks = [pick(r, n_instances=3, group="g") for _ in range(7)]
    assert picks == [0, 1, 2, 0, 1, 2, 0]


def test_pick_single_instance_is_zero():
    for kind in sorted(ROUTERS):
        assert pick(make_router(kind), 5.0, n_instances=1) == 0


def test_pick_rejects_bad_n():
    with pytest.raises(ValueError):
        pick(RoundRobinRouter(), n_instances=0)


def test_pick_groups_are_independent():
    r = RoundRobinRouter()
    assert pick(r, n_instances=2, group="a") == 0
    assert pick(r, n_instances=2, group="b") == 0
    assert pick(r, n_instances=2, group="a") == 1
    assert pick(r, n_instances=2, group="b") == 1


def test_pick_balanced_tracks_cumulative_load():
    r = TokenAwareBalancedRouter()
    first = pick(r, 100.0, n_instances=2, group="g")
    second = pick(r, 1.0, n_instances=2, group="g")
    assert second != first  # heavy request loads one side; next goes other
    third = pick(r, 1.0, n_instances=2, group="g")
    assert third == second  # still lighter than the 100-token side


def test_pick_resizes_when_replica_count_changes():
    r = TokenAwareBalancedRouter()
    for _ in range(6):
        assert pick(r, 1.0, n_instances=2, group="g") in (0, 1)
    # autoscale grows the set: new replicas must receive traffic
    picks = [pick(r, 1.0, n_instances=4, group="g") for _ in range(8)]
    assert set(picks) & {2, 3}
    # ... and shrinking stays in range
    picks = [pick(r, 1.0, n_instances=2, group="g") for _ in range(4)]
    assert set(picks) <= {0, 1}


def test_least_loaded_prefers_shallow_queue():
    r = LeastLoadedRouter()
    idx = pick(r, 1.0, n_instances=3, group="g", queue_depths=[5, 0, 9])
    assert idx == 1
    idx = pick(r, 1.0, n_instances=3, group="g", queue_depths=[0, 4, 4])
    assert idx == 0


def test_least_loaded_falls_back_without_depths():
    r = LeastLoadedRouter()
    picks = {pick(r, 1.0, n_instances=2, group="g") for _ in range(4)}
    assert picks == {0, 1}  # balanced fallback spreads


def test_default_cost_estimates_tokens():
    assert default_cost({"prompt": [1, 2, 3]}) == 3.0
    assert default_cost([1] * 7) == 7.0
    assert default_cost(42) == 1.0
    assert default_cost({"no_prompt": 1, "two_keys": 2}) == 1.0


# ---------------------------------------------------------------------------
# Prefix affinity: request signatures + sticky pick()
# ---------------------------------------------------------------------------


def test_request_signature_keys_on_bounded_prefix():
    base = {"prompt": [7] * 40}
    same_prefix = {"prompt": [7] * 40 + [1, 2, 3]}
    other = {"prompt": [8] * 40}
    assert request_signature(base) == request_signature(same_prefix)
    assert request_signature(base) != request_signature(other)
    # bounded: tokens past prefix_len don't matter, tokens within do
    assert request_signature({"prompt": [1, 2, 3]}, prefix_len=2) == \
        request_signature({"prompt": [1, 2, 9]}, prefix_len=2)
    assert request_signature({"prompt": [1, 2]}, prefix_len=2) != \
        request_signature({"prompt": [1, 9]}, prefix_len=2)
    # strings work too (tokenizer-free callers)
    assert request_signature("hello world", prefix_len=5) == \
        request_signature("hellooooo", prefix_len=5)


def test_request_signature_canonicalizes_integer_types():
    """Value-equal token ids must key identically whether they arrive as
    python ints or numpy scalars (one session's turns can mix both)."""
    import numpy as np

    plain = {"prompt": [1, 2, 3] * 20}
    npy = {"prompt": list(np.asarray([1, 2, 3] * 20))}
    assert request_signature(plain) == request_signature(npy)
    # floats are NOT coerced (lossy): they key by their own repr
    assert request_signature({"prompt": [1.5] * 40}) != \
        request_signature({"prompt": [1] * 40})


def test_request_signature_none_for_unkeyable_payloads():
    assert request_signature({"no_prompt": 1}) is None
    assert request_signature(42) is None
    assert request_signature(None) is None
    assert request_signature({"prompt": [1]}, prefix_len=0) is None


def test_signature_method_only_on_affinity_router():
    payload = {"prompt": [1] * 8}
    assert make_router("least_loaded").signature(payload) is None
    assert make_router("prefix_affinity").signature(payload) is not None
    assert PrefixAffinityRouter.uses_affinity
    assert not LeastLoadedRouter.uses_affinity


def test_prefix_affinity_sticks_same_key_to_same_replica():
    r = make_router("prefix_affinity")
    k = request_signature({"prompt": [3] * 40})
    first = pick(r, 1.0, n_instances=4, group="g", affinity_key=k)
    for _ in range(10):
        assert pick(r, 1.0, n_instances=4, group="g", affinity_key=k) == first


def test_prefix_affinity_reports_hit_miss_via_info():
    r = make_router("prefix_affinity")
    k = request_signature({"prompt": [3] * 40})
    info = {}
    pick(r, 1.0, n_instances=4, group="g", affinity_key=k, info=info)
    assert info["affinity"] == "miss"
    info = {}
    pick(r, 1.0, n_instances=4, group="g", affinity_key=k, info=info)
    assert info["affinity"] == "hit"
    info = {}
    pick(r, 1.0, n_instances=4, group="g", info=info)  # unkeyed: no report
    assert "affinity" not in info


def test_prefix_affinity_distinct_sessions_spread():
    """First-seen keys fall through to least-loaded, so distinct sessions
    land on distinct replicas instead of piling up."""
    r = make_router("prefix_affinity")
    homes = [pick(r, 10.0, n_instances=4, group="g",
                    affinity_key=request_signature({"prompt": [s] * 40}))
             for s in range(4)]
    assert sorted(homes) == [0, 1, 2, 3]


def test_prefix_affinity_spills_when_sticky_replica_backed_up():
    r = make_router("prefix_affinity", spill_factor=2.0)
    k = request_signature({"prompt": [1] * 40})
    home = pick(r, 1.0, n_instances=3, group="g", affinity_key=k)
    depths = [0.0] * 3
    depths[home] = 50.0  # way past spill_factor * (min + 1)
    info = {}
    spilled = pick(r, 1.0, n_instances=3, group="g", affinity_key=k,
                     queue_depths=depths, info=info)
    assert spilled != home
    assert info["affinity"] == "spill"
    # the session re-homed: next pick (no pressure) sticks to the new home
    info = {}
    assert pick(r, 1.0, n_instances=3, group="g", affinity_key=k,
                  info=info) == spilled
    assert info["affinity"] == "hit"


def test_prefix_affinity_spill_disabled_by_nonpositive_factor():
    r = make_router("prefix_affinity", spill_factor=0.0)
    k = request_signature({"prompt": [1] * 40})
    home = pick(r, 1.0, n_instances=3, group="g", affinity_key=k)
    depths = [0.0] * 3
    depths[home] = 1e9
    assert pick(r, 1.0, n_instances=3, group="g", affinity_key=k,
                  queue_depths=depths) == home


def test_prefix_affinity_resize_keeps_surviving_homes():
    r = make_router("prefix_affinity")
    keys = [request_signature({"prompt": [s] * 40}) for s in range(4)]
    homes = {k: pick(r, 1.0, n_instances=4, group="g", affinity_key=k)
             for k in keys}
    # shrink to 2: sessions homed on replicas 0/1 keep them, the rest
    # re-home in range; grow back keeps everything in range
    for n in (2, 4, 3):
        for k in keys:
            idx = pick(r, 1.0, n_instances=n, group="g", affinity_key=k)
            assert 0 <= idx < n
            if homes[k] < n <= 2:  # surviving home after the first shrink
                assert idx == homes[k]


def test_prefix_affinity_map_is_lru_bounded():
    r = make_router("prefix_affinity", map_capacity=8)
    for s in range(50):
        pick(r, 1.0, n_instances=2, group="g",
               affinity_key=request_signature({"prompt": [s, s + 1] * 20}))
    assert len(r._affinity["g"]["amap"]) <= 8


def test_prefix_affinity_single_instance_miss_then_hit():
    """Even at one replica, first contact is a miss and repeats are hits,
    so hit rates mean the same thing at every replica count."""
    r = make_router("prefix_affinity")
    info = {}
    assert pick(r, 1.0, n_instances=1, group="g",
                  affinity_key=1234, info=info) == 0
    assert info["affinity"] == "miss"
    info = {}
    assert pick(r, 1.0, n_instances=1, group="g",
                  affinity_key=1234, info=info) == 0
    assert info["affinity"] == "hit"


def test_router_from_policy_threads_affinity_knobs():
    class P:
        routing = "prefix_affinity"
        affinity_prefix_len = 7
        affinity_spill_factor = 5.5

    r = router_from_policy(P())
    assert isinstance(r, PrefixAffinityRouter)
    assert r.prefix_len == 7
    assert r.spill_factor == 5.5
    assert router_from_policy(None).__class__ is RoundRobinRouter


def test_router_from_policy_threads_radix_knobs():
    class P:
        routing = "radix_affinity"
        affinity_max_prefix = 64
        affinity_min_match = 5
        affinity_spill_factor = 3.0

    r = router_from_policy(P())
    assert isinstance(r, RadixAffinityRouter)
    assert r.max_prefix == 64
    assert r.min_match == 5
    assert r.spill_factor == 3.0


# ---------------------------------------------------------------------------
# Sticky assignments carry across membership changes (stable member ids)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("kind", ["prefix_affinity", "radix_affinity"])
def test_affinity_survives_membership_change_with_stable_members(kind):
    """Assignments name stable member identities: when the candidate set
    changes (autoscale/crash), sessions homed on surviving members keep
    their replica — only sessions on the departed member re-home."""
    r = make_router(kind, spill_factor=0.0)
    keys = [r.signature({"prompt": [s] * 40}) for s in range(6)]
    members = (10, 11, 12)
    home = {k: members[pick(r, 1.0, n_instances=3, group="m3",
                              affinity_key=k, members=members,
                              affinity_group="svc")]
            for k in keys}
    assert set(home.values()) == set(members)  # sessions spread first
    # member 12 dies: a new membership (and new balance group) forms
    survivors = (10, 11)
    for k in keys:
        idx = pick(r, 1.0, n_instances=2, group="m2", affinity_key=k,
                     members=survivors, affinity_group="svc")
        if home[k] in survivors:
            assert survivors[idx] == home[k], "surviving home lost"
        else:
            home[k] = survivors[idx]  # re-homed once, then sticky again
    # grow back with a NEW member id (13, never 12): homes keep holding
    grown = (10, 11, 13)
    for k in keys:
        idx = pick(r, 1.0, n_instances=3, group="m3b", affinity_key=k,
                     members=grown, affinity_group="svc")
        assert grown[idx] == home[k]


def test_pick_rejects_mismatched_members():
    with pytest.raises(ValueError):
        pick(make_router("prefix_affinity"),
             1.0, n_instances=2, affinity_key=1, members=(1, 2, 3))


# ---------------------------------------------------------------------------
# Radix longest-prefix-match routing
# ---------------------------------------------------------------------------


def test_request_prefix_is_lossless_and_bounded():
    assert request_prefix({"prompt": [1, 2, 3]}) == (1, 2, 3)
    assert request_prefix({"prompt": [1, 2, 3]}, max_len=2) == (1, 2)
    assert request_prefix("abc") == ("a", "b", "c")
    assert request_prefix({"no_prompt": 1}) is None
    assert request_prefix(42) is None
    assert request_prefix({"prompt": [1]}, max_len=0) is None
    assert request_prefix({"prompt": []}) is None
    # integer canonicalization matches request_signature's rule
    import numpy as np
    assert request_prefix({"prompt": list(np.asarray([1, 2]))}) == (1, 2)


def test_radix_sticks_through_divergence_past_hash_window():
    """The decisive case: two sessions share a 40-token stem (identical
    hashed signature) and diverge after it.  The hash key cannot tell them
    apart; radix longest-match homes each on its own replica."""
    stem = [7] * 40
    a1 = {"prompt": stem + [1, 1, 1, 1, 1, 1, 1, 1]}
    b1 = {"prompt": stem + [2, 2, 2, 2, 2, 2, 2, 2]}
    assert request_signature(a1) == request_signature(b1)  # hash collides
    r = make_router("radix_affinity", min_match=8)
    depths = [0.0, 0.0, 50.0]  # r2 busy: first contacts spread over r0/r1
    ha = pick(r, 1.0, n_instances=3, group="g", queue_depths=depths,
                affinity_key=r.signature(a1))
    # overload the first home so session b's stem match spills off it
    d2 = list(depths)
    d2[ha] = 50.0
    hb = pick(r, 1.0, n_instances=3, group="g", queue_depths=d2,
                affinity_key=r.signature(b1))
    assert hb != ha
    # turn 2 grows each transcript: longest-match returns each session to
    # its OWN home even though the stems (and hashes) are identical
    a2 = {"prompt": a1["prompt"] + [9, 9, 9]}
    b2 = {"prompt": b1["prompt"] + [8, 8, 8]}
    info = {}
    assert pick(r, 1.0, n_instances=3, group="g",
                  affinity_key=r.signature(a2), info=info) == ha
    assert info["affinity"] == "hit"
    info = {}
    assert pick(r, 1.0, n_instances=3, group="g",
                  affinity_key=r.signature(b2), info=info) == hb
    assert info["affinity"] == "hit"


def test_radix_short_common_prefix_routes_by_load():
    """Matches below min_match are noise (e.g. two unrelated prompts that
    open with the same token): route by load, account a miss."""
    r = make_router("radix_affinity", min_match=8)
    pick(r, 1.0, n_instances=2, group="g",
           affinity_key=r.signature({"prompt": [1, 2, 3, 4] * 10}))
    info = {}
    pick(r, 1.0, n_instances=2, group="g",
           affinity_key=r.signature({"prompt": [1, 2, 9, 9] * 10}),
           info=info)
    assert info["affinity"] == "miss"  # only 2 tokens shared


def test_radix_spills_to_second_longest_match():
    """Prefix-aware spill: an overloaded sticky replica sheds to the
    replica holding the SECOND-longest matching prefix (fed by residency
    gossip), not to the least-loaded one."""
    r = make_router("radix_affinity", min_match=4, spill_factor=2.0)
    prompt = list(range(100, 140))
    # member 0 served the whole session; member 1's engine holds a shorter
    # stem of it (gossiped residency); member 2 is idle but cache-cold
    r.update_residency("svc", 0, [prompt])
    r.update_residency("svc", 1, [prompt[:16]])
    info = {}
    idx = pick(r, 1.0, n_instances=3, group="g", members=(0, 1, 2),
                 affinity_group="svc", queue_depths=[50.0, 1.0, 0.0],
                 affinity_key=tuple(prompt), info=info)
    assert idx == 1  # second-longest match beats the idle cold replica
    assert info["affinity"] == "spill"


def test_radix_residency_gossip_creates_first_contact_hits():
    """A fresh router (no session memory) still routes a prompt to the
    replica whose gossiped residency covers it — e.g. after a router
    restart or a session spilling in from another entry point."""
    r = make_router("radix_affinity", min_match=4)
    r.update_residency("svc", 2, [[5, 6, 7, 8, 9, 10]])
    info = {}
    idx = pick(r, 1.0, n_instances=3, group="g", members=(1, 2, 3),
                 affinity_group="svc",
                 affinity_key=(5, 6, 7, 8, 9, 10, 11), info=info)
    assert (1, 2, 3)[idx] == 2
    assert info["affinity"] == "hit"


def test_radix_forget_member_rehomes_its_sessions():
    r = make_router("radix_affinity", min_match=4)
    key = r.signature({"prompt": [3] * 20})
    home = pick(r, 1.0, n_instances=2, group="g", members=(0, 1),
                  affinity_group="svc", affinity_key=key)
    r.forget_member("svc", (0, 1)[home])
    info = {}
    pick(r, 1.0, n_instances=2, group="g", members=(0, 1),
           affinity_group="svc", affinity_key=key, info=info)
    assert info["affinity"] == "miss"  # no stale assignment survived


def test_radix_unkeyed_and_hash_keys_fall_back_to_load():
    r = make_router("radix_affinity")
    info = {}
    pick(r, 1.0, n_instances=2, group="g", info=info)
    assert "affinity" not in info
    # an int key (e.g. from request_signature) is not a token prefix:
    # route by load rather than misindexing it
    assert pick(r, 1.0, n_instances=2, group="g", affinity_key=12345) in (0, 1)


def test_radix_equal_depth_matches_prefer_shallow_queue():
    """Several replicas holding the same shared stem (branching agents):
    equal-depth matches spread by live queue depth instead of piling onto
    one stem holder."""
    r = make_router("radix_affinity", min_match=4)
    stem = [1, 2, 3, 4, 5, 6, 7, 8]
    r.update_residency("svc", 0, [stem])
    r.update_residency("svc", 1, [stem])
    idx = pick(r, 1.0, n_instances=2, group="g", members=(0, 1),
                 affinity_group="svc", queue_depths=[3.0, 0.0],
                 affinity_key=tuple(stem + [9]))
    assert idx == 1


# ---------------------------------------------------------------------------
# Headroom-weighted radix matches (free-block gossip)
# ---------------------------------------------------------------------------


def test_radix_headroom_starved_match_spills_to_next_match():
    """A deep prefix match on a replica whose engine is nearly out of
    free blocks is a match about to be evicted: the router prefers the
    next-deepest NON-starved match and accounts the route as a spill."""
    r = make_router("radix_affinity", min_match=4,
                    headroom_watermark=0.25)
    prompt = list(range(200, 240))
    r.update_residency("svc", 0, [prompt])       # deepest match...
    r.update_residency("svc", 1, [prompt[:16]])  # shallower, healthy
    r.update_headroom("svc", 0, 1, 32)   # ...but 1/32 free: starved
    r.update_headroom("svc", 1, 16, 32)
    info = {}
    idx = pick(r, 1.0, n_instances=3, group="g", members=(0, 1, 2),
                 affinity_group="svc", queue_depths=[0.0, 0.0, 0.0],
                 affinity_key=tuple(prompt), info=info)
    assert idx == 1
    assert info["affinity"] == "spill"


def test_radix_headroom_recovery_restores_the_deep_match():
    """Headroom is a live gauge: once the starved replica frees blocks
    (requests drained / residencies evicted), its deep match wins again
    and counts as a hit."""
    r = make_router("radix_affinity", min_match=4,
                    headroom_watermark=0.25)
    prompt = list(range(50, 90))
    r.update_residency("svc", 0, [prompt])
    r.update_residency("svc", 1, [prompt[:16]])
    r.update_headroom("svc", 0, 2, 32)
    # member 1's queue is deeper, so once member 0 is healthy again the
    # equal-depth tie (0's residency vs the session memory the first pick
    # left on 1) resolves back to 0
    assert pick(r, 1.0, n_instances=2, group="g", members=(0, 1),
                  affinity_group="svc", queue_depths=[0.0, 1.0],
                  affinity_key=tuple(prompt)) == 1
    r.update_headroom("svc", 0, 20, 32)  # pool drained back above water
    info = {}
    assert pick(r, 1.0, n_instances=2, group="g", members=(0, 1),
                  affinity_group="svc", queue_depths=[0.0, 1.0],
                  affinity_key=tuple(prompt), info=info) == 0
    assert info["affinity"] == "hit"


def test_radix_headroom_all_starved_falls_back_by_load():
    """When every matching replica is starved the router does not pick a
    doomed match: it falls back to least-loaded and accounts a spill."""
    r = make_router("radix_affinity", min_match=4,
                    headroom_watermark=0.25)
    prompt = list(range(10, 40))
    r.update_residency("svc", 0, [prompt])
    r.update_residency("svc", 1, [prompt[:12]])
    r.update_headroom("svc", 0, 0, 32)
    r.update_headroom("svc", 1, 1, 32)
    info = {}
    idx = pick(r, 1.0, n_instances=3, group="g", members=(0, 1, 2),
                 affinity_group="svc", queue_depths=[5.0, 5.0, 0.0],
                 affinity_key=tuple(prompt), info=info)
    assert idx == 2  # least-loaded, cache-cold — but not about to evict
    assert info["affinity"] == "spill"


def test_radix_headroom_disabled_by_nonpositive_watermark():
    r = make_router("radix_affinity", min_match=4, headroom_watermark=0.0)
    prompt = list(range(300, 330))
    r.update_residency("svc", 0, [prompt])
    r.update_headroom("svc", 0, 0, 32)  # zero free, but weighting is off
    info = {}
    assert pick(r, 1.0, n_instances=2, group="g", members=(0, 1),
                  affinity_group="svc", affinity_key=tuple(prompt),
                  info=info) == 0
    assert info["affinity"] == "hit"


def test_radix_forget_member_drops_its_headroom():
    r = make_router("radix_affinity", min_match=4,
                    headroom_watermark=0.25)
    prompt = list(range(400, 430))
    r.update_residency("svc", 0, [prompt])
    r.update_headroom("svc", 0, 0, 32)
    r.forget_member("svc", 0)
    # re-gossiped residency with no headroom report routes normally
    r.update_residency("svc", 0, [prompt])
    info = {}
    assert pick(r, 1.0, n_instances=2, group="g", members=(0, 1),
                  affinity_group="svc", affinity_key=tuple(prompt),
                  info=info) == 0
    assert info["affinity"] == "hit"


def test_update_headroom_noop_on_plain_routers():
    make_router("least_loaded").update_headroom("svc", 0, 1, 32)
    make_router("round_robin").update_headroom("svc", 0, 1, 32)


def test_router_from_policy_threads_headroom_watermark():
    class P:
        routing = "radix_affinity"
        affinity_headroom_watermark = 0.33

    r = router_from_policy(P())
    assert isinstance(r, RadixAffinityRouter)
    assert r.headroom_watermark == 0.33


# ---------------------------------------------------------------------------
# route(): envelope-native behavior + the legacy pick() shim
# ---------------------------------------------------------------------------


def test_route_derives_affinity_from_envelope_payload():
    """An envelope with no explicit affinity key still routes sticky:
    route() derives the key from the payload with the router's own
    signature()."""
    r = make_router("prefix_affinity")
    payload = {"prompt": [3] * 40}
    first = r.route(InferenceRequest(payload=payload),
                    RouteContext(n_instances=4, group="g"))
    for _ in range(5):
        assert r.route(InferenceRequest(payload=payload),
                       RouteContext(n_instances=4, group="g")) == first


def test_route_explicit_affinity_wins_over_payload():
    r = make_router("prefix_affinity")
    k = request_signature({"prompt": [9] * 40})
    home = pick(r, n_instances=4, group="g", affinity_key=k)
    env = InferenceRequest(payload={"prompt": [1] * 40}, affinity=k)
    assert r.route(env, RouteContext(n_instances=4, group="g")) == home


def test_route_default_cost_comes_from_payload():
    r = TokenAwareBalancedRouter()
    heavy = InferenceRequest(payload={"prompt": [0] * 100})
    light = InferenceRequest(payload={"prompt": [0]})
    first = r.route(heavy, RouteContext(n_instances=2, group="g"))
    second = r.route(light, RouteContext(n_instances=2, group="g"))
    assert second != first  # 100-token side loaded; light goes other way


def test_route_rejects_bad_context():
    with pytest.raises(ValueError):
        RoundRobinRouter().route(InferenceRequest(payload=None),
                                 RouteContext(n_instances=0))
    with pytest.raises(ValueError):
        make_router("prefix_affinity").route(
            InferenceRequest(payload=None, affinity=1),
            RouteContext(n_instances=2, members=(1, 2, 3)))


def test_pick_shim_matches_route():
    """The deprecated pick() surface stays: same decisions, same state,
    as an equivalent route() call."""
    a, b = RoundRobinRouter(), RoundRobinRouter()
    for _ in range(7):
        assert a.pick(n_instances=3, group="g") == \
            pick(b, n_instances=3, group="g")


def test_pick_shim_threads_affinity_and_info():
    r = make_router("prefix_affinity")
    info = {}
    home = r.pick(1.0, n_instances=4, group="g", affinity_key=77, info=info)
    assert info["affinity"] == "miss"
    info = {}
    assert r.pick(1.0, n_instances=4, group="g", affinity_key=77,
                  info=info) == home
    assert info["affinity"] == "hit"
