"""Hypothesis property tests on system invariants.

``hypothesis`` is an optional dev dependency: skip the whole module (rather
than dying at collection) when it isn't installed, so ``pytest -x -q`` stays
green either way.
"""
import threading

import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core.coupling import InMemoryStore
from repro.core.resources import Allocation, ResourceDescription
from repro.core.router import ROUTERS, make_router, request_signature
from repro.training.optim import (dequantize_signed, dequantize_unsigned,
                                  quantize_signed, quantize_unsigned)


# ---------------------------------------------------------------------------
# Resource mapper: never oversubscribes; release restores capacity
# ---------------------------------------------------------------------------


@settings(max_examples=50, deadline=None)
@given(
    nodes=st.integers(1, 6),
    cores=st.integers(1, 16),
    reqs=st.lists(st.tuples(st.integers(1, 4), st.integers(1, 8)),
                  min_size=1, max_size=30),
)
def test_mapper_never_oversubscribes(nodes, cores, reqs):
    desc = ResourceDescription(nodes=nodes, cores_per_node=cores)
    alloc = Allocation(desc)
    placements = []
    for ranks, cpr in reqs:
        p = alloc.try_map(ranks, cpr, 0)
        if p is not None:
            placements.append(p)
            # every rank's cores are node-local and within range
            for nid, cs, gs in p.ranks:
                assert len(cs) == cpr
                assert all(0 <= c < cores for c in cs)
        assert alloc.used_cores <= alloc.total_cores
    # no core is double-booked
    booked = {}
    for p in placements:
        for nid, cs, _ in p.ranks:
            for c in cs:
                key = (nid, c)
                assert key not in booked, "core double-booked"
                booked[key] = True
    for p in placements:
        alloc.release(p)
    assert alloc.used_cores == 0


# ---------------------------------------------------------------------------
# Claim API: conservation under claim/release/drain interleavings, for
# both packing strategies — no core/gpu double-booked or leaked, failed
# mappings roll back fully
# ---------------------------------------------------------------------------


_claim_ops = st.lists(
    st.one_of(
        st.tuples(st.just("claim"), st.integers(1, 3), st.integers(1, 4),
                  st.integers(0, 2)),
        st.tuples(st.just("release"), st.integers(0, 63)),
        st.tuples(st.just("drain"), st.integers(0, 7)),
    ),
    min_size=1, max_size=40)


@settings(max_examples=60, deadline=None)
@given(
    nodes=st.integers(1, 5),
    cores=st.integers(1, 8),
    gpus=st.integers(0, 2),
    strategy=st.sampled_from(["first_fit", "best_fit"]),
    ops=_claim_ops,
)
def test_claim_release_drain_conservation(nodes, cores, gpus, strategy, ops):
    from repro.core.task import ResourceRequirements

    desc = ResourceDescription(nodes=nodes, cores_per_node=cores,
                               gpus_per_node=gpus)
    alloc = Allocation(desc, strategy=strategy)
    active = []

    def check():
        # booked == sum of live claims; free + used == total (no leak)
        assert alloc.used_cores == sum(c.placement.n_cores for c in active)
        assert alloc.used_gpus == sum(c.placement.n_gpus for c in active)
        free = alloc.free_capacity()
        assert free["cores"] + alloc.used_cores == alloc.total_cores
        assert free["gpus"] + alloc.used_gpus == alloc.total_gpus
        # no (node, core/gpu) double-booked across live claims
        booked = set()
        for c in active:
            for nid, cs, gs in c.placement.ranks:
                for core in cs:
                    assert ("c", nid, core) not in booked, "double-booked"
                    booked.add(("c", nid, core))
                for g in gs:
                    assert ("g", nid, g) not in booked, "double-booked"
                    booked.add(("g", nid, g))

    for op in ops:
        if op[0] == "claim":
            _, ranks, cpr, gpr = op
            before = (alloc.used_cores, alloc.used_gpus)
            c = alloc.claim(ResourceRequirements(
                ranks=ranks, cores_per_rank=cpr, gpus_per_rank=gpr))
            if c is None:  # denied: the partial binding rolled back fully
                assert (alloc.used_cores, alloc.used_gpus) == before
            else:
                active.append(c)
        elif op[0] == "release":
            if active:
                c = active.pop(op[1] % len(active))
                assert c.release() is True
                assert c.release() is False  # idempotent
        else:  # drain: only succeeds on a fully idle node
            alloc.drain_node(op[1])
        check()
    for c in active:
        assert c.release() is True
    assert alloc.used_cores == 0 and alloc.used_gpus == 0
    assert alloc.free_capacity()["cores"] == alloc.total_cores


@settings(max_examples=40, deadline=None)
@given(
    nodes=st.integers(1, 4),
    cores=st.integers(1, 8),
    reqs=st.lists(st.tuples(st.integers(1, 3), st.integers(1, 6)),
                  min_size=1, max_size=20),
)
def test_fits_agrees_with_actual_claiming(nodes, cores, reqs):
    """``fits(shape)`` must equal the number of identical claims that can
    actually be booked back-to-back (the autoscaler's admission bound)."""
    from repro.core.task import ResourceRequirements

    desc = ResourceDescription(nodes=nodes, cores_per_node=cores)
    for ranks, cpr in reqs:
        alloc = Allocation(desc)
        predicted = alloc.fits(ranks, cpr, 0)
        booked = 0
        while alloc.claim(ResourceRequirements(
                ranks=ranks, cores_per_rank=cpr)) is not None:
            booked += 1
            assert booked <= nodes * cores  # safety bound
        assert booked == predicted


# ---------------------------------------------------------------------------
# Routers: cover every request exactly once; balanced beats random on spread
# ---------------------------------------------------------------------------


@settings(max_examples=50, deadline=None)
@given(
    lens=st.lists(st.integers(1, 500), min_size=1, max_size=60),
    n=st.integers(1, 8),
)
def test_router_partition_property(lens, n):
    """EVERY registered router's assign() covers each request exactly once."""
    reqs = [[0] * L for L in lens]
    for kind in sorted(ROUTERS):
        assign = make_router(kind).assign(reqs, n, cost=len)
        flat = sorted(i for a in assign for i in a)
        assert flat == list(range(len(reqs)))  # exact cover


# one pick() step: (n_instances, cost, session id or None, depths?)
_pick_steps = st.lists(
    st.tuples(st.integers(1, 8), st.floats(0.0, 500.0),
              st.one_of(st.none(), st.integers(0, 5)), st.booleans()),
    min_size=1, max_size=80)


@settings(max_examples=50, deadline=None)
@given(kind=st.sampled_from(sorted(ROUTERS)), steps=_pick_steps)
def test_pick_always_in_range_under_interleaved_resizes(kind, steps):
    """Random pick() sequences with the replica count changing between
    calls (the autoscale pattern) never return an out-of-range index —
    for every registered router, keyed or not, with or without depths."""
    r = make_router(kind)
    for n, cost, session, with_depths in steps:
        key = (None if session is None else
               request_signature({"prompt": [session] * 40}))
        depths = [float((session or 0) + j) for j in range(n)] \
            if with_depths else None
        idx = r.pick(cost, n_instances=n, group="g", queue_depths=depths,
                     affinity_key=key)
        assert 0 <= idx < n


@settings(max_examples=50, deadline=None)
@given(
    sessions=st.lists(st.integers(0, 9), min_size=2, max_size=60),
    n=st.integers(2, 6),
)
def test_prefix_affinity_sticky_while_membership_stable(sessions, n):
    """With a stable replica count and no spill pressure, every repeat of
    a session key re-picks the replica that served it first."""
    r = make_router("prefix_affinity", spill_factor=0.0)  # never spill
    home: dict = {}
    for s in sessions:
        key = request_signature({"prompt": [s] * 40})
        idx = r.pick(1.0, n_instances=n, group="g", affinity_key=key)
        assert 0 <= idx < n
        if key in home:
            assert idx == home[key], "sticky violated on stable membership"
        else:
            home[key] = idx


@settings(max_examples=30, deadline=None)
@given(
    lens=st.lists(st.integers(1, 1000), min_size=8, max_size=60),
)
def test_balanced_router_no_worse_than_random(lens):
    reqs = [[0] * L for L in lens]
    n = 4

    def imbalance(assign):
        loads = [sum(lens[i] for i in a) for a in assign]
        return max(loads) - min(loads)

    bal = imbalance(make_router("balanced").assign(reqs, n, cost=len))
    rnd = imbalance(make_router("random", seed=1).assign(reqs, n, cost=len))
    assert bal <= rnd + max(lens)  # LPT bound: within one max item


# ---------------------------------------------------------------------------
# Coupling store: put/get roundtrip, concurrent readers
# ---------------------------------------------------------------------------


@settings(max_examples=20, deadline=None)
@given(data=st.lists(st.floats(-1e6, 1e6, allow_nan=False), min_size=1,
                     max_size=100))
def test_store_roundtrip(data):
    store = InMemoryStore()
    arr = np.asarray(data, np.float32)
    store.put("k", arr)
    out = store.get("k")
    np.testing.assert_array_equal(arr, out)


def test_store_blocking_get():
    store = InMemoryStore()
    result = {}

    def reader():
        result["v"] = store.get("late", timeout=5.0)

    t = threading.Thread(target=reader)
    t.start()
    store.put("late", np.arange(4))
    t.join(timeout=5)
    np.testing.assert_array_equal(result["v"], np.arange(4))


# ---------------------------------------------------------------------------
# 8-bit optimizer-state quantization: bounded relative error, shape-preserving
# ---------------------------------------------------------------------------


@settings(max_examples=30, deadline=None)
@given(
    shape=st.sampled_from([(7,), (4, 128), (3, 5, 256), (2, 130)]),
    scale=st.floats(1e-6, 1e3),
)
def test_quantization_error_bound(shape, scale):
    rng = np.random.RandomState(0)
    x = (rng.randn(*shape) * scale).astype(np.float32)
    q, s = quantize_signed(x)
    assert q.shape == x.shape
    back = np.asarray(dequantize_signed(q, s))
    # blockwise absmax quantization: error <= blockmax/254 per element
    err = np.abs(back - x)
    assert err.max() <= np.abs(x).max() / 254 + 1e-6

    xp = np.abs(x)
    q2, s2 = quantize_unsigned(xp)
    back2 = np.asarray(dequantize_unsigned(q2, s2))
    assert np.abs(back2 - xp).max() <= xp.max() / 510 + 1e-6


# ---------------------------------------------------------------------------
# Event-log invariants
# ---------------------------------------------------------------------------


@settings(max_examples=30, deadline=None)
@given(st.lists(st.tuples(st.integers(0, 5), st.booleans()),
                min_size=1, max_size=40))
def test_hw_bounded_by_distinct_types(transitions):
    from repro.core.events import EventLog

    log = EventLog()
    open_tasks = {}
    uid = 0
    types = set()
    for ttype_i, close in transitions:
        tt = f"type{ttype_i}"
        types.add(tt)
        if close and open_tasks:
            k, v = open_tasks.popitem()
            log.emit(k, "DONE", v)
        else:
            name = f"t{uid}"
            uid += 1
            log.emit(name, "RUNNING", tt)
            open_tasks[name] = tt
    for k, v in open_tasks.items():
        log.emit(k, "DONE", v)
    assert log.peak_hw() <= len(types)


# ---------------------------------------------------------------------------
# Paged-KV block allocator: conservation under allocate/fork/free
# interleavings — no block leaked, no double-free, refcounts always equal
# the live reference multiset (the copy-on-write safety invariant)
# ---------------------------------------------------------------------------


_block_ops = st.lists(
    st.one_of(
        st.tuples(st.just("alloc")),
        st.tuples(st.just("fork"), st.integers(0, 255)),
        st.tuples(st.just("free"), st.integers(0, 255)),
    ),
    min_size=1, max_size=80)


@settings(max_examples=60, deadline=None)
@given(num_blocks=st.integers(2, 12), ops=_block_ops)
def test_block_allocator_refcount_conservation(num_blocks, ops):
    """Ground-truth model: a multiset of references (one entry per block
    table pointing at a block).  After every op the allocator's refcounts
    must equal the model exactly, free + live must cover capacity, the
    null block must never be handed out, and ``block_savings`` must equal
    the model's duplicate count."""
    from repro.serving.kvcache import NULL_BLOCK, BlockAllocator

    alloc = BlockAllocator(num_blocks)
    refs: list = []  # one element per live reference

    def check():
        assert alloc.n_free + alloc.n_live == alloc.capacity  # no leak
        assert alloc.refcount(NULL_BLOCK) == 0
        for b in range(1, num_blocks):
            assert alloc.refcount(b) == refs.count(b)
        assert alloc.block_savings() == sum(
            max(0, refs.count(b) - 1) for b in set(refs))

    for op in ops:
        if op[0] == "alloc":
            b = alloc.allocate()
            if b is None:  # exhausted, never silently over-allocated
                assert alloc.n_free == 0
            else:
                assert b != NULL_BLOCK
                assert b not in refs  # a free block has no live refs
                refs.append(b)
        elif op[0] == "fork":
            if refs:  # fork only ever targets a live block (engine rule)
                b = refs[op[1] % len(refs)]
                alloc.fork(b)
                refs.append(b)
        else:  # free drops ONE reference; last one returns the block
            if refs:
                b = refs.pop(op[1] % len(refs))
                became_free = alloc.free(b)
                assert became_free == (b not in refs)
        check()
    # drain: releasing every reference restores full capacity
    while refs:
        alloc.free(refs.pop())
    assert alloc.n_free == alloc.capacity
    assert alloc.block_savings() == 0


@settings(max_examples=40, deadline=None)
@given(num_blocks=st.integers(3, 10), ops=_block_ops)
def test_block_allocator_cow_conservation(num_blocks, ops):
    """Copy-on-write as the engine performs it (allocate fresh, free the
    shared original's reference) conserves blocks: interpreting each op
    triple as fork-then-cow on a random shared block keeps free + live ==
    capacity and never double-frees."""
    from repro.serving.kvcache import BlockAllocator

    alloc = BlockAllocator(num_blocks)
    refs: list = []
    for op in ops:
        if op[0] == "alloc":
            b = alloc.allocate()
            if b is not None:
                refs.append(b)
        elif op[0] == "fork":
            if refs:
                b = refs[op[1] % len(refs)]
                alloc.fork(b)
                refs.append(b)
        else:  # cow: a shared block gets a private replacement
            shared = [b for b in refs if alloc.refcount(b) > 1]
            if shared:
                old = shared[op[1] % len(shared)]
                new = alloc.allocate()
                if new is None:
                    continue  # pool full: engine would evict first
                assert alloc.free(old) is False  # others still hold it
                refs.remove(old)
                refs.append(new)
        assert alloc.n_free + alloc.n_live == alloc.capacity
        for b in set(refs):
            assert alloc.refcount(b) == refs.count(b)
    while refs:
        alloc.free(refs.pop())
    assert alloc.n_free == alloc.capacity


# ---------------------------------------------------------------------------
# Multi-tenant QoS: WFQ scheduling + preemption never change tokens, and
# per-tenant accounting conserves under mixed (including failing) load
# ---------------------------------------------------------------------------

_QOS_LM: dict = {}  # built once; @given can't take module fixtures


def _qos_lm():
    if not _QOS_LM:
        import jax

        from repro.configs import get_config
        from repro.models import get_model, nn

        cfg = get_config("rhapsody-demo").scaled(
            n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
            d_ff=128, vocab=512)
        api = get_model(cfg)
        params, _ = nn.split(api.init(jax.random.PRNGKey(0), cfg))
        _QOS_LM["v"] = (cfg, params)
    return _QOS_LM["v"]


_QOS_ENGINE_KW = dict(max_num_seqs=4, max_num_batched_tokens=64, max_len=64,
                      block_size=8, num_blocks=32, prefill_buckets=(16, 32))

_qos_specs = st.lists(
    st.tuples(st.integers(1, 99),                     # prompt token value
              st.integers(3, 12),                     # prompt length
              st.sampled_from(["high", "normal", "low"])),
    min_size=2, max_size=4)


def _run_under_wfq(eng, sched, uids, *, force_preempt_after=None,
                   max_forced=2):
    """Drive an engine to completion under the WFQ scheduler, optionally
    force-preempting up to ``max_forced`` low-class decodes once they have
    emitted ``force_preempt_after`` tokens (on top of whatever pressure
    preemption the scheduler does on its own)."""
    done: dict = {}
    forced: set = set()
    for _ in range(2000):
        sched.schedule(eng)
        eng.step()
        for r in eng.collect_finished():
            done[r.uid] = r
            sched.on_finish(r.uid)
        if force_preempt_after is not None and len(forced) < max_forced:
            for uid, req in list(eng.running.items()):
                if (uid not in forced and req.qos_class == "low"
                        and len(req.output) >= force_preempt_after
                        and eng.preempt_sequence(uid)):
                    forced.add(uid)
                    break
        if len(done) == len(uids):
            return done
    raise AssertionError("engine did not drain under WFQ")


@settings(max_examples=5, deadline=None)
@given(specs=_qos_specs, preempt_after=st.integers(1, 4))
def test_wfq_preempt_resume_token_identity_paged(specs, preempt_after):
    """Random two-class mixes on the paged engine, with the scheduler armed
    AND extra forced preemptions at random decode depths: every transcript
    is token-identical to an unscheduled reference run, and every
    preemption is matched by a resume."""
    from repro.serving.engine import InferenceEngine
    from repro.serving.qos import WFQScheduler

    cfg, params = _qos_lm()
    kw = {**_QOS_ENGINE_KW, "paged": True}
    ref = InferenceEngine(cfg, params, **kw)
    ref_uids = [ref.submit([tok] * ln, max_new_tokens=6)
                for tok, ln, _cls in specs]
    ref_done = ref.run()

    eng = InferenceEngine(cfg, params, **kw)
    sched = WFQScheduler(preempt=True)
    uids = []
    for i, (tok, ln, cls) in enumerate(specs):
        uid = eng.submit([tok] * ln, max_new_tokens=6,
                         tenant=f"t{i}", qos_class=cls)
        sched.on_submit(eng.queue[-1])
        uids.append(uid)
    done = _run_under_wfq(eng, sched, uids,
                          force_preempt_after=preempt_after)

    for ru, u in zip(ref_uids, uids):
        assert done[u].output == ref_done[ru].output
    assert eng.stats.preemptions == eng.stats.preempt_resumes
    assert eng.stats.preemptions >= sched.preempted


@settings(max_examples=5, deadline=None)
@given(specs=_qos_specs)
def test_wfq_reorder_token_identity_dense(specs):
    """On the dense (slot-pool) engine WFQ can only reorder the queue —
    no preemption — and reordering alone never changes any transcript."""
    from repro.serving.engine import InferenceEngine
    from repro.serving.qos import WFQScheduler

    cfg, params = _qos_lm()
    kw = {**_QOS_ENGINE_KW, "paged": False}
    ref = InferenceEngine(cfg, params, **kw)
    ref_uids = [ref.submit([tok] * ln, max_new_tokens=6)
                for tok, ln, _cls in specs]
    ref_done = ref.run()

    eng = InferenceEngine(cfg, params, **kw)
    sched = WFQScheduler(preempt=True)  # preempt flag is a no-op unpaged
    uids = []
    for i, (tok, ln, cls) in enumerate(specs):
        uid = eng.submit([tok] * ln, max_new_tokens=6,
                         tenant=f"t{i}", qos_class=cls)
        sched.on_submit(eng.queue[-1])
        uids.append(uid)
    done = _run_under_wfq(eng, sched, uids)

    for ru, u in zip(ref_uids, uids):
        assert done[u].output == ref_done[ru].output
    assert eng.stats.preemptions == 0


@settings(max_examples=5, deadline=None)
@given(load=st.lists(st.tuples(st.sampled_from(["acme", "bulk"]),
                               st.sampled_from(["high", "low"]),
                               st.booleans()),          # request fails?
                     min_size=1, max_size=16))
def test_per_tenant_accounting_conserves_under_mixed_load(load):
    """Per-tenant ``requests == completed + errors`` holds for every
    tenant under a random two-class mix where any request may fail."""
    from repro.core import (ExecutionPolicy, ResourceDescription, Rhapsody,
                            ServiceDescription)

    class Flaky:
        def handle(self, payload):
            if payload.get("boom"):
                raise RuntimeError("boom")
            return "ok"

    rh = Rhapsody(ResourceDescription(nodes=1, cores_per_node=8),
                  policy=ExecutionPolicy(routing="round_robin"), n_workers=2)
    try:
        rs = rh.add_service(ServiceDescription(name="svc", factory=Flaky,
                                               replicas=2))
        futs = [rs.request({"prompt": [1], "boom": boom},
                           tenant=tenant, priority=prio)
                for tenant, prio, boom in load]
        for f in futs:
            try:
                f.result(timeout=20)
            except RuntimeError:
                pass
        pt = rs.stats()["per_tenant"]
        for tenant in {t for t, _, _ in load}:
            s = pt[tenant]
            assert s["requests"] == s["completed"] + s["errors"]
            assert s["requests"] == sum(1 for t, _, _ in load if t == tenant)
            assert s["errors"] == sum(1 for t, _, b in load
                                      if t == tenant and b)
    finally:
        rh.close()
