"""Continuous-batching inference engine (the vLLM building block).

The engine owns model params + a slot cache pool and exposes the two knobs
the paper sweeps (Fig. 5c): ``max_num_seqs`` (decode slot count) and
``max_num_batched_tokens`` (prefill admission budget per step).  Each
``step()``:

  1. admits queued requests while slots + prefill-token budget allow
     (prompt lengths are bucketed to bound recompilation),
  2. runs one batched decode over all slots,
  3. emits new tokens, retiring finished requests and freeing slots.

Prefix reuse (the serving half of prefix-affinity routing): a freed slot's
KV cache stays resident until the slot is recycled, and the token sequence
it covers is indexed in a per-engine ``RadixIndex`` (``repro.core.prefix``).
Admission asks the index for the deepest common prefix across ALL resident
slots in one O(len(prompt)) descent — replacing the old per-slot linear
scan — and resumes the best slot: its length is rewound to the covered
prefix and only the remaining suffix is fed through the (already batched)
decode path.  The match may be *partial*: a branching turn that shares a
stem with a resident sequence but diverges mid-way rewinds to the
divergence point instead of missing entirely (stale KV past the rewind is
never attended and is overwritten as the suffix feeds in).  The same index
exports ``residency_summary()``, which the replica set gossips to the
router so spill decisions know which replica holds which prefix.  Hits,
partial hits, and skipped tokens are tracked in ``EngineStats``.

Telemetry (per-step active slots, tokens, queue depth) feeds the paper's
utilization/throughput experiments.
"""
from __future__ import annotations

import dataclasses
import itertools
import time
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.prefix import RadixIndex
from repro.models import ModelApi, get_model
from repro.models.config import ModelConfig
from .kvcache import CachePool
from .sampling import sample


@dataclasses.dataclass
class Request:
    uid: int
    prompt: list
    max_new_tokens: int = 16
    temperature: float = 0.0
    eos_id: Optional[int] = None
    # filled by the engine
    output: list = dataclasses.field(default_factory=list)
    submitted_at: float = 0.0
    first_token_at: Optional[float] = None
    finished_at: Optional[float] = None
    slot: Optional[int] = None
    # prefix-reuse resume: prompt suffix still to be fed through decode
    # (one token per step); no output is emitted while any remain
    pending_prefix: list = dataclasses.field(default_factory=list)
    cached_prefix: int = 0  # prompt tokens whose prefill was skipped
    truncated: bool = False  # prompt exceeded max_len/bucket at prefill:
    #                          the cache does not cover the full prompt

    @property
    def done(self) -> bool:
        return self.finished_at is not None

    @property
    def n_prompt(self) -> int:
        return len(self.prompt)


def _bucket(n: int, buckets) -> int:
    for b in buckets:
        if n <= b:
            return b
    return buckets[-1]


@dataclasses.dataclass
class EngineStats:
    steps: int = 0
    decode_tokens: int = 0
    prefill_tokens: int = 0
    active_slot_steps: int = 0
    slot_steps: int = 0
    prefix_reuse_hits: int = 0  # admissions that resumed a resident slot
    prefix_partial_hits: int = 0  # resumes that rewound PAST a divergence
    #                               (resident sequence != prompt prefix)
    prefix_cached_tokens: int = 0  # prompt tokens whose prefill was skipped
    started: float = dataclasses.field(default_factory=time.perf_counter)

    @property
    def utilization(self) -> float:
        return self.active_slot_steps / max(1, self.slot_steps)

    @property
    def tokens_per_s(self) -> float:
        dt = time.perf_counter() - self.started
        return (self.decode_tokens + self.prefill_tokens) / max(1e-9, dt)


class InferenceEngine:
    """Single-model continuous-batching engine."""

    def __init__(self, cfg: ModelConfig, params, *, max_num_seqs: int = 8,
                 max_num_batched_tokens: int = 2048, max_len: int = 512,
                 prefill_buckets=(32, 64, 128, 256, 512), seed: int = 0,
                 mesh=None, enable_prefix_reuse: bool = True):
        self.cfg = cfg
        self.api: ModelApi = get_model(cfg)
        self.params = params
        self.max_num_seqs = max_num_seqs
        self.max_num_batched_tokens = max_num_batched_tokens
        self.max_len = max_len
        self.buckets = tuple(b for b in prefill_buckets if b <= max_len) or (max_len,)
        self.mesh = mesh
        self.pool = CachePool(cfg, max_num_seqs, max_len)
        self.queue: list[Request] = []
        self.running: dict[int, Request] = {}  # slot -> request
        # radix index over the token sequences freed slots' caches still
        # cover (value = slot id); admission finds the deepest resident
        # common prefix in one O(len(prompt)) descent.  State-carrying
        # families (ssm/hybrid) have no per-position KV to rewind, so the
        # fast path is gated off for them below.
        self._prefix_index = RadixIndex()
        self._resident_len: dict[int, int] = {}  # slot -> covered seq len
        # residency gossip PUSH channel: called (no args) whenever resident
        # KV is dropped (evicted or reclaimed), so the replica set can
        # refresh the router's residency view immediately instead of
        # leaving a staleness window until the next pull tick
        self.on_residency_drop: Optional[Callable[[], None]] = None
        self.stats = EngineStats()
        self._uid = itertools.count()
        self._key = jax.random.PRNGKey(seed)
        self._last_tokens = jnp.zeros((max_num_seqs,), jnp.int32)

        api = self.api

        def decode_fn(params, cache, tokens):
            return api.decode(params, cache, tokens, cfg, mesh=mesh)

        self._decode = jax.jit(decode_fn, donate_argnums=(1,))

        # KV-cache families: right-pad prompts into buckets, fix cache "len"
        # afterwards, read logits at the true last position.  State-carrying
        # families (ssm/hybrid) need exact-length prefill (order-dependent
        # state), which recompiles per distinct prompt length.
        self._exact_prefill = cfg.family in ("ssm", "hybrid")
        # prefix reuse needs prompt token i <-> cache position i: true for
        # pure text decoders, not for ssm/hybrid (monolithic state, nothing
        # to rewind) or vlm/encdec (vision/audio prefix offsets positions)
        self._prefix_reuse = (enable_prefix_reuse
                              and cfg.family in ("dense", "moe"))

        def prefill_fn(params, batch):
            kw = {"max_len": max_len}
            if not self._exact_prefill:
                kw["last_only"] = False
            return api.prefill(params, batch, cfg, mesh=mesh, **kw)

        self._prefill = jax.jit(prefill_fn)

    # ------------------------------------------------------------------
    # Public API
    # ------------------------------------------------------------------
    def submit(self, prompt, *, max_new_tokens=16, temperature=0.0,
               eos_id=None) -> int:
        req = Request(uid=next(self._uid), prompt=list(prompt),
                      max_new_tokens=max_new_tokens, temperature=temperature,
                      eos_id=eos_id, submitted_at=time.perf_counter())
        self.queue.append(req)
        return req.uid

    def has_work(self) -> bool:
        return bool(self.queue or self.running)

    def step(self) -> list:
        """One engine iteration. Returns [(uid, token), ...] emitted."""
        self._admit()
        events = []
        if self.running:
            events = self._decode_step()
        self.stats.steps += 1
        self.stats.active_slot_steps += len(self.running)
        self.stats.slot_steps += self.max_num_seqs
        return events

    def collect_finished(self) -> list:
        """Retire finished requests, freeing their slots.  With prefix
        reuse on, the freed slot's KV stays resident (it is only memory
        already allocated) and the sequence it covers is remembered so a
        later prompt extending it can skip that prefill."""
        done = []
        for slot, req in list(self.running.items()):
            if req.done:
                del self.running[slot]
                if self._prefix_reuse and not req.truncated:
                    seq = tuple(req.prompt) + tuple(req.output)
                    self._drop_residency(slot)  # stale entry, if any
                    self._prefix_index.insert(seq, slot)
                    self._resident_len[slot] = len(seq)
                    self.pool.free(slot, resident=True)
                else:
                    self.pool.free(slot)
                done.append(req)
        return done

    def run(self, *, max_steps: int = 100000) -> dict:
        """Drain the queue; returns completed requests keyed by uid."""
        done: dict[int, Request] = {}
        for _ in range(max_steps):
            if not self.has_work():
                break
            self.step()
            for req in self.collect_finished():
                done[req.uid] = req
        return done

    # ------------------------------------------------------------------
    # Internals
    # ------------------------------------------------------------------
    def _admit(self):
        budget = self.max_num_batched_tokens
        while self.queue and self.pool.n_free > 0:
            req = self.queue[0]
            if self._prefix_reuse and self._try_resume(req):
                self.queue.pop(0)  # resumed: no prefill, no budget charge
                continue
            n = min(req.n_prompt, self.max_len - 1)
            bucket = n if self._exact_prefill else _bucket(n, self.buckets)
            n = min(n, bucket)  # over-long prompts keep their last n tokens
            if bucket > budget:
                break
            self.queue.pop(0)
            slot = self.pool.allocate()  # blank-preferring: resident KV is
            self._drop_residency(slot)  # only evicted when no blank is left
            req.truncated = n < req.n_prompt
            budget -= bucket
            tokens = np.zeros((1, bucket), np.int32)
            tokens[0, :n] = req.prompt[-n:]  # right-pad into the bucket
            batch = {"tokens": jnp.asarray(tokens)}
            if self.cfg.family == "encdec":
                batch["frame_embeds"] = jnp.zeros(
                    (1, 64, self.cfg.d_model), jnp.float32)
            if self.cfg.family == "vlm":
                batch["patch_embeds"] = jnp.zeros(
                    (1, self.cfg.vision_tokens or 16, self.cfg.d_model),
                    jnp.float32)
            cache, logits = self._prefill(self.params, batch)
            self.pool.insert(slot, cache)
            if not self._exact_prefill:
                self.pool.set_len(slot, n)
                logits_last = logits[0, n - 1]
            else:
                logits_last = logits[0]
            self.stats.prefill_tokens += bucket
            if req.temperature > 0:
                # match the decode path's temperature gating: the first
                # generated token must follow the same sampling policy
                # whether it comes from a fresh prefill or a resumed slot
                self._key, sub = jax.random.split(self._key)
                tok = int(sample(logits_last[None, :], sub,
                                 temperature=req.temperature)[0])
            else:
                tok = int(jnp.argmax(logits_last))
            req.slot = slot
            req.output.append(tok)
            req.first_token_at = time.perf_counter()
            self._last_tokens = self._last_tokens.at[slot].set(tok)
            self.running[slot] = req
            self._check_done(req)

    def _drop_residency(self, slot: Optional[int], notify: bool = True):
        """Forget a slot's resident sequence (its cache is being replaced
        or re-claimed), notifying the push listener when coverage the
        router may rely on actually disappeared.  The prefix-reuse resume
        path passes ``notify=False``: a take-for-resume is a HIT (the
        consuming request is already routed here), and pushing on every
        hit would re-arm a near-continuous gossip loop on the hot path."""
        if slot is None:
            return
        had = self._resident_len.pop(slot, None) is not None
        self._prefix_index.remove_value(slot)
        if notify and had and self.on_residency_drop is not None:
            try:
                self.on_residency_drop()
            except Exception:
                pass  # gossip is best-effort; serving must not care

    def residency_summary(self, max_entries: Optional[int] = None,
                          max_len: int = 128) -> list:
        """Resident token sequences (newest first, truncated), the payload
        the replica set gossips to the router's residency index."""
        return self._prefix_index.summary(
            max_entries=max_entries or self.max_num_seqs, max_len=max_len)

    def _try_resume(self, req: Request) -> bool:
        """Prefix-reuse fast path: claim the freed slot whose resident KV
        shares the deepest usable prefix with ``req.prompt`` and skip
        prefill for that prefix.

        The radix index answers the best common-prefix length per resident
        slot in one O(len(prompt)) descent.  A resident sequence of length
        L has KV for its first L-1 tokens (the final emitted token was
        never fed back), and the prompt's first d tokens match the resident
        sequence, so positions < min(d, L-1) hold valid KV — including
        *partial* matches where the resident transcript diverges from the
        prompt at d < L (a branching turn).  The resume rewinds the slot's
        length to that point and feeds the remaining prompt through the
        batched decode — one token per step, exactly the incremental path —
        with the last feed's logits producing the first new token.  Stale
        KV at positions >= the rewind (divergence junk, or junk appended
        while the slot idled) is never attended and is overwritten by those
        feeds.
        """
        m = req.n_prompt
        if m >= self.max_len:  # would be truncated: prefix math breaks
            return False
        # minimum-benefit gate: the uncovered suffix is fed one token per
        # decode step, so resuming must cover at least half the prompt —
        # a short shared stem on a long fresh prompt is cheaper to prefill
        # in one bucketed call than to drip through hundreds of decodes
        threshold = max(1, (m + 1) // 2)
        candidates = []
        for slot, d in self._prefix_index.match_lengths(req.prompt).items():
            L = self._resident_len.get(slot)
            if L is None:
                continue
            covered = min(d, L - 1, m - 1)
            if covered >= threshold:
                candidates.append((covered, slot, L, d))
        candidates.sort(reverse=True)  # deepest usable rewind first
        for covered, slot, L, d in candidates:
            if not self.pool.take(slot):
                continue  # defensively skip a slot that is no longer free
            self._drop_residency(slot, notify=False)  # resume hit, not an
            #                                           eviction
            self.pool.set_len(slot, covered)
            self._last_tokens = self._last_tokens.at[slot].set(
                req.prompt[covered])
            req.pending_prefix = list(req.prompt[covered + 1:])
            req.cached_prefix = covered
            req.slot = slot
            self.running[slot] = req
            self.stats.prefix_reuse_hits += 1
            if d < L and d < m:  # the resident transcript and the prompt
                #                  genuinely diverge (not a mere replay of
                #                  a shorter prefix): a true partial resume
                self.stats.prefix_partial_hits += 1
            self.stats.prefix_cached_tokens += covered
            self.stats.prefill_tokens += 1  # the feed queued into
            #                  _last_tokens; the rest count as they are fed
            return True
        return False

    def _decode_step(self):
        self._key, sub = jax.random.split(self._key)
        self.pool.cache, logits = self._decode(
            self.params, self.pool.cache, self._last_tokens)
        temps = np.zeros((self.max_num_seqs,), np.float32)
        for slot, req in self.running.items():
            temps[slot] = req.temperature
        # greedy for temp==0 slots, sampled otherwise
        greedy = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        sampled = sample(logits, sub, temperature=1.0)
        t = jnp.asarray(temps)
        tokens = jnp.where(t > 0, sampled, greedy)
        tokens_np = np.asarray(tokens)
        # only a resumed request forces the host-side token rewrite (and
        # the device re-upload below); the common all-decode step keeps the
        # device array as-is
        has_pending = any(req.pending_prefix
                          for req in self.running.values())
        if has_pending:
            tokens_np = tokens_np.copy()
        events = []
        for slot, req in list(self.running.items()):
            if req.done:
                continue
            if req.pending_prefix:
                # resumed request still catching up on its prompt suffix:
                # force-feed the next prompt token instead of the model's
                # prediction, and emit nothing until the prompt is consumed
                tokens_np[slot] = req.pending_prefix.pop(0)
                self.stats.prefill_tokens += 1
                continue
            tok = int(tokens_np[slot])
            req.output.append(tok)
            if req.first_token_at is None:  # resumed: first real token
                req.first_token_at = time.perf_counter()
            events.append((req.uid, tok))
            self.stats.decode_tokens += 1
            self._check_done(req)
        self._last_tokens = jnp.asarray(tokens_np) if has_pending else tokens
        return events

    def _check_done(self, req: Request):
        if req.done:
            return
        hit_eos = req.eos_id is not None and req.output and \
            req.output[-1] == req.eos_id
        if len(req.output) >= req.max_new_tokens or hit_eos:
            req.finished_at = time.perf_counter()


def make_engine_from_scratch(cfg: ModelConfig, *, seed=0, **kw):
    """Init params and build an engine (used by services/examples)."""
    from repro.models import nn

    api = get_model(cfg)
    params, _ = nn.split(api.init(jax.random.PRNGKey(seed), cfg))
    return InferenceEngine(cfg, params, **kw)
