"""Continuous-batching inference engine (the vLLM building block).

The engine owns model params + a slot cache pool and exposes the two knobs
the paper sweeps (Fig. 5c): ``max_num_seqs`` (decode slot count) and
``max_num_batched_tokens`` (prefill admission budget per step).  Each
``step()``:

  1. admits queued requests while slots + prefill-token budget allow
     (prompt lengths are bucketed to bound recompilation),
  2. runs one batched decode over all slots,
  3. emits new tokens, retiring finished requests and freeing slots.

Telemetry (per-step active slots, tokens, queue depth) feeds the paper's
utilization/throughput experiments.
"""
from __future__ import annotations

import dataclasses
import itertools
import time
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import ModelApi, get_model
from repro.models.config import ModelConfig
from .kvcache import CachePool
from .sampling import sample


@dataclasses.dataclass
class Request:
    uid: int
    prompt: list
    max_new_tokens: int = 16
    temperature: float = 0.0
    eos_id: Optional[int] = None
    # filled by the engine
    output: list = dataclasses.field(default_factory=list)
    submitted_at: float = 0.0
    first_token_at: Optional[float] = None
    finished_at: Optional[float] = None
    slot: Optional[int] = None

    @property
    def done(self) -> bool:
        return self.finished_at is not None

    @property
    def n_prompt(self) -> int:
        return len(self.prompt)


def _bucket(n: int, buckets) -> int:
    for b in buckets:
        if n <= b:
            return b
    return buckets[-1]


@dataclasses.dataclass
class EngineStats:
    steps: int = 0
    decode_tokens: int = 0
    prefill_tokens: int = 0
    active_slot_steps: int = 0
    slot_steps: int = 0
    started: float = dataclasses.field(default_factory=time.perf_counter)

    @property
    def utilization(self) -> float:
        return self.active_slot_steps / max(1, self.slot_steps)

    @property
    def tokens_per_s(self) -> float:
        dt = time.perf_counter() - self.started
        return (self.decode_tokens + self.prefill_tokens) / max(1e-9, dt)


class InferenceEngine:
    """Single-model continuous-batching engine."""

    def __init__(self, cfg: ModelConfig, params, *, max_num_seqs: int = 8,
                 max_num_batched_tokens: int = 2048, max_len: int = 512,
                 prefill_buckets=(32, 64, 128, 256, 512), seed: int = 0,
                 mesh=None):
        self.cfg = cfg
        self.api: ModelApi = get_model(cfg)
        self.params = params
        self.max_num_seqs = max_num_seqs
        self.max_num_batched_tokens = max_num_batched_tokens
        self.max_len = max_len
        self.buckets = tuple(b for b in prefill_buckets if b <= max_len) or (max_len,)
        self.mesh = mesh
        self.pool = CachePool(cfg, max_num_seqs, max_len)
        self.queue: list[Request] = []
        self.running: dict[int, Request] = {}  # slot -> request
        self.stats = EngineStats()
        self._uid = itertools.count()
        self._key = jax.random.PRNGKey(seed)
        self._last_tokens = jnp.zeros((max_num_seqs,), jnp.int32)

        api = self.api

        def decode_fn(params, cache, tokens):
            return api.decode(params, cache, tokens, cfg, mesh=mesh)

        self._decode = jax.jit(decode_fn, donate_argnums=(1,))

        # KV-cache families: right-pad prompts into buckets, fix cache "len"
        # afterwards, read logits at the true last position.  State-carrying
        # families (ssm/hybrid) need exact-length prefill (order-dependent
        # state), which recompiles per distinct prompt length.
        self._exact_prefill = cfg.family in ("ssm", "hybrid")

        def prefill_fn(params, batch):
            kw = {"max_len": max_len}
            if not self._exact_prefill:
                kw["last_only"] = False
            return api.prefill(params, batch, cfg, mesh=mesh, **kw)

        self._prefill = jax.jit(prefill_fn)

    # ------------------------------------------------------------------
    # Public API
    # ------------------------------------------------------------------
    def submit(self, prompt, *, max_new_tokens=16, temperature=0.0,
               eos_id=None) -> int:
        req = Request(uid=next(self._uid), prompt=list(prompt),
                      max_new_tokens=max_new_tokens, temperature=temperature,
                      eos_id=eos_id, submitted_at=time.perf_counter())
        self.queue.append(req)
        return req.uid

    def has_work(self) -> bool:
        return bool(self.queue or self.running)

    def step(self) -> list:
        """One engine iteration. Returns [(uid, token), ...] emitted."""
        self._admit()
        events = []
        if self.running:
            events = self._decode_step()
        self.stats.steps += 1
        self.stats.active_slot_steps += len(self.running)
        self.stats.slot_steps += self.max_num_seqs
        return events

    def collect_finished(self) -> list:
        """Retire finished requests, freeing their slots."""
        done = []
        for slot, req in list(self.running.items()):
            if req.done:
                del self.running[slot]
                self.pool.free(slot)
                done.append(req)
        return done

    def run(self, *, max_steps: int = 100000) -> dict:
        """Drain the queue; returns completed requests keyed by uid."""
        done: dict[int, Request] = {}
        for _ in range(max_steps):
            if not self.has_work():
                break
            self.step()
            for req in self.collect_finished():
                done[req.uid] = req
        return done

    # ------------------------------------------------------------------
    # Internals
    # ------------------------------------------------------------------
    def _admit(self):
        budget = self.max_num_batched_tokens
        while self.queue and self.pool.n_free > 0:
            req = self.queue[0]
            n = min(req.n_prompt, self.max_len - 1)
            bucket = n if self._exact_prefill else _bucket(n, self.buckets)
            n = min(n, bucket)  # over-long prompts keep their last n tokens
            if bucket > budget:
                break
            self.queue.pop(0)
            slot = self.pool.allocate()
            budget -= bucket
            tokens = np.zeros((1, bucket), np.int32)
            tokens[0, :n] = req.prompt[-n:]  # right-pad into the bucket
            batch = {"tokens": jnp.asarray(tokens)}
            if self.cfg.family == "encdec":
                batch["frame_embeds"] = jnp.zeros(
                    (1, 64, self.cfg.d_model), jnp.float32)
            if self.cfg.family == "vlm":
                batch["patch_embeds"] = jnp.zeros(
                    (1, self.cfg.vision_tokens or 16, self.cfg.d_model),
                    jnp.float32)
            cache, logits = self._prefill(self.params, batch)
            self.pool.insert(slot, cache)
            if not self._exact_prefill:
                self.pool.set_len(slot, n)
                logits_last = logits[0, n - 1]
            else:
                logits_last = logits[0]
            self.stats.prefill_tokens += bucket
            tok = int(jnp.argmax(logits_last))
            req.slot = slot
            req.output.append(tok)
            req.first_token_at = time.perf_counter()
            self._last_tokens = self._last_tokens.at[slot].set(tok)
            self.running[slot] = req
            self._check_done(req)

    def _decode_step(self):
        self._key, sub = jax.random.split(self._key)
        self.pool.cache, logits = self._decode(
            self.params, self.pool.cache, self._last_tokens)
        temps = np.zeros((self.max_num_seqs,), np.float32)
        for slot, req in self.running.items():
            temps[slot] = req.temperature
        # greedy for temp==0 slots, sampled otherwise
        greedy = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        sampled = sample(logits, sub, temperature=1.0)
        t = jnp.asarray(temps)
        tokens = jnp.where(t > 0, sampled, greedy)
        self._last_tokens = tokens
        tokens_np = np.asarray(tokens)
        events = []
        for slot, req in list(self.running.items()):
            if req.done:
                continue
            tok = int(tokens_np[slot])
            req.output.append(tok)
            events.append((req.uid, tok))
            self.stats.decode_tokens += 1
            self._check_done(req)
        return events

    def _check_done(self, req: Request):
        if req.done:
            return
        hit_eos = req.eos_id is not None and req.output and \
            req.output[-1] == req.eos_id
        if len(req.output) >= req.max_new_tokens or hit_eos:
            req.finished_at = time.perf_counter()


def make_engine_from_scratch(cfg: ModelConfig, *, seed=0, **kw):
    """Init params and build an engine (used by services/examples)."""
    from repro.models import nn

    api = get_model(cfg)
    params, _ = nn.split(api.init(jax.random.PRNGKey(seed), cfg))
    return InferenceEngine(cfg, params, **kw)
