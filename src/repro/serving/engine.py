"""Continuous-batching inference engine (the vLLM building block).

The engine owns model params + a slot cache pool and exposes the two knobs
the paper sweeps (Fig. 5c): ``max_num_seqs`` (decode slot count) and
``max_num_batched_tokens`` (prefill admission budget per step).  Each
``step()``:

  1. admits queued requests while slots + prefill-token budget allow
     (prompt lengths are bucketed to bound recompilation),
  2. runs one batched decode over all slots,
  3. emits new tokens, retiring finished requests and freeing slots.

Prefix reuse (the serving half of prefix-affinity routing): a freed slot's
KV cache stays resident until the slot is recycled, remembering the token
sequence it holds.  When a submitted prompt *extends* a resident sequence
— the multi-turn chat pattern the ``prefix_affinity`` router steers back
to this replica — admission skips prefill for the cached prefix entirely:
the slot is re-claimed, its length rewound to the covered prefix, and only
the new suffix is fed through the (already batched) decode path.  Hits and
skipped tokens are tracked in ``EngineStats``.

Telemetry (per-step active slots, tokens, queue depth) feeds the paper's
utilization/throughput experiments.
"""
from __future__ import annotations

import dataclasses
import itertools
import time
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import ModelApi, get_model
from repro.models.config import ModelConfig
from .kvcache import CachePool
from .sampling import sample


@dataclasses.dataclass
class Request:
    uid: int
    prompt: list
    max_new_tokens: int = 16
    temperature: float = 0.0
    eos_id: Optional[int] = None
    # filled by the engine
    output: list = dataclasses.field(default_factory=list)
    submitted_at: float = 0.0
    first_token_at: Optional[float] = None
    finished_at: Optional[float] = None
    slot: Optional[int] = None
    # prefix-reuse resume: prompt suffix still to be fed through decode
    # (one token per step); no output is emitted while any remain
    pending_prefix: list = dataclasses.field(default_factory=list)
    cached_prefix: int = 0  # prompt tokens whose prefill was skipped
    truncated: bool = False  # prompt exceeded max_len/bucket at prefill:
    #                          the cache does not cover the full prompt

    @property
    def done(self) -> bool:
        return self.finished_at is not None

    @property
    def n_prompt(self) -> int:
        return len(self.prompt)


def _bucket(n: int, buckets) -> int:
    for b in buckets:
        if n <= b:
            return b
    return buckets[-1]


@dataclasses.dataclass
class EngineStats:
    steps: int = 0
    decode_tokens: int = 0
    prefill_tokens: int = 0
    active_slot_steps: int = 0
    slot_steps: int = 0
    prefix_reuse_hits: int = 0  # admissions that resumed a resident slot
    prefix_cached_tokens: int = 0  # prompt tokens whose prefill was skipped
    started: float = dataclasses.field(default_factory=time.perf_counter)

    @property
    def utilization(self) -> float:
        return self.active_slot_steps / max(1, self.slot_steps)

    @property
    def tokens_per_s(self) -> float:
        dt = time.perf_counter() - self.started
        return (self.decode_tokens + self.prefill_tokens) / max(1e-9, dt)


class InferenceEngine:
    """Single-model continuous-batching engine."""

    def __init__(self, cfg: ModelConfig, params, *, max_num_seqs: int = 8,
                 max_num_batched_tokens: int = 2048, max_len: int = 512,
                 prefill_buckets=(32, 64, 128, 256, 512), seed: int = 0,
                 mesh=None, enable_prefix_reuse: bool = True):
        self.cfg = cfg
        self.api: ModelApi = get_model(cfg)
        self.params = params
        self.max_num_seqs = max_num_seqs
        self.max_num_batched_tokens = max_num_batched_tokens
        self.max_len = max_len
        self.buckets = tuple(b for b in prefill_buckets if b <= max_len) or (max_len,)
        self.mesh = mesh
        self.pool = CachePool(cfg, max_num_seqs, max_len)
        self.queue: list[Request] = []
        self.running: dict[int, Request] = {}  # slot -> request
        # slot -> token sequence its (freed) cache still covers; consulted
        # at admission for the prefix-reuse fast path.  State-carrying
        # families (ssm/hybrid) have no per-position KV to rewind, so the
        # fast path is gated off for them below.
        self._resident: dict[int, list] = {}
        self.stats = EngineStats()
        self._uid = itertools.count()
        self._key = jax.random.PRNGKey(seed)
        self._last_tokens = jnp.zeros((max_num_seqs,), jnp.int32)

        api = self.api

        def decode_fn(params, cache, tokens):
            return api.decode(params, cache, tokens, cfg, mesh=mesh)

        self._decode = jax.jit(decode_fn, donate_argnums=(1,))

        # KV-cache families: right-pad prompts into buckets, fix cache "len"
        # afterwards, read logits at the true last position.  State-carrying
        # families (ssm/hybrid) need exact-length prefill (order-dependent
        # state), which recompiles per distinct prompt length.
        self._exact_prefill = cfg.family in ("ssm", "hybrid")
        # prefix reuse needs prompt token i <-> cache position i: true for
        # pure text decoders, not for ssm/hybrid (monolithic state, nothing
        # to rewind) or vlm/encdec (vision/audio prefix offsets positions)
        self._prefix_reuse = (enable_prefix_reuse
                              and cfg.family in ("dense", "moe"))

        def prefill_fn(params, batch):
            kw = {"max_len": max_len}
            if not self._exact_prefill:
                kw["last_only"] = False
            return api.prefill(params, batch, cfg, mesh=mesh, **kw)

        self._prefill = jax.jit(prefill_fn)

    # ------------------------------------------------------------------
    # Public API
    # ------------------------------------------------------------------
    def submit(self, prompt, *, max_new_tokens=16, temperature=0.0,
               eos_id=None) -> int:
        req = Request(uid=next(self._uid), prompt=list(prompt),
                      max_new_tokens=max_new_tokens, temperature=temperature,
                      eos_id=eos_id, submitted_at=time.perf_counter())
        self.queue.append(req)
        return req.uid

    def has_work(self) -> bool:
        return bool(self.queue or self.running)

    def step(self) -> list:
        """One engine iteration. Returns [(uid, token), ...] emitted."""
        self._admit()
        events = []
        if self.running:
            events = self._decode_step()
        self.stats.steps += 1
        self.stats.active_slot_steps += len(self.running)
        self.stats.slot_steps += self.max_num_seqs
        return events

    def collect_finished(self) -> list:
        """Retire finished requests, freeing their slots.  With prefix
        reuse on, the freed slot's KV stays resident (it is only memory
        already allocated) and the sequence it covers is remembered so a
        later prompt extending it can skip that prefill."""
        done = []
        for slot, req in list(self.running.items()):
            if req.done:
                del self.running[slot]
                self.pool.free(slot)
                if self._prefix_reuse and not req.truncated:
                    self._resident[slot] = list(req.prompt) + list(req.output)
                done.append(req)
        return done

    def run(self, *, max_steps: int = 100000) -> dict:
        """Drain the queue; returns completed requests keyed by uid."""
        done: dict[int, Request] = {}
        for _ in range(max_steps):
            if not self.has_work():
                break
            self.step()
            for req in self.collect_finished():
                done[req.uid] = req
        return done

    # ------------------------------------------------------------------
    # Internals
    # ------------------------------------------------------------------
    def _admit(self):
        budget = self.max_num_batched_tokens
        while self.queue and self.pool.n_free > 0:
            req = self.queue[0]
            if self._prefix_reuse and self._try_resume(req):
                self.queue.pop(0)  # resumed: no prefill, no budget charge
                continue
            n = min(req.n_prompt, self.max_len - 1)
            bucket = n if self._exact_prefill else _bucket(n, self.buckets)
            n = min(n, bucket)  # over-long prompts keep their last n tokens
            if bucket > budget:
                break
            self.queue.pop(0)
            slot = self.pool.allocate()
            self._resident.pop(slot, None)  # cache is about to be replaced
            req.truncated = n < req.n_prompt
            budget -= bucket
            tokens = np.zeros((1, bucket), np.int32)
            tokens[0, :n] = req.prompt[-n:]  # right-pad into the bucket
            batch = {"tokens": jnp.asarray(tokens)}
            if self.cfg.family == "encdec":
                batch["frame_embeds"] = jnp.zeros(
                    (1, 64, self.cfg.d_model), jnp.float32)
            if self.cfg.family == "vlm":
                batch["patch_embeds"] = jnp.zeros(
                    (1, self.cfg.vision_tokens or 16, self.cfg.d_model),
                    jnp.float32)
            cache, logits = self._prefill(self.params, batch)
            self.pool.insert(slot, cache)
            if not self._exact_prefill:
                self.pool.set_len(slot, n)
                logits_last = logits[0, n - 1]
            else:
                logits_last = logits[0]
            self.stats.prefill_tokens += bucket
            if req.temperature > 0:
                # match the decode path's temperature gating: the first
                # generated token must follow the same sampling policy
                # whether it comes from a fresh prefill or a resumed slot
                self._key, sub = jax.random.split(self._key)
                tok = int(sample(logits_last[None, :], sub,
                                 temperature=req.temperature)[0])
            else:
                tok = int(jnp.argmax(logits_last))
            req.slot = slot
            req.output.append(tok)
            req.first_token_at = time.perf_counter()
            self._last_tokens = self._last_tokens.at[slot].set(tok)
            self.running[slot] = req
            self._check_done(req)

    def _try_resume(self, req: Request) -> bool:
        """Prefix-reuse fast path: if ``req.prompt`` extends the token
        sequence a freed slot's cache still covers, claim that slot and
        skip prefill for the covered prefix.

        A resident sequence of length L has KV for its first L-1 tokens
        (the final emitted token was never fed back), so the resume rewinds
        the slot's length to L-1 and feeds ``prompt[L-1:]`` through the
        batched decode — one token per step, exactly the incremental path —
        with the last feed's logits producing the first new token.  Junk
        appended at positions >= L-1 while the slot idled (decode advances
        every slot) is overwritten by those feeds after the rewind.
        """
        m = req.n_prompt
        if m >= self.max_len:  # would be truncated: prefix math breaks
            return False
        # minimum-benefit gate: the uncovered suffix is fed one token per
        # decode step, so resuming must cover at least half the prompt —
        # a short shared stem on a long fresh prompt is cheaper to prefill
        # in one bucketed call than to drip through hundreds of decodes
        best_slot, best_len = None, max(1, (m + 1) // 2)
        for slot, seq in self._resident.items():
            L = len(seq)
            if L > best_len and L <= m and req.prompt[:L] == seq:
                best_slot, best_len = slot, L
        if best_slot is None or not self.pool.take(best_slot):
            return False
        seq = self._resident.pop(best_slot)
        covered = len(seq) - 1
        self.pool.set_len(best_slot, covered)
        self._last_tokens = self._last_tokens.at[best_slot].set(
            req.prompt[covered])
        req.pending_prefix = list(req.prompt[covered + 1:])
        req.cached_prefix = covered
        req.slot = best_slot
        self.running[best_slot] = req
        self.stats.prefix_reuse_hits += 1
        self.stats.prefix_cached_tokens += covered
        self.stats.prefill_tokens += 1  # the feed queued into _last_tokens;
        #                                 the rest count as they are fed
        return True

    def _decode_step(self):
        self._key, sub = jax.random.split(self._key)
        self.pool.cache, logits = self._decode(
            self.params, self.pool.cache, self._last_tokens)
        temps = np.zeros((self.max_num_seqs,), np.float32)
        for slot, req in self.running.items():
            temps[slot] = req.temperature
        # greedy for temp==0 slots, sampled otherwise
        greedy = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        sampled = sample(logits, sub, temperature=1.0)
        t = jnp.asarray(temps)
        tokens = jnp.where(t > 0, sampled, greedy)
        tokens_np = np.asarray(tokens)
        # only a resumed request forces the host-side token rewrite (and
        # the device re-upload below); the common all-decode step keeps the
        # device array as-is
        has_pending = any(req.pending_prefix
                          for req in self.running.values())
        if has_pending:
            tokens_np = tokens_np.copy()
        events = []
        for slot, req in list(self.running.items()):
            if req.done:
                continue
            if req.pending_prefix:
                # resumed request still catching up on its prompt suffix:
                # force-feed the next prompt token instead of the model's
                # prediction, and emit nothing until the prompt is consumed
                tokens_np[slot] = req.pending_prefix.pop(0)
                self.stats.prefill_tokens += 1
                continue
            tok = int(tokens_np[slot])
            req.output.append(tok)
            if req.first_token_at is None:  # resumed: first real token
                req.first_token_at = time.perf_counter()
            events.append((req.uid, tok))
            self.stats.decode_tokens += 1
            self._check_done(req)
        self._last_tokens = jnp.asarray(tokens_np) if has_pending else tokens
        return events

    def _check_done(self, req: Request):
        if req.done:
            return
        hit_eos = req.eos_id is not None and req.output and \
            req.output[-1] == req.eos_id
        if len(req.output) >= req.max_new_tokens or hit_eos:
            req.finished_at = time.perf_counter()


def make_engine_from_scratch(cfg: ModelConfig, *, seed=0, **kw):
    """Init params and build an engine (used by services/examples)."""
    from repro.models import nn

    api = get_model(cfg)
    params, _ = nn.split(api.init(jax.random.PRNGKey(seed), cfg))
    return InferenceEngine(cfg, params, **kw)
