"""Continuous-batching inference engine (the vLLM building block).

The engine owns model params + a KV cache pool and exposes the two knobs
the paper sweeps (Fig. 5c): ``max_num_seqs`` (decode slot count) and
``max_num_batched_tokens`` (prefill admission budget per step).  Each
``step()``:

  1. admits queued requests while slots + prefill-token budget allow
     (prompt lengths are bucketed to bound recompilation),
  2. runs one batched decode over all slots,
  3. emits new tokens, retiring finished requests and freeing slots.

Prefix reuse (the serving half of prefix-affinity routing): a freed slot's
KV cache stays resident until the slot is recycled, and the token sequence
it covers is indexed in a per-engine ``RadixIndex`` (``repro.core.prefix``).
Admission asks the index for the deepest common prefix across ALL resident
slots in one O(len(prompt)) descent — replacing the old per-slot linear
scan — and resumes the best slot: its length is rewound to the covered
prefix and only the remaining suffix is fed through the (already batched)
decode path.  The match may be *partial*: a branching turn that shares a
stem with a resident sequence but diverges mid-way rewinds to the
divergence point instead of missing entirely (stale KV past the rewind is
never attended and is overwritten as the suffix feeds in).  The same index
exports ``residency_summary()``, which the replica set gossips to the
router so spill decisions know which replica holds which prefix.  Hits,
partial hits, and skipped tokens are tracked in ``EngineStats``.

``paged=True`` switches to the block-paged pool (``PagedCachePool``):

  * sequences hold *block tables* over a ``[num_blocks, block_size, ...]``
    physical store, so concurrency is bounded by free BLOCKS, not by a
    fixed slot count — short sequences no longer pin a whole
    ``max_len`` slot and the engine admits well past ``max_num_seqs``;
  * prompts prefill in CHUNKS (``api.extend``) interleaved with decode
    steps — a long prompt no longer stalls the decode batch, and the
    per-step chunk budget is ``max_num_batched_tokens``;
  * a radix residency hit FORKS the resident blocks (refcount++) instead
    of exclusively taking a slot: many live sequences share one physical
    copy of a common prefix, and the first divergent write triggers
    copy-on-write of just the boundary block;
  * admission reserves ``ceil(len/block_size)`` blocks against
    free + reclaimable-resident capacity, so a mid-flight sequence can
    always grow (block-granular residency eviction, coldest first,
    supplies the reserve);
  * decode runs DIRECTLY on the physical store
    (``paged_decode_mode="direct"``, the default): the new token's K/V is
    written into only its tail block and attention reads K/V through the
    block table (``api.decode_paged`` -> the scalar-prefetch Pallas
    kernel when ``use_pallas`` is on), so per-token HBM traffic is
    O(blocks-touched) instead of the O(B*Smax*H*D) gather/scatter
    round-trip.  ``paged_decode_mode="gather"`` keeps the old
    reassembled-view decode for A/B benchmarking; chunked *extend*
    (prefill) still gathers — it touches the whole prefix anyway.

Both paths produce token-for-token identical greedy output: chunked
extend is bit-exact versus one full prefill (masked softmax columns
underflow to exact zeros), and both the gathered block view and the
direct path's table-gathered read are bit-identical to a contiguous
slot cache (masked columns underflow to exact zeros in the softmax).

Telemetry (per-step active slots, tokens, queue depth, live
free/reserved block gauges) feeds the paper's utilization/throughput
experiments and the replica set's headroom-aware routing.

Speculative decoding (``SpecDecodeSession``) couples TWO engines into a
propose/verify/rewind cycle — the first cross-group *pipeline* (cheap
surrogate proposes, expensive model validates).  Each round: (1) the
DRAFT engine proposes ``k`` tokens per active sequence via its ordinary
batched greedy decode (feeding any catch-up tokens it missed first);
(2) the TARGET engine verifies all ``k+1`` positions in ONE forward
through the chunked-extend path (``ModelApi.extend`` — on the paged pool
the verified chunk's K/V is scattered straight into the block store, no
per-token decode round-trips); (3) the leftover-token acceptance rule
(``repro.serving.sampling.speculative_accept``) emits the longest
matching proposal prefix PLUS the target's own pick at the first
divergence, so greedy output is token-for-token identical to target-only
decode; (4) both engines REWIND past the rejected suffix — the slot pool
by batch-resetting cache lengths (``set_lens``), the paged pool by
truncating the block table (tail blocks free back to the admission
reserve; stale K/V below the rewind is never attended and is overwritten
by the next round's writes).  A session whose measured acceptance rate
stays under ``min_acceptance`` turns speculation off and degenerates to
plain target-engine stepping — the same graceful-off signal the
``weighted_capacity`` autoscaler consumes fleet-wide.
"""
from __future__ import annotations

import dataclasses
import itertools
import time
from collections import OrderedDict
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.prefix import RadixIndex
from repro.models import ModelApi, get_model
from repro.models.config import ModelConfig
from .kvcache import (CachePool, PagedCachePool, extract_blocks,
                      gather_block_view, insert_blocks,
                      scatter_block_writes)
from .sampling import sample


@dataclasses.dataclass
class Request:
    uid: int
    prompt: list
    max_new_tokens: int = 16
    temperature: float = 0.0
    eos_id: Optional[int] = None
    # multi-tenant QoS identity (accounting + weighted-fair queueing +
    # preemption order; see repro.serving.qos)
    tenant: Optional[str] = None
    qos_class: str = "normal"
    # filled by the engine
    output: list = dataclasses.field(default_factory=list)
    submitted_at: float = 0.0
    first_token_at: Optional[float] = None
    finished_at: Optional[float] = None
    slot: Optional[int] = None
    # prefix-reuse resume: prompt suffix still to be fed through decode
    # (one token per step); no output is emitted while any remain
    pending_prefix: list = dataclasses.field(default_factory=list)
    cached_prefix: int = 0  # prompt tokens whose prefill was skipped
    truncated: bool = False  # prompt exceeded max_len/bucket at prefill:
    #                          the cache does not cover the full prompt
    # paged engine state
    table: list = dataclasses.field(default_factory=list)  # physical blocks
    pos: int = 0  # cache positions holding valid KV
    pending_tokens: list = dataclasses.field(default_factory=list)  # unfed
    reserve_left: int = 0  # admission-reserved blocks not yet allocated
    last_token: Optional[int] = None  # next decode feed

    @property
    def done(self) -> bool:
        return self.finished_at is not None

    @property
    def n_prompt(self) -> int:
        return len(self.prompt)


def _bucket(n: int, buckets) -> int:
    for b in buckets:
        if n <= b:
            return b
    return buckets[-1]


@dataclasses.dataclass
class _Residency:
    """A retired sequence whose blocks stay allocated for prefix resume."""

    blocks: tuple
    length: int  # tokens of the sequence (KV covers length - 1)


@dataclasses.dataclass
class EngineStats:
    steps: int = 0
    decode_tokens: int = 0
    prefill_tokens: int = 0
    active_slot_steps: int = 0
    slot_steps: int = 0
    prefix_reuse_hits: int = 0  # admissions that resumed a resident slot
    prefix_partial_hits: int = 0  # resumes that rewound PAST a divergence
    #                               (resident sequence != prompt prefix)
    prefix_cached_tokens: int = 0  # prompt tokens whose prefill was skipped
    # paged-pool telemetry
    cow_copies: int = 0  # shared blocks duplicated on first divergent write
    peak_running: int = 0  # high-water concurrent admitted sequences
    shared_block_peak: int = 0  # max physical blocks saved by sharing
    evicted_residencies: int = 0  # resident sequences dropped for space
    preemptions: int = 0  # decoding sequences requeued by the WFQ
    #                       scheduler (KV retired to residency)
    preempt_resumes: int = 0  # preempted sequences re-admitted (resumed
    #                           from residency or re-prefilled)
    # live gauges (refreshed every paged step, not cumulative): the
    # pool's unallocated blocks and the admission-reserved blocks not
    # yet allocated — the "why is admission stalling" signal operators
    # and the autoscaler were missing when only peaks were reported
    free_blocks: int = 0
    reserved_blocks: int = 0
    started: float = dataclasses.field(default_factory=time.perf_counter)

    @property
    def utilization(self) -> float:
        return self.active_slot_steps / max(1, self.slot_steps)

    @property
    def tokens_per_s(self) -> float:
        dt = time.perf_counter() - self.started
        return (self.decode_tokens + self.prefill_tokens) / max(1e-9, dt)


class InferenceEngine:
    """Single-model continuous-batching engine."""

    def __init__(self, cfg: ModelConfig, params, *, max_num_seqs: int = 8,
                 max_num_batched_tokens: int = 2048, max_len: int = 512,
                 prefill_buckets=(32, 64, 128, 256, 512), seed: int = 0,
                 mesh=None, enable_prefix_reuse: bool = True,
                 paged: bool = False, block_size: int = 16,
                 num_blocks: Optional[int] = None,
                 prefill_chunk: Optional[int] = None,
                 max_running: Optional[int] = None,
                 paged_decode_mode: str = "direct"):
        self.cfg = cfg
        self.api: ModelApi = get_model(cfg)
        self.params = params
        self.max_num_seqs = max_num_seqs
        self.max_num_batched_tokens = max_num_batched_tokens
        self.max_len = max_len
        self.buckets = tuple(b for b in prefill_buckets if b <= max_len) or (max_len,)
        self.mesh = mesh
        self.queue: list[Request] = []
        self.running: dict[int, Request] = {}  # slot (or uid) -> request
        # radix index over token sequences whose KV is still resident
        # (value = slot id, or a residency id in paged mode); admission
        # finds the deepest resident common prefix in one O(len(prompt))
        # descent.  State-carrying families (ssm/hybrid) have no
        # per-position KV to rewind, so the fast path is gated off below.
        self._prefix_index = RadixIndex()
        self._resident_len: dict[int, int] = {}  # slot -> covered seq len
        # residency gossip PUSH channel: called (no args) whenever resident
        # KV is dropped (evicted or reclaimed), so the replica set can
        # refresh the router's residency view immediately instead of
        # leaving a staleness window until the next pull tick
        self.on_residency_drop: Optional[Callable[[], None]] = None
        self.stats = EngineStats()
        self._uid = itertools.count()
        self._key = jax.random.PRNGKey(seed)
        self._last_tokens = jnp.zeros((max_num_seqs,), jnp.int32)

        api = self.api
        self.paged = paged

        # KV-cache families: right-pad prompts into buckets, fix cache "len"
        # afterwards, read logits at the true last position.  State-carrying
        # families (ssm/hybrid) need exact-length prefill (order-dependent
        # state), which recompiles per distinct prompt length.
        self._exact_prefill = cfg.family in ("ssm", "hybrid")
        # prefix reuse needs prompt token i <-> cache position i: true for
        # pure text decoders, not for ssm/hybrid (monolithic state, nothing
        # to rewind) or vlm/encdec (vision/audio prefix offsets positions)
        self._prefix_reuse = (enable_prefix_reuse
                              and cfg.family in ("dense", "moe"))

        if paged:
            if cfg.family not in ("dense", "moe") or api.extend is None:
                raise ValueError(
                    f"paged=True requires a pure text-decoder family with "
                    f"chunked extend (dense/moe), not {cfg.family!r}")
            self.block_size = block_size
            # memory parity by default: same KV cells as the slot pool
            # (+1 for the reserved null block)
            if num_blocks is None:
                num_blocks = max_num_seqs * (-(-max_len // block_size)) + 1
            self.num_blocks = num_blocks
            self.pool: Any = PagedCachePool(cfg, num_blocks, block_size,
                                            max_len)
            self.prefill_chunk = min(prefill_chunk or max(self.buckets),
                                     max_num_batched_tokens)
            self._chunk_buckets = tuple(
                b for b in self.buckets if b <= self.prefill_chunk) \
                or (self.prefill_chunk,)
            # concurrency is block-bounded; max_running only caps the
            # decode batch (and its gathered-view footprint)
            self.max_running = max_running or self.pool.alloc.capacity
            self._prefill_order: list[Request] = []  # FIFO chunk scheduling
            self._residency: "OrderedDict[int, _Residency]" = OrderedDict()
            self._res_holds: dict[int, int] = {}  # block -> residency refs
            self._res_counter = itertools.count()
            self._reserved = 0  # admission-reserved, not-yet-allocated

            def paged_extend_fn(params, store, bt, lens, tokens, wphys, woff):
                view = gather_block_view(store, bt, lens)
                view, logits = api.extend(params, view, tokens, cfg,
                                          mesh=mesh)
                T = tokens.shape[1]
                wpos = lens[:, None] + jnp.arange(T)[None, :]
                store = scatter_block_writes(store, view, wphys, woff, wpos)
                return store, logits

            if paged_decode_mode not in ("direct", "gather"):
                raise ValueError(
                    f"paged_decode_mode must be 'direct' or 'gather', "
                    f"not {paged_decode_mode!r}")
            self.paged_decode_mode = paged_decode_mode

            if paged_decode_mode == "direct":
                # the tentpole path: no gather_block_view on decode — the
                # model writes the token's K/V into its tail block and
                # reads K/V through the block table (Pallas paged kernel
                # under use_pallas, jnp table-gather fallback otherwise)
                def paged_decode_fn(params, store, bt, lens, tokens, wphys,
                                    woff):
                    return api.decode_paged(params, store, bt, lens, tokens,
                                            wphys, woff, cfg, mesh=mesh)
            else:
                # legacy A/B path: reassemble a contiguous [B, Smax] view,
                # run the slot-pool decode, scatter the one new row back
                def paged_decode_fn(params, store, bt, lens, tokens, wphys,
                                    woff):
                    view = gather_block_view(store, bt, lens)
                    view, logits = api.decode(params, view, tokens, cfg,
                                              mesh=mesh)
                    store = scatter_block_writes(store, view, wphys[:, None],
                                                 woff[:, None], lens[:, None])
                    return store, logits

            self._paged_extend = jax.jit(paged_extend_fn, donate_argnums=(1,))
            self._paged_decode = jax.jit(paged_decode_fn, donate_argnums=(1,))
            self.stats.free_blocks = self.pool.n_free
            return

        self.pool = CachePool(cfg, max_num_seqs, max_len)

        def decode_fn(params, cache, tokens):
            return api.decode(params, cache, tokens, cfg, mesh=mesh)

        self._decode = jax.jit(decode_fn, donate_argnums=(1,))

        def prefill_fn(params, batch):
            kw = {"max_len": max_len}
            if not self._exact_prefill:
                kw["last_only"] = False
            return api.prefill(params, batch, cfg, mesh=mesh, **kw)

        self._prefill = jax.jit(prefill_fn)

    # ------------------------------------------------------------------
    # Public API
    # ------------------------------------------------------------------
    def submit(self, prompt, *, max_new_tokens=16, temperature=0.0,
               eos_id=None, tenant=None, qos_class="normal") -> int:
        req = Request(uid=next(self._uid), prompt=list(prompt),
                      max_new_tokens=max_new_tokens, temperature=temperature,
                      eos_id=eos_id, submitted_at=time.perf_counter(),
                      tenant=tenant, qos_class=qos_class or "normal")
        self.queue.append(req)
        return req.uid

    def has_work(self) -> bool:
        return bool(self.queue or self.running)

    def step(self) -> list:
        """One engine iteration. Returns [(uid, token), ...] emitted."""
        if self.paged:
            return self._step_paged()
        self._admit()
        events = []
        if self.running:
            events = self._decode_step()
        self.stats.steps += 1
        self.stats.active_slot_steps += len(self.running)
        self.stats.slot_steps += self.max_num_seqs
        return events

    def collect_finished(self) -> list:
        """Retire finished requests, freeing their slots.  With prefix
        reuse on, the freed slot's KV stays resident (it is only memory
        already allocated) and the sequence it covers is remembered so a
        later prompt extending it can skip that prefill."""
        if self.paged:
            return self._collect_finished_paged()
        done = []
        for slot, req in list(self.running.items()):
            if req.done:
                del self.running[slot]
                if self._prefix_reuse and not req.truncated:
                    seq = tuple(req.prompt) + tuple(req.output)
                    self._drop_residency(slot)  # stale entry, if any
                    self._prefix_index.insert(seq, slot)
                    self._resident_len[slot] = len(seq)
                    self.pool.free(slot, resident=True)
                else:
                    self.pool.free(slot)
                done.append(req)
        return done

    def block_telemetry(self) -> Optional[dict]:
        """Live physical-block telemetry for a paged engine (None for the
        slot pool).  The replica set aggregates this per model group and
        gossips (free, total) to headroom-aware routers, so a deep prefix
        match on a memory-starved replica stops winning placement."""
        if not self.paged:
            return None
        return {
            "free_blocks": self.pool.n_free,
            "total_blocks": self.pool.alloc.capacity,
            "reserved_blocks": self._reserved,
            "shared_blocks": self.pool.block_savings(),
            "cow_copies": self.stats.cow_copies,
            "evicted_residencies": self.stats.evicted_residencies,
            "preemptions": self.stats.preemptions,
            "preempt_resumes": self.stats.preempt_resumes,
        }

    def step_prefill_only(self) -> list:
        """One PREFILL-ROLE iteration (disaggregated serving): admit and
        chunk-prefill, but never decode — a dedicated prefill replica
        spends every step's full token budget on prompt chunks instead
        of interleaving them with decode steps it will never own.
        Sequences whose first token is out (and that are not already
        done) sit in ``running`` awaiting ``export_sequence()``."""
        if not self.paged:
            raise ValueError("step_prefill_only requires a paged engine")
        self._admit_paged()
        self.stats.peak_running = max(self.stats.peak_running,
                                      len(self.running))
        self._prefill_step_paged()
        self.stats.steps += 1
        self.stats.active_slot_steps += len(self.running)
        self.stats.slot_steps += max(self.max_num_seqs, len(self.running))
        self.stats.shared_block_peak = max(self.stats.shared_block_peak,
                                           self.pool.block_savings())
        self.stats.free_blocks = self.pool.n_free
        self.stats.reserved_blocks = self._reserved
        return []

    def exportable(self) -> list:
        """Uids of running sequences ready for a prefill->decode handoff:
        past prefill (first token emitted), not finished."""
        if not self.paged:
            return []
        return [r.uid for r in self.running.values()
                if r.output and not r.pending_tokens and not r.done]

    def export_sequence(self, uid: int) -> dict:
        """Export a running sequence for migration to another paged
        engine (the disaggregated prefill->decode KV handoff).

        The sequence must be past prefill: its KV covers positions
        ``[0, pos)`` and the first generated token(s) are in ``output``.
        Returns serialized ``[n_blocks, block_size, ...]`` K/V leaves
        (``extract_blocks``) plus the metadata ``import_sequence`` needs
        to resume decode bit-for-bit.  The request then RETIRES here:
        its admission reserve is released and its blocks either transfer
        to a residency entry (prefix reuse on — a follow-up turn hitting
        this prefill replica resumes the prompt's KV for free) or free,
        exactly mirroring ``_collect_finished_paged``."""
        if not self.paged:
            raise ValueError("export_sequence requires a paged engine")
        req = self.running.get(uid)
        if req is None:
            raise KeyError(f"no running request {uid}")
        if not req.output or req.pending_tokens:
            raise ValueError(f"request {uid} has not finished prefill")
        payload = {
            "leaves": extract_blocks(self.pool.cache, req.table),
            "block_size": self.block_size,
            "n_blocks": len(req.table),
            "pos": req.pos,
            "prompt": list(req.prompt),
            "output": list(req.output),
            "last_token": req.last_token,
            "max_new_tokens": req.max_new_tokens,
            "temperature": req.temperature,
            "eos_id": req.eos_id,
            "cached_prefix": req.cached_prefix,
            "truncated": req.truncated,
            "submitted_at": req.submitted_at,
            "first_token_at": req.first_token_at,
        }
        # retire the exported request (mirrors _collect_finished_paged):
        # release the unconsumed reserve, keep the prompt KV resident
        # when prefix reuse allows so later turns skip this prefill
        del self.running[uid]
        if req in self._prefill_order:
            self._prefill_order.remove(req)
        self._reserved -= req.reserve_left
        req.reserve_left = 0
        if self._prefix_reuse and not req.truncated and req.table:
            seq = tuple(req.prompt) + tuple(req.output)
            res_id = next(self._res_counter)
            self._residency[res_id] = _Residency(tuple(req.table), len(seq))
            for b in req.table:
                self._res_holds[b] = self._res_holds.get(b, 0) + 1
            self._prefix_index.insert(seq, res_id)
        else:
            for b in req.table:
                self.pool.alloc.free(b)
        req.table = []
        self.stats.free_blocks = self.pool.n_free
        self.stats.reserved_blocks = self._reserved
        return payload

    def import_sequence(self, payload: dict) -> Optional[int]:
        """Adopt an exported sequence into freshly reserved blocks and
        resume its decode here (the receiving half of the handoff).

        Admission-gated exactly like ``_admit_paged``: the full
        remaining generation must be covered by free + reclaimable
        blocks net of existing reservations, or the import is REFUSED
        (returns None) and the caller falls back to recomputing the
        prompt — a full decode pool degrades to recompute-on-miss, never
        to a deadlock.  Block-size mismatches are likewise refused (the
        block rows cannot be remapped 1:1).  On success the request
        joins ``running`` ready for the next decode batch, keeping the
        original submit/first-token stamps so TTFT/ITL accounting spans
        the migration."""
        if not self.paged:
            raise ValueError("import_sequence requires a paged engine")
        if payload["block_size"] != self.block_size:
            return None
        pos = int(payload["pos"])
        out = list(payload["output"])
        if pos >= self.max_len:
            return None
        if len(self.running) >= self.max_running:
            return None
        remaining = max(0, int(payload["max_new_tokens"]) - len(out))
        need = self._blocks_needed(pos + remaining, 0)
        if not self._reserve(need):
            return None
        req = Request(uid=next(self._uid), prompt=list(payload["prompt"]),
                      max_new_tokens=int(payload["max_new_tokens"]),
                      temperature=float(payload["temperature"]),
                      eos_id=payload["eos_id"], output=out,
                      submitted_at=payload["submitted_at"],
                      first_token_at=payload["first_token_at"],
                      cached_prefix=int(payload.get("cached_prefix", 0)),
                      truncated=bool(payload.get("truncated", False)),
                      pos=pos, last_token=payload["last_token"])
        req.reserve_left = need
        n_blocks = int(payload["n_blocks"])
        req.table = [self._alloc_block(req) for _ in range(n_blocks)]
        self.pool.cache = insert_blocks(self.pool.cache, payload["leaves"],
                                        req.table)
        self.running[req.uid] = req
        self._check_done(req)
        self.stats.free_blocks = self.pool.n_free
        self.stats.reserved_blocks = self._reserved
        return req.uid

    def run(self, *, max_steps: int = 100000) -> dict:
        """Drain the queue; returns completed requests keyed by uid."""
        done: dict[int, Request] = {}
        for _ in range(max_steps):
            if not self.has_work():
                break
            self.step()
            for req in self.collect_finished():
                done[req.uid] = req
        return done

    # ------------------------------------------------------------------
    # Internals (slot pool)
    # ------------------------------------------------------------------
    def _admit(self):
        budget = self.max_num_batched_tokens
        while self.queue and self.pool.n_free > 0:
            req = self.queue[0]
            if self._prefix_reuse and self._try_resume(req):
                self.queue.pop(0)  # resumed: no prefill, no budget charge
                continue
            n = min(req.n_prompt, self.max_len - 1)
            bucket = n if self._exact_prefill else _bucket(n, self.buckets)
            n = min(n, bucket)  # over-long prompts keep their last n tokens
            if bucket > budget:
                break
            self.queue.pop(0)
            slot = self.pool.allocate()  # blank-preferring: resident KV is
            self._drop_residency(slot)  # only evicted when no blank is left
            req.truncated = n < req.n_prompt
            budget -= bucket
            tokens = np.zeros((1, bucket), np.int32)
            tokens[0, :n] = req.prompt[-n:]  # right-pad into the bucket
            batch = {"tokens": jnp.asarray(tokens)}
            if self.cfg.family == "encdec":
                batch["frame_embeds"] = jnp.zeros(
                    (1, 64, self.cfg.d_model), jnp.float32)
            if self.cfg.family == "vlm":
                batch["patch_embeds"] = jnp.zeros(
                    (1, self.cfg.vision_tokens or 16, self.cfg.d_model),
                    jnp.float32)
            cache, logits = self._prefill(self.params, batch)
            self.pool.insert(slot, cache)
            if not self._exact_prefill:
                self.pool.set_len(slot, n)
                logits_last = logits[0, n - 1]
            else:
                logits_last = logits[0]
            self.stats.prefill_tokens += bucket
            if req.temperature > 0:
                # match the decode path's temperature gating: the first
                # generated token must follow the same sampling policy
                # whether it comes from a fresh prefill or a resumed slot
                self._key, sub = jax.random.split(self._key)
                tok = int(sample(logits_last[None, :], sub,
                                 temperature=req.temperature)[0])
            else:
                tok = int(jnp.argmax(logits_last))
            req.slot = slot
            req.output.append(tok)
            req.first_token_at = time.perf_counter()
            self._last_tokens = self._last_tokens.at[slot].set(tok)
            self.running[slot] = req
            self._check_done(req)

    def _drop_residency(self, slot: Optional[int], notify: bool = True):
        """Forget a slot's resident sequence (its cache is being replaced
        or re-claimed), notifying the push listener when coverage the
        router may rely on actually disappeared.  The prefix-reuse resume
        path passes ``notify=False``: a take-for-resume is a HIT (the
        consuming request is already routed here), and pushing on every
        hit would re-arm a near-continuous gossip loop on the hot path."""
        if slot is None:
            return
        had = self._resident_len.pop(slot, None) is not None
        self._prefix_index.remove_value(slot)
        if notify and had and self.on_residency_drop is not None:
            try:
                self.on_residency_drop()
            except Exception:
                pass  # gossip is best-effort; serving must not care

    def residency_summary(self, max_entries: Optional[int] = None,
                          max_len: int = 128) -> list:
        """Resident token sequences (newest first, truncated), the payload
        the replica set gossips to the router's residency index."""
        return self._prefix_index.summary(
            max_entries=max_entries or self.max_num_seqs, max_len=max_len)

    def _try_resume(self, req: Request) -> bool:
        """Prefix-reuse fast path: claim the freed slot whose resident KV
        shares the deepest usable prefix with ``req.prompt`` and skip
        prefill for that prefix.

        The radix index answers the best common-prefix length per resident
        slot in one O(len(prompt)) descent.  A resident sequence of length
        L has KV for its first L-1 tokens (the final emitted token was
        never fed back), and the prompt's first d tokens match the resident
        sequence, so positions < min(d, L-1) hold valid KV — including
        *partial* matches where the resident transcript diverges from the
        prompt at d < L (a branching turn).  The resume rewinds the slot's
        length to that point and feeds the remaining prompt through the
        batched decode — one token per step, exactly the incremental path —
        with the last feed's logits producing the first new token.  Stale
        KV at positions >= the rewind (divergence junk, or junk appended
        while the slot idled) is never attended and is overwritten by those
        feeds.
        """
        m = req.n_prompt
        if m >= self.max_len:  # would be truncated: prefix math breaks
            return False
        # minimum-benefit gate: the uncovered suffix is fed one token per
        # decode step, so resuming must cover at least half the prompt —
        # a short shared stem on a long fresh prompt is cheaper to prefill
        # in one bucketed call than to drip through hundreds of decodes
        threshold = max(1, (m + 1) // 2)
        candidates = []
        for slot, d in self._prefix_index.match_lengths(req.prompt).items():
            L = self._resident_len.get(slot)
            if L is None:
                continue
            covered = min(d, L - 1, m - 1)
            if covered >= threshold:
                candidates.append((covered, slot, L, d))
        candidates.sort(reverse=True)  # deepest usable rewind first
        for covered, slot, L, d in candidates:
            if not self.pool.take(slot):
                continue  # defensively skip a slot that is no longer free
            self._drop_residency(slot, notify=False)  # resume hit, not an
            #                                           eviction
            self.pool.set_len(slot, covered)
            self._last_tokens = self._last_tokens.at[slot].set(
                req.prompt[covered])
            req.pending_prefix = list(req.prompt[covered + 1:])
            req.cached_prefix = covered
            req.slot = slot
            self.running[slot] = req
            self.stats.prefix_reuse_hits += 1
            if d < L and d < m:  # the resident transcript and the prompt
                #                  genuinely diverge (not a mere replay of
                #                  a shorter prefix): a true partial resume
                self.stats.prefix_partial_hits += 1
            self.stats.prefix_cached_tokens += covered
            self.stats.prefill_tokens += 1  # the feed queued into
            #                  _last_tokens; the rest count as they are fed
            return True
        return False

    def _decode_step(self):
        self.pool.cache, logits = self._decode(
            self.params, self.pool.cache, self._last_tokens)
        temps = np.zeros((self.max_num_seqs,), np.float32)
        for slot, req in self.running.items():
            temps[slot] = req.temperature
        # greedy for temp==0 slots, sampled otherwise; an all-greedy batch
        # (the common serving case) skips the sampled path AND the key
        # split entirely instead of paying for tokens it discards
        greedy = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        if np.any(temps > 0):
            self._key, sub = jax.random.split(self._key)
            sampled = sample(logits, sub, temperature=1.0)
            tokens = jnp.where(jnp.asarray(temps) > 0, sampled, greedy)
        else:
            tokens = greedy
        tokens_np = np.asarray(tokens)
        # only a resumed request forces the host-side token rewrite (and
        # the device re-upload below); the common all-decode step keeps the
        # device array as-is
        has_pending = any(req.pending_prefix
                          for req in self.running.values())
        if has_pending:
            tokens_np = tokens_np.copy()
        events = []
        for slot, req in list(self.running.items()):
            if req.done:
                continue
            if req.pending_prefix:
                # resumed request still catching up on its prompt suffix:
                # force-feed the next prompt token instead of the model's
                # prediction, and emit nothing until the prompt is consumed
                tokens_np[slot] = req.pending_prefix.pop(0)
                self.stats.prefill_tokens += 1
                continue
            tok = int(tokens_np[slot])
            req.output.append(tok)
            if req.first_token_at is None:  # resumed: first real token
                req.first_token_at = time.perf_counter()
            events.append((req.uid, tok))
            self.stats.decode_tokens += 1
            self._check_done(req)
        self._last_tokens = jnp.asarray(tokens_np) if has_pending else tokens
        return events

    def _check_done(self, req: Request):
        if req.done:
            return
        hit_eos = req.eos_id is not None and req.output and \
            req.output[-1] == req.eos_id
        if len(req.output) >= req.max_new_tokens or hit_eos:
            req.finished_at = time.perf_counter()

    # ------------------------------------------------------------------
    # Internals (paged pool)
    # ------------------------------------------------------------------
    def _step_paged(self) -> list:
        self._admit_paged()
        self.stats.peak_running = max(self.stats.peak_running,
                                      len(self.running))
        self._prefill_step_paged()
        events = self._decode_step_paged()
        self.stats.steps += 1
        self.stats.active_slot_steps += len(self.running)
        self.stats.slot_steps += max(self.max_num_seqs, len(self.running))
        self.stats.shared_block_peak = max(self.stats.shared_block_peak,
                                           self.pool.block_savings())
        self.stats.free_blocks = self.pool.n_free
        self.stats.reserved_blocks = self._reserved
        return events

    def _blocks_needed(self, total_len: int, covered: int) -> int:
        """Blocks a sequence of ``total_len`` tokens must be able to
        allocate, given ``covered`` resumed positions: blocks strictly
        before ``covered // block_size`` are shared read-only and never
        written; a partial boundary block still counts (its first write
        may need a copy-on-write replacement)."""
        bs = self.block_size
        total = min(total_len, self.pool.max_blocks * bs)
        return max(1, -(-total // bs) - covered // bs)

    def _reclaimable_blocks(self) -> int:
        """Blocks whose every reference is a residency hold — freeing
        them needs only eviction, no live sequence loses KV."""
        alloc = self.pool.alloc
        return sum(1 for b, h in self._res_holds.items()
                   if h > 0 and alloc.refcount(b) == h)

    def _reserve(self, need: int, pinned: int = 0) -> bool:
        """Admission control: admit only when ``need`` blocks are covered
        by free + reclaimable capacity net of earlier reservations (and of
        ``pinned`` reclaimable blocks this admission is about to share),
        so an admitted sequence can ALWAYS grow to its full length —
        over-admitting would deadlock: every running sequence blocked on a
        block none of them can free."""
        avail = (self.pool.n_free + self._reclaimable_blocks()
                 - pinned - self._reserved)
        if avail < need:
            return False
        self._reserved += need
        return True

    def _admit_paged(self):
        while self.queue and len(self.running) < self.max_running:
            req = self.queue[0]
            if req.output:  # preempted mid-generation: dedicated resume
                if not self._readmit_preempted(req):
                    break
                self.queue.pop(0)
                continue
            if self._prefix_reuse and self._try_resume_paged(req):
                self.queue.pop(0)
                continue
            m = min(req.n_prompt, self.max_len - 1)
            need = self._blocks_needed(m + req.max_new_tokens, 0)
            if not self._reserve(need):
                break
            self.queue.pop(0)
            req.truncated = m < req.n_prompt
            req.pending_tokens = list(req.prompt[-m:])
            req.reserve_left = need
            self.running[req.uid] = req
            self._prefill_order.append(req)

    def _try_resume_paged(self, req: Request) -> bool:
        """Prefix resume by block sharing: fork (refcount++) the resident
        blocks covering the prompt's deepest resident prefix instead of
        exclusively claiming a slot.  The residency entry SURVIVES the
        resume — that is the paging win: any number of concurrent
        sequences extend one physical copy of a shared stem, and only
        boundary blocks are duplicated (copy-on-write) when they write.

        Gate: at least one full block must be covered — sharing only a
        partial boundary block would be immediately copied-on-write,
        costing a block copy to save less than one block of prefill."""
        m = req.n_prompt
        if m >= self.max_len:
            return False
        bs = self.block_size
        best = None
        for res_id, d in self._prefix_index.match_lengths(req.prompt).items():
            ent = self._residency.get(res_id)
            if ent is None:
                continue
            covered = min(d, ent.length - 1, m - 1)
            if covered >= bs and (best is None or covered > best[0]):
                best = (covered, res_id, ent, d)
        if best is None:
            return False
        covered, res_id, ent, d = best
        shared = ent.blocks[:-(-covered // bs)]
        need = self._blocks_needed(m + req.max_new_tokens, covered)
        # the shared blocks stop being reclaimable the moment this
        # sequence pins them: account for that in the reservation check
        alloc = self.pool.alloc
        pinned = sum(1 for b in set(shared)
                     if self._res_holds.get(b, 0) > 0
                     and alloc.refcount(b) == self._res_holds[b])
        if not self._reserve(need, pinned=pinned):
            return False
        for b in shared:
            alloc.fork(b)
        req.table = list(shared)
        req.pos = covered
        req.pending_tokens = list(req.prompt[covered:])
        req.reserve_left = need
        req.cached_prefix = covered
        self.running[req.uid] = req
        self._prefill_order.append(req)
        self._residency.move_to_end(res_id)  # hit: refresh retirement order
        self.stats.prefix_reuse_hits += 1
        if d < ent.length and d < m:
            self.stats.prefix_partial_hits += 1
        self.stats.prefix_cached_tokens += covered
        return True

    def preempt_sequence(self, uid: int) -> bool:
        """Preempt a DECODING sequence: retire its paged KV to a residency
        entry (block references move, exactly like finish-time retirement)
        and push the request back onto the queue, where the WFQ scheduler
        re-orders it by virtual finish time.  Resuming is cheap — the
        readmit path forks the residency back (usually the sequence's own,
        still warm) and catches up from the last covered position, so the
        resumed transcript is token-identical to uninterrupted decode.

        Only decode-phase sequences are preemptable: mid-prefill requests
        hold no emitted tokens worth preserving (the scheduler simply
        won't admit them), finished ones retire normally, and truncated
        ones cannot retire to residency (their KV does not cover the
        prompt).  Returns False when ``uid`` is not preemptable."""
        if not self.paged:
            return False
        req = self.running.get(uid)
        if (req is None or req.done or req.pending_tokens
                or not req.output or req.truncated or not req.table):
            return False
        del self.running[uid]
        if req in self._prefill_order:
            self._prefill_order.remove(req)
        self._reserved -= req.reserve_left
        req.reserve_left = 0
        if self._prefix_reuse:
            seq = tuple(req.prompt) + tuple(req.output)
            res_id = next(self._res_counter)
            self._residency[res_id] = _Residency(tuple(req.table), len(seq))
            for b in req.table:
                self._res_holds[b] = self._res_holds.get(b, 0) + 1
            self._prefix_index.insert(seq, res_id)
        else:
            for b in req.table:
                self.pool.alloc.free(b)
        req.table = []
        req.pos = 0
        req.last_token = None
        self.queue.append(req)
        self.stats.preemptions += 1
        self.stats.free_blocks = self.pool.n_free
        self.stats.reserved_blocks = self._reserved
        return True

    def _readmit_preempted(self, req: Request) -> bool:
        """Re-admit a preempted request: the catch-up 'prompt' is the full
        transcript so far (prompt + emitted output, ending with the last
        emitted token).  The deepest resident prefix — normally the
        sequence's own retirement, unless eviction claimed it — is forked
        back and only the tail is re-fed; the catch-up chunk's final
        logits row then produces exactly the token uninterrupted decode
        would have produced next (greedy), so preemption is invisible in
        the transcript."""
        seq = list(req.prompt) + list(req.output)
        L = len(seq)
        remaining = req.max_new_tokens - len(req.output)
        bs = self.block_size
        best = None
        if self._prefix_reuse:
            for res_id, d in self._prefix_index.match_lengths(seq).items():
                ent = self._residency.get(res_id)
                if ent is None:
                    continue
                covered = min(d, ent.length - 1, L - 1)
                if covered >= bs and (best is None or covered > best[0]):
                    best = (covered, res_id, ent)
        covered, shared, pinned = 0, (), 0
        if best is not None:
            covered, res_id, ent = best
            shared = ent.blocks[:-(-covered // bs)]
            alloc = self.pool.alloc
            pinned = sum(1 for b in set(shared)
                         if self._res_holds.get(b, 0) > 0
                         and alloc.refcount(b) == self._res_holds[b])
        need = self._blocks_needed(L + remaining, covered)
        if not self._reserve(need, pinned=pinned):
            return False
        for b in shared:
            self.pool.alloc.fork(b)
        if best is not None:
            self._residency.move_to_end(res_id)
            self.stats.prefix_cached_tokens += covered
        req.table = list(shared)
        req.pos = covered
        req.pending_tokens = list(seq[covered:])
        req.reserve_left = need
        self.running[req.uid] = req
        self._prefill_order.append(req)
        self.stats.preempt_resumes += 1
        return True

    def _alloc_block(self, req: Request) -> int:
        """Allocate one physical block for ``req``, evicting resident
        sequences (coldest first) as needed; consumes the request's
        admission reserve.  Admission control guarantees this succeeds."""
        b = self.pool.alloc.allocate()
        while b is None and self._residency:
            self._evict_residency()
            b = self.pool.alloc.allocate()
        if b is None:
            raise RuntimeError(
                "paged KV pool exhausted despite admission reservation")
        if req.reserve_left > 0:
            req.reserve_left -= 1
            self._reserved -= 1
        return b

    def _evict_residency(self):
        """Drop the coldest resident sequence: decref its blocks (shared
        ones survive under their live references) and forget its index
        entry, notifying the residency-gossip listener."""
        res_id, ent = self._residency.popitem(last=False)
        for b in ent.blocks:
            self._res_holds[b] -= 1
            if self._res_holds[b] == 0:
                del self._res_holds[b]
            self.pool.alloc.free(b)
        self._prefix_index.remove_value(res_id)
        self.stats.evicted_residencies += 1
        if self.on_residency_drop is not None:
            try:
                self.on_residency_drop()
            except Exception:
                pass  # gossip is best-effort; serving must not care

    def _ensure_writable(self, req: Request, start: int, n: int):
        """Make positions [start, start+n) writable: grow the block table
        (append-only) and copy-on-write any shared block about to be
        written — writing a block another table points at would corrupt
        the other sequence's (or the residency's) KV."""
        bs = self.block_size
        alloc = self.pool.alloc
        # past-capacity writes clamp to the final position, mirroring the
        # slot pool's clamped scatter when generation outruns max_len
        cap = self.pool.max_blocks * bs - 1
        start = min(start, cap)
        for lb in range(start // bs, (min(start + n - 1, cap)) // bs + 1):
            if lb < len(req.table):
                b = req.table[lb]
                if alloc.refcount(b) > 1:  # shared: copy before write
                    nb = self._alloc_block(req)
                    self.pool.copy_block(b, nb)
                    alloc.free(b)  # drop only OUR reference
                    req.table[lb] = nb
                    self.stats.cow_copies += 1
            else:
                assert lb == len(req.table), "non-contiguous block write"
                req.table.append(self._alloc_block(req))

    def _prefill_step_paged(self):
        """Feed one prompt chunk per prefilling sequence (admission FIFO)
        until the per-step token budget runs out.  Chunk lengths are
        bucketed to bound recompilation; the final chunk's last real
        logits row produces the first generated token.

        The budget is charged at the BUCKETED size — the padded bucket is
        what actually runs through ``_paged_extend``, so charging only the
        real tokens would let a step of many short ragged chunks exceed
        ``max_num_batched_tokens`` of compute and stall interleaved
        decode.  Each chunk therefore picks the largest bucket that still
        fits the remaining budget (the smallest chunk bucket always fits a
        fresh budget, so prefill never stalls)."""
        budget = self.max_num_batched_tokens
        mb = self.pool.max_blocks
        bs = self.block_size
        for req in list(self._prefill_order):
            if req.done or not req.pending_tokens:
                self._prefill_order.remove(req)
                continue
            fitting = [b for b in self._chunk_buckets if b <= budget]
            if not fitting:
                break
            T = min(len(req.pending_tokens), self.prefill_chunk, fitting[-1])
            bucket = _bucket(T, self._chunk_buckets)
            T = min(T, bucket)
            self._ensure_writable(req, req.pos, T)
            bt = np.zeros((1, mb), np.int32)
            bt[0, :len(req.table)] = req.table
            tokens = np.zeros((1, bucket), np.int32)
            tokens[0, :T] = req.pending_tokens[:T]
            # padded chunk positions scatter into the null block
            wphys = np.zeros((1, bucket), np.int32)
            woff = np.zeros((1, bucket), np.int32)
            for t in range(T):
                p = req.pos + t
                wphys[0, t] = req.table[p // bs]
                woff[0, t] = p % bs
            self.pool.cache, logits = self._paged_extend(
                self.params, self.pool.cache, jnp.asarray(bt),
                jnp.asarray([req.pos], jnp.int32), jnp.asarray(tokens),
                jnp.asarray(wphys), jnp.asarray(woff))
            req.pending_tokens = req.pending_tokens[T:]
            req.pos += T
            budget -= bucket  # charge the padded size that actually ran
            self.stats.prefill_tokens += T
            if not req.pending_tokens:  # prompt complete: first token
                self._prefill_order.remove(req)
                logits_last = logits[0, T - 1]
                if req.temperature > 0:
                    self._key, sub = jax.random.split(self._key)
                    tok = int(sample(logits_last[None, :], sub,
                                     temperature=req.temperature)[0])
                else:
                    tok = int(jnp.argmax(logits_last))
                req.output.append(tok)
                req.last_token = tok
                if req.first_token_at is None:
                    # preempted-and-resumed sequences re-run this path
                    # (their catch-up "prompt" ends mid-generation); TTFT
                    # must keep the ORIGINAL first-token stamp
                    req.first_token_at = time.perf_counter()
                self._check_done(req)

    def _decode_step_paged(self) -> list:
        """One batched decode over every sequence past prefill.  The
        batch is padded to a power of two (padding rows carry the null
        block table and length 0, so their writes land in the null
        block), bounding recompilation to O(log max_running) shapes."""
        active = [r for r in self.running.values()
                  if not r.pending_tokens and not r.done and r.output]
        if not active:
            return []
        for r in active:
            self._ensure_writable(r, r.pos, 1)
        B = 1
        while B < len(active):
            B *= 2
        mb = self.pool.max_blocks
        bs = self.block_size
        bt = np.zeros((B, mb), np.int32)
        lens = np.zeros((B,), np.int32)
        tokens = np.zeros((B,), np.int32)
        # padding rows write to the null block's cell (0, 0); duplicate
        # writes there are harmless because masked positions are never
        # attended
        wphys = np.zeros((B,), np.int32)
        woff = np.zeros((B,), np.int32)
        temps = np.zeros((B,), np.float32)
        for i, r in enumerate(active):
            bt[i, :len(r.table)] = r.table
            lens[i] = r.pos
            tokens[i] = r.last_token
            p = min(r.pos, mb * bs - 1)  # clamp like the slot pool
            wphys[i] = r.table[p // bs]
            woff[i] = p % bs
            temps[i] = r.temperature
        self.pool.cache, logits = self._paged_decode(
            self.params, self.pool.cache, jnp.asarray(bt),
            jnp.asarray(lens), jnp.asarray(tokens), jnp.asarray(wphys),
            jnp.asarray(woff))
        # all-greedy batches skip the sampled path and the key split (the
        # same fast path as the slot pool's _decode_step)
        greedy = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        if np.any(temps > 0):
            self._key, sub = jax.random.split(self._key)
            sampled = sample(logits, sub, temperature=1.0)
            toks = np.asarray(jnp.where(jnp.asarray(temps) > 0, sampled,
                                        greedy))
        else:
            toks = np.asarray(greedy)
        events = []
        for i, r in enumerate(active):
            tok = int(toks[i])
            r.output.append(tok)
            r.last_token = tok
            r.pos += 1
            events.append((r.uid, tok))
            self.stats.decode_tokens += 1
            self._check_done(r)
        return events

    def _collect_finished_paged(self) -> list:
        """Retire finished requests.  With prefix reuse on, the block
        table transfers to a residency entry (no refcount change — the
        references move, they are not duplicated), so the blocks stay
        shareable until block-granular eviction reclaims them."""
        done = []
        for uid, req in list(self.running.items()):
            if not req.done:
                continue
            del self.running[uid]
            if req in self._prefill_order:
                self._prefill_order.remove(req)
            self._reserved -= req.reserve_left
            req.reserve_left = 0
            if self._prefix_reuse and not req.truncated and req.table:
                seq = tuple(req.prompt) + tuple(req.output)
                res_id = next(self._res_counter)
                self._residency[res_id] = _Residency(tuple(req.table),
                                                     len(seq))
                for b in req.table:
                    self._res_holds[b] = self._res_holds.get(b, 0) + 1
                self._prefix_index.insert(seq, res_id)
            else:
                for b in req.table:
                    self.pool.alloc.free(b)
            req.table = []
            done.append(req)
        self.stats.free_blocks = self.pool.n_free
        self.stats.reserved_blocks = self._reserved
        return done


@dataclasses.dataclass
class _SpecSeq:
    """One sequence's coupled state across the draft and target engines."""

    treq: Request  # target-engine request (owns the emitted transcript)
    dreq: Optional[Request]  # draft-engine request (proposal KV)
    max_new: int  # real token budget (treq's is inflated until pairing)
    ready: bool = False  # both engines prefilled; in the propose rotation
    t_cov: int = 0  # target cache positions holding valid KV
    d_cov: int = 0  # draft cache positions holding valid KV
    last_tok: int = 0  # last emitted token (target's next verify feed)
    # sequence tokens the draft has not fed yet (ends with last_tok);
    # normally one token, two after a fully-accepted round
    draft_pending: list = dataclasses.field(default_factory=list)


class SpecDecodeSession:
    """Cross-engine speculative decoding: DRAFT proposes, TARGET verifies.

    Wraps two ``InferenceEngine``s (any mix of slot-pool and paged) behind
    the engine's own submit/step/collect_finished surface.  Per round the
    draft runs ``k`` batched greedy decode steps to propose ``k`` tokens
    per active sequence, then the target verifies all ``k+1`` positions in
    ONE ``extend`` forward; the leftover-token rule emits the longest
    matching proposal prefix plus the target's pick at the first
    divergence (so greedy output is token-for-token identical to
    target-only decode), and both caches rewind past the rejected suffix
    (paged: block-table truncation, tail blocks return to the admission
    reserve; slot: batched length reset).

    Greedy only: sampled requests need the rejection-sampling acceptance
    rule and are refused at ``submit``.  ``min_acceptance`` > 0 arms the
    graceful-off path: once ``probe_proposals`` proposals have been
    measured, a session whose acceptance rate sits below the floor stops
    speculating permanently and every subsequent ``step()`` is a plain
    target-engine step (identical call pattern and cost to vanilla
    decode).  ``proposed``/``accepted`` counters feed the per-group stats
    the ``weighted_capacity`` autoscaler uses to shrink a low-acceptance
    draft group's entitlement fleet-wide.
    """

    def __init__(self, target: InferenceEngine, draft: InferenceEngine, *,
                 k: int = 4, min_acceptance: float = 0.0,
                 probe_proposals: int = 64):
        if target.api.extend is None or \
                target.cfg.family not in ("dense", "moe"):
            raise ValueError(
                "speculative decoding needs a target family with chunked "
                f"extend (dense/moe), not {target.cfg.family!r}")
        if draft.cfg.family not in ("dense", "moe"):
            raise ValueError(
                "speculative decoding needs a positional-KV draft family "
                f"(dense/moe), not {draft.cfg.family!r}")
        if k < 1:
            raise ValueError(f"k must be >= 1, got {k}")
        self.target = target
        self.draft = draft
        self.k = k
        self.min_acceptance = float(min_acceptance)
        self.probe_proposals = int(probe_proposals)
        self.spec_enabled = True
        self.proposed = 0
        self.accepted = 0
        self.rounds = 0
        self._seqs: "OrderedDict[int, _SpecSeq]" = OrderedDict()
        self._extend_jits: dict = {}  # id(engine) -> jitted slot extend

    # ------------------------------------------------------------------
    # Engine-compatible surface
    # ------------------------------------------------------------------
    @property
    def stats(self) -> EngineStats:
        return self.target.stats

    def spec_stats(self) -> dict:
        return {
            "k": self.k,
            "proposed": self.proposed,
            "accepted": self.accepted,
            "acceptance_rate": (self.accepted / self.proposed
                                if self.proposed else None),
            "rounds": self.rounds,
            "enabled": self.spec_enabled,
        }

    def submit(self, prompt, *, max_new_tokens=16, temperature=0.0,
               eos_id=None, tenant=None, qos_class="normal") -> int:
        if temperature and temperature > 0:
            raise ValueError(
                "SpecDecodeSession serves greedy (temperature=0) requests "
                "only; the leftover-token rule does not cover sampling")
        prompt = list(prompt)
        m = len(prompt)
        # the verify forward writes up to k+1 positions past the accepted
        # prefix, so the full budget must fit both caches with that slack
        need = m + max_new_tokens + self.k + 1
        for eng, who in ((self.target, "target"), (self.draft, "draft")):
            if need >= eng.max_len:
                raise ValueError(
                    f"prompt ({m}) + max_new_tokens ({max_new_tokens}) + "
                    f"k+1 must fit the {who} engine max_len ({eng.max_len})")
            if not eng.paged and m > max(eng.buckets):
                raise ValueError(
                    f"prompt ({m}) exceeds the {who} engine's largest "
                    f"prefill bucket ({max(eng.buckets)}): the truncated "
                    f"prefill would break the verify position math")
        if not self.spec_enabled:
            # speculation permanently off: plain target submit — the
            # inflated budget below is only ever restored by
            # _pair_ready, which a disabled session never runs
            return self.target.submit(prompt,
                                      max_new_tokens=max_new_tokens,
                                      eos_id=eos_id, tenant=tenant,
                                      qos_class=qos_class)
        # inflate the target budget so admission (paged: the block
        # reservation; both: _check_done) covers the speculative
        # overshoot; restored to the real budget when the pair activates
        uid = self.target.submit(prompt,
                                 max_new_tokens=max_new_tokens + self.k + 1,
                                 eos_id=eos_id, tenant=tenant,
                                 qos_class=qos_class)
        treq = self.target.queue[-1]
        dreq = None
        if self.spec_enabled:
            self.draft.submit(prompt,
                              max_new_tokens=max_new_tokens + self.k + 2,
                              eos_id=None)  # the draft never self-finishes
            dreq = self.draft.queue[-1]
        self._seqs[uid] = _SpecSeq(treq=treq, dreq=dreq,
                                   max_new=max_new_tokens)
        return uid

    def has_work(self) -> bool:
        return self.target.has_work()

    def step(self) -> list:
        t = self.target
        if not self.spec_enabled:
            # degenerate mode: EXACTLY a vanilla engine step (same calls,
            # same cost) — speculation is off, not merely idle
            return t.step()
        if t.paged:
            t._admit_paged()
            t.stats.peak_running = max(t.stats.peak_running, len(t.running))
            t._prefill_step_paged()
        else:
            t._admit()
            self._complete_slot_resumes(t)
        d = self.draft
        if d.paged:
            d._admit_paged()
            d._prefill_step_paged()
        else:
            d._admit()
            self._complete_slot_resumes(d)
        self._pair_ready()
        active = [s for s in self._seqs.values()
                  if s.ready and not s.treq.done]
        events = self._spec_round(active) if active else []
        t.stats.steps += 1
        t.stats.active_slot_steps += len(t.running)
        t.stats.slot_steps += max(t.max_num_seqs, len(t.running))
        if t.paged:
            t.stats.shared_block_peak = max(t.stats.shared_block_peak,
                                            t.pool.block_savings())
            t.stats.free_blocks = t.pool.n_free
            t.stats.reserved_blocks = t._reserved
        if self.min_acceptance > 0 and self.proposed >= self.probe_proposals \
                and self.accepted < self.min_acceptance * self.proposed:
            self._disable_spec()
        return events

    def collect_finished(self) -> list:
        done = self.target.collect_finished()
        for req in done:
            seq = self._seqs.pop(req.uid, None)
            if seq is not None and seq.dreq is not None:
                self._retire_draft(seq)
        if self.spec_enabled:
            self.draft.collect_finished()
        return done

    def run(self, *, max_steps: int = 100000) -> dict:
        done: dict[int, Request] = {}
        for _ in range(max_steps):
            if not self.has_work():
                break
            self.step()
            for req in self.collect_finished():
                done[req.uid] = req
        return done

    # ------------------------------------------------------------------
    # Pairing and teardown
    # ------------------------------------------------------------------
    @staticmethod
    def _prefilled(eng: InferenceEngine, req: Request) -> bool:
        if not req.output:
            return False
        if eng.paged:
            return not req.pending_tokens
        return req.slot is not None and not req.pending_prefix

    def _pair_ready(self):
        for s in self._seqs.values():
            if s.ready or s.treq.done:
                continue
            if not self._prefilled(self.target, s.treq):
                continue
            if s.dreq is None or not self._prefilled(self.draft, s.dreq):
                continue
            m = s.treq.n_prompt
            s.t_cov = s.treq.pos if self.target.paged else m
            s.d_cov = s.dreq.pos if self.draft.paged else m
            s.last_tok = s.treq.output[-1]
            s.draft_pending = [s.last_tok]
            s.treq.max_new_tokens = s.max_new  # restore the real budget
            self.target._check_done(s.treq)
            s.ready = True

    def _retire_draft(self, seq: _SpecSeq):
        """Finish the draft-side request so its engine frees (or retains
        as residency) the proposal KV.  The residency transcript is
        truncated to what the draft cache actually covers — claiming the
        full emitted sequence would let a later resume attend garbage."""
        d = self.draft
        dreq = seq.dreq
        for i, r in enumerate(d.queue):  # identity, not dataclass ==
            if r is dreq:
                del d.queue[i]
                return
        if not self._prefilled(d, dreq):
            dreq.truncated = True  # mid-prefill: no residency claim
        else:
            transcript = (list(seq.treq.prompt) + list(seq.treq.output))
            d_cov = seq.d_cov if seq.ready else dreq.n_prompt
            dreq.output = transcript[dreq.n_prompt:d_cov + 1]
            if not dreq.output:
                dreq.truncated = True
        dreq.finished_at = time.perf_counter()

    def _disable_spec(self):
        """Acceptance collapsed: stop speculating for good.  Draft-side
        requests retire (their KV frees), inflated target budgets are
        restored, and every later step() is a plain target-engine step."""
        self.spec_enabled = False
        slot_tokens = {}
        for s in self._seqs.values():
            if not s.ready:
                s.treq.max_new_tokens = s.max_new
                self.target._check_done(s.treq)
            elif not self.target.paged and s.treq.slot is not None:
                slot_tokens[s.treq.slot] = s.last_tok
            if s.dreq is not None:
                self._retire_draft(s)
                s.dreq = None
        if slot_tokens:  # hand the feeds to the vanilla decode loop
            lt = np.asarray(self.target._last_tokens).copy()
            for slot, tok in slot_tokens.items():
                lt[slot] = tok
            self.target._last_tokens = jnp.asarray(lt)
        self.draft.collect_finished()

    # ------------------------------------------------------------------
    # Slot-pool prefix-resume completion (chunked, via extend)
    # ------------------------------------------------------------------
    def _extend_for(self, eng: InferenceEngine):
        fn = self._extend_jits.get(id(eng))
        if fn is None:
            api, cfg, mesh = eng.api, eng.cfg, eng.mesh

            def extend_fn(params, cache, tokens):
                return api.extend(params, cache, tokens, cfg, mesh=mesh)

            fn = jax.jit(extend_fn, donate_argnums=(1,))
            self._extend_jits[id(eng)] = fn
        return fn

    def _complete_slot_resumes(self, eng: InferenceEngine):
        """A slot-pool prefix resume leaves the prompt suffix to drip in
        one token per decode step; the session instead feeds the whole
        suffix through ONE bucketed extend (the same chunked path verify
        uses), so a resumed sequence joins the propose rotation
        immediately.  A running request with no output yet is NECESSARILY
        a resume (fresh admission emits its first token inside
        ``_admit``) — including the fully-covered case where
        ``pending_prefix`` is empty and only the final prompt token needs
        feeding, which the vanilla decode loop would pick up from
        ``_last_tokens`` but the session must extend explicitly.  All
        resumes admitted this step share ONE extend (each slot's suffix
        in its own row, bucket sized to the longest) — per-request
        forwards would pay full depth per resume."""
        todo = [req for req in eng.running.values()
                if not req.output and req.slot is not None]
        if not todo:
            return
        chunks = {req.slot: list(req.prompt[req.cached_prefix:])
                  for req in todo}
        bucket = _bucket(max(len(c) for c in chunks.values()), eng.buckets)
        tokens = np.zeros((eng.max_num_seqs, bucket), np.int32)
        for slot, chunk in chunks.items():
            tokens[slot, :len(chunk)] = chunk
        ext = self._extend_for(eng)
        eng.pool.cache, logits = ext(eng.params, eng.pool.cache,
                                     jnp.asarray(tokens))
        gtok = np.asarray(jnp.argmax(logits, axis=-1).astype(jnp.int32))
        extra = {}
        for req in todo:
            T0 = len(chunks[req.slot])
            tok = int(gtok[req.slot, T0 - 1])
            req.pending_prefix = []
            req.output.append(tok)
            req.last_token = tok
            req.first_token_at = time.perf_counter()
            eng.stats.prefill_tokens += T0 - 1
            extra[req.slot] = req.cached_prefix + T0
            if eng is self.target:
                eng._last_tokens = eng._last_tokens.at[req.slot].set(tok)
                eng._check_done(req)
        self._rewind_slots(eng, extra=extra)

    def _rewind_slots(self, eng: InferenceEngine, extra=None):
        """Batch-reset slot lengths after an extend/decode advanced EVERY
        slot: each running sequence returns to its true coverage (stale KV
        past it is never attended and is overwritten by later writes —
        the same argument the prefix-resume rewind makes).  Untracked
        requests (admitted but not yet paired) are covered too: their
        lens were bumped just the same."""
        updates = dict(extra or {})
        cov_by_req = {}
        for s in self._seqs.values():
            if not s.ready or s.treq.done:
                continue
            if eng is self.target:
                cov_by_req[id(s.treq)] = s.t_cov
            elif s.dreq is not None:
                cov_by_req[id(s.dreq)] = s.d_cov
        for slot, req in eng.running.items():
            if slot in updates or req.done:
                continue
            cov = cov_by_req.get(id(req))
            if cov is None:
                if not req.output:  # resume whose catch-up extend has
                    cov = req.cached_prefix  # not run yet (first-token
                    #                          feed still pending)
                else:  # freshly prefilled, waiting to pair
                    n = min(req.n_prompt, eng.max_len - 1)
                    cov = min(n, _bucket(n, eng.buckets))
            updates[slot] = cov
        eng.pool.set_lens(updates)

    # ------------------------------------------------------------------
    # The propose / verify / rewind round
    # ------------------------------------------------------------------
    def _spec_round(self, active) -> list:
        k = self.k
        props = {id(s): [] for s in active}
        pend = {id(s): list(s.draft_pending) for s in active}
        steps = max(len(s.draft_pending) for s in active) - 1 + k
        # -- propose: k batched greedy draft decodes (catch-up feeds
        #    first); a sequence whose proposals are complete re-feeds its
        #    last token — the rewind below discards that garbage anyway
        for j in range(steps):
            feed = []
            for s in active:
                fl = pend[id(s)]
                feed.append(fl[j] if j < len(fl) else fl[-1])
            toks = self._draft_step(active, feed)
            for i, s in enumerate(active):
                fl = pend[id(s)]
                if len(fl) - 1 <= j and len(props[id(s)]) < k:
                    t = int(toks[i])
                    props[id(s)].append(t)
                    fl.append(t)
        # -- verify: ONE extend forward over [last_tok, d_1..d_k]
        chunks = np.zeros((len(active), k + 1), np.int32)
        for i, s in enumerate(active):
            chunks[i, 0] = s.last_tok
            chunks[i, 1:] = props[id(s)]
        g = self._verify(active, chunks)  # target greedy picks [B, k+1]
        # -- accept + emit + rewind
        events = []
        t_slot_updates = {}
        d_slot_updates = {}
        for i, s in enumerate(active):
            prop = props[id(s)]
            row = g[i]
            a = 0
            while a < k and prop[a] == int(row[a]):
                a += 1
            self.proposed += k
            self.accepted += a
            treq = s.treq
            n = s.t_cov + 1  # emitted sequence length before this round
            for j in range(a + 1):
                if treq.done:
                    break
                tok = int(row[j])
                treq.output.append(tok)
                events.append((treq.uid, tok))
                self.target.stats.decode_tokens += 1
                self.target._check_done(treq)
            seq_len = treq.n_prompt + len(treq.output)
            # valid coverage: the verified feeds matching the true
            # sequence (capped by what was actually emitted)
            t_new = min(n + a, seq_len - 1)
            d_new = min(n + a if a < k else n + k - 1, seq_len - 1)
            if treq.done:
                continue  # retirement keeps the written KV; no rewind
            s.t_cov = t_new
            s.d_cov = d_new
            s.last_tok = treq.output[-1]
            transcript = list(treq.prompt) + list(treq.output)
            s.draft_pending = transcript[d_new:]
            if self.target.paged:
                self._rewind_paged(self.target, treq, t_new)
                treq.last_token = s.last_tok
            else:
                t_slot_updates[treq.slot] = t_new
            if self.draft.paged:
                self._rewind_paged(self.draft, s.dreq, d_new)
            else:
                d_slot_updates[s.dreq.slot] = d_new
        if not self.target.paged:
            self._rewind_slots(self.target, extra=t_slot_updates)
        if not self.draft.paged:
            self._rewind_slots(self.draft, extra=d_slot_updates)
        self.rounds += 1
        return events

    def _draft_step(self, active, feed):
        """One batched greedy decode on the draft engine; returns the
        proposal token per active sequence."""
        eng = self.draft
        eng.stats.steps += 1
        if not eng.paged:
            feeds = np.zeros((eng.max_num_seqs,), np.int32)
            for s, f in zip(active, feed):
                feeds[s.dreq.slot] = f
            eng.pool.cache, logits = eng._decode(
                eng.params, eng.pool.cache, jnp.asarray(feeds))
            gtok = np.asarray(jnp.argmax(logits, axis=-1).astype(jnp.int32))
            return [int(gtok[s.dreq.slot]) for s in active]
        B = 1
        while B < len(active):
            B *= 2
        mb, bs = eng.pool.max_blocks, eng.block_size
        bt = np.zeros((B, mb), np.int32)
        lens = np.zeros((B,), np.int32)
        tokens = np.zeros((B,), np.int32)
        wphys = np.zeros((B,), np.int32)
        woff = np.zeros((B,), np.int32)
        for i, s in enumerate(active):
            r = s.dreq
            eng._ensure_writable(r, r.pos, 1)
            bt[i, :len(r.table)] = r.table
            lens[i] = r.pos
            tokens[i] = feed[i]
            p = min(r.pos, mb * bs - 1)
            wphys[i] = r.table[p // bs]
            woff[i] = p % bs
        eng.pool.cache, logits = eng._paged_decode(
            eng.params, eng.pool.cache, jnp.asarray(bt), jnp.asarray(lens),
            jnp.asarray(tokens), jnp.asarray(wphys), jnp.asarray(woff))
        for s in active:
            s.dreq.pos += 1
        gtok = np.asarray(jnp.argmax(logits, axis=-1).astype(jnp.int32))
        return [int(gtok[i]) for i in range(len(active))]

    def _verify(self, active, chunks):
        """ONE extend forward verifying all k+1 positions per sequence;
        returns the target's greedy pick at each position [B, k+1]."""
        eng = self.target
        T = chunks.shape[1]
        if not eng.paged:
            tokens = np.zeros((eng.max_num_seqs, T), np.int32)
            for i, s in enumerate(active):
                tokens[s.treq.slot] = chunks[i]
            ext = self._extend_for(eng)
            eng.pool.cache, logits = ext(eng.params, eng.pool.cache,
                                         jnp.asarray(tokens))
            gtok = np.asarray(jnp.argmax(logits, axis=-1).astype(jnp.int32))
            return gtok[[s.treq.slot for s in active]]
        B = 1
        while B < len(active):
            B *= 2
        mb, bs = eng.pool.max_blocks, eng.block_size
        bt = np.zeros((B, mb), np.int32)
        lens = np.zeros((B,), np.int32)
        tokens = np.zeros((B, T), np.int32)
        wphys = np.zeros((B, T), np.int32)
        woff = np.zeros((B, T), np.int32)
        for i, s in enumerate(active):
            r = s.treq
            eng._ensure_writable(r, s.t_cov, T)
            bt[i, :len(r.table)] = r.table
            lens[i] = s.t_cov
            tokens[i] = chunks[i]
            for t in range(T):
                p = min(s.t_cov + t, mb * bs - 1)
                wphys[i, t] = r.table[p // bs]
                woff[i, t] = p % bs
        eng.pool.cache, logits = eng._paged_extend(
            eng.params, eng.pool.cache, jnp.asarray(bt), jnp.asarray(lens),
            jnp.asarray(tokens), jnp.asarray(wphys), jnp.asarray(woff))
        gtok = np.asarray(jnp.argmax(logits, axis=-1).astype(jnp.int32))
        return gtok[:len(active)]

    def _rewind_paged(self, eng: InferenceEngine, req: Request,
                      new_pos: int):
        """Truncate the block table past the accepted prefix: tail blocks
        holding only rejected K/V free back to the pool AND to the
        request's admission reserve (symmetric with ``_alloc_block``), so
        chunk-budget accounting stays exact across rounds."""
        bs = eng.block_size
        keep = max(1, -(-new_pos // bs))
        while len(req.table) > keep:
            b = req.table.pop()
            eng.pool.alloc.free(b)
            req.reserve_left += 1
            eng._reserved += 1
        req.pos = new_pos


def make_engine_from_scratch(cfg: ModelConfig, *, seed=0, **kw):
    """Init params and build an engine (used by services/examples)."""
    from repro.models import nn

    api = get_model(cfg)
    params, _ = nn.split(api.init(jax.random.PRNGKey(seed), cfg))
    return InferenceEngine(cfg, params, **kw)
