"""Token sampling and the speculative-decoding acceptance rule.

``sample`` is the per-step token pick (greedy / temperature / top-k /
top-p).  ``speculative_accept`` is the *leftover-token* acceptance rule
for greedy speculative decoding: given the draft's ``k`` proposals and
the target's greedy pick at each of the ``k+1`` verified positions, it
returns how many proposals survive and which tokens are emitted.  The
emitted tokens are ALWAYS the target's own greedy picks (a proposal is
accepted only when it equals the target pick at its position, and the
first rejected position contributes the target pick instead), which is
what makes speculative greedy decode token-for-token identical to
target-only greedy decode.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def sample(logits, key, *, temperature: float = 0.0, top_k: int = 0,
           top_p: float = 1.0):
    """logits [B, V] -> tokens [B].

    ``temperature <= 0`` is greedy (argmax).  ``top_k > 0`` keeps the k
    highest-logit tokens; ``top_p < 1`` keeps the smallest
    nucleus whose cumulative probability reaches ``top_p`` (``top_p=0``
    degenerates to greedy-by-construction: only the single most probable
    token survives).  Filters compose: top-k first, then top-p over the
    surviving mass."""
    if temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    logits = logits / temperature
    if top_k > 0:
        vals, _ = jax.lax.top_k(logits, top_k)
        cutoff = vals[:, -1:]
        logits = jnp.where(logits < cutoff, -jnp.inf, logits)
    if top_p < 1.0:
        sorted_logits = jnp.sort(logits, axis=-1)[:, ::-1]
        probs = jax.nn.softmax(sorted_logits, axis=-1)
        cum = jnp.cumsum(probs, axis=-1)
        # keep tokens whose cumulative mass *before* them is < top_p —
        # and pin the highest-probability token explicitly, so top_p=0
        # degenerates to greedy instead of an all-False keep mask whose
        # -inf cutoff would silently disable the filter
        keep = (cum - probs) < top_p
        keep = keep.at[:, 0].set(True)
        cutoff = jnp.max(jnp.where(keep, sorted_logits, -jnp.inf), axis=-1,
                         keepdims=True)
        logits = jnp.where(logits < cutoff, -jnp.inf, logits)
    return jax.random.categorical(key, logits, axis=-1).astype(jnp.int32)


def speculative_accept(proposed, target_tokens):
    """Leftover-token acceptance for greedy speculative decoding.

    ``proposed`` [B, k] are the draft's proposals; ``target_tokens``
    [B, k+1] are the target's greedy picks at the k+1 verified positions
    (position j's pick conditions on the previous token plus proposals
    ``proposed[:, :j]``).  Returns ``n_accept`` [B] — the length of the
    longest matching prefix (proposal i is only valid if every earlier
    proposal matched, hence the cumulative product) — and the emitted
    tokens are ``target_tokens[b, : n_accept[b] + 1]`` per row: the
    accepted proposals (which EQUAL the target picks) plus the target's
    "leftover" pick at the first divergence (or the bonus token when all
    k were accepted).
    """
    proposed = jnp.asarray(proposed)
    target_tokens = jnp.asarray(target_tokens)
    if proposed.ndim != 2 or target_tokens.ndim != 2 or \
            target_tokens.shape[1] != proposed.shape[1] + 1:
        raise ValueError(
            f"expected proposed [B, k] and target [B, k+1], got "
            f"{proposed.shape} / {target_tokens.shape}")
    matches = (proposed == target_tokens[:, :-1]).astype(jnp.int32)
    n_accept = jnp.sum(jnp.cumprod(matches, axis=1), axis=1)
    return n_accept
