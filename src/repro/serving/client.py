"""LLM servicer + client helpers: the glue between the middleware service
abstraction and the continuous-batching engine (Figs. 1-2: AI workers)."""
from __future__ import annotations

import time
from typing import Any, Optional

from repro.core.service import ModelGroup
from repro.models.config import ModelConfig
from .engine import (InferenceEngine, SpecDecodeSession,
                     make_engine_from_scratch)
from .qos import WFQScheduler


def _resolve_paged(cfg: ModelConfig, engine_kw: dict) -> dict:
    """Paged-by-default policy for replicas: dense/moe engines get the
    block-paged pool unless the caller opts out (``paged=False``);
    state-carrying and prefix-offset families (ssm/hybrid/vlm/encdec)
    keep the slot pool.  ``paged=None`` (or absent) means "auto"."""
    kw = dict(engine_kw)
    if kw.get("paged") is None:
        kw["paged"] = cfg.family in ("dense", "moe")
    if not kw["paged"]:
        # the slot-pool engine does not take paged-only tuning knobs
        for k in ("block_size", "num_blocks", "prefill_chunk",
                  "max_running", "paged_decode_mode"):
            kw.pop(k, None)
    return kw


def _resolve_draft_engine(spec, *, seed: int = 0) -> InferenceEngine:
    """``draft_group`` resolution: accept the co-located draft in any of
    the shapes a launcher naturally holds — an ``InferenceEngine``, a
    built ``LLMServicer``, a ``ModelGroup`` (whose factory builds one; the
    ``--multi-model`` path hands exactly this), or a bare ``ModelConfig``
    (a fresh engine with auto-resolved pool)."""
    if isinstance(spec, InferenceEngine):
        return spec
    if isinstance(spec, LLMServicer):
        return spec.engine
    if isinstance(spec, ModelGroup):
        if spec.factory is None:
            raise ValueError(
                f"draft_group {spec.name!r} has no factory to build a "
                f"draft servicer from")
        servicer = spec.factory()
        engine = getattr(servicer, "engine", None)
        if engine is None:
            raise TypeError(
                f"draft_group {spec.name!r} factory built "
                f"{type(servicer).__name__}, which exposes no .engine")
        return engine
    if isinstance(spec, ModelConfig):
        return make_engine_from_scratch(spec, seed=seed,
                                        **_resolve_paged(spec, {}))
    raise TypeError(f"cannot resolve a draft engine from {type(spec)}")


class LLMServicer:
    """Servicer protocol (submit/step) around an InferenceEngine.

    Request payload: {"prompt": [ids...], "max_new_tokens": int,
                      "temperature": float}.
    Result: {"tokens": [...], "n_prompt": int, "ttft_s": float,
             "latency_s": float}.

    Replicas default to the block-paged engine for dense/moe configs
    (``paged=None`` auto-resolves via ``_resolve_paged``); pass
    ``paged=False`` to force the slot pool.

    ``draft_group`` arms cross-group speculative decoding: a co-located
    draft engine (resolved from a ``ModelGroup``/``ModelConfig``/engine,
    see ``_resolve_draft_engine``) proposes ``spec_k`` tokens per round
    and this replica's target engine verifies them in one extend forward
    (``SpecDecodeSession``).  Greedy output stays token-for-token
    identical to target-only decode; sampled requests are refused by the
    session.  ``spec_stats()`` exposes the proposed/accepted counters the
    replica set aggregates per group for the autoscaler.

    ``phase`` selects the replica's disaggregated-serving role:

    * ``"serve"`` (default) — unified prefill+decode, as before.
    * ``"prefill"`` — the replica ONLY chunk-prefills (no decode
      interleave: ``engine.step_prefill_only``); the moment a sequence's
      first token is out it is exported (``engine.export_sequence``) and
      the step result carries the serialized KV under ``"handoff_export"``
      for the replica set to re-dispatch to the paired decode group.
    * ``"decode"`` — ``submit`` accepts envelopes whose ``handoff``
      field carries an exported sequence and adopts the KV via
      ``engine.import_sequence``; a full pool falls back to recomputing
      the prompt here (counted in ``handoff_stats()``), never to
      failure.

    Both disagg phases require the paged engine (the handoff moves
    physical KV blocks) and are incompatible with ``draft_group``.

    ``qos=True`` (or an explicit ``qos_class_weights`` dict) arms a
    per-replica ``WFQScheduler``: admission is ordered by weighted-fair
    virtual finish times over (tenant, priority-class) flows, and — on
    paged engines with ``qos_preempt`` — a blocked heavier-class head
    preempts lighter decoding sequences (KV retires to residency and
    resumes token-identically).  Tenant/class identity arrives on the
    ``InferenceRequest`` envelope (``accepts_envelope``).
    """

    accepts_envelope = True  # submit() takes the envelope keyword

    def __init__(self, cfg: ModelConfig, params=None, *, seed: int = 0,
                 draft_group=None, spec_k: int = 4,
                 spec_min_acceptance: float = 0.0,
                 spec_probe_proposals: int = 64, phase: str = "serve",
                 qos: bool = False, qos_class_weights=None,
                 qos_preempt: bool = True, **engine_kw):
        if phase not in ("serve", "prefill", "decode"):
            raise ValueError(
                f"phase must be 'serve', 'prefill' or 'decode', "
                f"not {phase!r}")
        if phase != "serve" and draft_group is not None:
            raise ValueError(
                "speculative decoding and disaggregated phases do not "
                "compose: a prefill/decode replica cannot host a draft")
        self.phase = phase
        engine_kw = _resolve_paged(cfg, engine_kw)
        if params is None:
            self.engine = make_engine_from_scratch(cfg, seed=seed, **engine_kw)
        else:
            self.engine = InferenceEngine(cfg, params, **engine_kw)
        if phase != "serve" and not self.engine.paged:
            raise ValueError(
                f"phase={phase!r} requires the block-paged engine (the "
                f"KV handoff moves physical blocks)")
        self.session = None
        if draft_group is not None:
            draft = _resolve_draft_engine(draft_group, seed=seed)
            self.session = SpecDecodeSession(
                self.engine, draft, k=spec_k,
                min_acceptance=spec_min_acceptance,
                probe_proposals=spec_probe_proposals)
        # everything below drives this one surface: the session when
        # speculating, the bare engine otherwise (identical protocol)
        self._driver = self.session or self.engine
        self._handoff_exports = 0
        self._handoff_imports = 0
        self._handoff_recomputes = 0
        self._imported: set = set()
        self._recomputed: set = set()
        self._stream_leftovers: list = []
        self._qos = None
        if qos or qos_class_weights is not None:
            self._qos = WFQScheduler(class_weights=qos_class_weights,
                                     preempt=qos_preempt)

    def submit(self, payload, *, envelope=None, **meta) -> int:
        tenant = envelope.tenant if envelope is not None else None
        qos_class = envelope.priority if envelope is not None else "normal"
        handoff = envelope.handoff if envelope is not None else None
        if handoff is not None and self.phase != "prefill":
            uid = self.engine.import_sequence(handoff)
            if uid is not None:
                self._handoff_imports += 1
                self._imported.add(uid)
            else:
                # decode pool full (or incompatible blocks): recompute
                # the prompt here instead of failing the request — the
                # original submit stamp is preserved so end-to-end
                # latency still spans the whole migration
                self._handoff_recomputes += 1
                uid = self.engine.submit(
                    handoff["prompt"],
                    max_new_tokens=handoff["max_new_tokens"],
                    temperature=handoff["temperature"],
                    eos_id=handoff["eos_id"],
                    tenant=tenant, qos_class=qos_class)
                self.engine.queue[-1].submitted_at = handoff["submitted_at"]
                self._recomputed.add(uid)
        else:
            uid = self._driver.submit(
                payload["prompt"],
                max_new_tokens=payload.get("max_new_tokens", 16),
                temperature=payload.get("temperature", 0.0),
                eos_id=payload.get("eos_id"),
                tenant=tenant, qos_class=qos_class,
            )
        if self._qos is not None:
            req = self._find_request(uid)
            if req is not None:
                self._qos.on_submit(req)
        return uid

    def _result(self, req) -> dict:
        itl = None
        if (req.first_token_at is not None and req.finished_at is not None
                and len(req.output) > 1):
            itl = ((req.finished_at - req.first_token_at)
                   / (len(req.output) - 1))
        res = {
            "tokens": req.output,
            "n_prompt": req.n_prompt,
            "ttft_s": (req.first_token_at - req.submitted_at
                       if req.first_token_at else None),
            "itl_s": itl,
            "latency_s": req.finished_at - req.submitted_at,
        }
        if req.uid in self._imported:
            self._imported.discard(req.uid)
            res["handoff"] = True
            res["role"] = "decode"
        elif req.uid in self._recomputed:
            self._recomputed.discard(req.uid)
            res["handoff"] = True
            res["recompute"] = True
            res["role"] = "decode"
        elif self.phase != "serve":
            res["role"] = self.phase
        return res

    def step(self):
        out = []
        if self._stream_leftovers:
            out, self._stream_leftovers = self._stream_leftovers, []
        if not self._driver.has_work():
            if not out:
                time.sleep(1e-4)
            return out
        if self.phase == "prefill":
            return out + self._step_prefill()
        if self._qos is not None:
            self._qos.schedule(self.engine)
        self._driver.step()
        for req in self._driver.collect_finished():
            if self._qos is not None:
                self._qos.on_finish(req.uid)
            out.append((req.uid, self._result(req)))
        return out

    def _step_prefill(self):
        """Prefill-role step: chunk-prefill only, then export every
        sequence whose first token is out.  The handoff result keeps the
        normal result shape (so a crash-replay or a drain still resolves
        the future sanely) plus the serialized KV under
        ``"handoff_export"`` for the replica set's re-dispatch hook."""
        eng = self.engine
        if self._qos is not None:
            self._qos.schedule(eng)
        eng.step_prefill_only()
        out = []
        for req in eng.collect_finished():  # finished AT prefill
            if self._qos is not None:  # (e.g. max_new_tokens=1)
                self._qos.on_finish(req.uid)
            out.append((req.uid, self._result(req)))
        for uid in eng.exportable():
            pay = eng.export_sequence(uid)
            self._handoff_exports += 1
            if self._qos is not None:
                self._qos.on_finish(uid)
            now = time.perf_counter()
            out.append((uid, {
                "handoff_export": pay,
                "tokens": list(pay["output"]),
                "n_prompt": len(pay["prompt"]),
                "ttft_s": (pay["first_token_at"] - pay["submitted_at"]
                           if pay["first_token_at"] else None),
                "itl_s": None,
                "latency_s": now - pay["submitted_at"],
                "role": "prefill",
            }))
        return out

    def generate_stream(self, payload, *, max_steps: int = 100000, **meta):
        """Synchronously drive ONE request to completion, yielding
        ``{"token": t}`` per generated token and finally ``{"done":
        True, **result}`` with the same keys ``step()`` reports
        (``ttft_s``/``itl_s``/``latency_s``/``tokens``).  A
        ``max_new_tokens <= 0`` payload yields only the final event with
        ``ttft_s: None`` — an empty generation has no first token.

        This drives the WHOLE engine (a convenience for tests, examples
        and single-tenant tools, not the replica-set path); results of
        other in-flight requests completing meanwhile are buffered and
        returned by the next ``step()`` call rather than dropped."""
        if self.phase == "prefill":
            raise ValueError(
                "generate_stream runs prefill+decode; a prefill-role "
                "replica hands sequences off instead of decoding them")
        n_prompt = len(payload.get("prompt", ()))
        if payload.get("max_new_tokens", 16) <= 0:
            yield {"done": True, "tokens": [], "n_prompt": n_prompt,
                   "ttft_s": None, "itl_s": None, "latency_s": 0.0}
            return
        uid = self.submit(payload, **meta)
        req = self._find_request(uid)
        sent = 0
        final = None
        for _ in range(max_steps):
            self._driver.step()
            for r in self._driver.collect_finished():
                res = self._result(r)
                if r.uid == uid:
                    final = res
                else:
                    self._stream_leftovers.append((r.uid, res))
            if req is not None:
                while sent < len(req.output):
                    yield {"token": req.output[sent]}
                    sent += 1
            if final is not None:
                break
        if final is None:
            raise RuntimeError(
                f"generate_stream: request {uid} did not finish within "
                f"{max_steps} steps")
        yield {"done": True, **final}

    def _find_request(self, uid):
        eng = self.engine
        for r in eng.queue:
            if r.uid == uid:
                return r
        for r in eng.running.values():
            if r.uid == uid:
                return r
        return None

    def residency_summary(self, max_len: int = 128):
        """Resident prefix sequences for router gossip (thread-safe: the
        engine's radix index locks internally, so the replica set may
        snapshot while the engine thread serves).  ``max_len`` is the
        router's match fidelity (``affinity_max_prefix``)."""
        return self.engine.residency_summary(max_len=max_len)

    def set_residency_listener(self, cb):
        """Gossip push: the replica set's callback fires on KV eviction so
        the router's residency view refreshes without waiting for the next
        pull tick."""
        self.engine.on_residency_drop = cb

    def warmup(self):
        """Prime the replica before it becomes routable: run one tiny
        request end-to-end so prefill/decode are compiled and the first
        real request pays no compilation tail (autoscale warm-up).  A
        decode-role replica warms with max_new_tokens=2 — one real
        decode step — because its working path is the batched decode an
        imported sequence lands in, which a prefill-terminal
        single-token warmup would never compile."""
        mnt = 2 if self.phase == "decode" else 1
        self.engine.submit([1, 2, 3, 4], max_new_tokens=mnt)
        self.engine.run(max_steps=64)

    @property
    def stats(self):
        return self.engine.stats

    def spec_stats(self):
        """Speculative-decoding counters (k, proposed, accepted,
        acceptance_rate, rounds, enabled) when a draft is armed; None on
        plain replicas.  The replica set sums these per group and the
        ``weighted_capacity`` autoscaler turns the set-wide acceptance
        rate into the draft group's capacity entitlement."""
        return self.session.spec_stats() if self.session else None

    def block_telemetry(self):
        """Live paged-pool gauges (free/total/reserved/shared blocks, CoW
        copies, evictions) the replica set aggregates per group and
        gossips to headroom-aware routers; None for slot-pool engines."""
        return self.engine.block_telemetry()

    def qos_stats(self):
        """WFQ scheduler counters (scheduler-initiated preemptions, the
        virtual clock, live flow count) plus the engine's preemption /
        resume totals; None when QoS is not armed on this replica."""
        if self._qos is None:
            return None
        out = self._qos.stats()
        out["engine_preemptions"] = self.engine.stats.preemptions
        out["engine_preempt_resumes"] = self.engine.stats.preempt_resumes
        return out

    def handoff_stats(self):
        """Disaggregation counters (exports on prefill replicas, imports
        + recompute fallbacks on decode replicas), aggregated per group
        by ``ReplicaSet.stats()``; None on unified replicas."""
        if self.phase == "serve":
            return None
        return {
            "role": self.phase,
            "exports": self._handoff_exports,
            "imports": self._handoff_imports,
            "recomputes": self._handoff_recomputes,
        }


def llm_service_factory(cfg: ModelConfig, params=None, **engine_kw):
    """Factory suitable for ServiceDescription(factory=...).

    Engine kwargs pass through; ``paged`` defaults to auto (block-paged
    pool for dense/moe, slot pool otherwise — see ``_resolve_paged``)."""

    def make():
        return LLMServicer(cfg, params, **engine_kw)

    return make


def llm_model_group(name: str, cfg: ModelConfig, params=None, *,
                    weight: float = 1.0, replicas: Optional[int] = None,
                    slo_p95_ms: Optional[float] = None,
                    requirements=None, role: str = "serve",
                    paired_with: Optional[str] = None,
                    min_replicas: Optional[int] = None,
                    max_replicas: Optional[int] = None,
                    borrow_limit: Optional[int] = None, **engine_kw):
    """One model config of a multi-model service: a ``ModelGroup`` whose
    factory builds an ``LLMServicer`` for ``cfg``.

    Several of these behind ONE ``ServiceDescription(models=[...])`` share
    a replica set, router, and partition ledger; clients address a model
    by tagging the payload (``{"prompt": ..., "model": name}``) or passing
    ``ReplicaSet.request(payload, model=name)`` — the router only
    considers that group's replicas, so a request can never land on a
    wrong-model engine.  ``weight`` anchors the group's share of the
    set's capacity; ``slo_p95_ms`` gives it its own latency target under
    the ``weighted_capacity`` autoscaler.  Engine kwargs (including the
    auto-defaulting ``paged`` flag and its ``block_size``/``num_blocks``
    knobs, plus the spec-decode ``draft_group``/``spec_k`` servicer
    kwargs) apply to every replica of the group.

    ``role="draft"`` marks the group as the proposer side of a
    speculative pair: ``paired_with`` names the target group (routing
    aliases both onto one affinity namespace so drafts land where the
    target's KV prefix is resident), and the ``weighted_capacity``
    autoscaler scales the group's entitlement by the measured acceptance
    rate.  ``min_replicas``/``max_replicas`` bound autoscaling per group;
    an explicit ``min_replicas=0`` allows a cold draft group to be
    scaled away entirely.  ``borrow_limit`` caps how many replicas the
    group may lend below its weight-anchored entitlement when the
    ``weighted_capacity`` autoscaler picks it as the donor of a
    capacity-neutral rebalance.

    ``role="prefill"`` / ``role="decode"`` declare a DISAGGREGATED pair
    sharing this set: clients address the prefill group, whose replicas
    only chunk-prefill (``phase="prefill"`` servicers, typically with a
    large ``max_num_batched_tokens``); on first token each sequence's KV
    is exported and re-dispatched to the ``paired_with`` decode group
    (named on the prefill group), whose replicas import it and serve
    pure decode.  The prefill group's ``slo_p95_ms`` is then a TTFT
    target and the decode group's an ITL target — the two-SLO split the
    ``weighted_capacity`` autoscaler rebalances independently.
    """
    if role in ("prefill", "decode"):
        engine_kw.setdefault("phase", role)
    return ModelGroup(name=name,
                      factory=llm_service_factory(cfg, params, **engine_kw),
                      weight=weight, replicas=replicas,
                      slo_p95_ms=slo_p95_ms, requirements=requirements,
                      role=role, paired_with=paired_with,
                      min_replicas=min_replicas, max_replicas=max_replicas,
                      borrow_limit=borrow_limit)
