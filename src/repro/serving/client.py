"""LLM servicer + client helpers: the glue between the middleware service
abstraction and the continuous-batching engine (Figs. 1-2: AI workers)."""
from __future__ import annotations

import time
from typing import Any, Optional

from repro.core.service import ModelGroup
from repro.models.config import ModelConfig
from .engine import (InferenceEngine, SpecDecodeSession,
                     make_engine_from_scratch)


def _resolve_paged(cfg: ModelConfig, engine_kw: dict) -> dict:
    """Paged-by-default policy for replicas: dense/moe engines get the
    block-paged pool unless the caller opts out (``paged=False``);
    state-carrying and prefix-offset families (ssm/hybrid/vlm/encdec)
    keep the slot pool.  ``paged=None`` (or absent) means "auto"."""
    kw = dict(engine_kw)
    if kw.get("paged") is None:
        kw["paged"] = cfg.family in ("dense", "moe")
    if not kw["paged"]:
        # the slot-pool engine does not take paged-only tuning knobs
        for k in ("block_size", "num_blocks", "prefill_chunk",
                  "max_running", "paged_decode_mode"):
            kw.pop(k, None)
    return kw


def _resolve_draft_engine(spec, *, seed: int = 0) -> InferenceEngine:
    """``draft_group`` resolution: accept the co-located draft in any of
    the shapes a launcher naturally holds — an ``InferenceEngine``, a
    built ``LLMServicer``, a ``ModelGroup`` (whose factory builds one; the
    ``--multi-model`` path hands exactly this), or a bare ``ModelConfig``
    (a fresh engine with auto-resolved pool)."""
    if isinstance(spec, InferenceEngine):
        return spec
    if isinstance(spec, LLMServicer):
        return spec.engine
    if isinstance(spec, ModelGroup):
        if spec.factory is None:
            raise ValueError(
                f"draft_group {spec.name!r} has no factory to build a "
                f"draft servicer from")
        servicer = spec.factory()
        engine = getattr(servicer, "engine", None)
        if engine is None:
            raise TypeError(
                f"draft_group {spec.name!r} factory built "
                f"{type(servicer).__name__}, which exposes no .engine")
        return engine
    if isinstance(spec, ModelConfig):
        return make_engine_from_scratch(spec, seed=seed,
                                        **_resolve_paged(spec, {}))
    raise TypeError(f"cannot resolve a draft engine from {type(spec)}")


class LLMServicer:
    """Servicer protocol (submit/step) around an InferenceEngine.

    Request payload: {"prompt": [ids...], "max_new_tokens": int,
                      "temperature": float}.
    Result: {"tokens": [...], "n_prompt": int, "ttft_s": float,
             "latency_s": float}.

    Replicas default to the block-paged engine for dense/moe configs
    (``paged=None`` auto-resolves via ``_resolve_paged``); pass
    ``paged=False`` to force the slot pool.

    ``draft_group`` arms cross-group speculative decoding: a co-located
    draft engine (resolved from a ``ModelGroup``/``ModelConfig``/engine,
    see ``_resolve_draft_engine``) proposes ``spec_k`` tokens per round
    and this replica's target engine verifies them in one extend forward
    (``SpecDecodeSession``).  Greedy output stays token-for-token
    identical to target-only decode; sampled requests are refused by the
    session.  ``spec_stats()`` exposes the proposed/accepted counters the
    replica set aggregates per group for the autoscaler.
    """

    def __init__(self, cfg: ModelConfig, params=None, *, seed: int = 0,
                 draft_group=None, spec_k: int = 4,
                 spec_min_acceptance: float = 0.0,
                 spec_probe_proposals: int = 64, **engine_kw):
        engine_kw = _resolve_paged(cfg, engine_kw)
        if params is None:
            self.engine = make_engine_from_scratch(cfg, seed=seed, **engine_kw)
        else:
            self.engine = InferenceEngine(cfg, params, **engine_kw)
        self.session = None
        if draft_group is not None:
            draft = _resolve_draft_engine(draft_group, seed=seed)
            self.session = SpecDecodeSession(
                self.engine, draft, k=spec_k,
                min_acceptance=spec_min_acceptance,
                probe_proposals=spec_probe_proposals)
        # everything below drives this one surface: the session when
        # speculating, the bare engine otherwise (identical protocol)
        self._driver = self.session or self.engine

    def submit(self, payload, **meta) -> int:
        return self._driver.submit(
            payload["prompt"],
            max_new_tokens=payload.get("max_new_tokens", 16),
            temperature=payload.get("temperature", 0.0),
            eos_id=payload.get("eos_id"),
        )

    def step(self):
        if not self._driver.has_work():
            time.sleep(1e-4)
            return []
        self._driver.step()
        out = []
        for req in self._driver.collect_finished():
            out.append((req.uid, {
                "tokens": req.output,
                "n_prompt": req.n_prompt,
                "ttft_s": (req.first_token_at - req.submitted_at
                           if req.first_token_at else None),
                "latency_s": req.finished_at - req.submitted_at,
            }))
        return out

    def residency_summary(self, max_len: int = 128):
        """Resident prefix sequences for router gossip (thread-safe: the
        engine's radix index locks internally, so the replica set may
        snapshot while the engine thread serves).  ``max_len`` is the
        router's match fidelity (``affinity_max_prefix``)."""
        return self.engine.residency_summary(max_len=max_len)

    def set_residency_listener(self, cb):
        """Gossip push: the replica set's callback fires on KV eviction so
        the router's residency view refreshes without waiting for the next
        pull tick."""
        self.engine.on_residency_drop = cb

    def warmup(self):
        """Prime the replica before it becomes routable: run one tiny
        request end-to-end so prefill/decode are compiled and the first
        real request pays no compilation tail (autoscale warm-up)."""
        self.engine.submit([1, 2, 3, 4], max_new_tokens=1)
        self.engine.run(max_steps=64)

    @property
    def stats(self):
        return self.engine.stats

    def spec_stats(self):
        """Speculative-decoding counters (k, proposed, accepted,
        acceptance_rate, rounds, enabled) when a draft is armed; None on
        plain replicas.  The replica set sums these per group and the
        ``weighted_capacity`` autoscaler turns the set-wide acceptance
        rate into the draft group's capacity entitlement."""
        return self.session.spec_stats() if self.session else None

    def block_telemetry(self):
        """Live paged-pool gauges (free/total/reserved/shared blocks, CoW
        copies, evictions) the replica set aggregates per group and
        gossips to headroom-aware routers; None for slot-pool engines."""
        return self.engine.block_telemetry()


def llm_service_factory(cfg: ModelConfig, params=None, **engine_kw):
    """Factory suitable for ServiceDescription(factory=...).

    Engine kwargs pass through; ``paged`` defaults to auto (block-paged
    pool for dense/moe, slot pool otherwise — see ``_resolve_paged``)."""

    def make():
        return LLMServicer(cfg, params, **engine_kw)

    return make


def llm_model_group(name: str, cfg: ModelConfig, params=None, *,
                    weight: float = 1.0, replicas: Optional[int] = None,
                    slo_p95_ms: Optional[float] = None,
                    requirements=None, role: str = "serve",
                    paired_with: Optional[str] = None,
                    min_replicas: Optional[int] = None,
                    max_replicas: Optional[int] = None, **engine_kw):
    """One model config of a multi-model service: a ``ModelGroup`` whose
    factory builds an ``LLMServicer`` for ``cfg``.

    Several of these behind ONE ``ServiceDescription(models=[...])`` share
    a replica set, router, and partition ledger; clients address a model
    by tagging the payload (``{"prompt": ..., "model": name}``) or passing
    ``ReplicaSet.request(payload, model=name)`` — the router only
    considers that group's replicas, so a request can never land on a
    wrong-model engine.  ``weight`` anchors the group's share of the
    set's capacity; ``slo_p95_ms`` gives it its own latency target under
    the ``weighted_capacity`` autoscaler.  Engine kwargs (including the
    auto-defaulting ``paged`` flag and its ``block_size``/``num_blocks``
    knobs, plus the spec-decode ``draft_group``/``spec_k`` servicer
    kwargs) apply to every replica of the group.

    ``role="draft"`` marks the group as the proposer side of a
    speculative pair: ``paired_with`` names the target group (routing
    aliases both onto one affinity namespace so drafts land where the
    target's KV prefix is resident), and the ``weighted_capacity``
    autoscaler scales the group's entitlement by the measured acceptance
    rate.  ``min_replicas``/``max_replicas`` bound autoscaling per group;
    an explicit ``min_replicas=0`` allows a cold draft group to be
    scaled away entirely.
    """
    return ModelGroup(name=name,
                      factory=llm_service_factory(cfg, params, **engine_kw),
                      weight=weight, replicas=replicas,
                      slo_p95_ms=slo_p95_ms, requirements=requirements,
                      role=role, paired_with=paired_with,
                      min_replicas=min_replicas, max_replicas=max_replicas)
