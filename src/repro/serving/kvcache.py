"""Slot-based cache pool — the TPU adaptation of PagedAttention.

vLLM's block tables fight GPU memory fragmentation with dynamic paging; XLA
wants ahead-of-time allocation, so the same insight (decouple request
lifetime from cache storage; admit/evict at slot granularity) becomes a fixed
``[max_seqs, max_len]`` pool with slot allocation + continuous batching
(JetStream-style).  Works for every model family: leaf batch dims are located
by the same path rules the dry-run uses for cache shardings.
"""
from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.launch import specs as sp
from repro.models.config import ModelConfig


def _path_keys(path):
    return [getattr(k, "key", getattr(k, "idx", None)) for k in path]


def batch_dim_for(keys, rank: int) -> int:
    name = keys[-1]
    if name in ("k", "v", "cross_k", "cross_v"):
        return rank - 4
    if name == "len":
        return rank - 1
    if name == "wkv":
        return rank - 4
    if name == "shift":
        return rank - 2
    if name == "ssm":
        return rank - 4
    if len(keys) >= 2 and keys[-2] == "conv":
        return rank - 3
    raise ValueError(f"unknown cache leaf {keys}")


class CachePool:
    """Zero-initialized cache for ``max_seqs`` slots + residency-aware
    slot allocator.

    A freed slot may stay *resident*: its KV still covers a token sequence
    the engine's radix residency index remembers, so a later prompt can
    resume it.  ``allocate()`` therefore prefers blank free slots (FIFO)
    and only recycles a resident one when no blank slot is left — evicting
    reusable KV while a never-used slot sits idle would throw away prefill
    work for nothing.  Among resident slots, free order approximates
    least-recent retirement, so the coldest cache is evicted first."""

    def __init__(self, cfg: ModelConfig, max_seqs: int, max_len: int):
        self.cfg = cfg
        self.max_seqs = max_seqs
        self.max_len = max_len
        tmpl = sp.cache_template(cfg, max_seqs, max_len)
        self.cache = jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), tmpl)
        self._free = list(range(max_seqs))
        self._resident: set[int] = set()

    # -- slot allocation ------------------------------------------------
    def allocate(self) -> Optional[int]:
        """Pop a free slot, blank ones first; the caller must drop any
        residency bookkeeping for the returned slot (its cache is about
        to be replaced)."""
        if not self._free:
            return None
        for i, slot in enumerate(self._free):
            if slot not in self._resident:
                return self._free.pop(i)
        slot = self._free.pop(0)  # all free slots resident: evict coldest
        self._resident.discard(slot)
        return slot

    def free(self, slot: int, resident: bool = False):
        """Return a slot to the pool; ``resident=True`` marks its KV as
        still covering a resumable sequence (prefix reuse)."""
        self._free.append(slot)
        if resident:
            self._resident.add(slot)
        else:
            self._resident.discard(slot)

    def take(self, slot: int) -> bool:
        """Claim a SPECIFIC free slot (prefix-reuse admission: the engine
        wants the slot whose cache already holds a matching prefix, not
        whichever the allocator would pop).  Returns False if taken."""
        try:
            self._free.remove(slot)
        except ValueError:
            return False
        self._resident.discard(slot)
        return True

    @property
    def n_free(self) -> int:
        return len(self._free)

    @property
    def n_free_blank(self) -> int:
        """Free slots with no resident cache (allocate() serves these
        first)."""
        return sum(1 for s in self._free if s not in self._resident)

    # -- data movement ----------------------------------------------------
    def insert(self, slot: int, prefill_cache):
        """Write a single-request prefill cache (batch=1) into ``slot``."""

        def upd(path, pool_leaf, new_leaf):
            keys = _path_keys(path)
            bdim = batch_dim_for(keys, pool_leaf.ndim)
            # move batch to front, set, move back
            pool_t = jnp.moveaxis(pool_leaf, bdim, 0)
            src = jnp.moveaxis(new_leaf, batch_dim_for(keys, new_leaf.ndim), 0)
            src0 = src[0]
            # prefill cache may cover fewer positions than the pool
            if src0.shape != pool_t.shape[1:]:
                pad = [(0, p - s) for p, s in zip(pool_t.shape[1:], src0.shape)]
                src0 = jnp.pad(src0, pad)
            pool_t = pool_t.at[slot].set(src0.astype(pool_t.dtype))
            return jnp.moveaxis(pool_t, 0, bdim)

        self.cache = jax.tree_util.tree_map_with_path(
            upd, self.cache, prefill_cache)

    def set_len(self, slot: int, n: int):
        """Fix the true sequence length of a right-padded bucketed prefill."""

        def upd(path, leaf):
            keys = _path_keys(path)
            if keys[-1] != "len":
                return leaf
            bdim = batch_dim_for(keys, leaf.ndim)
            t = jnp.moveaxis(leaf, bdim, 0)
            t = t.at[slot].set(jnp.full_like(t[slot], n))
            return jnp.moveaxis(t, 0, bdim)

        self.cache = jax.tree_util.tree_map_with_path(upd, self.cache)

    def reset_slot(self, slot: int):
        def zero(path, pool_leaf):
            keys = _path_keys(path)
            bdim = batch_dim_for(keys, pool_leaf.ndim)
            pool_t = jnp.moveaxis(pool_leaf, bdim, 0)
            pool_t = pool_t.at[slot].set(jnp.zeros_like(pool_t[slot]))
            return jnp.moveaxis(pool_t, 0, bdim)

        self.cache = jax.tree_util.tree_map_with_path(zero, self.cache)
