"""KV-cache pools for the serving engine: slot-granular and block-paged.

Two designs live here, both XLA-friendly (every physical buffer is
allocated ahead of time; only *indices* change at runtime):

``CachePool`` — the original slot pool: one ``[max_seqs, max_len]``
region per cache leaf, admit/evict at whole-slot granularity
(JetStream-style).  It remains the path for every model family,
including the state-carrying ones (ssm/hybrid) that have no
per-position KV to page.

``PagedCachePool`` — the TPU adaptation of vLLM's PagedAttention
proper: each cache leaf is a ``[num_blocks, block_size, ...]`` physical
store, a sequence is a *block table* (list of physical block ids), and
``BlockAllocator`` hands out blocks with per-block refcounts.  Multiple
sequences sharing a prompt prefix point their tables at the same
physical blocks (refcount > 1); the first divergent write triggers
copy-on-write of just the boundary block.  Admission is by free-block
count, eviction is block-granular, and the engine's radix residency
index becomes real memory headroom instead of whole-slot duplication.
Decode runs DIRECTLY on the physical store: the engine's default
``paged_decode_mode="direct"`` writes each new token's K/V into its
sequence's tail block (one cell per row) and attends through the block
table — the Pallas paged-decode kernel under ``use_pallas``, a jnp
table-gather fallback on CPU — so the per-step cost scales with the
blocks a sequence actually occupies, not ``max_len``.  Only chunked
prefill/extend still reassembles a contiguous ``[B, max_len, ...]``
view (``gather_block_view``) and scatters the newly produced positions
back (``scatter_block_writes``), because extend consumes a whole chunk
of positions at once; ``paged_decode_mode="gather"`` keeps that
round-trip on decode too, as the A/B baseline.  Block 0 is reserved as
a null block: padded batch rows and padded chunk positions write
there, so bucketing never needs masking logic inside the model.

Leaf batch dims are located by the same path rules the dry-run uses for
cache shardings.
"""
from __future__ import annotations

import math
from collections import deque
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.launch import specs as sp
from repro.models.config import ModelConfig


def _path_keys(path):
    return [getattr(k, "key", getattr(k, "idx", None)) for k in path]


def batch_dim_for(keys, rank: int) -> int:
    name = keys[-1]
    if name in ("k", "v", "cross_k", "cross_v"):
        return rank - 4
    if name == "len":
        return rank - 1
    if name == "wkv":
        return rank - 4
    if name == "shift":
        return rank - 2
    if name == "ssm":
        return rank - 4
    if len(keys) >= 2 and keys[-2] == "conv":
        return rank - 3
    raise ValueError(f"unknown cache leaf {keys}")


class CachePool:
    """Zero-initialized cache for ``max_seqs`` slots + residency-aware
    slot allocator.

    A freed slot may stay *resident*: its KV still covers a token sequence
    the engine's radix residency index remembers, so a later prompt can
    resume it.  ``allocate()`` therefore prefers blank free slots (FIFO)
    and only recycles a resident one when no blank slot is left — evicting
    reusable KV while a never-used slot sits idle would throw away prefill
    work for nothing.  Among resident slots, free order approximates
    least-recent retirement, so the coldest cache is evicted first.

    The free list is kept as two deques (blank FIFO / resident FIFO), so
    ``allocate()`` is O(1) instead of the old O(n) scan with an O(n)
    ``pop(i)`` inside it."""

    def __init__(self, cfg: ModelConfig, max_seqs: int, max_len: int):
        self.cfg = cfg
        self.max_seqs = max_seqs
        self.max_len = max_len
        tmpl = sp.cache_template(cfg, max_seqs, max_len)
        self.cache = jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), tmpl)
        self._free_blank: deque[int] = deque(range(max_seqs))
        self._free_resident: deque[int] = deque()
        self._resident: set[int] = set()

    # -- slot allocation ------------------------------------------------
    def allocate(self) -> Optional[int]:
        """Pop a free slot, blank ones first; the caller must drop any
        residency bookkeeping for the returned slot (its cache is about
        to be replaced)."""
        if self._free_blank:
            return self._free_blank.popleft()
        if self._free_resident:  # no blank slot left: evict the coldest
            slot = self._free_resident.popleft()
            self._resident.discard(slot)
            return slot
        return None

    def free(self, slot: int, resident: bool = False):
        """Return a slot to the pool; ``resident=True`` marks its KV as
        still covering a resumable sequence (prefix reuse)."""
        if resident:
            self._resident.add(slot)
            self._free_resident.append(slot)
        else:
            self._resident.discard(slot)
            self._free_blank.append(slot)

    def take(self, slot: int) -> bool:
        """Claim a SPECIFIC free slot (prefix-reuse admission: the engine
        wants the slot whose cache already holds a matching prefix, not
        whichever the allocator would pop).  Returns False if taken."""
        for q in (self._free_resident, self._free_blank):
            try:
                q.remove(slot)
            except ValueError:
                continue
            self._resident.discard(slot)
            return True
        return False

    @property
    def n_free(self) -> int:
        return len(self._free_blank) + len(self._free_resident)

    @property
    def n_free_blank(self) -> int:
        """Free slots with no resident cache (allocate() serves these
        first)."""
        return len(self._free_blank)

    # -- data movement ----------------------------------------------------
    def insert(self, slot: int, prefill_cache):
        """Write a single-request prefill cache (batch=1) into ``slot``."""

        def upd(path, pool_leaf, new_leaf):
            keys = _path_keys(path)
            bdim = batch_dim_for(keys, pool_leaf.ndim)
            # move batch to front, set, move back
            pool_t = jnp.moveaxis(pool_leaf, bdim, 0)
            src = jnp.moveaxis(new_leaf, batch_dim_for(keys, new_leaf.ndim), 0)
            src0 = src[0]
            # prefill cache may cover fewer positions than the pool
            if src0.shape != pool_t.shape[1:]:
                pad = [(0, p - s) for p, s in zip(pool_t.shape[1:], src0.shape)]
                src0 = jnp.pad(src0, pad)
            pool_t = pool_t.at[slot].set(src0.astype(pool_t.dtype))
            return jnp.moveaxis(pool_t, 0, bdim)

        self.cache = jax.tree_util.tree_map_with_path(
            upd, self.cache, prefill_cache)

    def set_len(self, slot: int, n: int):
        """Fix the true sequence length of a right-padded bucketed prefill."""

        def upd(path, leaf):
            keys = _path_keys(path)
            if keys[-1] != "len":
                return leaf
            bdim = batch_dim_for(keys, leaf.ndim)
            t = jnp.moveaxis(leaf, bdim, 0)
            t = t.at[slot].set(jnp.full_like(t[slot], n))
            return jnp.moveaxis(t, 0, bdim)

        self.cache = jax.tree_util.tree_map_with_path(upd, self.cache)

    def set_lens(self, updates: dict):
        """Batch ``set_len``: one cache-tree rebuild for many slots.  The
        speculative-decode rewind uses this — a verify forward advances
        EVERY slot's length by the chunk width, so all tracked slots
        rewind together in one pass instead of one tree walk per slot."""
        if not updates:
            return

        def upd(path, leaf):
            keys = _path_keys(path)
            if keys[-1] != "len":
                return leaf
            bdim = batch_dim_for(keys, leaf.ndim)
            t = jnp.moveaxis(leaf, bdim, 0)
            for slot, n in updates.items():
                t = t.at[slot].set(jnp.full_like(t[slot], n))
            return jnp.moveaxis(t, 0, bdim)

        self.cache = jax.tree_util.tree_map_with_path(upd, self.cache)

    def reset_slot(self, slot: int):
        def zero(path, pool_leaf):
            keys = _path_keys(path)
            bdim = batch_dim_for(keys, pool_leaf.ndim)
            pool_t = jnp.moveaxis(pool_leaf, bdim, 0)
            pool_t = pool_t.at[slot].set(jnp.zeros_like(pool_t[slot]))
            return jnp.moveaxis(pool_t, 0, bdim)

        self.cache = jax.tree_util.tree_map_with_path(zero, self.cache)


# ---------------------------------------------------------------------------
# Block-paged pool
# ---------------------------------------------------------------------------


NULL_BLOCK = 0  # physical block 0 is never allocated: padded rows write here


class BlockAllocator:
    """Refcounted free-block allocator over ``num_blocks`` physical blocks.

    Block 0 is reserved as the null block (padded batch rows and padded
    chunk positions are redirected there), so ``capacity`` is
    ``num_blocks - 1``.  ``allocate()`` and ``free()`` are O(1);
    ``fork()`` adds a reference so several block tables (or residency
    entries) can share one physical block, and the last ``free()``
    returns it to the free list.  Double frees and forks of unallocated
    blocks raise — a block table pointing at a recycled block silently
    corrupts another sequence's KV, so the invariant is enforced here."""

    def __init__(self, num_blocks: int):
        if num_blocks < 2:
            raise ValueError("need >= 2 blocks (block 0 is the null block)")
        self.num_blocks = num_blocks
        self._free: deque[int] = deque(range(1, num_blocks))
        self._ref = [0] * num_blocks

    @property
    def capacity(self) -> int:
        return self.num_blocks - 1

    @property
    def n_free(self) -> int:
        return len(self._free)

    @property
    def n_live(self) -> int:
        return self.capacity - len(self._free)

    def refcount(self, block: int) -> int:
        return self._ref[block]

    def allocate(self) -> Optional[int]:
        """Pop a free block with refcount 1, or None when exhausted."""
        if not self._free:
            return None
        b = self._free.popleft()
        self._ref[b] = 1
        return b

    def fork(self, block: int):
        """Add a reference: a second block table now points at ``block``."""
        if block <= NULL_BLOCK or block >= self.num_blocks:
            raise ValueError(f"fork of invalid block {block}")
        if self._ref[block] <= 0:
            raise ValueError(f"fork of unallocated block {block}")
        self._ref[block] += 1

    def free(self, block: int) -> bool:
        """Drop one reference; returns True when the block became free."""
        if block <= NULL_BLOCK or block >= self.num_blocks:
            raise ValueError(f"free of invalid block {block}")
        if self._ref[block] <= 0:
            raise ValueError(f"double free of block {block}")
        self._ref[block] -= 1
        if self._ref[block] == 0:
            self._free.append(block)
            return True
        return False

    def block_savings(self) -> int:
        """Physical blocks saved by sharing: sum of (refcount - 1) over
        live blocks — each extra reference is a block the slot design
        would have duplicated."""
        return sum(r - 1 for r in self._ref if r > 1)


def _kv_write_rows(view_leaf, bdim, write_pos):
    """Rows of a contiguous view at per-sequence positions: [B, T, rest]."""
    v2 = jnp.moveaxis(view_leaf, (bdim, bdim + 1), (0, 1))  # [B, S, rest]
    B = v2.shape[0]
    return v2[jnp.arange(B)[:, None], write_pos]


def gather_block_view(store, block_tables, lens):
    """Reassemble a contiguous cache view from a blocked store.

    ``store``: cache tree with leaves ``[..., num_blocks, block_size, ...]``
    (the batch/seq dims of ``cache_template``); ``block_tables``:
    ``[B, max_blocks]`` int32 physical block ids; ``lens``: ``[B]`` int32
    valid lengths.  Returns a tree shaped like a ``[B, max_blocks *
    block_size, ...]`` slot cache, with ``len`` leaves broadcast from
    ``lens`` — exactly what ``ModelApi.decode`` / ``extend`` expect.
    """
    B, mb = block_tables.shape

    def g(path, leaf):
        keys = _path_keys(path)
        bdim = batch_dim_for(keys, leaf.ndim)
        if keys[-1] == "len":
            lead = leaf.shape[:bdim]
            return jnp.broadcast_to(lens.astype(jnp.int32), lead + (B,))
        s2 = jnp.moveaxis(leaf, (bdim, bdim + 1), (0, 1))  # [N, bs, rest]
        bs = s2.shape[1]
        v = s2[block_tables]  # [B, mb, bs, rest]
        v = v.reshape((B, mb * bs) + s2.shape[2:])
        return jnp.moveaxis(v, (0, 1), (bdim, bdim + 1))

    return jax.tree_util.tree_map_with_path(g, store)


def scatter_block_writes(store, view, write_phys, write_off, write_pos):
    """Write the view rows at ``write_pos[b, t]`` into store blocks
    ``(write_phys[b, t], write_off[b, t])``.

    Only the positions actually produced this step move back — the rest
    of the gathered view is a read-only copy.  Padded (b, t) entries are
    redirected to the null block by the caller (phys 0), so collisions
    there are harmless.  ``len`` leaves of the store are untouched (the
    engine tracks logical lengths host-side)."""

    def s(path, sleaf, vleaf):
        keys = _path_keys(path)
        if keys[-1] == "len":
            return sleaf
        bdim = batch_dim_for(keys, sleaf.ndim)
        written = _kv_write_rows(vleaf, bdim, write_pos)  # [B, T, rest]
        s2 = jnp.moveaxis(sleaf, (bdim, bdim + 1), (0, 1))  # [N, bs, rest]
        s2 = s2.at[write_phys, write_off].set(written.astype(s2.dtype))
        return jnp.moveaxis(s2, (0, 1), (bdim, bdim + 1))

    return jax.tree_util.tree_map_with_path(s, store, view)


def extract_blocks(store, blocks):
    """Serialize physical blocks out of a paged store for migration.

    Returns ``{leaf_path: host_array}`` where each array is the leaf's
    rows at ``blocks`` with the block dim moved to the front —
    ``[n_blocks, block_size, ...]`` — exactly what ``insert_blocks``
    writes back on the receiving engine.  ``len`` leaves are omitted
    (logical lengths are engine host state, carried in the handoff
    metadata, not in the store)."""
    idx = jnp.asarray(list(blocks), jnp.int32)
    out = {}

    def g(path, leaf):
        keys = tuple(_path_keys(path))
        if keys[-1] == "len":
            return leaf
        bdim = batch_dim_for(keys, leaf.ndim)
        t = jnp.moveaxis(leaf, bdim, 0)
        out[keys] = jax.device_get(t[idx])
        return leaf

    jax.tree_util.tree_map_with_path(g, store)
    return out


def insert_blocks(store, leaves, dst_blocks):
    """Write serialized block rows (from ``extract_blocks``, possibly on
    another engine) into this store at ``dst_blocks``.  Leaf paths must
    match — both pools were built from the same ``cache_template`` — and
    ``len`` leaves are untouched."""
    idx = jnp.asarray(list(dst_blocks), jnp.int32)

    def s(path, leaf):
        keys = tuple(_path_keys(path))
        src = leaves.get(keys)
        if src is None:
            return leaf
        bdim = batch_dim_for(keys, leaf.ndim)
        t = jnp.moveaxis(leaf, bdim, 0)
        t = t.at[idx].set(jnp.asarray(src).astype(t.dtype))
        return jnp.moveaxis(t, 0, bdim)

    return jax.tree_util.tree_map_with_path(s, store)


class PagedCachePool:
    """Block-paged physical KV store + allocator.

    Each cache leaf is allocated once as ``[num_blocks, block_size, ...]``
    (via ``cache_template`` with batch=num_blocks, max_len=block_size);
    sequences own *block tables* mapping logical block index ->
    physical block id.  The pool only moves data: the engine owns
    tables, refcount policy (via ``alloc``), and scheduling."""

    def __init__(self, cfg: ModelConfig, num_blocks: int, block_size: int,
                 max_len: int):
        if cfg.family not in ("dense", "moe"):
            raise ValueError(
                f"paged KV cache requires per-position KV (dense/moe), "
                f"not family {cfg.family!r}")
        self.cfg = cfg
        self.num_blocks = num_blocks
        self.block_size = block_size
        self.max_len = max_len
        self.max_blocks = -(-max_len // block_size)  # blocks per sequence
        if self.max_blocks > num_blocks - 1:
            raise ValueError(
                f"num_blocks={num_blocks} cannot hold one max_len={max_len} "
                f"sequence ({self.max_blocks} blocks of {block_size})")
        tmpl = sp.cache_template(cfg, num_blocks, block_size)
        self.cache = jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), tmpl)
        self.alloc = BlockAllocator(num_blocks)

        def copy_fn(store, src, dst):
            def cp(path, leaf):
                keys = _path_keys(path)
                if keys[-1] == "len":
                    return leaf
                bdim = batch_dim_for(keys, leaf.ndim)
                t = jnp.moveaxis(leaf, bdim, 0)
                t = t.at[dst].set(t[src])
                return jnp.moveaxis(t, 0, bdim)

            return jax.tree_util.tree_map_with_path(cp, store)

        self._copy = jax.jit(copy_fn, donate_argnums=(0,))

    def copy_block(self, src: int, dst: int):
        """Copy-on-write: duplicate physical block ``src`` into ``dst``."""
        self.cache = self._copy(self.cache, src, dst)

    @property
    def n_free(self) -> int:
        return self.alloc.n_free

    def block_savings(self) -> int:
        return self.alloc.block_savings()
