"""Weighted-fair queueing + decode preemption for multi-tenant QoS.

``WFQScheduler`` sits between a servicer and its ``InferenceEngine``:
every submitted request is stamped with a VIRTUAL FINISH TIME — the
tenant/class flow's virtual clock advanced by ``cost / weight`` — and
``schedule()`` re-orders the engine's admission queue by those stamps
before each step.  Heavier classes (see ``DEFAULT_CLASS_WEIGHTS``)
accumulate virtual time more slowly, so under contention their requests
sort ahead; an idle flow's clock is pulled up to the global virtual
clock on its next submit, so sleeping never banks credit (the classic
WFQ start-time rule).

When the queue head cannot be admitted (no free sequence slot, or not
enough free + reclaimable blocks for its reservation) and ``preempt``
is on, the scheduler preempts the running DECODE-phase sequence with
the lightest class weight and the latest virtual finish — strictly
lighter than the head's class.  The victim is RE-STAMPED at its flow's
current virtual time (preempted work re-enters the queue as new work),
so it always sorts behind the head that displaced it — preemption can
never churn by re-admitting the victim first.  Preempted KV retires to
residency and the victim re-enters the queue (see
``InferenceEngine.preempt_sequence``); its resume is token-identical,
so QoS is invisible in transcripts.

The scheduler is deliberately engine-agnostic about WHAT admission
needs — it recomputes the head's block need with the engine's own
``_blocks_needed`` (coverage-blind, i.e. conservative: a resident match
only makes admission easier).
"""
from __future__ import annotations

from typing import Optional

from repro.core.request import DEFAULT_CLASS_WEIGHTS


class WFQScheduler:
    """Per-replica weighted-fair admission order with decode preemption."""

    def __init__(self, class_weights: Optional[dict] = None,
                 preempt: bool = True, max_preempt_per_round: int = 4):
        self.weights = dict(DEFAULT_CLASS_WEIGHTS if class_weights is None
                            else class_weights)
        self.preempt = preempt
        self.max_preempt_per_round = max_preempt_per_round
        self._vtime: dict = {}  # (tenant, class) flow -> virtual clock
        self._v = 0.0  # global virtual clock (floor for idle flows)
        self._finish: dict = {}  # uid -> virtual finish stamp
        self.preempted = 0  # scheduler-initiated preemptions

    def weight_of(self, qos_class: str) -> float:
        return max(self.weights.get(qos_class, 1.0), 1e-9)

    # -- submission ---------------------------------------------------------
    def on_submit(self, req, cost: Optional[float] = None):
        """Stamp an engine ``Request`` with its virtual finish time.
        ``cost`` defaults to the work the request will actually do
        (prompt prefill + decode budget, in tokens)."""
        if cost is None:
            cost = len(req.prompt) + req.max_new_tokens
        flow = (req.tenant, req.qos_class)
        start = max(self._vtime.get(flow, 0.0), self._v)
        fin = start + cost / self.weight_of(req.qos_class)
        self._vtime[flow] = fin
        self._finish[req.uid] = fin

    def on_finish(self, uid: int):
        self._finish.pop(uid, None)

    # -- scheduling ---------------------------------------------------------
    def _need(self, eng, req) -> int:
        """Coverage-blind block need for admitting ``req`` (mirrors
        ``_admit_paged`` / ``_readmit_preempted`` without their resident-
        prefix credit)."""
        if req.output:  # preempted readmit: catch-up over the transcript
            total = req.n_prompt + req.max_new_tokens
        else:
            m = min(req.n_prompt, eng.max_len - 1)
            total = m + req.max_new_tokens
        return eng._blocks_needed(total, 0)

    def _head_admits(self, eng, head) -> bool:
        if len(eng.running) >= eng.max_running:
            return False
        avail = (eng.pool.n_free + eng._reclaimable_blocks()
                 - eng._reserved)
        return avail >= self._need(eng, head)

    def schedule(self, eng):
        """Re-order ``eng.queue`` by virtual finish and, if the head is
        blocked, preempt lighter running decodes to make room.  Call
        immediately before ``eng.step()`` (the step's admission pass then
        sees the WFQ order)."""
        if not eng.queue:
            return
        fin = self._finish
        eng.queue.sort(key=lambda r: fin.get(r.uid, 0.0))  # stable
        head = eng.queue[0]
        head_fin = fin.get(head.uid, 0.0)
        self._v = max(self._v, head_fin)
        if not (self.preempt and getattr(eng, "paged", False)):
            return
        head_w = self.weight_of(head.qos_class)
        tries = self.max_preempt_per_round
        while tries > 0 and not self._head_admits(eng, head):
            victim = None
            vkey = None
            for r in eng.running.values():
                if r.done or r.pending_tokens or not r.output \
                        or r.truncated:
                    continue  # only decode-phase sequences are preemptable
                w = self.weight_of(r.qos_class)
                if w >= head_w:
                    continue  # never preempt an equal/heavier class
                key = (-w, fin.get(r.uid, 0.0))  # lightest class first,
                #                                  then latest finish
                if victim is None or key > vkey:
                    victim, vkey = r, key
            if victim is None or not eng.preempt_sequence(victim.uid):
                break
            # re-stamp the victim at its flow's CURRENT virtual time: the
            # catch-up replay is new work, and the fresh stamp (>= the
            # global clock >= head_fin) pins it behind the head it made
            # room for
            w = self.weight_of(victim.qos_class)
            flow = (victim.tenant, victim.qos_class)
            start = max(self._vtime.get(flow, 0.0), self._v)
            nf = start + (victim.n_prompt + victim.max_new_tokens) / w
            self._vtime[flow] = nf
            self._finish[victim.uid] = nf
            self.preempted += 1
            tries -= 1
        # preempted victims re-entered the queue: restore WFQ order
        eng.queue.sort(key=lambda r: fin.get(r.uid, 0.0))
        if len(self._finish) > 4096:  # prune stamps of departed requests
            live = {r.uid for r in eng.queue}
            live.update(eng.running.keys())
            self._finish = {u: f for u, f in self._finish.items()
                            if u in live}

    def stats(self) -> dict:
        return {"preempted": self.preempted,
                "virtual_clock": self._v,
                "flows": len(self._vtime)}
