"""Train-step factory: value_and_grad + microbatch accumulation + AdamW.

``make_train_step`` builds a jit-compiled step with explicit in/out shardings
(FSDP over "data", TP over "model", DP over ("pod","data")).  Microbatching
runs a ``lax.scan`` over gradient accumulation steps so saved activations are
O(one microbatch); gradients accumulate in fp32 sharded like the params
(reduce-scatter semantics under GSPMD).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.launch import sharding as shd
from repro.models import ModelApi, get_model
from repro.models.config import ModelConfig
from .optim import OptimizerConfig, adamw_init, adamw_update


@dataclasses.dataclass
class TrainConfig:
    global_batch: int = 8
    seq_len: int = 128
    microbatches: int = 1
    optimizer: OptimizerConfig = dataclasses.field(default_factory=OptimizerConfig)
    checkpoint_dir: Optional[str] = None
    checkpoint_every: int = 100
    keep_checkpoints: int = 3


def init_state(key, api: ModelApi, cfg: ModelConfig, opt_cfg: OptimizerConfig):
    """TrainState pytree: {"params", "opt", "rng"}."""
    params_px = api.init(key, cfg)
    from repro.models import nn

    params, axes = nn.split(params_px)
    opt = adamw_init(params, opt_cfg)
    return {"params": params, "opt": opt}, axes


def state_shardings(axes, opt_cfg: OptimizerConfig, mesh, rules=None):
    rules = rules or shd.TRAIN_RULES
    p_sh = shd.make_shardings(axes, rules, mesh)
    opt_axes = shd.opt_axes_like(axes, opt_cfg.quantize_states)
    o_sh = shd.make_shardings(opt_axes, rules, mesh)
    return {"params": p_sh, "opt": o_sh}


def batch_shardings(cfg: ModelConfig, mesh):
    """Sharding tree for a training batch dict."""
    tok = shd.batch_sharding(mesh, extra_dims=1)
    out = {"tokens": tok, "targets": tok, "loss_mask": tok}
    if cfg.family == "encdec":
        out["frame_embeds"] = shd.batch_sharding(mesh, extra_dims=2)
    if cfg.family == "vlm":
        out["patch_embeds"] = shd.batch_sharding(mesh, extra_dims=2)
    return out


def make_train_step(api: ModelApi, cfg: ModelConfig, tcfg: TrainConfig,
                    mesh=None, *, rules=None, donate=True, param_specs=None):
    """Returns jitted ``train_step(state, batch) -> (state, metrics)``.

    ``param_specs``: optional PartitionSpec tree matching params — the
    gradient-accumulation carry is constrained to it (otherwise the scan
    carry can lose its sharding and the per-microbatch gradient reduction
    happens on full replicated f32 tensors)."""
    opt_cfg = tcfg.optimizer
    n_micro = tcfg.microbatches

    def _pin_grads(g):
        if param_specs is None or mesh is None:
            return g
        from repro.models import nn as _nn

        return jax.tree.map(
            lambda x, s: _nn.constrain(x, mesh, s), g, param_specs)

    def loss_fn(params, mb):
        return api.loss(params, mb, cfg, mesh=mesh)

    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def step(state, batch):
        params = state["params"]
        if n_micro == 1:
            (loss, metrics), grads = grad_fn(params, batch)
            grads = _pin_grads(grads)
        else:
            def split_mb(x):
                b = x.shape[0]
                return x.reshape(n_micro, b // n_micro, *x.shape[1:])

            mbs = jax.tree.map(split_mb, batch)
            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)

            def accum(carry, mb):
                g_acc, loss_acc = carry
                (l, m), g = grad_fn(params, mb)
                g_acc = jax.tree.map(
                    lambda a, b: a + b.astype(jnp.float32), g_acc, g)
                return (_pin_grads(g_acc), loss_acc + l), m

            (grads, loss_sum), ms = jax.lax.scan(
                accum, (zeros, jnp.zeros((), jnp.float32)), mbs)
            grads = jax.tree.map(lambda g: g / n_micro, grads)
            loss = loss_sum / n_micro
            metrics = jax.tree.map(lambda x: x[-1], ms)

        new_params, new_opt, stats = adamw_update(
            grads, state["opt"], params, opt_cfg)
        metrics = dict(metrics, loss=loss, **stats)
        return {"params": new_params, "opt": new_opt}, metrics

    if mesh is None:
        return jax.jit(step, donate_argnums=(0,) if donate else ())

    # explicit shardings at scale
    return step  # caller jits with shardings via jit_train_step


def jit_train_step(step_fn, state_sh, batch_sh, *, donate=True):
    metrics_sh = None  # let the compiler choose (scalars)
    return jax.jit(
        step_fn,
        in_shardings=(state_sh, batch_sh),
        out_shardings=(state_sh, metrics_sh),
        donate_argnums=(0,) if donate else (),
    )


def train_loop(api, cfg: ModelConfig, tcfg: TrainConfig, *, steps: int,
               data_iter, key=None, mesh=None, state=None, start_step=0,
               checkpointer=None, log_every: int = 10,
               on_metrics: Optional[Callable] = None):
    """Simple driver used by examples/tests (single-host)."""
    key = key if key is not None else jax.random.PRNGKey(0)
    if state is None:
        state, axes = init_state(key, api, cfg, tcfg.optimizer)
    step_fn = make_train_step(api, cfg, tcfg, mesh)
    history = []
    for i in range(start_step, steps):
        batch = next(data_iter)
        state, metrics = step_fn(state, batch)
        if i % log_every == 0 or i == steps - 1:
            m = {k: float(v) for k, v in metrics.items()}
            history.append({"step": i, **m})
            if on_metrics:
                on_metrics(i, m)
        if checkpointer is not None and (i + 1) % tcfg.checkpoint_every == 0:
            checkpointer.save(state, i + 1)
    return state, history
