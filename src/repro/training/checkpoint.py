"""Checkpoint/restart (fault tolerance).

Atomic step-tagged checkpoints: arrays are flattened to ``path -> ndarray``
and written to ``step_<N>.npz`` alongside a JSON manifest with a content
checksum; writes go to a temp file + ``os.replace`` so a crash mid-save never
corrupts the latest checkpoint.  ``restore_latest`` skips corrupt/partial
checkpoints (validated against the manifest checksum) and falls back to the
newest valid one — the node-failure recovery path.
"""
from __future__ import annotations

import hashlib
import json
import os
import re
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np


def _flatten(tree, prefix=""):
    out = {}
    if isinstance(tree, dict):
        for k in sorted(tree):
            out.update(_flatten(tree[k], f"{prefix}{k}/"))
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            out.update(_flatten(v, f"{prefix}{i}/"))
    else:
        out[prefix[:-1]] = np.asarray(tree)
    return out


def _unflatten_into(template, flat):
    def rebuild(tree, prefix=""):
        if isinstance(tree, dict):
            return {k: rebuild(tree[k], f"{prefix}{k}/") for k in tree}
        if isinstance(tree, (list, tuple)):
            vals = [rebuild(v, f"{prefix}{i}/") for i, v in enumerate(tree)]
            return type(tree)(vals)
        key = prefix[:-1]
        arr = flat[key]
        leaf = tree
        return jnp.asarray(arr, dtype=leaf.dtype if hasattr(leaf, "dtype") else None)

    return rebuild(template)


class Checkpointer:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)

    # -- save ---------------------------------------------------------------
    def save(self, state, step: int, extra: Optional[dict] = None):
        flat = _flatten(state)
        payload_path = os.path.join(self.dir, f"step_{step:08d}.npz")
        tmp = payload_path + ".tmp"
        with open(tmp, "wb") as f:
            np.savez(f, **flat)
        digest = _file_checksum(tmp)
        os.replace(tmp, payload_path)
        manifest = {
            "step": step,
            "checksum": digest,
            "keys": sorted(flat.keys()),
            "extra": extra or {},
        }
        mpath = os.path.join(self.dir, f"step_{step:08d}.json")
        mtmp = mpath + ".tmp"
        with open(mtmp, "w") as f:
            json.dump(manifest, f)
        os.replace(mtmp, mpath)
        self._gc()
        return payload_path

    # -- restore ------------------------------------------------------------
    def steps(self):
        out = []
        for fn in os.listdir(self.dir):
            m = re.match(r"step_(\d+)\.json$", fn)
            if m:
                out.append(int(m.group(1)))
        return sorted(out)

    def is_valid(self, step: int) -> bool:
        mpath = os.path.join(self.dir, f"step_{step:08d}.json")
        ppath = os.path.join(self.dir, f"step_{step:08d}.npz")
        if not (os.path.exists(mpath) and os.path.exists(ppath)):
            return False
        try:
            with open(mpath) as f:
                manifest = json.load(f)
            return _file_checksum(ppath) == manifest["checksum"]
        except Exception:
            return False

    def restore(self, template, step: int):
        ppath = os.path.join(self.dir, f"step_{step:08d}.npz")
        with np.load(ppath) as z:
            flat = {k: z[k] for k in z.files}
        return _unflatten_into(template, flat)

    def restore_latest(self, template):
        """Newest *valid* checkpoint (corrupt ones are skipped) or None."""
        for step in reversed(self.steps()):
            if self.is_valid(step):
                return self.restore(template, step), step
        return None, 0

    def manifest(self, step: int) -> dict:
        with open(os.path.join(self.dir, f"step_{step:08d}.json")) as f:
            return json.load(f)

    def _gc(self):
        steps = self.steps()
        for s in steps[:-self.keep] if self.keep > 0 else []:
            for ext in (".npz", ".json"):
                try:
                    os.remove(os.path.join(self.dir, f"step_{s:08d}{ext}"))
                except OSError:
                    pass


def _file_checksum(path: str) -> str:
    h = hashlib.sha256()
    with open(path, "rb") as f:
        for chunk in iter(lambda: f.read(1 << 20), b""):
            h.update(chunk)
    return h.hexdigest()
