"""Optimizers: AdamW with optional 8-bit blockwise-quantized moments.

The quantized variant is the distributed-optimization trick that lets
nemotron-4-340b's optimizer state fit the production mesh: ``m`` is stored as
int8 and ``v`` as uint8, both with per-block (last-dim blocks of
``QBLOCK``) fp32 scales — bitsandbytes-style, adapted to a shape-preserving
layout so optimizer-state shardings mirror param shardings.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

QBLOCK = 128


@dataclasses.dataclass
class OptimizerConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    decay_steps: int = 10_000
    min_lr_ratio: float = 0.1
    quantize_states: bool = False  # 8-bit m/v (blockwise)


# ---------------------------------------------------------------------------
# Schedule
# ---------------------------------------------------------------------------


def lr_at(step, cfg: OptimizerConfig):
    step = step.astype(jnp.float32) if hasattr(step, "astype") else float(step)
    warm = jnp.minimum(1.0, (step + 1) / max(1, cfg.warmup_steps))
    prog = jnp.clip((step - cfg.warmup_steps)
                    / max(1, cfg.decay_steps - cfg.warmup_steps), 0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    frac = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * cos
    return cfg.lr * warm * frac


# ---------------------------------------------------------------------------
# Blockwise 8-bit quantization (shape-preserving layout)
# ---------------------------------------------------------------------------


def _blocked_shape(shape):
    last = shape[-1] if shape else 1
    if last % QBLOCK == 0:
        return shape[:-1] + (last // QBLOCK,), QBLOCK
    return shape[:-1] + (1,), last  # one scale per row


def quantize_signed(x):
    """fp32 -> (int8 codes, fp32 blockwise scales)."""
    shape = x.shape if x.ndim else (1,)
    x2 = x.reshape(shape)
    sshape, bs = _blocked_shape(shape)
    xb = x2.reshape(sshape + (bs,))
    scale = jnp.max(jnp.abs(xb), axis=-1) / 127.0
    safe = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(xb / safe[..., None]), -127, 127).astype(jnp.int8)
    return q.reshape(shape), scale


def dequantize_signed(q, scale):
    shape = q.shape
    sshape, bs = _blocked_shape(shape)
    qb = q.reshape(sshape + (bs,)).astype(jnp.float32)
    return (qb * scale[..., None]).reshape(shape)


def quantize_unsigned(x):
    """Non-negative fp32 -> (uint8 codes, fp32 blockwise scales)."""
    shape = x.shape if x.ndim else (1,)
    sshape, bs = _blocked_shape(shape)
    xb = x.reshape(sshape + (bs,))
    scale = jnp.max(xb, axis=-1) / 255.0
    safe = jnp.maximum(scale, 1e-30)
    q = jnp.clip(jnp.round(xb / safe[..., None]), 0, 255).astype(jnp.uint8)
    return q.reshape(shape), scale


def dequantize_unsigned(q, scale):
    shape = q.shape
    sshape, bs = _blocked_shape(shape)
    qb = q.reshape(sshape + (bs,)).astype(jnp.float32)
    return (qb * scale[..., None]).reshape(shape)


# ---------------------------------------------------------------------------
# AdamW
# ---------------------------------------------------------------------------


def adamw_init(params, cfg: OptimizerConfig):
    if cfg.quantize_states:
        def mk(p):
            mq, ms = quantize_signed(jnp.zeros(p.shape, jnp.float32))
            vq, vs = quantize_unsigned(jnp.zeros(p.shape, jnp.float32))
            return {"mq": mq, "ms": ms, "vq": vq, "vs": vs}
    else:
        def mk(p):
            return {"m": jnp.zeros(p.shape, jnp.float32),
                    "v": jnp.zeros(p.shape, jnp.float32)}
    return {
        "moments": jax.tree.map(mk, params),
        "step": jnp.zeros((), jnp.int32),
    }


def global_norm(tree):
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def adamw_update(grads, opt_state, params, cfg: OptimizerConfig):
    """Returns (new_params, new_opt_state, stats)."""
    step = opt_state["step"]
    lr = lr_at(step, cfg)
    gnorm = global_norm(grads)
    clip = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-9)) \
        if cfg.grad_clip > 0 else 1.0

    t = (step + 1).astype(jnp.float32)
    bc1 = 1 - cfg.b1 ** t
    bc2 = 1 - cfg.b2 ** t

    def upd(g, mom, p):
        g = g.astype(jnp.float32) * clip
        if cfg.quantize_states:
            m = dequantize_signed(mom["mq"], mom["ms"])
            v = dequantize_unsigned(mom["vq"], mom["vs"])
        else:
            m, v = mom["m"], mom["v"]
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * g * g
        mhat = m / bc1
        vhat = v / bc2
        upd = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if cfg.weight_decay > 0 and p.ndim >= 2:  # decay matrices only
            upd = upd + cfg.weight_decay * p.astype(jnp.float32)
        new_p = (p.astype(jnp.float32) - lr * upd).astype(p.dtype)
        if cfg.quantize_states:
            mq, ms = quantize_signed(m)
            vq, vs = quantize_unsigned(v)
            return new_p, {"mq": mq, "ms": ms, "vq": vq, "vs": vs}
        return new_p, {"m": m, "v": v}

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(opt_state["moments"])
    out = [upd(g, m, p) for g, m, p in zip(flat_g, flat_m, flat_p)]
    new_params = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_moments = jax.tree.unflatten(treedef, [o[1] for o in out])
    new_state = {"moments": new_moments, "step": step + 1}
    return new_params, new_state, {"lr": lr, "grad_norm": gnorm}
