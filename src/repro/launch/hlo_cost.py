"""Post-fusion HLO cost model with while-loop trip-count multiplication.

XLA-CPU's ``compiled.cost_analysis()`` counts loop bodies ONCE, so any cost
inside a ``lax.scan`` (layers, microbatches, chunked recurrences) is lost.
This module re-derives the three roofline quantities directly from
``compiled.as_text()``:

  * flops            — 2*M*N*K for every ``dot`` (batch dims included),
                       multiplied through ``while`` trip counts
                       (``backend_config known_trip_count``).
  * bytes            — per-op surface traffic (operand + output bytes) of
                       compute ops on the post-fusion HLO; fusions count
                       their boundary traffic only (that IS the HBM traffic).
  * collective bytes — output-shape bytes of all-gather / reduce-scatter /
                       all-to-all / collective-permute (x1) and all-reduce
                       (x2, ring), trip-multiplied.

All quantities are per-device (the partitioned SPMD module).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Optional

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16, "s4": 1, "u4": 1,
}

SHAPE_RE = re.compile(
    r"(f64|f32|bf16|f16|f8e4m3|f8e5m2|s64|u64|s32|u32|s16|u16|s8|u8|s4|u4|"
    r"pred|c64|c128)\[([0-9,]*)\]")

OPCODE_RE = re.compile(r" ([a-z][a-z0-9\-]*)\(")
HEADER_RE = re.compile(r"^(ENTRY )?%?([\w.\-]+) \((.*)\) -> (.+) \{\s*$")
DEF_RE = re.compile(r"^\s*(?:ROOT )?%([\w.\-]+) = (.+)$")
TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
CALLS_RE = re.compile(r"calls=%?([\w.\-]+)")
BODY_RE = re.compile(r"body=%?([\w.\-]+)")
COND_RE = re.compile(r"condition=%?([\w.\-]+)")
TO_APPLY_RE = re.compile(r"to_apply=%?([\w.\-]+)")
OPERAND_RE = re.compile(r"%([\w.\-]+)")

COLLECTIVE_WEIGHTS = {
    "all-gather": 1.0, "all-gather-start": 1.0,
    "all-reduce": 2.0, "all-reduce-start": 2.0,
    "reduce-scatter": 1.0,
    "all-to-all": 1.0,
    "collective-permute": 1.0, "collective-permute-start": 1.0,
}

# ops with no real memory traffic of their own
_FREE_OPS = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "after-all", "all-gather-done", "all-reduce-done",
    "collective-permute-done", "partition-id", "replica-id", "domain",
    "opt-barrier",
}
# control ops: traffic accounted inside their called computations
_CONTROL_OPS = {"while", "fusion", "call", "conditional", "custom-call",
                "async-start", "async-done"}


def _shapes_in(text: str):
    return [(dt, tuple(int(d) for d in dims.split(",") if d))
            for dt, dims in SHAPE_RE.findall(text)]


def _shape_bytes(text: str) -> int:
    total = 0
    for dt, dims in _shapes_in(text):
        n = 1
        for d in dims:
            n *= d
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclasses.dataclass
class Op:
    name: str
    opcode: str
    out_text: str  # output shape text (before opcode)
    operands: list
    attrs: str  # everything after operand list


@dataclasses.dataclass
class Computation:
    name: str
    params: dict  # name -> shape text
    ops: dict  # name -> Op


@dataclasses.dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0
    collective_bytes: float = 0.0
    collective_detail: Optional[dict] = None

    def scaled(self, k: float) -> "Cost":
        det = None
        if self.collective_detail:
            det = {op: v * k for op, v in self.collective_detail.items()}
        return Cost(self.flops * k, self.bytes * k,
                    self.collective_bytes * k, det)

    def add(self, other: "Cost"):
        self.flops += other.flops
        self.bytes += other.bytes
        self.collective_bytes += other.collective_bytes
        if other.collective_detail:
            self.collective_detail = self.collective_detail or {}
            for op, v in other.collective_detail.items():
                self.collective_detail[op] = self.collective_detail.get(op, 0.0) + v


def _split_top_level(s: str) -> list:
    out, depth, cur = [], 0, []
    for ch in s:
        if ch in "([{":
            depth += 1
        elif ch in ")]}":
            depth -= 1
        if ch == "," and depth == 0:
            out.append("".join(cur))
            cur = []
        else:
            cur.append(ch)
    if cur:
        out.append("".join(cur))
    return out


def parse_module(hlo: str) -> dict:
    """Parse an HLO module dump into {computation_name: Computation}."""
    comps = {}
    cur = None
    for line in hlo.splitlines():
        if cur is None:
            m = HEADER_RE.match(line)
            if m:
                params = {}
                for part in _split_top_level(m.group(3)):
                    part = part.strip()
                    if not part or ":" not in part:
                        continue
                    pname, ptype = part.split(":", 1)
                    params[pname.strip().lstrip("%")] = ptype.strip()
                cur = Computation(m.group(2), params, {})
            continue
        if line.strip() == "}":
            comps[cur.name] = cur
            cur = None
            continue
        m = DEF_RE.match(line)
        if not m:
            continue
        name, rhs = m.group(1), m.group(2)
        om = OPCODE_RE.search(rhs)
        if not om:
            continue
        opcode = om.group(1)
        out_text = rhs[:om.start()]
        # operand list: balanced parens from om.end()-1
        i = om.end() - 1
        depth = 0
        j = i
        for j in range(i, len(rhs)):
            if rhs[j] == "(":
                depth += 1
            elif rhs[j] == ")":
                depth -= 1
                if depth == 0:
                    break
        operand_text = rhs[i + 1:j]
        attrs = rhs[j + 1:]
        operands = OPERAND_RE.findall(operand_text)
        cur.ops[name] = Op(name, opcode, out_text, operands, attrs)
    return comps


def _operand_shape_text(comp: Computation, name: str) -> str:
    if name in comp.ops:
        return comp.ops[name].out_text
    if name in comp.params:
        return comp.params[name]
    return ""


def _dot_flops(comp: Computation, op: Op) -> float:
    out_shapes = _shapes_in(op.out_text)
    if not out_shapes:
        return 0.0
    _, out_dims = out_shapes[0]
    out_elems = 1
    for d in out_dims:
        out_elems *= d
    # contraction size from lhs operand shape + lhs_contracting_dims
    m = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", op.attrs)
    if not m or not op.operands:
        return 2.0 * out_elems  # degenerate
    cdims = [int(x) for x in m.group(1).split(",") if x]
    lhs_text = _operand_shape_text(comp, op.operands[0])
    lhs_shapes = _shapes_in(lhs_text)
    if not lhs_shapes:
        return 2.0 * out_elems
    _, lhs_dims = lhs_shapes[0]
    k = 1
    for c in cdims:
        if c < len(lhs_dims):
            k *= lhs_dims[c]
    return 2.0 * out_elems * k


def _conv_flops(comp: Computation, op: Op) -> float:
    out_shapes = _shapes_in(op.out_text)
    if not out_shapes or len(op.operands) < 2:
        return 0.0
    _, out_dims = out_shapes[0]
    out_elems = 1
    for d in out_dims:
        out_elems *= d
    rhs_shapes = _shapes_in(_operand_shape_text(comp, op.operands[1]))
    if not rhs_shapes:
        return 0.0
    _, ker = rhs_shapes[0]
    ker_elems = 1
    for d in ker:
        ker_elems *= d
    out_feat = out_dims[-1] if out_dims else 1
    return 2.0 * out_elems * (ker_elems / max(1, out_feat))


class HloCostModel:
    def __init__(self, hlo_text: str, bf16_dims=None):
        self.comps = parse_module(hlo_text)
        self._memo: dict[str, Cost] = {}
        self._consumers = None  # lazy (comp_name, op_name) -> [consumer ops]
        # TPU-projection hint: activation tensors with these dims are bf16
        # in the model's compute dtype (XLA-CPU shows them as f32 around
        # collectives because CPU legalizes bf16 dots via f32 converts)
        self.bf16_dims = set(bf16_dims or ())
        entry = [c for c in self.comps if "main" in c]
        self.entry = entry[0] if entry else next(iter(self.comps))

    def cost(self, comp_name: Optional[str] = None) -> Cost:
        comp_name = comp_name or self.entry
        if comp_name in self._memo:
            return self._memo[comp_name]
        comp = self.comps.get(comp_name)
        total = Cost(collective_detail={})
        if comp is None:
            return total
        self._memo[comp_name] = total  # guards cycles
        for op in comp.ops.values():
            total.add(self._op_cost(comp, op))
        return total

    def _op_cost(self, comp: Computation, op: Op) -> Cost:
        c = Cost(collective_detail={})
        oc = op.opcode
        if oc in COLLECTIVE_WEIGHTS:
            scale = self._collective_dtype_projection(comp, op)
            b = _shape_bytes(op.out_text) * COLLECTIVE_WEIGHTS[oc] * scale
            key = oc.replace("-start", "")
            c.collective_bytes += b
            c.collective_detail[key] = c.collective_detail.get(key, 0) + b
            c.bytes += _shape_bytes(op.out_text) * scale
            return c
        if oc == "while":
            trips = 1
            m = TRIP_RE.search(op.attrs)
            if m:
                trips = int(m.group(1))
            bm = BODY_RE.search(op.attrs)
            if bm:
                c.add(self.cost(bm.group(1)).scaled(trips))
            cm = COND_RE.search(op.attrs)
            if cm:
                c.add(self.cost(cm.group(1)).scaled(trips))
            return c
        if oc in ("fusion",):
            m = CALLS_RE.search(op.attrs)
            if m:
                inner = self.cost(m.group(1))
                c.flops += inner.flops
                c.collective_bytes += inner.collective_bytes
                if inner.collective_detail:
                    for k, v in inner.collective_detail.items():
                        c.collective_detail[k] = c.collective_detail.get(k, 0) + v
            # fusion boundary traffic
            c.bytes += self._surface_bytes(comp, op)
            return c
        if oc in ("call", "conditional", "async-start"):
            for pat in (CALLS_RE, TO_APPLY_RE, BODY_RE):
                m = pat.search(op.attrs)
                if m:
                    c.add(self.cost(m.group(1)))
            return c
        if oc == "dot":
            c.flops += _dot_flops(comp, op)
            c.bytes += self._surface_bytes(comp, op)
            return c
        if oc == "convolution":
            c.flops += _conv_flops(comp, op)
            c.bytes += self._surface_bytes(comp, op)
            return c
        if oc in _FREE_OPS:
            return c
        if oc == "reduce" or oc == "reduce-window":
            c.bytes += self._surface_bytes(comp, op)
            return c
        # generic compute op: surface traffic only
        c.bytes += self._surface_bytes(comp, op)
        return c

    def _collective_dtype_projection(self, comp: Computation, op: Op) -> float:
        """TPU dtype projection for collectives.

        XLA-CPU legalizes bf16 dots by inserting f32 converts and its
        convert-mover hoists them across collectives, so bf16 model
        collectives appear as f32 in the CPU-compiled HLO (2x bytes).  A TPU
        compilation keeps them bf16.  Detect the sandwich — a collective
        whose operand is a widening convert, or whose result feeds a
        narrowing convert — and scale to the narrow width.  Genuinely-f32
        collectives (grad reductions, loss psums) have no adjacent bf16
        converts and are unaffected.
        """
        out_shapes = _shapes_in(op.out_text)
        if not out_shapes:
            return 1.0
        out_dt = out_shapes[0][0]
        if out_dt != "f32":
            return 1.0
        # activation-shaped f32 collectives in a bf16 model: project to bf16
        if self.bf16_dims and any(
                d in self.bf16_dims for d in out_shapes[0][1]):
            return 0.5
        # operand side: widening convert feeding the collective
        for o in op.operands:
            src = comp.ops.get(o)
            if src is not None and src.opcode == "convert" and src.operands:
                in_shapes = _shapes_in(
                    _operand_shape_text(comp, src.operands[0]))
                if in_shapes and _DTYPE_BYTES[in_shapes[0][0]] < 4:
                    return _DTYPE_BYTES[in_shapes[0][0]] / 4.0
        # consumer side: narrowing convert of the collective result
        if self._consumers is None:
            self._consumers = {}
            for cname, cc in self.comps.items():
                for o2 in cc.ops.values():
                    for operand in o2.operands:
                        self._consumers.setdefault((cname, operand),
                                                   []).append(o2)
        for cons in self._consumers.get((comp.name, op.name), []):
            if cons.opcode == "convert":
                cshapes = _shapes_in(cons.out_text)
                if cshapes and _DTYPE_BYTES[cshapes[0][0]] < 4:
                    return _DTYPE_BYTES[cshapes[0][0]] / 4.0
            # common pattern: fusion that immediately converts to bf16
            if cons.opcode == "fusion" and "convert" in cons.name:
                cshapes = _shapes_in(cons.out_text)
                if cshapes and _DTYPE_BYTES[cshapes[0][0]] < 4 and \
                        cshapes[0][1] == out_shapes[0][1]:
                    return _DTYPE_BYTES[cshapes[0][0]] / 4.0
        return 1.0

    def _bf16_scale(self, text: str) -> float:
        """bf16 projection for surface traffic (same rationale as the
        collective projection): XLA-CPU legalizes bf16 dots via f32 converts,
        materializing f32 copies of large bf16 model tensors (weights, KV
        cache, activations) that a TPU compilation never creates."""
        if not self.bf16_dims:
            return 1.0
        shapes = _shapes_in(text)
        scaled = 0.0
        plain = 0.0
        for dt, dims in shapes:
            n = 1
            for d in dims:
                n *= d
            b = n * _DTYPE_BYTES[dt]
            if (dt == "f32" and n >= (1 << 20)
                    and any(d in self.bf16_dims for d in dims)):
                scaled += b * 0.5
            else:
                plain += b
        tot = scaled + plain
        ref = sum(
            (1 if not d else 1) for d in ())  # keep simple: ratio below
        base = _shape_bytes(text)
        return (tot / base) if base else 1.0

    def _surface_bytes(self, comp: Computation, op: Op) -> float:
        """TPU-realistic surface traffic for one op.

        Slicing/in-place patterns are counted at slice granularity: XLA-CPU
        materializes whole-buffer round-trips (e.g. converting an entire
        remat stack inside a DUS fusion each loop iteration) that a TPU
        compilation performs in place.
        """
        out_b = float(_shape_bytes(op.out_text)) * self._bf16_scale(op.out_text)
        operand_bytes = [
            float(_shape_bytes(_operand_shape_text(comp, o)))
            * self._bf16_scale(_operand_shape_text(comp, o))
            for o in op.operands
        ]
        if op.opcode == "dynamic-update-slice":
            # in-place: read+write the update slice only
            upd = operand_bytes[1] if len(operand_bytes) > 1 else out_b
            return 2.0 * upd
        if op.opcode in ("dynamic-slice", "gather"):
            # reads the slice, not the whole operand
            small = sum(b for b in operand_bytes if b <= out_b)
            return out_b + small
        if op.opcode == "scatter":
            # in-place under buffer donation: traffic = updates r+w (+indices)
            rest = sum(operand_bytes[1:]) if operand_bytes else 0.0
            return 2.0 * rest
        if op.opcode == "fusion":
            # in-place accumulate pattern: an operand aliasing the output
            # (same byte count, >1MB) means the big buffer is updated in
            # place — traffic is the remaining (slice-sized) operands r+w
            # loop fusions read each operand at most pointwise per output
            # element; larger operands are sliced inside (remat-stack reads)
            capped = [min(b, out_b) for b in operand_bytes]
            # big in-place stack updates (remat-stack DUS): an operand
            # aliasing a >128MB output is updated in place — only the
            # slice-sized remainder is real traffic
            if out_b > (1 << 27):
                for i, b in enumerate(operand_bytes):
                    if b == out_b:
                        rest = sum(capped) - capped[i]
                        return 2.0 * rest
            return out_b + sum(capped)
        return out_b + sum(operand_bytes)


def top_bytes(hlo_text: str, n: int = 30):
    """Debug: top ops by trip-multiplied bytes, using the real traversal."""
    model = HloCostModel(hlo_text)
    mult: dict[str, float] = {model.entry: 1.0}
    order = [model.entry]
    seen = {model.entry}
    i = 0
    while i < len(order):
        cname = order[i]
        i += 1
        comp = model.comps.get(cname)
        if comp is None:
            continue
        k = mult[cname]
        for op in comp.ops.values():
            if op.opcode not in ("while", "call", "conditional"):
                continue  # fusion/reduce inner comps: bytes counted at surface
            trips = 1
            if op.opcode == "while":
                m = TRIP_RE.search(op.attrs)
                trips = int(m.group(1)) if m else 1
            for pat in (BODY_RE, COND_RE, CALLS_RE, TO_APPLY_RE):
                m = pat.search(op.attrs)
                if m:
                    sub = m.group(1)
                    mult[sub] = mult.get(sub, 0.0) + k * trips
                    if sub not in seen:
                        seen.add(sub)
                        order.append(sub)
    rows = []
    for cname, comp in model.comps.items():
        k = mult.get(cname, 0.0)
        if k <= 0:
            continue
        for op in comp.ops.values():
            if op.opcode in _FREE_OPS or op.opcode in (
                    "while", "call", "conditional"):
                continue
            c = model._op_cost(comp, op)
            # fusions: count only surface here (inner flops not bytes)
            b = c.bytes * k
            if b > 0:
                rows.append((b, k, op.opcode, op.out_text.strip()[:48],
                             cname[:40], op.name[:30]))
    rows.sort(reverse=True)
    return rows[:n]


def analyze(hlo_text: str, bf16_dims=None) -> dict:
    model = HloCostModel(hlo_text, bf16_dims=bf16_dims)
    c = model.cost()
    return {
        "flops": c.flops,
        "bytes": c.bytes,
        "collective_bytes": c.collective_bytes,
        "collective_detail": c.collective_detail or {},
    }
