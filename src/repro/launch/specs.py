"""Abstract input specs for the dry-run: ShapeDtypeStruct stand-ins.

Every model input (train batch, prefill batch, decode token + cache) is
described without allocating anything.  Cache templates are constructed
directly per family (validated structurally against ``jax.eval_shape`` of the
real prefill in tests).
"""
from __future__ import annotations

import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax import ShapeDtypeStruct as SDS

from repro.models import ModelApi
from repro.models.config import ModelConfig
from repro.models.mamba2 import ssm_dims


# The assigned LM shape grid: name -> (seq_len, global_batch, kind)
SHAPES = {
    "train_4k": (4096, 256, "train"),
    "prefill_32k": (32768, 32, "prefill"),
    "decode_32k": (32768, 128, "decode"),
    "long_500k": (524288, 1, "decode"),
}

# long_500k needs sub-quadratic sequence state; only hybrid/ssm run it.
LONG_CONTEXT_FAMILIES = ("hybrid", "ssm")

WHISPER_FRAMES = 1500  # fixed audio context (frontend stub length)


def cell_supported(cfg: ModelConfig, shape: str) -> tuple[bool, str]:
    if shape == "long_500k" and cfg.family not in LONG_CONTEXT_FAMILIES:
        return False, ("full-attention arch: 500k-context requires "
                       "sub-quadratic attention (skip noted in DESIGN.md)")
    return True, ""


# ---------------------------------------------------------------------------
# Abstract init (no allocation)
# ---------------------------------------------------------------------------


def abstract_params(api: ModelApi, cfg: ModelConfig):
    """(SDS params tree, logical axes tree) without allocating params."""
    from repro.models import nn

    captured = {}

    def f(key):
        px = api.init(key, cfg)
        vals, axes = nn.split(px)
        captured["axes"] = axes
        return vals

    vals = jax.eval_shape(f, jax.random.PRNGKey(0))
    return vals, captured["axes"]


def abstract_opt_state(params_sds, opt_cfg):
    from repro.training.optim import adamw_init

    return jax.eval_shape(functools.partial(adamw_init, cfg=opt_cfg),
                          params_sds)


# ---------------------------------------------------------------------------
# Batch specs
# ---------------------------------------------------------------------------


def train_batch_specs(cfg: ModelConfig, batch: int, seq: int):
    tok = SDS((batch, seq), jnp.int32)
    out = {
        "tokens": tok,
        "targets": tok,
        "loss_mask": SDS((batch, seq), jnp.float32),
    }
    if cfg.family == "encdec":
        out["frame_embeds"] = SDS((batch, WHISPER_FRAMES, cfg.d_model),
                                  jnp.float32)
    if cfg.family == "vlm":
        out["patch_embeds"] = SDS((batch, cfg.vision_tokens, cfg.d_model),
                                  jnp.float32)
    return out


def prefill_batch_specs(cfg: ModelConfig, batch: int, seq: int):
    out = {"tokens": SDS((batch, seq), jnp.int32)}
    if cfg.family == "encdec":
        out["frame_embeds"] = SDS((batch, WHISPER_FRAMES, cfg.d_model),
                                  jnp.float32)
    if cfg.family == "vlm":
        out["patch_embeds"] = SDS((batch, cfg.vision_tokens, cfg.d_model),
                                  jnp.float32)
    return out


# ---------------------------------------------------------------------------
# Cache templates (must mirror the runtime prefill cache structure)
# ---------------------------------------------------------------------------


def cache_template(cfg: ModelConfig, batch: int, max_len: int):
    cd = cfg.cdtype
    if cfg.family == "hybrid":
        G = cfg.n_layers // cfg.attn_every
        K = cfg.attn_every
        d_in, H, N, _ = ssm_dims(cfg)
        W = cfg.ssm_conv
        return {
            "ssm": {
                "conv": {
                    "x": SDS((G, K, batch, W - 1, d_in), cd),
                    "B": SDS((G, K, batch, W - 1, N), cd),
                    "C": SDS((G, K, batch, W - 1, N), cd),
                },
                "ssm": SDS((G, K, batch, H, N, cfg.ssm_head_dim), jnp.float32),
            },
            "attn": {
                "k": SDS((G, batch, max_len, cfg.n_kv_heads, cfg.head_dim), cd),
                "v": SDS((G, batch, max_len, cfg.n_kv_heads, cfg.head_dim), cd),
                "len": SDS((G, batch), jnp.int32),
            },
        }
    if cfg.family == "ssm":
        H = cfg.d_model // cfg.rwkv_head_dim
        L = cfg.n_layers
        d = cfg.d_model
        return {
            "att": {
                "shift": SDS((L, batch, d), cd),
                "wkv": SDS((L, batch, H, cfg.rwkv_head_dim,
                            cfg.rwkv_head_dim), jnp.float32),
            },
            "ffn": {"shift": SDS((L, batch, d), cd)},
        }
    # transformer families
    n_dec = cfg.dec_layers or cfg.n_layers
    n_pre = cfg.first_dense_layers if cfg.is_moe else 0
    n_scan = n_dec - n_pre

    def layer_cache(lead=()):
        c = {
            "k": SDS(lead + (batch, max_len, cfg.n_kv_heads, cfg.head_dim), cd),
            "v": SDS(lead + (batch, max_len, cfg.n_kv_heads, cfg.head_dim), cd),
            "len": SDS(lead + (batch,), jnp.int32),
        }
        if cfg.family == "encdec":
            c["cross_k"] = SDS(lead + (batch, WHISPER_FRAMES, cfg.n_kv_heads,
                                       cfg.head_dim), cd)
            c["cross_v"] = SDS(lead + (batch, WHISPER_FRAMES, cfg.n_kv_heads,
                                       cfg.head_dim), cd)
        return c

    cache = {"scan": layer_cache((n_scan,))}
    if n_pre:
        cache["pre"] = {f"layer_{i}": layer_cache() for i in range(n_pre)}
    return cache


# ---------------------------------------------------------------------------
# Cache partition specs (path-based)
# ---------------------------------------------------------------------------


def cache_specs(cfg: ModelConfig, mesh, batch: int, max_len: int):
    """PartitionSpec tree for the cache: batch -> (pod,data) when divisible,
    KV sequence / head-like dims -> "model" (when divisible)."""
    import math

    from jax.sharding import PartitionSpec as P

    b_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    b_size = math.prod(mesh.shape[a] for a in b_axes) if b_axes else 1
    batch_entry = b_axes if (b_axes and batch % b_size == 0) else None
    model_size = mesh.shape.get("model", 1)

    def model_if(divisible_dim: int):
        return "model" if divisible_dim % model_size == 0 else None

    def spec_for(path, leaf):
        keys = [getattr(k, "key", getattr(k, "idx", None)) for k in path]
        name = keys[-1]
        rank = len(leaf.shape)
        ent = [None] * rank
        if name in ("k", "v", "cross_k", "cross_v"):
            bdim = rank - 4
            ent[bdim] = batch_entry
            ent[bdim + 1] = model_if(leaf.shape[bdim + 1])  # kv sequence
        elif name == "len":
            ent[rank - 1] = batch_entry
        elif name == "wkv":
            ent[rank - 4] = batch_entry
            ent[rank - 3] = model_if(leaf.shape[rank - 3])  # rwkv heads
        elif name == "shift":
            ent[rank - 2] = batch_entry
        elif name == "ssm":
            ent[rank - 4] = batch_entry
            ent[rank - 3] = model_if(leaf.shape[rank - 3])  # ssm heads
        elif len(keys) >= 2 and keys[-2] == "conv":
            ent[rank - 3] = batch_entry
            if name == "x":
                ent[rank - 1] = model_if(leaf.shape[rank - 1])  # d_in
        return P(*ent)

    tmpl = cache_template(cfg, batch, max_len)
    return jax.tree_util.tree_map_with_path(spec_for, tmpl)
