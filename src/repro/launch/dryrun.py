import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this builds the production mesh, the abstract state/batch/cache
specs, jits the appropriate step (train_step / prefill / decode) with explicit
shardings, and runs ``.lower().compile()``.  It then extracts:

  * ``compiled.memory_analysis()``  — proves the cell fits per-device HBM,
  * ``compiled.cost_analysis()``    — HLO FLOPs / bytes for the roofline,
  * collective operand bytes parsed from the compiled HLO text
    (all-gather / all-reduce / reduce-scatter / all-to-all /
    collective-permute; all-reduce weighted 2x for its ring cost),

and writes a JSON record consumed by ``benchmarks/bench_roofline.py`` and
EXPERIMENTS.md.  Compile succeeding for the 16x16 AND 2x16x16 meshes for every
supported cell is the multi-pod runnability deliverable.
"""
import argparse
import functools
import json
import math
import re
import sys
import time
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax import ShapeDtypeStruct as SDS
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import get_config, list_archs
from repro.launch import sharding as shd
from repro.launch import specs as sp
from repro.launch.mesh import make_production_mesh
from repro.models import get_model
from repro.training.optim import OptimizerConfig
from repro.training.train import TrainConfig, make_train_step

# Hardware constants (TPU v5e-class target)
PEAK_FLOPS = 197e12  # bf16 / chip
HBM_BW = 819e9  # bytes/s / chip
ICI_BW = 50e9  # bytes/s / link

# Per-arch microbatch counts for train_4k (keep activations ~O(1 sample))
MICROBATCHES = {
    "nemotron-4-340b": 16,
    "qwen3-8b": 4,
    "llama3.2-3b": 4,
    "zamba2-2.7b": 4,
    "moonshot-v1-16b-a3b": 4,
    "deepseek-moe-16b": 4,
    "rwkv6-1.6b": 4,
}
DEFAULT_MICRO = 2

# Archs whose optimizer state only fits with 8-bit moments
QUANTIZED_OPT = {"nemotron-4-340b"}


def arch_overrides(arch: str, shape: str, extra: Optional[dict] = None) -> dict:
    over = dict(extra or {})
    return over


# ---------------------------------------------------------------------------
# Collective-bytes extraction from compiled HLO
# ---------------------------------------------------------------------------

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"(f64|f32|bf16|f16|f8e4m3|f8e5m2|s64|u64|s32|u32|"
                       r"s16|u16|s8|u8|pred|c64|c128)\[([0-9,]*)\]")

_COLLECTIVES = {
    "all-gather": 1.0,
    "all-reduce": 2.0,  # ring: reduce-scatter + all-gather
    "reduce-scatter": 1.0,
    "all-to-all": 1.0,
    "collective-permute": 1.0,
}


def _shape_bytes(text: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> dict[str, Any]:
    """Sum output-shape bytes of collective ops (per-device program)."""
    per_op: dict[str, float] = {k: 0.0 for k in _COLLECTIVES}
    counts: dict[str, int] = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        s = line.strip()
        if "=" not in s:
            continue
        for op, weight in _COLLECTIVES.items():
            # match "all-reduce(", "all-reduce-start(" but not "-done("
            if f" {op}(" in s or f" {op}-start(" in s:
                lhs = s.split("=", 1)[1]
                opname_idx = lhs.find(op)
                shape_part = lhs[:opname_idx]
                b = _shape_bytes(shape_part)
                per_op[op] += b * weight
                counts[op] += 1
                break
    total = sum(per_op.values())
    return {"total_bytes": total, "per_op_bytes": per_op, "counts": counts}


# ---------------------------------------------------------------------------
# Cell construction
# ---------------------------------------------------------------------------


def build_cell(arch: str, shape: str, mesh, *, overrides: Optional[dict] = None):
    """Returns (jitted_fn, example_args) for the cell — ready to lower."""
    seq, batch, kind = sp.SHAPES[shape]
    cfg = get_config(arch, **arch_overrides(arch, shape, overrides))
    api = get_model(cfg)
    params_sds, axes = sp.abstract_params(api, cfg)

    if kind == "train":
        opt_cfg = OptimizerConfig(quantize_states=arch in QUANTIZED_OPT)
        n_devices = math.prod(mesh.devices.shape)
        dp = n_devices // mesh.shape.get("model", 1)
        micro = MICROBATCHES.get(arch, DEFAULT_MICRO)
        while batch % (micro * dp) and micro > 1:
            micro //= 2
        tcfg = TrainConfig(global_batch=batch, seq_len=seq,
                           microbatches=micro, optimizer=opt_cfg)
        opt_sds = sp.abstract_opt_state(params_sds, opt_cfg)
        state_sds = {"params": params_sds, "opt": opt_sds}
        p_sh = shd.make_specs(axes, shd.TRAIN_RULES, mesh)
        o_axes = shd.opt_axes_like(axes, opt_cfg.quantize_states)
        o_sh = shd.make_specs(o_axes, shd.TRAIN_RULES, mesh)
        state_sh = {"params": p_sh, "opt": o_sh}
        batch_sds = sp.train_batch_specs(cfg, batch, seq)
        b_sh = jax.tree.map(
            lambda x: shd.batch_spec(mesh, extra_dims=len(x.shape) - 1),
            batch_sds)
        step = make_train_step(api, cfg, tcfg, mesh, param_specs=p_sh)
        fn = jax.jit(step,
                     in_shardings=(_ns(mesh, state_sh), _ns(mesh, b_sh)),
                     donate_argnums=(0,))
        return fn, (state_sds, batch_sds), cfg, {"microbatches": tcfg.microbatches}

    p_sh = shd.make_specs(axes, shd.SERVE_RULES, mesh)
    # vlm: the vision prefix occupies cache positions ahead of the tokens
    eff_len = seq + (cfg.vision_tokens if cfg.family == "vlm" else 0)
    if kind == "prefill":
        batch_sds = sp.prefill_batch_specs(cfg, batch, seq)
        b_sh = jax.tree.map(
            lambda x: shd.batch_spec(mesh, extra_dims=len(x.shape) - 1),
            batch_sds)
        c_sh = sp.cache_specs(cfg, mesh, batch, eff_len)

        def prefill_fn(params, b):
            return api.prefill(params, b, cfg, max_len=eff_len, mesh=mesh)

        fn = jax.jit(
            prefill_fn,
            in_shardings=(_ns(mesh, p_sh), _ns(mesh, b_sh)),
            out_shardings=(_ns(mesh, c_sh),
                           NamedSharding(mesh, P(_batch_axes(mesh, batch), "model"))),
        )
        return fn, (params_sds, batch_sds), cfg, {}

    # decode
    cache_sds = sp.cache_template(cfg, batch, seq)
    c_sh = sp.cache_specs(cfg, mesh, batch, seq)
    tok_sds = SDS((batch,), jnp.int32)
    tok_sh = NamedSharding(mesh, P(_batch_axes(mesh, batch)))

    def decode_fn(params, cache, tokens):
        return api.decode(params, cache, tokens, cfg, mesh=mesh)

    fn = jax.jit(
        decode_fn,
        in_shardings=(_ns(mesh, p_sh), _ns(mesh, c_sh), tok_sh),
        out_shardings=(_ns(mesh, c_sh),
                       NamedSharding(mesh, P(_batch_axes(mesh, batch), "model"))),
        donate_argnums=(1,),
    )
    return fn, (params_sds, cache_sds, tok_sds), cfg, {}


def _batch_axes(mesh, batch: int):
    axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    size = math.prod(mesh.shape[a] for a in axes) if axes else 1
    return axes if (axes and batch % size == 0) else None


def _ns(mesh, spec_tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))


# ---------------------------------------------------------------------------
# Roofline terms
# ---------------------------------------------------------------------------


def roofline_from(compiled, cfg, *, tokens: int, n_chips: int,
                  kind: str = "train", seq: int = 0) -> dict:
    from repro.launch import hlo_cost

    cost = compiled.cost_analysis() or {}
    hlo = compiled.as_text()
    bf16_dims = None
    if cfg.compute_dtype == "bfloat16" and seq:
        bf16_dims = {seq, seq // 16, seq // 256}
    model = hlo_cost.analyze(hlo, bf16_dims=bf16_dims)
    flops = float(model["flops"])
    byts = float(model["bytes"])
    coll = {"total_bytes": model["collective_bytes"],
            "per_op_bytes": model["collective_detail"],
            "xla_cost_analysis_flops": float(cost.get("flops", 0.0))}
    t_compute = flops / PEAK_FLOPS
    t_memory = byts / HBM_BW
    t_coll = coll["total_bytes"] / ICI_BW
    # 6*N*D for training (fwd+bwd), 2*N*D for inference forward; attention
    # FLOPs are excluded from MODEL_FLOPS by convention, so long-context
    # cells legitimately show ratios > 1 worth of attention compute.
    factor = 6 if kind == "train" else 2
    model_flops = factor * cfg.active_param_count() * tokens
    terms = {
        "hlo_flops_per_device": flops,
        "hlo_bytes_per_device": byts,
        "collective_bytes_per_device": coll["total_bytes"],
        "collective_detail": coll,
        "t_compute_s": t_compute,
        "t_memory_s": t_memory,
        "t_collective_s": t_coll,
        "bottleneck": max(
            [("compute", t_compute), ("memory", t_memory),
             ("collective", t_coll)], key=lambda kv: kv[1])[0],
        "model_flops_total": model_flops,
        "useful_flops_ratio": (model_flops / (flops * n_chips)
                               if flops else 0.0),
    }
    return terms


def memory_summary(compiled) -> dict:
    try:
        ma = compiled.memory_analysis()
    except Exception as e:  # pragma: no cover
        return {"error": str(e)}
    out = {}
    for k in ("argument_size_in_bytes", "output_size_in_bytes",
              "temp_size_in_bytes", "alias_size_in_bytes",
              "generated_code_size_in_bytes"):
        v = getattr(ma, k, None)
        if v is not None:
            out[k] = int(v)
    if out:
        live = (out.get("argument_size_in_bytes", 0)
                + out.get("output_size_in_bytes", 0)
                + out.get("temp_size_in_bytes", 0)
                - out.get("alias_size_in_bytes", 0))
        out["est_live_bytes"] = int(live)
    return out


# ---------------------------------------------------------------------------
# Runner
# ---------------------------------------------------------------------------


def run_cell(arch: str, shape: str, *, multi_pod: bool,
             overrides: Optional[dict] = None, keep_hlo: bool = False) -> dict:
    seq, batch, kind = sp.SHAPES[shape]
    cfg0 = get_config(arch)
    ok, why = sp.cell_supported(cfg0, shape)
    rec: dict[str, Any] = {
        "arch": arch, "shape": shape, "kind": kind,
        "multi_pod": multi_pod, "seq": seq, "batch": batch,
    }
    if not ok:
        rec.update(status="skipped", reason=why)
        return rec
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = math.prod(mesh.devices.shape)
    t0 = time.time()
    fn, args, cfg, extra = build_cell(arch, shape, mesh,
                                      overrides=overrides)
    with mesh:
        lowered = fn.lower(*args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
    tokens = batch * (seq if kind == "train" else (seq if kind == "prefill" else 1))
    rec.update(
        status="ok",
        n_chips=n_chips,
        lower_s=round(t_lower, 2),
        compile_s=round(t_compile, 2),
        memory=memory_summary(compiled),
        roofline=roofline_from(compiled, cfg, tokens=tokens, n_chips=n_chips,
                               kind=kind, seq=seq),
        **extra,
    )
    if keep_hlo:
        rec["hlo_path"] = f"/tmp/hlo_{arch}_{shape}_{'mp' if multi_pod else 'sp'}.txt"
        with open(rec["hlo_path"], "w") as f:
            f.write(compiled.as_text())
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(sp.SHAPES) + [None])
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--out", default=None, help="JSON output path")
    ap.add_argument("--keep-hlo", action="store_true")
    ap.add_argument("--override", action="append", default=[],
                    help="cfg override key=value (python literal)")
    args = ap.parse_args()

    overrides = {}
    for kv in args.override:
        k, v = kv.split("=", 1)
        import ast

        try:
            overrides[k] = ast.literal_eval(v)
        except (SyntaxError, ValueError):
            overrides[k] = v

    archs = [args.arch] if args.arch else list_archs()
    shapes = [args.shape] if args.shape else list(sp.SHAPES)
    meshes = [False, True] if args.both_meshes else [args.multi_pod]

    records = []
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                label = f"{arch} x {shape} x {'2x16x16' if mp else '16x16'}"
                try:
                    rec = run_cell(arch, shape, multi_pod=mp,
                                   overrides=overrides or None,
                                   keep_hlo=args.keep_hlo)
                except Exception as e:  # noqa: BLE001 — report, keep going
                    rec = {"arch": arch, "shape": shape, "multi_pod": mp,
                           "status": "error", "error": f"{type(e).__name__}: {e}"}
                records.append(rec)
                st = rec["status"]
                msg = f"[dryrun] {label}: {st}"
                if st == "ok":
                    r = rec["roofline"]
                    msg += (f" compile={rec['compile_s']}s"
                            f" bottleneck={r['bottleneck']}"
                            f" t_comp={r['t_compute_s']:.2e}s"
                            f" t_mem={r['t_memory_s']:.2e}s"
                            f" t_coll={r['t_collective_s']:.2e}s")
                elif st == "error":
                    msg += f" {rec['error']}"
                print(msg, flush=True)

    if args.out:
        with open(args.out, "w") as f:
            json.dump(records, f, indent=1)
        print(f"[dryrun] wrote {len(records)} records to {args.out}")
    bad = [r for r in records if r["status"] == "error"]
    sys.exit(1 if bad else 0)


if __name__ == "__main__":
    main()
