import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Reproduce the EXPERIMENTS.md §Perf hillclimb cells (baseline vs optimized).

Usage: PYTHONPATH=src python -m repro.launch.hillclimb [--out results/hillclimb.json]
"""
import argparse
import json

from repro.launch import dryrun
from repro.launch.dryrun import build_cell, roofline_from
from repro.launch.mesh import make_production_mesh
from repro.launch.specs import SHAPES

CELLS = [
    ("A0-baseline", "nemotron-4-340b", "train_4k", None, 16),
    ("A*-optimized", "nemotron-4-340b", "train_4k",
     {"explicit_tp": True, "fsdp_params": True,
      "seq_shard_activations": True}, 4),
    ("B0-baseline", "llama3.2-3b", "prefill_32k", None, None),
    ("B*-optimized", "llama3.2-3b", "prefill_32k",
     {"pad_heads_to": 32, "explicit_tp": True}, None),
    ("C0-baseline", "moonshot-v1-16b-a3b", "decode_32k", None, None),
    ("C*-optimized", "moonshot-v1-16b-a3b", "decode_32k",
     {"explicit_tp": True}, None),
]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="results/hillclimb.json")
    args = ap.parse_args()
    mesh = make_production_mesh()
    records = []
    for label, arch, shape, ov, micro in CELLS:
        if micro:
            dryrun.MICROBATCHES[arch] = micro
        fn, cell_args, cfg, extra = build_cell(arch, shape, mesh,
                                               overrides=ov)
        seq, batch, kind = SHAPES[shape]
        tokens = batch * (seq if kind != "decode" else 1)
        with mesh:
            compiled = fn.lower(*cell_args).compile()
        rl = roofline_from(compiled, cfg, tokens=tokens, n_chips=256,
                           kind=kind, seq=seq)
        dom = max(rl["t_compute_s"], rl["t_memory_s"], rl["t_collective_s"])
        rec = {"label": label, "arch": arch, "shape": shape,
               "overrides": ov, "roofline": rl,
               "dominant_s": dom,
               "roofline_fraction": rl["t_compute_s"] / dom if dom else 0.0,
               **extra}
        records.append(rec)
        print(f"{label:14s} t=({rl['t_compute_s']:.4f},"
              f"{rl['t_memory_s']:.4f},{rl['t_collective_s']:.4f}) "
              f"frac={rec['roofline_fraction']:.3f}", flush=True)
    with open(args.out, "w") as f:
        json.dump(records, f, indent=1)
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
