"""Logical-axis -> mesh-axis sharding rules (MaxText-style).

Param trees carry logical axis names per dim (see ``repro.models.nn.Px``);
these rules map them to mesh axes.  ``make_shardings`` produces a
NamedSharding tree mirroring any axes tree.

Training default: tensor-parallel dims on "model", FSDP on "data" via the
"embed" dim, batch on ("pod","data").  Serving/decode swaps KV-cache sequence
onto "model" (kv heads are often < 16, so head-sharding is infeasible — the
softmax over the sharded KV axis lowers to partial reduce + all-reduce, i.e.
flash-decode).
"""
from __future__ import annotations

from typing import Optional

import jax
from jax.sharding import NamedSharding, PartitionSpec as P


# Rules shared by every regime; logical axes not listed are replicated.
_COMMON = {
    # tensor-parallel dims
    "vocab": "model",
    # input embedding tables: vocab must stay unsharded (token gather);
    # shard the embed dim over "model" instead
    "tokens_vocab": None,
    "embed_g": "model",
    "mlp": "model",
    "q_proj": "model",
    "kv_proj": None,  # kv heads < mesh "model" for GQA archs -> replicate
    "wkv_proj": "model",
    "ssm_inner": "model",
    "ssm_heads": "model",
    "experts": "model",
    "router_experts": None,
    "expert_in": None,
    "expert_ff": None,
    # replicated small dims
    "head_dim": None,
    "pos": None,
    "layers": None,
    "group": None,
    "conv_w": None,
    "ssm_state": None,
    "lora": None,
    "mix5": None,
}

TRAIN_RULES = dict(
    _COMMON,
    embed="data",  # FSDP: gather per layer inside the scan (ZeRO-3)
)

SERVE_RULES = dict(
    _COMMON,
    embed=None,  # serving keeps params gathered along data; batch-parallel
)


def resolve_rule(axis_name: Optional[str], rules: dict):
    if axis_name is None:
        return None
    return rules.get(axis_name)


def spec_for_axes(axes: tuple, rules: dict, mesh) -> P:
    names = set(mesh.axis_names)
    entries = []
    for a in axes:
        r = resolve_rule(a, rules)
        if isinstance(r, tuple):
            r = tuple(x for x in r if x in names) or None
        elif r is not None and r not in names:
            r = None
        entries.append(r)
    return P(*entries)


def make_specs(axes_tree, rules: dict, mesh):
    """PartitionSpec tree mirroring an axes tree."""
    return jax.tree.map(
        lambda axes: spec_for_axes(axes, rules, mesh),
        axes_tree,
        is_leaf=lambda x: isinstance(x, tuple),
    )


def make_shardings(axes_tree, rules: dict, mesh):
    specs = make_specs(axes_tree, rules, mesh)
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                        is_leaf=lambda x: isinstance(x, P))


def batch_spec(mesh, extra_dims: int = 1) -> P:
    """[B, ...] inputs: batch over (pod, data)."""
    b = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    return P(b if b else None, *([None] * extra_dims))


def batch_sharding(mesh, extra_dims: int = 1) -> NamedSharding:
    return NamedSharding(mesh, batch_spec(mesh, extra_dims))


def replicated(mesh) -> NamedSharding:
    return NamedSharding(mesh, P())


# ---------------------------------------------------------------------------
# Optimizer-state shardings mirror param shardings (moments share param axes;
# blockwise-quantization scales share all but the last dim's partitioning).
# ---------------------------------------------------------------------------


def opt_axes_like(param_axes_tree, quantized: bool):
    def mk(axes):
        if quantized:
            return {"mq": axes, "ms": axes, "vq": axes, "vs": axes}
        return {"m": axes, "v": axes}

    moments = jax.tree.map(mk, param_axes_tree,
                           is_leaf=lambda x: isinstance(x, tuple))
    return {"moments": moments, "step": ()}
