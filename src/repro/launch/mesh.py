"""Production meshes.

``make_production_mesh`` is a FUNCTION (not module-level state) so importing
this module never touches jax device state.  The multi-pod mesh adds a leading
"pod" axis: (2 pods x 16 x 16) = 512 chips; single-pod is 16 x 16 = 256.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh(data: int = 1, model: int = 1):
    """Small mesh over however many devices the host actually has."""
    return jax.make_mesh((data, model), ("data", "model"))


def batch_axes(mesh) -> tuple:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def mesh_devices(mesh) -> int:
    import math

    return math.prod(mesh.devices.shape)
