"""Serving launcher: ``python -m repro.launch.serve --arch <id> [...]``.

Brings up ONE replicated inference service (``--replicas N``) through the
RHAPSODY middleware and drives a synthetic request stream as INFERENCE
tasks, so every request is routed to a replica by the policy router
(``--routing``: random | round_robin | balanced | least_loaded |
prefix_affinity).  With ``prefix_affinity``, requests sharing a prompt
prefix stick to one replica (``--affinity-prefix-len`` tokens hashed into
the session key, spilling to the least-loaded replica past
``--affinity-spill-factor``), and the engines skip prefill for resident
prefixes; per-replica ``prefix_hits``/``prefix_misses`` are reported.
Replicas claim cores from the middleware's resource ledger
(admission-controlled), ``--warmup`` primes each replica before it becomes
routable, and ``--autoscale`` turns on the pluggable autoscaler
(``--autoscaler queue_depth|latency_slo|weighted_capacity``,
``--slo-p95-ms`` target) bounded by the partition's free capacity.

``--models NAME:WEIGHT [NAME:WEIGHT ...]`` launches a MULTI-MODEL set:
several model groups behind the one service name, each replica tagged with
its group, requests addressed by tagging the payload (``{"model": ...}``)
so the router only considers that group's replicas.  ``--replicas`` then
names the TOTAL, split across groups proportionally to weight; a two-model
launch is just::

    python -m repro.launch.serve --smoke --models chat:2 draft:1 \
        --replicas 3 --requests 24

``--disagg`` launches DISAGGREGATED serving instead: ``--replicas`` is
split into a prefill pool (large chunked-prefill budget, no decode
interleave; ``--prefill-replicas`` overrides the half-split) and a decode
pool behind one service name.  Every request is addressed to the prefill
group; on first token the sequence's paged KV blocks are exported and
imported into a decode replica (recompute fallback when its pool is
full), and per-phase TTFT/ITL p95s are reported per group.

Reports aggregate + per-replica (and per-group) throughput, latency, and
utilization — the runnable end of the inference-at-scale path the dry-run
lowers at production shapes.
"""
from __future__ import annotations

import argparse
import time

import numpy as np

from repro.configs import get_config, get_smoke_config, list_archs
from repro.core import (ExecutionPolicy, ResourceDescription, Rhapsody,
                        ServiceDescription, TaskDescription, TaskKind)
from repro.core.router import ROUTERS
from repro.serving.client import llm_model_group, llm_service_factory


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="rhapsody-demo",
                    choices=list_archs() + ["rhapsody-demo"])
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--replicas", "--services", dest="replicas", type=int,
                    default=2, help="service replica count (scaling unit)")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--max-new-tokens", type=int, default=8)
    ap.add_argument("--max-num-seqs", type=int, default=4)
    ap.add_argument("--max-num-batched-tokens", type=int, default=512)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--paged", action=argparse.BooleanOptionalAction,
                    default=None,
                    help="block-paged KV cache: admission by free-block "
                         "count, chunked prefill, copy-on-write prefix "
                         "sharing, direct paged decode.  Default: auto "
                         "(ON for dense/moe archs, slot pool otherwise); "
                         "--no-paged forces the slot pool")
    ap.add_argument("--block-size", type=int, default=16,
                    help="KV positions per physical block (--paged)")
    ap.add_argument("--num-blocks", type=int, default=None,
                    help="physical KV blocks; default matches the slot "
                         "pool's memory budget (--paged)")
    ap.add_argument("--routing", default="balanced",
                    choices=tuple(ROUTERS))
    ap.add_argument("--affinity-prefix-len", type=int, default=32,
                    help="prompt tokens hashed into the sticky-session key "
                         "(prefix_affinity routing)")
    ap.add_argument("--affinity-spill-factor", type=float, default=2.0,
                    help="sticky replica sheds load when its queue exceeds "
                         "factor * (min depth + 1); <=0 never spills")
    ap.add_argument("--warmup", action="store_true",
                    help="prime each replica (compile + a token of decode) "
                         "before the router may route to it")
    ap.add_argument("--autoscale", action="store_true",
                    help="let the autoscaler grow/shrink the replica set "
                         "within the partition's free capacity")
    ap.add_argument("--autoscaler", default="queue_depth",
                    choices=("queue_depth", "latency_slo",
                             "weighted_capacity"))
    ap.add_argument("--slo-p95-ms", type=float, default=250.0,
                    help="latency_slo autoscaler: p95 end-to-end target")
    ap.add_argument("--models", nargs="*", metavar="NAME:WEIGHT",
                    help="serve SEVERAL model groups from one replica set "
                         "(e.g. --models chat:2 draft:1); --replicas "
                         "becomes the total, split by weight")
    ap.add_argument("--disagg", action="store_true",
                    help="disaggregated serving: split --replicas into a "
                         "prefill pool (large chunked-prefill budget, no "
                         "decode interleave) and a decode pool; sequences "
                         "migrate on first token via a paged-KV handoff. "
                         "Requires the paged cache; incompatible with "
                         "--models")
    ap.add_argument("--prefill-replicas", type=int, default=None,
                    help="--disagg: prefill pool size (default: half of "
                         "--replicas, at least 1)")
    args = ap.parse_args()
    if args.disagg and args.models:
        ap.error("--disagg and --models are mutually exclusive")
    if args.disagg and args.paged is False:
        ap.error("--disagg requires the paged KV cache (drop --no-paged)")

    cfg = (get_smoke_config(args.arch)
           if args.smoke or args.arch != "rhapsody-demo"
           else get_config(args.arch))
    rh = Rhapsody(ResourceDescription(nodes=args.replicas,
                                      cores_per_node=16),
                  policy=ExecutionPolicy(
                      routing=args.routing,
                      affinity_prefix_len=args.affinity_prefix_len,
                      affinity_spill_factor=args.affinity_spill_factor,
                      warmup=args.warmup,
                      autoscale=args.autoscale,
                      autoscaler=args.autoscaler,
                      autoscale_max_replicas=max(4, args.replicas),
                      slo_p95_ms=args.slo_p95_ms),
                  n_workers=2)
    engine_kw = dict(max_num_seqs=args.max_num_seqs,
                     max_num_batched_tokens=args.max_num_batched_tokens,
                     max_len=args.max_len, prefill_buckets=(16, 32, 64),
                     # None = auto: LLMServicer resolves to paged for
                     # dense/moe, slot pool for state-carrying families
                     paged=args.paged, block_size=args.block_size,
                     num_blocks=args.num_blocks)
    model_names: list = []
    try:
        if args.disagg:
            n_pre = args.prefill_replicas or max(1, args.replicas // 2)
            n_dec = max(1, args.replicas - n_pre)
            disagg_kw = dict(engine_kw, paged=True)
            groups = [
                llm_model_group(
                    "prefill", cfg, role="prefill", paired_with="decode",
                    replicas=n_pre, slo_p95_ms=args.slo_p95_ms,
                    **dict(disagg_kw,
                           # prefill replicas never interleave decode:
                           # run the whole prompt in as few chunks as
                           # possible
                           max_num_batched_tokens=max(
                               args.max_num_batched_tokens, args.max_len))),
                llm_model_group(
                    "decode", cfg, role="decode", replicas=n_dec,
                    slo_p95_ms=args.slo_p95_ms, **disagg_kw),
            ]
            replica_set = rh.add_service(ServiceDescription(
                name="llm", replicas=args.replicas, models=groups))
            print(f"[serve] {cfg.name} disaggregated "
                  f"{replica_set.group_counts()} ready:",
                  rh.services.list())
        elif args.models:
            groups = []
            for spec in args.models:
                name, _, w = spec.partition(":")
                groups.append(llm_model_group(
                    name, cfg, weight=float(w) if w else 1.0, **engine_kw))
            model_names = [g.name for g in groups]
            replica_set = rh.add_service(ServiceDescription(
                name="llm", replicas=args.replicas, models=groups))
            print(f"[serve] {cfg.name} x {args.replicas} replicas "
                  f"across groups {replica_set.group_counts()} ready:",
                  rh.services.list())
        else:
            replica_set = rh.add_service(ServiceDescription(
                name="llm", replicas=args.replicas,
                factory=llm_service_factory(cfg, **engine_kw)))
            print(f"[serve] {cfg.name} x {args.replicas} replicas ready:",
                  rh.services.list())

        rng = np.random.RandomState(0)
        lens = np.clip(np.exp(rng.normal(3.0, 0.7, args.requests)), 4,
                       args.max_len - args.max_new_tokens - 1).astype(int)
        prompts = [list(rng.randint(0, cfg.vocab, size=int(L)))
                   for L in lens]

        def payload(i, p):
            out = {"prompt": p, "max_new_tokens": args.max_new_tokens}
            if args.disagg:  # clients always address the prefill pool;
                #              the set migrates each sequence to a decode
                #              replica on first token
                out["model"] = "prefill"
            elif model_names:  # address models round-robin across stream
                out["model"] = model_names[i % len(model_names)]
            return out

        descs = [TaskDescription(kind=TaskKind.INFERENCE, service="llm",
                                 payload=payload(i, p),
                                 task_type="inference")
                 for i, p in enumerate(prompts)]
        t0 = time.perf_counter()
        uids = rh.submit(descs)
        if not rh.wait(uids, timeout=1200):
            raise TimeoutError("inference stream timed out")
        results = [rh.result(u) for u in uids]
        dt = time.perf_counter() - t0
        tokens = sum(len(r["tokens"]) + r["n_prompt"] for r in results)
        lat = sorted(r["latency_s"] for r in results)
        stats = replica_set.stats()
        utils = [inst.servicer.stats.utilization
                 for inst in replica_set.instances]
        print(f"[serve] {len(results)} requests, {dt:.2f}s, "
              f"{tokens / dt:.0f} tok/s, routing={args.routing}")
        print(f"[serve] latency p50 {lat[len(lat) // 2]:.2f}s "
              f"p95 {lat[int(len(lat) * 0.95)]:.2f}s; "
              f"mean slot-utilization {np.mean(utils):.2f}")
        print("[serve] per-replica requests:",
              [p["requests"] for p in stats["per_replica"]])
        btel = {g: s.get("block_telemetry")
                for g, s in stats["per_group"].items()}
        if any(t is not None for t in btel.values()):
            print("[serve] paged-block telemetry per group:",
                  {g: {"free": t["free_blocks"], "total": t["total_blocks"],
                       "shared": t["shared_blocks"],
                       "cow": t["cow_copies"]}
                   for g, t in btel.items() if t is not None})
        if args.disagg:
            handed = sum(1 for r in results if r.get("handoff"))
            print(f"[serve] disagg: {handed}/{len(results)} sequences "
                  f"migrated prefill->decode; handoff totals:",
                  replica_set.handoff_totals())
            print("[serve] per-phase groups:",
                  {g: {"replicas": s["replicas"],
                       "role": s["role"],
                       "requests": s["requests"],
                       "ttft_p95_ms": s["ttft_p95_ms"]
                       and round(s["ttft_p95_ms"], 1),
                       "itl_p95_ms": s["itl_p95_ms"]
                       and round(s["itl_p95_ms"], 1)}
                   for g, s in stats["per_group"].items()})
        if model_names:
            print("[serve] per-model groups:",
                  {g: {"replicas": s["replicas"],
                       "requests": s["requests"],
                       "cores": s["cores"],
                       "p95_ms": s["latency_p95_ms"]
                       and round(s["latency_p95_ms"], 1)}
                   for g, s in stats["per_group"].items()})
        ledger = rh.utilization()
        print("[serve] shared ledger:",
              {k: {"cores": round(v["cores"], 2),
                   "service_cores": v["service_cores"],
                   "service_replicas": v["service_replicas"]}
               for k, v in ledger.items()},
              f"admission_denied={stats['admission_denied']}")
        if args.routing == "prefix_affinity":
            hits, misses = stats["prefix_hits"], stats["prefix_misses"]
            reuse = [inst.servicer.stats.prefix_cached_tokens
                     for inst in replica_set.instances]
            print(f"[serve] prefix-affinity: {hits} hits / {misses} misses "
                  f"(rate {hits / max(1, hits + misses):.2f}); "
                  f"engine prefill tokens skipped per replica: {reuse}")
    finally:
        rh.close()


if __name__ == "__main__":
    main()
