"""Serving launcher: ``python -m repro.launch.serve --arch <id> [...]``.

Brings up N inference services through the RHAPSODY middleware, routes a
synthetic request stream (token-aware balanced routing by default), and
reports throughput/latency/utilization — the runnable end of the
inference-at-scale path the dry-run lowers at production shapes.
"""
from __future__ import annotations

import argparse
import time

import numpy as np

from repro.configs import get_config, get_smoke_config, list_archs
from repro.core import ResourceDescription, Rhapsody, ServiceDescription
from repro.core.router import make_router
from repro.serving.client import llm_service_factory


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="rhapsody-demo",
                    choices=list_archs() + ["rhapsody-demo"])
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--services", type=int, default=2)
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--max-new-tokens", type=int, default=8)
    ap.add_argument("--max-num-seqs", type=int, default=4)
    ap.add_argument("--max-num-batched-tokens", type=int, default=512)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--routing", default="balanced",
                    choices=("random", "round_robin", "balanced"))
    args = ap.parse_args()

    cfg = (get_smoke_config(args.arch)
           if args.smoke or args.arch != "rhapsody-demo"
           else get_config(args.arch))
    rh = Rhapsody(ResourceDescription(nodes=args.services, cores_per_node=8),
                  n_workers=2)
    try:
        eps = [rh.add_service(ServiceDescription(
            name=f"llm{i}",
            factory=llm_service_factory(
                cfg, max_num_seqs=args.max_num_seqs,
                max_num_batched_tokens=args.max_num_batched_tokens,
                max_len=args.max_len,
                prefill_buckets=(16, 32, 64), seed=i)))
            for i in range(args.services)]
        print(f"[serve] {args.services} x {cfg.name} services ready:",
              rh.services.list())

        rng = np.random.RandomState(0)
        lens = np.clip(np.exp(rng.normal(3.0, 0.7, args.requests)), 4,
                       args.max_len - args.max_new_tokens - 1).astype(int)
        prompts = [list(rng.randint(0, cfg.vocab, size=int(L)))
                   for L in lens]
        assign = make_router(args.routing).assign(prompts, args.services,
                                                  cost=len)
        t0 = time.perf_counter()
        futs = [(eps[si].request({"prompt": prompts[i],
                                  "max_new_tokens": args.max_new_tokens}))
                for si, idxs in enumerate(assign) for i in idxs]
        results = [f.result(timeout=1200) for f in futs]
        dt = time.perf_counter() - t0
        tokens = sum(len(r["tokens"]) + r["n_prompt"] for r in results)
        lat = sorted(r["latency_s"] for r in results)
        utils = [rh.services.instances[f"llm{i}"].servicer.stats.utilization
                 for i in range(args.services)]
        print(f"[serve] {len(results)} requests, {dt:.2f}s, "
              f"{tokens / dt:.0f} tok/s, routing={args.routing}")
        print(f"[serve] latency p50 {lat[len(lat) // 2]:.2f}s "
              f"p95 {lat[int(len(lat) * 0.95)]:.2f}s; "
              f"mean slot-utilization {np.mean(utils):.2f}")
    finally:
        rh.close()


if __name__ == "__main__":
    main()
