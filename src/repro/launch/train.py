"""Training launcher: ``python -m repro.launch.train --arch <id> [...]``.

Single-host execution with the real data pipeline, checkpoint/restart, and
(optionally) a local device mesh.  At production scale the same factories
are consumed by the dry-run (``repro.launch.dryrun``) with the 16x16 /
2x16x16 meshes — this CLI is the runnable end of the same code path.
"""
from __future__ import annotations

import argparse
import time

import jax

from repro.configs import get_config, get_smoke_config, list_archs
from repro.launch.mesh import make_local_mesh
from repro.models import get_model
from repro.substrate.data import DataConfig, DataPipeline
from repro.training.checkpoint import Checkpointer
from repro.training.optim import OptimizerConfig
from repro.training.train import TrainConfig, init_state, make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="rhapsody-demo",
                    choices=list_archs() + ["rhapsody-demo"])
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced smoke config (CPU-friendly)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--quantize-opt", action="store_true")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    cfg = (get_smoke_config(args.arch) if args.smoke or args.arch != "rhapsody-demo"
           else get_config(args.arch))
    api = get_model(cfg)
    opt = OptimizerConfig(lr=args.lr, warmup_steps=max(1, args.steps // 20),
                          decay_steps=args.steps,
                          quantize_states=args.quantize_opt)
    tcfg = TrainConfig(global_batch=args.batch, seq_len=args.seq,
                       microbatches=args.microbatches, optimizer=opt,
                       checkpoint_every=args.ckpt_every)
    data = DataPipeline(DataConfig(vocab=cfg.vocab, seq_len=args.seq,
                                   global_batch=args.batch))
    ck = Checkpointer(args.ckpt_dir, keep=3) if args.ckpt_dir else None

    state, _ = init_state(jax.random.PRNGKey(0), api, cfg, opt)
    start = 0
    if args.resume and ck is not None:
        restored, start = ck.restore_latest({"state": state,
                                             "data": data.state()})
        if restored is not None:
            state = restored["state"]
            data.restore(jax.tree.map(int, restored["data"]))
            print(f"[train] resumed from step {start}")

    step_fn = make_train_step(api, cfg, tcfg)
    t0 = time.perf_counter()
    tokens_done = 0
    for i in range(start, args.steps):
        batch = data.next_batch()
        state, metrics = step_fn(state, batch)
        tokens_done += args.batch * args.seq
        if i % args.log_every == 0 or i == args.steps - 1:
            dt = time.perf_counter() - t0
            print(f"[train] step {i:5d} loss {float(metrics['loss']):.4f} "
                  f"lr {float(metrics['lr']):.2e} "
                  f"{tokens_done / max(dt, 1e-9):.0f} tok/s", flush=True)
        if ck is not None and (i + 1) % tcfg.checkpoint_every == 0:
            ck.save({"state": state, "data": data.state()}, i + 1)
    print(f"[train] done: {args.steps - start} steps, arch={cfg.name}")


if __name__ == "__main__":
    main()
