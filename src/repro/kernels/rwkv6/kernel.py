"""Chunked WKV6 — Pallas TPU kernel.

Grid ``(B, H, n_chunks)``: innermost chunk axis is sequential, carrying the
``[hd, hd]`` WKV state in VMEM scratch.  Within a chunk the intra-chunk
pairwise term is computed directly (all decay exponents are differences of a
decreasing cumulative log-decay, so every exp argument is <= 0 — numerically
safe, same scheme as the jnp reference).

VMEM per program (L=32, hd=64, fp32): r/k/v/lw tiles 4 x 8KB + state 16KB +
pairwise decay tile L x L x hd = 256KB — well inside VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _wkv_kernel(r_ref, k_ref, v_ref, lw_ref, u_ref, o_ref, s_ref, *,
                chunk: int, n_chunks: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        s_ref[...] = jnp.zeros_like(s_ref)

    r = r_ref[0, :, 0, :].astype(jnp.float32)  # [L, hd]
    k = k_ref[0, :, 0, :].astype(jnp.float32)
    v = v_ref[0, :, 0, :].astype(jnp.float32)
    lw = lw_ref[0, :, 0, :].astype(jnp.float32)
    u = u_ref[0].astype(jnp.float32)  # [hd]
    L = r.shape[0]

    cum = jnp.cumsum(lw, axis=0)  # [L, hd], decreasing
    cum_prev = cum - lw
    # intra-chunk pairwise: A[t,j] = sum_a r_t[a] k_j[a] exp(cp_t[a]-cum_j[a])
    diff = cum_prev[:, None, :] - cum[None, :, :]  # [t, j, hd]
    tri = jax.lax.broadcasted_iota(jnp.int32, (L, L), 0) > \
        jax.lax.broadcasted_iota(jnp.int32, (L, L), 1)
    dec = jnp.where(tri[:, :, None], jnp.exp(diff), 0.0)
    A = jnp.einsum("ta,tja,ja->tj", r, dec, k)
    diag = jnp.sum(r * u[None, :] * k, axis=1)  # bonus term
    y = jax.lax.dot_general(A, v, (((1,), (0,)), ((), ())))
    y = y + diag[:, None] * v
    # inter-chunk: y += (r_t * exp(cum_prev_t)) @ S
    S = s_ref[...]
    y = y + jax.lax.dot_general(r * jnp.exp(cum_prev), S,
                                (((1,), (0,)), ((), ())))
    # state update: S' = diag(exp(cum_L)) S + sum_j (k_j exp(cum_L-cum_j)) v_j
    end = cum[-1:, :]
    k_out = k * jnp.exp(end - cum)
    s_ref[...] = jnp.exp(end[0])[:, None] * S + jax.lax.dot_general(
        k_out, v, (((0,), (0,)), ((), ())))
    o_ref[0, :, 0, :] = y.astype(o_ref.dtype)


def wkv_bhtc(r, k, v, lw, u, *, chunk: int = 32, interpret: bool = False):
    """r/k/v/lw: [B, T, H, hd]; u: [H, hd]. Returns y [B, T, H, hd]."""
    B, T, H, hd = r.shape
    chunk = min(chunk, T)
    if T % chunk:
        raise ValueError(f"T={T} % chunk={chunk} != 0")
    n_chunks = T // chunk
    kernel = functools.partial(_wkv_kernel, chunk=chunk, n_chunks=n_chunks)
    spec = pl.BlockSpec((1, chunk, 1, hd), lambda b, h, ci: (b, ci, h, 0))
    return pl.pallas_call(
        kernel,
        grid=(B, H, n_chunks),
        in_specs=[spec, spec, spec, spec,
                  pl.BlockSpec((1, hd), lambda b, h, ci: (h, 0))],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct(r.shape, r.dtype),
        scratch_shapes=[pltpu.VMEM((hd, hd), jnp.float32)],
        interpret=interpret,
    )(r, k, v, lw, u)
