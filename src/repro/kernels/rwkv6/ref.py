"""Oracle: the recurrent WKV from the model library."""
from repro.models.rwkv6 import wkv_recurrent


def wkv_ref(r, k, v, lw, u):
    y, _ = wkv_recurrent(r, k, v, lw, u)
    return y
