"""jit'd wrapper for the WKV6 kernel."""
from __future__ import annotations

import functools

import jax

from .kernel import wkv_bhtc


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def wkv(r, k, v, lw, u, *, chunk: int = 32, interpret: bool = False):
    return wkv_bhtc(r, k, v, lw, u, chunk=chunk, interpret=interpret)
