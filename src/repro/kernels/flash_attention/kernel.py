"""Causal flash attention — Pallas TPU kernel.

Grid ``(batch*heads, n_q_blocks, n_k_blocks)`` with the innermost KV axis
iterated sequentially (TPU grid semantics), carrying the online-softmax state
(acc, running max, running sum) in VMEM scratch.  Block shapes are MXU-
aligned (multiples of 128 recommended); causal KV blocks beyond the query
block's range are predicated off with ``pl.when`` (no wasted compute).

VMEM working set per program:
    q (bq x d) + k,v (bk x d each) + acc (bq x d f32) + stats (2 x bq)
e.g. bq=bk=256, d=128, bf16 inputs: ~0.45 MB — comfortably inside VMEM.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
                  sm_scale: float, block_q: int, block_k: int, causal: bool,
                  n_k_blocks: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    # causal: KV block strictly after the query block contributes nothing
    needed = (not causal) or (ki * block_k <= (qi + 1) * block_q - 1)

    @pl.when(needed)
    def _compute():
        q = q_ref[0].astype(jnp.float32)  # [bq, d]
        k = k_ref[0].astype(jnp.float32)  # [bk, d]
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ()))) * sm_scale
        if causal:
            qpos = qi * block_q + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_k), 0)
            kpos = ki * block_k + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_k), 1)
            s = jnp.where(qpos >= kpos, s, NEG_INF)
        m_prev = m_ref[...]
        l_prev = l_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m_prev - m_new)
        l_new = alpha * l_prev + jnp.sum(p, axis=1)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())))
        m_ref[...] = m_new
        l_ref[...] = l_new

    @pl.when(ki == n_k_blocks - 1)
    def _finish():
        denom = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / denom[:, None]).astype(o_ref.dtype)


def flash_attention_bhsd(q, k, v, *, causal: bool = True,
                         block_q: int = 128, block_k: int = 128,
                         interpret: bool = False):
    """q/k/v: [BH, S, D] (kv already repeated to q heads). Returns [BH, S, D]."""
    BH, S, D = q.shape
    block_q = min(block_q, S)
    block_k = min(block_k, S)
    if S % block_q or S % block_k:
        raise ValueError(f"S={S} must divide block sizes {block_q}/{block_k}")
    n_q = S // block_q
    n_k = S // block_k
    sm_scale = 1.0 / math.sqrt(D)
    kernel = functools.partial(
        _flash_kernel, sm_scale=sm_scale, block_q=block_q, block_k=block_k,
        causal=causal, n_k_blocks=n_k)
    return pl.pallas_call(
        kernel,
        grid=(BH, n_q, n_k),
        in_specs=[
            pl.BlockSpec((1, block_q, D), lambda b, qi, ki: (b, qi, 0)),
            pl.BlockSpec((1, block_k, D), lambda b, qi, ki: (b, ki, 0)),
            pl.BlockSpec((1, block_k, D), lambda b, qi, ki: (b, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, D), lambda b, qi, ki: (b, qi, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, D), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
