"""jit'd public wrapper: GQA-aware flash attention on [B,S,H,D] layouts."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernel import flash_attention_bhsd


@functools.partial(jax.jit, static_argnames=("causal", "block_q", "block_k",
                                             "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, block_q: int = 128,
                    block_k: int = 128, interpret: bool = False):
    """q: [B,S,Hq,D]; k/v: [B,S,Hkv,D] (Hq % Hkv == 0). Returns [B,S,Hq,D]."""
    B, S, Hq, D = q.shape
    Hkv = k.shape[2]
    if Hq != Hkv:
        rep = Hq // Hkv
        k = jnp.repeat(k, rep, axis=2)
        v = jnp.repeat(v, rep, axis=2)
    qf = jnp.transpose(q, (0, 2, 1, 3)).reshape(B * Hq, S, D)
    kf = jnp.transpose(k, (0, 2, 1, 3)).reshape(B * Hq, S, D)
    vf = jnp.transpose(v, (0, 2, 1, 3)).reshape(B * Hq, S, D)
    of = flash_attention_bhsd(qf, kf, vf, causal=causal, block_q=block_q,
                              block_k=block_k, interpret=interpret)
    return jnp.transpose(of.reshape(B, Hq, S, D), (0, 2, 1, 3))
