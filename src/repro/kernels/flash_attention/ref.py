"""Pure-jnp oracle for the flash attention kernel."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def attention_ref(q, k, v, *, causal: bool = True):
    """q/k/v: [BH, S, D] -> [BH, S, D] (full softmax, fp32)."""
    BH, S, D = q.shape
    s = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / math.sqrt(D)
    if causal:
        mask = jnp.tril(jnp.ones((S, S), bool))
        s = jnp.where(mask[None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", p, v.astype(jnp.float32)).astype(q.dtype)
