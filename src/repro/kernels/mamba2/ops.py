"""jit'd wrapper for the SSD kernel."""
from __future__ import annotations

import functools

import jax

from .kernel import ssd_bthp


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd(x, dt, A, Bm, Cm, *, chunk: int = 64, interpret: bool = False):
    return ssd_bthp(x, dt, A, Bm, Cm, chunk=chunk, interpret=interpret)
