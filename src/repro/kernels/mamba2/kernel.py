"""Chunked SSD (Mamba2) — Pallas TPU kernel.

Grid ``(B, n_chunks)``: sequential chunk axis carries the full ``[H, N, P]``
SSM state in VMEM scratch (zamba2-2.7b: 80 x 64 x 64 fp32 = 1.3 MB).  Within
a chunk the decay matrix is per-head scalar (not per-channel), so the
pairwise tile is only ``[L, L, H]`` and the three contractions are
MXU-friendly dots over N/P.

All decay exponents are differences of a decreasing cumulative log-decay
(<= 0), mirroring the jnp reference's numerics.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, o_ref, h_ref, *,
                chunk: int):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    x = x_ref[0].astype(jnp.float32)  # [L, H, P]
    dt = dt_ref[0].astype(jnp.float32)  # [L, H]
    A = a_ref[...].astype(jnp.float32)  # [H]
    Bm = b_ref[0].astype(jnp.float32)  # [L, N]
    Cm = c_ref[0].astype(jnp.float32)  # [L, N]
    L = x.shape[0]

    a = dt * A[None, :]  # [L, H] <= 0
    cum = jnp.cumsum(a, axis=0)

    # intra-chunk: scores[t,j,h] = (C_t . B_j) exp(cum_t - cum_j) dt_j, j<=t
    CB = jax.lax.dot_general(Cm, Bm, (((1,), (1,)), ((), ())))  # [L, L]
    delta = cum[:, None, :] - cum[None, :, :]  # [t, j, H]
    tri = jax.lax.broadcasted_iota(jnp.int32, (L, L), 0) >= \
        jax.lax.broadcasted_iota(jnp.int32, (L, L), 1)
    dec = jnp.where(tri[:, :, None], jnp.exp(delta), 0.0)
    scores = CB[:, :, None] * dec * dt[None, :, :]  # [t, j, H]
    y = jnp.einsum("tjh,jhp->thp", scores, x)

    # inter-chunk: y += exp(cum_t) * C_t . h_prev
    h_prev = h_ref[...]  # [H, N, P]
    y = y + jnp.einsum("tn,th,hnp->thp", Cm, jnp.exp(cum), h_prev)

    # state update: h' = exp(cum_L) h + sum_j exp(cum_L - cum_j) dt_j B_j x_j
    decay_to_end = jnp.exp(cum[-1:, :] - cum) * dt  # [L, H]
    h_new = jnp.exp(cum[-1])[:, None, None] * h_prev + jnp.einsum(
        "jh,jn,jhp->hnp", decay_to_end, Bm, x)
    h_ref[...] = h_new
    o_ref[0] = y.astype(o_ref.dtype)


def ssd_bthp(x, dt, A, Bm, Cm, *, chunk: int = 64, interpret: bool = False):
    """x [B,T,H,P]; dt [B,T,H]; A [H]; Bm/Cm [B,T,N]. Returns y [B,T,H,P]."""
    B, T, H, P = x.shape
    N = Bm.shape[-1]
    chunk = min(chunk, T)
    if T % chunk:
        raise ValueError(f"T={T} % chunk={chunk} != 0")
    n_chunks = T // chunk
    kernel = functools.partial(_ssd_kernel, chunk=chunk)
    return pl.pallas_call(
        kernel,
        grid=(B, n_chunks),
        in_specs=[
            pl.BlockSpec((1, chunk, H, P), lambda b, ci: (b, ci, 0, 0)),
            pl.BlockSpec((1, chunk, H), lambda b, ci: (b, ci, 0)),
            pl.BlockSpec((H,), lambda b, ci: (0,)),
            pl.BlockSpec((1, chunk, N), lambda b, ci: (b, ci, 0)),
            pl.BlockSpec((1, chunk, N), lambda b, ci: (b, ci, 0)),
        ],
        out_specs=pl.BlockSpec((1, chunk, H, P), lambda b, ci: (b, ci, 0, 0)),
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        scratch_shapes=[pltpu.VMEM((H, N, P), jnp.float32)],
        interpret=interpret,
    )(x, dt, A, Bm, Cm)
