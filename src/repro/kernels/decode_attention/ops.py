"""jit'd wrapper for flash-decode on model-layout tensors."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernel import decode_attention_grouped


@functools.partial(jax.jit, static_argnames=("block_k", "interpret"))
def decode_attention(q, k_cache, v_cache, kv_length, *, block_k: int = 256,
                     interpret: bool = False):
    """q [B,1,Hq,D]; caches [B,S,Hkv,D]; kv_length [B] -> [B,1,Hq,D]."""
    B, _, Hq, D = q.shape
    Hkv = k_cache.shape[2]
    qg = q[:, 0].reshape(B, Hkv, Hq // Hkv, D)
    out = decode_attention_grouped(qg, k_cache, v_cache,
                                   kv_length.astype(jnp.int32),
                                   block_k=block_k, interpret=interpret)
    return out.reshape(B, 1, Hq, D)
