"""jit'd wrapper for flash-decode on model-layout tensors."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernel import decode_attention_grouped, paged_decode_attention_grouped


@functools.partial(jax.jit, static_argnames=("block_k", "interpret"))
def decode_attention(q, k_cache, v_cache, kv_length, *, block_k: int = 256,
                     interpret: bool = False):
    """q [B,1,Hq,D]; caches [B,S,Hkv,D]; kv_length [B] -> [B,1,Hq,D]."""
    B, _, Hq, D = q.shape
    Hkv = k_cache.shape[2]
    qg = q[:, 0].reshape(B, Hkv, Hq // Hkv, D)
    out = decode_attention_grouped(qg, k_cache, v_cache,
                                   kv_length.astype(jnp.int32),
                                   block_k=block_k, interpret=interpret)
    return out.reshape(B, 1, Hq, D)


@functools.partial(jax.jit, static_argnames=("interpret",))
def paged_decode_attention(q, k_store, v_store, block_tables, kv_length, *,
                           interpret: bool = False):
    """Paged flash-decode on model-layout tensors.

    q [B,1,Hq,D]; stores [num_blocks, block_size, Hkv, D]; block_tables
    [B, max_blocks] int32; kv_length [B] -> [B,1,Hq,D]."""
    B, _, Hq, D = q.shape
    Hkv = k_store.shape[2]
    qg = q[:, 0].reshape(B, Hkv, Hq // Hkv, D)
    out = paged_decode_attention_grouped(qg, k_store, v_store,
                                         block_tables.astype(jnp.int32),
                                         kv_length.astype(jnp.int32),
                                         interpret=interpret)
    return out.reshape(B, 1, Hq, D)
