"""Pure-jnp oracle for flash-decode."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def decode_ref(q, k_cache, v_cache, kv_length):
    """q [B,Hkv,G,D]; caches [B,S,Hkv,D]; kv_length [B] -> [B,Hkv,G,D]."""
    B, Hkv, G, D = q.shape
    S = k_cache.shape[1]
    s = jnp.einsum("bhgd,bkhd->bhgk", q.astype(jnp.float32),
                   k_cache.astype(jnp.float32)) / math.sqrt(D)
    valid = jnp.arange(S)[None, :] < kv_length[:, None]
    s = jnp.where(valid[:, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgk,bkhd->bhgd", p, v_cache.astype(jnp.float32))
    return out.astype(q.dtype)
