"""Pure-jnp oracle for flash-decode."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def decode_ref(q, k_cache, v_cache, kv_length):
    """q [B,Hkv,G,D]; caches [B,S,Hkv,D]; kv_length [B] -> [B,Hkv,G,D]."""
    B, Hkv, G, D = q.shape
    S = k_cache.shape[1]
    s = jnp.einsum("bhgd,bkhd->bhgk", q.astype(jnp.float32),
                   k_cache.astype(jnp.float32)) / math.sqrt(D)
    valid = jnp.arange(S)[None, :] < kv_length[:, None]
    s = jnp.where(valid[:, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgk,bkhd->bhgd", p, v_cache.astype(jnp.float32))
    return out.astype(q.dtype)


def gather_kv(store, block_tables):
    """Materialize contiguous caches from a paged store (oracle gather).

    store [num_blocks, block_size, Hkv, D]; block_tables [B, max_blocks]
    -> [B, max_blocks * block_size, Hkv, D]."""
    B, mb = block_tables.shape
    _, bs, Hkv, D = store.shape
    return store[block_tables].reshape(B, mb * bs, Hkv, D)


def paged_decode_ref(q, k_store, v_store, block_tables, kv_length):
    """Paged oracle: gather through the block tables, then ``decode_ref``.

    q [B,Hkv,G,D]; stores [num_blocks, block_size, Hkv, D]; block_tables
    [B, max_blocks]; kv_length [B] -> [B,Hkv,G,D]."""
    return decode_ref(q, gather_kv(k_store, block_tables),
                      gather_kv(v_store, block_tables), kv_length)
