"""Flash-decode — single-token GQA attention over a long KV cache.

Grid ``(B, Hkv, n_k_blocks)``: each program streams one KV block of one kv
head for one sequence, updating the online-softmax state for that head's
``G = Hq/Hkv`` query group in VMEM scratch.  KV-length masking handles the
ragged valid region of the cache; out-of-range blocks are predicated off.

This is the memory-roofline kernel: per block it moves ``2 * bk * D`` cache
bytes and does ``O(G * bk * D)`` MACs — arithmetic intensity ~G.

Two variants share the online-softmax body:

* ``decode_attention_grouped`` — contiguous caches ``[B, S, Hkv, D]``
  (the slot-pool layout); the KV block index IS the grid index.
* ``paged_decode_attention_grouped`` — block-paged stores
  ``[num_blocks, block_size, Hkv, D]`` plus per-sequence block tables:
  the tables and lengths ride in scalar-prefetch SMEM
  (``PrefetchScalarGridSpec``) so each grid step's BlockSpec index map
  dereferences ``table[b, ki]`` and the DMA engine fetches the right
  *physical* block — the gather costs no extra copy.  Logical blocks at
  or past a sequence's length are predicated off (their table entries
  point at the null block 0).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _decode_kernel(len_ref, q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref,
                   l_ref, *, block_k: int, n_k_blocks: int, sm_scale: float):
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    kv_len = len_ref[0]
    needed = ki * block_k < kv_len

    @pl.when(needed)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)  # [G, D]
        k = k_ref[0, :, 0, :].astype(jnp.float32)  # [bk, D]
        v = v_ref[0, :, 0, :].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ()))) * sm_scale
        pos = ki * block_k + jax.lax.broadcasted_iota(
            jnp.int32, s.shape, 1)
        s = jnp.where(pos < kv_len, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = alpha * l_ref[...] + jnp.sum(p, axis=1)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())))
        m_ref[...] = m_new

    @pl.when(ki == n_k_blocks - 1)
    def _finish():
        denom = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / denom[:, None]).astype(o_ref.dtype)


def decode_attention_grouped(q, k_cache, v_cache, kv_length, *,
                             block_k: int = 256, interpret: bool = False):
    """q: [B, Hkv, G, D]; caches: [B, S, Hkv, D]; kv_length: [B] int32.

    Returns [B, Hkv, G, D].
    """
    B, Hkv, G, D = q.shape
    S = k_cache.shape[1]
    block_k = min(block_k, S)
    if S % block_k:
        raise ValueError(f"cache len {S} % block_k {block_k} != 0")
    n_k = S // block_k
    kernel = functools.partial(_decode_kernel, block_k=block_k,
                               n_k_blocks=n_k, sm_scale=1.0 / math.sqrt(D))
    return pl.pallas_call(
        kernel,
        grid=(B, Hkv, n_k),
        in_specs=[
            pl.BlockSpec((1,), lambda b, h, ki: (b,)),
            pl.BlockSpec((1, 1, G, D), lambda b, h, ki: (b, h, 0, 0)),
            pl.BlockSpec((1, block_k, 1, D), lambda b, h, ki: (b, ki, h, 0)),
            pl.BlockSpec((1, block_k, 1, D), lambda b, h, ki: (b, ki, h, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, D), lambda b, h, ki: (b, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((G, D), jnp.float32),
            pltpu.VMEM((G,), jnp.float32),
            pltpu.VMEM((G,), jnp.float32),
        ],
        interpret=interpret,
    )(kv_length, q, k_cache, v_cache)


def _paged_decode_kernel(bt_ref, len_ref, q_ref, k_ref, v_ref, o_ref,
                         acc_ref, m_ref, l_ref, *, block_size: int,
                         max_blocks: int, sm_scale: float):
    b = pl.program_id(0)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    kv_len = len_ref[b]
    needed = ki * block_size < kv_len

    @pl.when(needed)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)  # [G, D]
        k = k_ref[0, :, 0, :].astype(jnp.float32)  # [block_size, D]
        v = v_ref[0, :, 0, :].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ()))) * sm_scale
        pos = ki * block_size + jax.lax.broadcasted_iota(
            jnp.int32, s.shape, 1)
        s = jnp.where(pos < kv_len, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = alpha * l_ref[...] + jnp.sum(p, axis=1)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())))
        m_ref[...] = m_new

    @pl.when(ki == max_blocks - 1)
    def _finish():
        denom = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / denom[:, None]).astype(o_ref.dtype)


def paged_decode_attention_grouped(q, k_store, v_store, block_tables,
                                   kv_length, *, interpret: bool = False):
    """Paged flash-decode through block-table indirection.

    q: [B, Hkv, G, D]; stores: [num_blocks, block_size, Hkv, D];
    block_tables: [B, max_blocks] int32 physical block ids (entries at or
    past ceil(kv_length/block_size) must point at a valid — conventionally
    the null — block; they are compute-predicated off); kv_length: [B].
    Returns [B, Hkv, G, D].

    The tables/lengths are scalar-prefetched: the k/v BlockSpec index maps
    receive them AFTER the grid indices and return
    ``(table[b, ki], 0, h, 0)``, so the physical block is resolved at DMA
    issue time — the paged gather is free relative to the contiguous
    kernel, which is the point of paging on a machine that cannot
    reallocate buffers dynamically.
    """
    B, Hkv, G, D = q.shape
    _, block_size, _, _ = k_store.shape
    max_blocks = block_tables.shape[1]
    kernel = functools.partial(_paged_decode_kernel, block_size=block_size,
                               max_blocks=max_blocks,
                               sm_scale=1.0 / math.sqrt(D))
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,  # block_tables, kv_length
        grid=(B, Hkv, max_blocks),
        in_specs=[
            pl.BlockSpec((1, 1, G, D), lambda b, h, ki, bt, ln: (b, h, 0, 0)),
            pl.BlockSpec((1, block_size, 1, D),
                         lambda b, h, ki, bt, ln: (bt[b, ki], 0, h, 0)),
            pl.BlockSpec((1, block_size, 1, D),
                         lambda b, h, ki, bt, ln: (bt[b, ki], 0, h, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, D),
                               lambda b, h, ki, bt, ln: (b, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((G, D), jnp.float32),
            pltpu.VMEM((G,), jnp.float32),
            pltpu.VMEM((G,), jnp.float32),
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        interpret=interpret,
    )(block_tables.astype(jnp.int32), kv_length.astype(jnp.int32),
      q, k_store, v_store)
