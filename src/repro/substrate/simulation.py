"""Simulation "executables": jitted JAX numerical kernels.

These stand in for the paper's MPI simulation codes (GROMACS-class payloads)
so middleware benchmarks move real compute + real arrays, not sleeps:

  * ``heat_stencil``  — 2-D five-point heat equation steps,
  * ``lj_step``       — Lennard-Jones particle forces + Euler integration,
  * ``surrogate_eval``— small MLP surrogate inference (AI-in-HPC analogue).

Each accepts ``_ranks``/``_placement`` kwargs (injected by the EXECUTABLE
path of the pool backend) and splits its domain across "ranks".
"""
from __future__ import annotations

import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np


@functools.partial(jax.jit, static_argnames=("steps",))
def _heat_steps(grid, steps: int):
    def one(g, _):
        interior = 0.25 * (g[:-2, 1:-1] + g[2:, 1:-1]
                           + g[1:-1, :-2] + g[1:-1, 2:])
        g = g.at[1:-1, 1:-1].set(interior)
        return g, None

    grid, _ = jax.lax.scan(one, grid, None, length=steps)
    return grid


def heat_stencil(n: int = 64, steps: int = 10, seed: int = 0,
                 _ranks: int = 1, _placement=None) -> np.ndarray:
    """Run a 2-D heat stencil; domain rows split across ranks."""
    key = jax.random.PRNGKey(seed)
    grid = jax.random.uniform(key, (n, n))
    per = max(1, n // max(1, _ranks))
    outs = []
    for r in range(max(1, _ranks)):  # rank loop (domain decomposition)
        block = grid[r * per:(r + 1) * per + 2]
        if block.shape[0] < 3:
            continue
        outs.append(_heat_steps(block, steps))
    result = jnp.concatenate(outs, axis=0) if outs else grid
    return np.asarray(result)


@functools.partial(jax.jit, static_argnames=("steps",))
def _lj_steps(pos, vel, steps: int, dt: float = 1e-3):
    def forces(p):
        diff = p[:, None, :] - p[None, :, :]
        r2 = jnp.sum(diff * diff, axis=-1) + jnp.eye(p.shape[0])
        inv6 = 1.0 / (r2 ** 3)
        mag = 24 * (2 * inv6 * inv6 - inv6) / r2
        mag = mag * (1 - jnp.eye(p.shape[0]))
        return jnp.sum(mag[:, :, None] * diff, axis=1)

    def one(state, _):
        p, v = state
        v = v + dt * forces(p)
        p = p + dt * v
        return (p, v), None

    (pos, vel), _ = jax.lax.scan(one, (pos, vel), None, length=steps)
    return pos, vel


def lj_step(n_particles: int = 64, steps: int = 5, seed: int = 0,
            _ranks: int = 1, _placement=None) -> np.ndarray:
    key = jax.random.PRNGKey(seed)
    pos = jax.random.uniform(key, (n_particles, 3)) * 4.0
    vel = jnp.zeros_like(pos)
    pos, vel = _lj_steps(pos, vel, steps)
    return np.asarray(pos)


@functools.partial(jax.jit, static_argnames=("hidden",))
def _mlp_forward(x, w1, w2, hidden: int):
    return jax.nn.relu(x @ w1) @ w2


def surrogate_eval(x: Optional[np.ndarray] = None, dim: int = 64,
                   hidden: int = 128, seed: int = 0,
                   _ranks: int = 1, _placement=None) -> np.ndarray:
    """Tiny MLP surrogate scoring a batch (docking-surrogate analogue)."""
    key = jax.random.PRNGKey(seed)
    k1, k2, k3 = jax.random.split(key, 3)
    if x is None:
        x = jax.random.normal(k1, (32, dim))
    else:
        x = jnp.asarray(x, jnp.float32)
        if x.ndim == 1:
            x = x[None, :]
    w1 = jax.random.normal(k2, (x.shape[-1], hidden)) * 0.1
    w2 = jax.random.normal(k3, (hidden, 1)) * 0.1
    return np.asarray(_mlp_forward(x, w1, w2, hidden))


def noop(*args, **kwargs):
    """The Exp-1 null payload."""
    return None
