"""LM data pipeline: sharded, deterministic, checkpoint-resumable.

The paper's workflows stream data between components; training needs a
pipeline whose *cursor* participates in checkpoint/restart (fault
tolerance).  This one synthesizes a reproducible token corpus (a mixture of
Zipfian "documents" with structure, so losses actually decrease), packs it
into fixed-length sequences, shards batches across data-parallel ranks, and
exposes `state()`/`restore()` so a restarted job continues from the exact
batch where it left off.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass
class DataConfig:
    vocab: int = 2048
    seq_len: int = 128
    global_batch: int = 8
    seed: int = 0
    n_docs: int = 512
    doc_len: int = 384
    dp_rank: int = 0  # this host's data-parallel shard
    dp_size: int = 1


class SyntheticCorpus:
    """Deterministic Zipf-mixture corpus with local n-gram structure."""

    def __init__(self, cfg: DataConfig):
        rng = np.random.RandomState(cfg.seed)
        self.cfg = cfg
        # per-doc bigram tendencies give the model something learnable
        docs = []
        base = rng.zipf(1.5, size=(cfg.n_docs, cfg.doc_len)) % cfg.vocab
        shift = rng.randint(0, cfg.vocab, size=(cfg.n_docs, 1))
        docs = (base + shift) % cfg.vocab
        # inject repeated motifs (learnable structure)
        motif = rng.randint(0, cfg.vocab, size=(cfg.n_docs, 8))
        for i in range(cfg.n_docs):
            for start in range(16, cfg.doc_len - 8, 48):
                docs[i, start:start + 8] = motif[i]
        self.tokens = docs.reshape(-1).astype(np.int32)

    def __len__(self):
        return len(self.tokens)


class DataPipeline:
    """Packs the corpus into [batch, seq] with a resumable cursor."""

    def __init__(self, cfg: DataConfig, corpus: Optional[SyntheticCorpus] = None):
        self.cfg = cfg
        self.corpus = corpus or SyntheticCorpus(cfg)
        self.step = 0

    # -- checkpoint integration ------------------------------------------
    def state(self) -> dict:
        return {"step": self.step, "seed": self.cfg.seed}

    def restore(self, state: dict):
        if state.get("seed") != self.cfg.seed:
            raise ValueError("data pipeline seed mismatch on restore")
        self.step = int(state["step"])

    # -- iteration ----------------------------------------------------------
    def _slice(self, step: int) -> np.ndarray:
        cfg = self.cfg
        toks = self.corpus.tokens
        n = len(toks)
        per_rank = cfg.global_batch // cfg.dp_size
        out = np.empty((per_rank, cfg.seq_len + 1), np.int32)
        for b in range(per_rank):
            gb = cfg.dp_rank * per_rank + b
            start = (step * cfg.global_batch + gb) * cfg.seq_len % (
                n - cfg.seq_len - 1)
            out[b] = toks[start:start + cfg.seq_len + 1]
        return out

    def next_batch(self) -> dict:
        chunk = self._slice(self.step)
        self.step += 1
        return {
            "tokens": jnp.asarray(chunk[:, :-1]),
            "targets": jnp.asarray(chunk[:, 1:]),
            "loss_mask": jnp.ones((chunk.shape[0], self.cfg.seq_len),
                                  jnp.float32),
        }

    def __iter__(self) -> Iterator[dict]:
        while True:
            yield self.next_batch()
