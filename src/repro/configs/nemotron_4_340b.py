"""nemotron-4-340b [dense]: 96L d=18432 96H (GQA kv=8) d_ff=73728 vocab=256000.

Squared-ReLU (non-gated) MLP. [arXiv:2402.16819; unverified]
"""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="nemotron-4-340b", family="dense",
        n_layers=96, d_model=18432, n_heads=96, n_kv_heads=8, head_dim=192,
        d_ff=73728, vocab=256000,
        activation="relu2", gated_mlp=False,
        rope_theta=1e4, max_seq=32768,
        param_dtype="bfloat16", compute_dtype="bfloat16",
    )


def smoke_config() -> ModelConfig:
    return config().scaled(
        n_layers=2, d_model=64, n_heads=8, n_kv_heads=2, head_dim=8,
        d_ff=256, vocab=256, max_seq=128,
        param_dtype="float32", compute_dtype="float32",
    )
