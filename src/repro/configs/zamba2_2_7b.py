"""zamba2-2.7b [hybrid]: 54L d=2560 32H (kv=32) d_ff=10240 vocab=32000.

Mamba2 backbone (ssm_state=64) + one shared full-attention block applied
every 6 layers (the Zamba trick). [arXiv:2411.15242; hf]
"""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="zamba2-2.7b", family="hybrid",
        n_layers=54, d_model=2560, n_heads=32, n_kv_heads=32, head_dim=80,
        d_ff=10240, vocab=32000,
        ssm_state=64, ssm_conv=4, ssm_head_dim=64, ssm_expand=2,
        ssm_chunk=128, attn_every=6,
        activation="gelu", gated_mlp=True,
        rope_theta=1e4, max_seq=524288,
        param_dtype="bfloat16", compute_dtype="bfloat16",
    )


def smoke_config() -> ModelConfig:
    return config().scaled(
        n_layers=4, attn_every=2, d_model=64, n_heads=4, n_kv_heads=4,
        head_dim=16, d_ff=128, vocab=256,
        ssm_state=16, ssm_head_dim=16, ssm_chunk=16, max_seq=128,
        param_dtype="float32", compute_dtype="float32",
    )
