"""Assigned-architecture registry: ``--arch <id>`` -> ModelConfig.

Each module defines ``config()`` (the full published config) and
``smoke_config()`` (a reduced same-family config for CPU smoke tests).
"""
from __future__ import annotations

import importlib

from repro.models.config import ModelConfig

ARCHS = {
    "moonshot-v1-16b-a3b": "moonshot_v1_16b_a3b",
    "deepseek-moe-16b": "deepseek_moe_16b",
    "whisper-small": "whisper_small",
    "qwen1.5-0.5b": "qwen1_5_0_5b",
    "qwen3-8b": "qwen3_8b",
    "nemotron-4-340b": "nemotron_4_340b",
    "llama3.2-3b": "llama3_2_3b",
    "zamba2-2.7b": "zamba2_2_7b",
    "internvl2-1b": "internvl2_1b",
    "rwkv6-1.6b": "rwkv6_1_6b",
    "rhapsody-demo": "rhapsody_demo",
}


def get_config(arch: str, **overrides) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{ARCHS[arch]}")
    cfg = mod.config()
    return cfg.scaled(**overrides) if overrides else cfg


def get_smoke_config(arch: str, **overrides) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{ARCHS[arch]}")
    cfg = mod.smoke_config()
    return cfg.scaled(**overrides) if overrides else cfg


def list_archs():
    return [a for a in ARCHS if a != "rhapsody-demo"]
