"""moonshot-v1-16b-a3b [moe]: 48L d=2048 16H (kv=16) d_ff=1408 vocab=163840.

MoE 64 experts top-6 (kimi/moonlight style, fine-grained).
[hf:moonshotai/Moonlight-16B-A3B; hf]
"""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="moonshot-v1-16b-a3b", family="moe",
        n_layers=48, d_model=2048, n_heads=16, n_kv_heads=16, head_dim=128,
        d_ff=1408, vocab=163840,
        n_experts=64, top_k=6, n_shared_experts=2, first_dense_layers=1,
        dense_ff=11264, capacity_factor=1.25,
        activation="silu", gated_mlp=True,
        rope_theta=5e4, max_seq=32768,
        param_dtype="bfloat16", compute_dtype="bfloat16",
    )


def smoke_config() -> ModelConfig:
    return config().scaled(
        n_layers=3, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
        d_ff=32, dense_ff=128, vocab=256, max_seq=128,
        n_experts=8, top_k=2, n_shared_experts=2, first_dense_layers=1,
        param_dtype="float32", compute_dtype="float32",
    )
