"""whisper-small [audio]: 12+12L d=768 12H d_ff=3072 vocab=51865, enc-dec.

Vocab padded 51865 -> 51872 (multiple of 32/16) for TP sharding — standard
TPU practice; padded ids are never targeted.

Conv/audio frontend is a STUB (input_specs provides frame embeddings).
[arXiv:2212.04356; unverified]
"""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="whisper-small", family="encdec",
        n_layers=12, enc_layers=12, dec_layers=12, cross_attention=True,
        d_model=768, n_heads=12, n_kv_heads=12, d_ff=3072, vocab=51872,
        activation="gelu", gated_mlp=False,
        positions="learned", max_seq=32768,
        param_dtype="bfloat16", compute_dtype="bfloat16",
    )


def smoke_config() -> ModelConfig:
    return config().scaled(
        n_layers=2, enc_layers=2, dec_layers=2,
        d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
        d_ff=128, vocab=256, max_seq=128,
        param_dtype="float32", compute_dtype="float32",
    )
