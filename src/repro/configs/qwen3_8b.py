"""qwen3-8b [dense]: 36L d=4096 32H (GQA kv=8) d_ff=12288 vocab=151936, qk_norm.

[hf:Qwen/Qwen3-8B; hf]
"""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen3-8b", family="dense",
        n_layers=36, d_model=4096, n_heads=32, n_kv_heads=8, head_dim=128,
        d_ff=12288, vocab=151936,
        qk_norm=True, activation="silu", gated_mlp=True,
        rope_theta=1e6, max_seq=32768,
        param_dtype="bfloat16", compute_dtype="bfloat16",
    )


def smoke_config() -> ModelConfig:
    return config().scaled(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, vocab=256, max_seq=128,
        param_dtype="float32", compute_dtype="float32",
    )
