"""deepseek-moe-16b [moe]: 28L d=2048 16H (kv=16) d_ff=1408 vocab=102400.

2 shared + 64 routed top-6, fine-grained; first layer dense.
[arXiv:2401.06066; hf]
"""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="deepseek-moe-16b", family="moe",
        n_layers=28, d_model=2048, n_heads=16, n_kv_heads=16, head_dim=128,
        d_ff=1408, vocab=102400,
        n_experts=64, top_k=6, n_shared_experts=2, first_dense_layers=1,
        dense_ff=10944, capacity_factor=1.25,
        activation="silu", gated_mlp=True,
        rope_theta=1e4, max_seq=32768,
        param_dtype="bfloat16", compute_dtype="bfloat16",
    )


def smoke_config() -> ModelConfig:
    return config().scaled(
        n_layers=3, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
        d_ff=32, dense_ff=128, vocab=256, max_seq=128,
        n_experts=8, top_k=2, n_shared_experts=2, first_dense_layers=1,
        param_dtype="float32", compute_dtype="float32",
    )
