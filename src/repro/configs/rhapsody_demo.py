"""rhapsody-demo: small LM used by examples/benchmarks as the service model."""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="rhapsody-demo", family="dense",
        n_layers=4, d_model=256, n_heads=8, n_kv_heads=4, head_dim=32,
        d_ff=1024, vocab=2048,
        activation="silu", gated_mlp=True,
        rope_theta=1e4, max_seq=2048,
        param_dtype="float32", compute_dtype="float32",
    )


def smoke_config() -> ModelConfig:
    return config().scaled(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
                           head_dim=16, d_ff=128, vocab=256)
