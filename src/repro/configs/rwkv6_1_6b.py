"""rwkv6-1.6b [ssm]: 24L d=2048 (attn-free) d_ff=7168 vocab=65536.

Finch: data-dependent per-channel decay. [arXiv:2404.05892; unverified]
"""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="rwkv6-1.6b", family="ssm",
        n_layers=24, d_model=2048, n_heads=32, n_kv_heads=32,
        d_ff=7168, vocab=65536,
        rwkv_head_dim=64, rwkv_lora_decay=64, rwkv_lora_mix=32, rwkv_chunk=32,
        positions="none", max_seq=524288,
        param_dtype="bfloat16", compute_dtype="bfloat16",
    )


def smoke_config() -> ModelConfig:
    return config().scaled(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
        d_ff=128, vocab=256, rwkv_head_dim=16, rwkv_lora_decay=8,
        rwkv_lora_mix=8, rwkv_chunk=8, max_seq=128,
        param_dtype="float32", compute_dtype="float32",
    )
