"""llama3.2-3b [dense]: 28L d=3072 24H (GQA kv=8) d_ff=8192 vocab=128256.

[hf:meta-llama/Llama-3.2-3B; unverified]
"""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="llama3.2-3b", family="dense",
        n_layers=28, d_model=3072, n_heads=24, n_kv_heads=8, head_dim=128,
        d_ff=8192, vocab=128256,
        activation="silu", gated_mlp=True,
        rope_theta=5e5, max_seq=32768,
        param_dtype="bfloat16", compute_dtype="bfloat16",
    )


def smoke_config() -> ModelConfig:
    return config().scaled(
        n_layers=2, d_model=48, n_heads=6, n_kv_heads=2, head_dim=8,
        d_ff=96, vocab=256, max_seq=128,
        param_dtype="float32", compute_dtype="float32",
    )
