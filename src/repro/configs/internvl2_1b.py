"""internvl2-1b [vlm]: 24L d=896 14H (GQA kv=2) d_ff=4864 vocab=151655.

Vocab padded 151655 -> 151680 (multiple of 128) for TP sharding — standard
TPU practice; padded ids are never targeted.

InternViT frontend is a STUB (input_specs provides patch embeddings);
backbone is the Qwen2-0.5B-style LM. [arXiv:2404.16821; hf]
"""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="internvl2-1b", family="vlm",
        n_layers=24, d_model=896, n_heads=14, n_kv_heads=2, head_dim=64,
        d_ff=4864, vocab=151680,
        qkv_bias=True, activation="silu", gated_mlp=True,
        rope_theta=1e6, max_seq=32768, vision_tokens=256,
        param_dtype="bfloat16", compute_dtype="bfloat16",
    )


def smoke_config() -> ModelConfig:
    return config().scaled(
        n_layers=2, d_model=56, n_heads=7, n_kv_heads=1, head_dim=8,
        d_ff=112, vocab=256, max_seq=128, vision_tokens=8,
        param_dtype="float32", compute_dtype="float32",
    )
