"""Inference request routing across service replicas (Exp 4, Fig 5d).

Two APIs on every router:

  * ``assign(requests, n_instances, cost)`` — batch: split a known request
    set into per-instance index lists (offline benchmarks, launchers).
  * ``pick(cost, n_instances=..., group=...)`` — incremental: route ONE
    request as it arrives; this is what the middleware dispatch path uses.
    State is kept per ``group`` (one group per replicated service) so a
    single shared router instance balances each replica set independently.

``RandomRouter`` assigns uniformly at random; ``RoundRobinRouter`` cycles;
the paper's ``TokenAwareBalancedRouter`` greedily equalizes BOTH request
count and estimated input-token volume per instance (longest-processing-
time-first bin packing in batch mode), which suppresses stragglers under
heterogeneous prompt costs; ``LeastLoadedRouter`` additionally reads live
per-replica queue depths so slow or backed-up replicas shed load.

``PrefixAffinityRouter`` adds KV-cache awareness on top of least-loaded:
requests carrying the same ``affinity_key`` (a hash of a bounded prompt
prefix, see ``request_signature``) stick to the replica that served the
key before — the replica whose KV cache already holds the shared prefix —
spilling to the least-loaded replica only when the sticky one is backed
up past ``spill_factor``.  This is the vLLM-prefix-caching / SGLang-
RadixAttention scheduling insight: affinity beats pure balance once the
serving side can reuse prefill work (see ``repro.serving.engine``).
"""
from __future__ import annotations

import hashlib
import random
import threading
from collections import OrderedDict
from typing import Any, Callable, Optional, Sequence


def default_cost(request) -> float:
    """Estimated cost of one request: its token count when discernible.
    Dict payloads are costed by their prompt alone — a dict's key count
    says nothing about the work it requests."""
    if isinstance(request, dict):
        prompt = request.get("prompt")
        if prompt is not None and hasattr(prompt, "__len__"):
            return float(len(prompt))
        return 1.0
    if hasattr(request, "__len__"):
        return float(len(request))
    return 1.0


def request_signature(request, prefix_len: int = 32) -> Optional[int]:
    """Affinity key for one request: a stable hash of its bounded prompt
    prefix.  Requests sharing the first ``prefix_len`` prompt tokens (or
    characters) map to the same key, so a prefix-affinity router can pin
    them to the replica whose KV cache already holds that prefix.  Dict
    payloads are keyed by ``payload["prompt"]``; requests with no
    discernible prompt return ``None`` (no affinity — route by load).
    """
    prompt = request.get("prompt") if isinstance(request, dict) else request
    if prompt is None or prefix_len <= 0:
        return None
    if isinstance(prompt, (str, bytes)):
        prefix = prompt[:prefix_len]
    else:
        try:
            prefix = tuple(prompt[:prefix_len])
        except TypeError:  # not sliceable (int uid, object payload, ...)
            return None
        try:
            # canonicalize integer token ids: the hash must not depend on
            # the element type (python int vs numpy scalar) or on numpy's
            # repr, or value-equal turns of one session would key apart
            prefix = tuple(x.__index__() for x in prefix)
        except (AttributeError, TypeError):
            pass  # non-integer elements: hash their repr as-is
    # blake2b, not hash(): stable across processes/PYTHONHASHSEED so
    # offline traces and live routing agree on session identity
    digest = hashlib.blake2b(repr(prefix).encode(), digest_size=8)
    return int.from_bytes(digest.digest(), "big")


class Router:
    """Base router: per-group incremental state + a generic batch assign.

    Subclasses implement ``_new_state(n)`` and ``_pick(state, cost,
    queue_depths)``; ``pick`` handles locking, group bookkeeping, and
    resizing state when a replica set grows or shrinks (autoscaling).
    Affinity-aware subclasses override ``_pick_affinity`` instead, which
    additionally sees the request's ``affinity_key`` and may report how
    the pick was made through the ``info`` out-dict.
    """

    uses_affinity = False  # True -> callers should compute signature()

    def __init__(self):
        self._lock = threading.Lock()
        self._groups: dict[str, Any] = {}

    def signature(self, request) -> Optional[int]:
        """Affinity key for ``request``; None for affinity-blind routers
        (so callers can pass ``signature(payload)`` unconditionally)."""
        return None

    # -- incremental API ----------------------------------------------------
    def pick(self, cost: float = 1.0, *, n_instances: int,
             group: str = "default",
             queue_depths: Optional[Sequence[float]] = None,
             affinity_key: Optional[int] = None,
             info: Optional[dict] = None) -> int:
        """Route one request of estimated ``cost``; returns a replica index.

        ``affinity_key`` (see ``request_signature``) lets sticky routers
        pin requests sharing a prompt prefix to one replica; ``info``, if
        given, is filled with ``{"affinity": "hit"|"miss"|"spill"}`` so the
        caller can account KV-reuse without a second lookup.
        """
        if n_instances <= 0:
            raise ValueError("n_instances must be >= 1")
        if n_instances == 1 and (affinity_key is None
                                 or not self.uses_affinity):
            return 0  # trivial: skip state bookkeeping entirely
        # keyed picks on an affinity router take the full path even at
        # n=1, so first contact still counts as a miss and hit rates stay
        # comparable across replica counts
        with self._lock:
            state = self._groups.pop(group, None)
            if state is None or state["n"] != n_instances:
                state = self._resize(state, n_instances)
                if len(self._groups) >= 512:  # LRU-evict a group:
                    # membership-keyed groups (see ReplicaSet.route) churn
                    # under autoscaling and would otherwise grow unbounded
                    self._groups.pop(next(iter(self._groups)))
            # pop + reinsert keeps insertion order = recency order, so
            # the eviction above drops the least-recently-USED group
            self._groups[group] = state
            idx = self._pick_affinity(state, cost, queue_depths,
                                      affinity_key, info)
        return idx

    def reset(self, group: str = "default"):
        with self._lock:
            self._groups.pop(group, None)

    # -- batch API ----------------------------------------------------------
    def _batch_order(self, requests: Sequence, cost: Callable):
        """Iteration order for batch assign; subclasses may reorder."""
        return range(len(requests))

    def assign(self, requests: Sequence, n_instances: int,
               cost: Optional[Callable] = None) -> list:
        """Return per-instance request index lists."""
        cost = cost or default_cost
        out: list = [[] for _ in range(n_instances)]
        group = object()  # private throwaway group for this batch
        for i in self._batch_order(requests, cost):
            out[self.pick(cost(requests[i]), n_instances=n_instances,
                          group=group)].append(i)
        self.reset(group)
        return out

    # -- subclass hooks -----------------------------------------------------
    def _new_state(self, n: int) -> dict:
        return {"n": n}

    def _resize(self, state: Optional[dict], n: int) -> dict:
        """Default: start fresh when the replica count changes."""
        return self._new_state(n)

    def _pick_affinity(self, state: dict, cost: float,
                       queue_depths: Optional[Sequence[float]],
                       affinity_key: Optional[int],
                       info: Optional[dict]) -> int:
        """Affinity-blind default: ignore the key, delegate to ``_pick``."""
        return self._pick(state, cost, queue_depths)

    def _pick(self, state: dict, cost: float,
              queue_depths: Optional[Sequence[float]]) -> int:
        raise NotImplementedError


class RandomRouter(Router):
    def __init__(self, seed: int = 0):
        super().__init__()
        self.rng = random.Random(seed)

    def _pick(self, state, cost, queue_depths):
        return self.rng.randrange(state["n"])


class RoundRobinRouter(Router):
    def _new_state(self, n):
        return {"n": n, "i": 0}

    def _resize(self, state, n):
        fresh = self._new_state(n)
        if state is not None:  # keep cycling through the new size
            fresh["i"] = state["i"] % n
        return fresh

    def _pick(self, state, cost, queue_depths):
        idx = state["i"] % state["n"]
        state["i"] = idx + 1
        return idx


class TokenAwareBalancedRouter(Router):
    """Greedy balance of BOTH cumulative token load and request count: each
    request goes to the instance with minimum (load, count).  Batch mode is
    LPT: sort by estimated token cost descending first."""

    def _new_state(self, n):
        return {"n": n, "loads": [0.0] * n, "counts": [0] * n}

    def _resize(self, state, n):
        fresh = self._new_state(n)
        if state is not None:
            # carry balance history when a FIXED group changes size (the
            # incremental pick() API contract; the middleware path keys
            # groups by replica membership, so it starts fresh instead):
            # new replicas start at the current minimum so they pick up
            # work immediately without a thundering herd
            old_n = state["n"]
            base_l = min(state["loads"]) if old_n else 0.0
            base_c = min(state["counts"]) if old_n else 0
            for k in range(n):
                fresh["loads"][k] = state["loads"][k] if k < old_n else base_l
                fresh["counts"][k] = (state["counts"][k] if k < old_n
                                      else base_c)
        return fresh

    def _pick(self, state, cost, queue_depths):
        loads, counts = state["loads"], state["counts"]
        j = min(range(state["n"]), key=lambda k: (loads[k], counts[k]))
        loads[j] += cost
        counts[j] += 1
        return j

    def _batch_order(self, requests, cost):
        # LPT: place the most expensive requests first
        return sorted(range(len(requests)), key=lambda i: -cost(requests[i]))


class LeastLoadedRouter(TokenAwareBalancedRouter):
    """Queue-depth-aware: prefer the replica with the shallowest live queue
    (outstanding requests), breaking ties by cumulative token load.  Falls
    back to token-aware balancing when no depths are observable (batch
    mode, or endpoints without stats)."""

    def _pick(self, state, cost, queue_depths):
        n = state["n"]
        if queue_depths is not None and len(queue_depths) == n:
            loads, counts = state["loads"], state["counts"]
            j = min(range(n),
                    key=lambda k: (queue_depths[k], loads[k], counts[k]))
            loads[j] += cost
            counts[j] += 1
            return j
        return super()._pick(state, cost, queue_depths)


class PrefixAffinityRouter(LeastLoadedRouter):
    """Sticky-session routing keyed by prompt-prefix hash (KV-cache reuse).

    Per group, a bounded LRU map ``affinity_key -> replica index`` pins a
    session (all requests sharing a prompt prefix) to one replica, so the
    serving engine behind it can skip prefill for the resident prefix.
    Unkeyed requests and first-seen keys fall through to the least-loaded
    policy; a sticky replica whose live queue depth exceeds
    ``spill_factor * (min_depth + 1)`` sheds the request (and re-homes the
    session) rather than letting affinity defeat load balance.  Resizes
    (autoscaling a FIXED group) keep mappings that still point at live
    replicas and drop the rest.
    """

    uses_affinity = True

    def __init__(self, prefix_len: int = 32, spill_factor: float = 2.0,
                 map_capacity: int = 4096):
        super().__init__()
        self.prefix_len = prefix_len
        self.spill_factor = spill_factor
        self.map_capacity = map_capacity

    def signature(self, request) -> Optional[int]:
        return request_signature(request, prefix_len=self.prefix_len)

    def _new_state(self, n):
        state = super()._new_state(n)
        state["amap"] = OrderedDict()  # affinity_key -> replica idx (LRU)
        return state

    def _resize(self, state, n):
        fresh = super()._resize(state, n)
        if state is not None:
            # sessions pinned to replicas that survive the resize keep
            # their (still cache-warm) home; the rest re-home on next pick
            fresh["amap"] = OrderedDict(
                (k, v) for k, v in state["amap"].items() if v < n)
        return fresh

    def _overloaded(self, sticky: int, queue_depths) -> bool:
        if queue_depths is None or self.spill_factor <= 0:
            return False  # no live load signal: stickiness wins
        return queue_depths[sticky] > self.spill_factor * (
            min(queue_depths) + 1.0)

    def _pick_affinity(self, state, cost, queue_depths, affinity_key, info):
        if affinity_key is None:
            return self._pick(state, cost, queue_depths)
        amap = state["amap"]
        sticky = amap.get(affinity_key)
        if sticky is not None and sticky < state["n"]:
            if not self._overloaded(sticky, queue_depths):
                amap.move_to_end(affinity_key)
                # charge the balance history the fallback policy reads, so
                # sticky traffic still counts as load on its home replica
                state["loads"][sticky] += cost
                state["counts"][sticky] += 1
                if info is not None:
                    info["affinity"] = "hit"
                return sticky
            if info is not None:
                info["affinity"] = "spill"
        elif info is not None:
            info["affinity"] = "miss"
        idx = self._pick(state, cost, queue_depths)
        amap[affinity_key] = idx  # (re-)home the session where it landed
        amap.move_to_end(affinity_key)
        while len(amap) > self.map_capacity:
            amap.popitem(last=False)
        return idx


ROUTERS = {
    "random": RandomRouter,
    "round_robin": RoundRobinRouter,
    "balanced": TokenAwareBalancedRouter,
    "least_loaded": LeastLoadedRouter,
    "prefix_affinity": PrefixAffinityRouter,
}


def make_router(kind: str, **kw) -> Router:
    return ROUTERS[kind](**kw)


def router_from_policy(policy) -> Router:
    """Build the policy's router, threading through its affinity knobs."""
    kind = getattr(policy, "routing", None) or "round_robin"
    kw = {}
    if kind == "prefix_affinity":
        kw = {
            "prefix_len": getattr(policy, "affinity_prefix_len", 32),
            "spill_factor": getattr(policy, "affinity_spill_factor", 2.0),
        }
    return make_router(kind, **kw)
