"""Inference request routing across service instances (Exp 4, Fig 5d).

``RandomRouter`` assigns requests uniformly at random; the paper's
``TokenAwareBalancedRouter`` greedily equalizes BOTH request count and
estimated input-token volume per instance (longest-processing-time-first
bin packing), which suppresses stragglers under heterogeneous prompt costs.
"""
from __future__ import annotations

import random
from typing import Any, Callable, Optional, Sequence


class Router:
    def assign(self, requests: Sequence, n_instances: int,
               cost: Optional[Callable] = None) -> list:
        """Return per-instance request index lists."""
        raise NotImplementedError


class RandomRouter(Router):
    def __init__(self, seed: int = 0):
        self.rng = random.Random(seed)

    def assign(self, requests, n_instances, cost=None):
        out = [[] for _ in range(n_instances)]
        for i in range(len(requests)):
            out[self.rng.randrange(n_instances)].append(i)
        return out


class RoundRobinRouter(Router):
    def assign(self, requests, n_instances, cost=None):
        out = [[] for _ in range(n_instances)]
        for i in range(len(requests)):
            out[i % n_instances].append(i)
        return out


class TokenAwareBalancedRouter(Router):
    """Greedy LPT: sort by estimated token cost desc, place each request on
    the instance with minimum (load, count) so both token volume and request
    count stay balanced."""

    def assign(self, requests, n_instances, cost=None):
        cost = cost or (lambda r: len(r) if hasattr(r, "__len__") else 1)
        order = sorted(range(len(requests)),
                       key=lambda i: -cost(requests[i]))
        loads = [0.0] * n_instances
        counts = [0] * n_instances
        out = [[] for _ in range(n_instances)]
        for i in order:
            j = min(range(n_instances), key=lambda k: (loads[k], counts[k]))
            out[j].append(i)
            loads[j] += cost(requests[i])
            counts[j] += 1
        return out


ROUTERS = {
    "random": RandomRouter,
    "round_robin": RoundRobinRouter,
    "balanced": TokenAwareBalancedRouter,
}


def make_router(kind: str, **kw) -> Router:
    return ROUTERS[kind](**kw)
