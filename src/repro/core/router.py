"""Inference request routing across service replicas (Exp 4, Fig 5d).

Two APIs on every router:

  * ``assign(requests, n_instances, cost)`` — batch: split a known request
    set into per-instance index lists (offline benchmarks, launchers).
  * ``pick(cost, n_instances=..., group=...)`` — incremental: route ONE
    request as it arrives; this is what the middleware dispatch path uses.
    State is kept per ``group`` (one group per replicated service) so a
    single shared router instance balances each replica set independently.

``RandomRouter`` assigns uniformly at random; ``RoundRobinRouter`` cycles;
the paper's ``TokenAwareBalancedRouter`` greedily equalizes BOTH request
count and estimated input-token volume per instance (longest-processing-
time-first bin packing in batch mode), which suppresses stragglers under
heterogeneous prompt costs; ``LeastLoadedRouter`` additionally reads live
per-replica queue depths so slow or backed-up replicas shed load.
"""
from __future__ import annotations

import random
import threading
from typing import Any, Callable, Optional, Sequence


def default_cost(request) -> float:
    """Estimated cost of one request: its token count when discernible.
    Dict payloads are costed by their prompt alone — a dict's key count
    says nothing about the work it requests."""
    if isinstance(request, dict):
        prompt = request.get("prompt")
        if prompt is not None and hasattr(prompt, "__len__"):
            return float(len(prompt))
        return 1.0
    if hasattr(request, "__len__"):
        return float(len(request))
    return 1.0


class Router:
    """Base router: per-group incremental state + a generic batch assign.

    Subclasses implement ``_new_state(n)`` and ``_pick(state, cost,
    queue_depths)``; ``pick`` handles locking, group bookkeeping, and
    resizing state when a replica set grows or shrinks (autoscaling).
    """

    def __init__(self):
        self._lock = threading.Lock()
        self._groups: dict[str, Any] = {}

    # -- incremental API ----------------------------------------------------
    def pick(self, cost: float = 1.0, *, n_instances: int,
             group: str = "default",
             queue_depths: Optional[Sequence[float]] = None) -> int:
        """Route one request of estimated ``cost``; returns a replica index."""
        if n_instances <= 0:
            raise ValueError("n_instances must be >= 1")
        if n_instances == 1:
            return 0
        with self._lock:
            state = self._groups.pop(group, None)
            if state is None or state["n"] != n_instances:
                state = self._resize(state, n_instances)
                if len(self._groups) >= 512:  # LRU-evict a group:
                    # membership-keyed groups (see ReplicaSet.route) churn
                    # under autoscaling and would otherwise grow unbounded
                    self._groups.pop(next(iter(self._groups)))
            # pop + reinsert keeps insertion order = recency order, so
            # the eviction above drops the least-recently-USED group
            self._groups[group] = state
            idx = self._pick(state, cost, queue_depths)
        return idx

    def reset(self, group: str = "default"):
        with self._lock:
            self._groups.pop(group, None)

    # -- batch API ----------------------------------------------------------
    def _batch_order(self, requests: Sequence, cost: Callable):
        """Iteration order for batch assign; subclasses may reorder."""
        return range(len(requests))

    def assign(self, requests: Sequence, n_instances: int,
               cost: Optional[Callable] = None) -> list:
        """Return per-instance request index lists."""
        cost = cost or default_cost
        out: list = [[] for _ in range(n_instances)]
        group = object()  # private throwaway group for this batch
        for i in self._batch_order(requests, cost):
            out[self.pick(cost(requests[i]), n_instances=n_instances,
                          group=group)].append(i)
        self.reset(group)
        return out

    # -- subclass hooks -----------------------------------------------------
    def _new_state(self, n: int) -> dict:
        return {"n": n}

    def _resize(self, state: Optional[dict], n: int) -> dict:
        """Default: start fresh when the replica count changes."""
        return self._new_state(n)

    def _pick(self, state: dict, cost: float,
              queue_depths: Optional[Sequence[float]]) -> int:
        raise NotImplementedError


class RandomRouter(Router):
    def __init__(self, seed: int = 0):
        super().__init__()
        self.rng = random.Random(seed)

    def _pick(self, state, cost, queue_depths):
        return self.rng.randrange(state["n"])


class RoundRobinRouter(Router):
    def _new_state(self, n):
        return {"n": n, "i": 0}

    def _resize(self, state, n):
        fresh = self._new_state(n)
        if state is not None:  # keep cycling through the new size
            fresh["i"] = state["i"] % n
        return fresh

    def _pick(self, state, cost, queue_depths):
        idx = state["i"] % state["n"]
        state["i"] = idx + 1
        return idx


class TokenAwareBalancedRouter(Router):
    """Greedy balance of BOTH cumulative token load and request count: each
    request goes to the instance with minimum (load, count).  Batch mode is
    LPT: sort by estimated token cost descending first."""

    def _new_state(self, n):
        return {"n": n, "loads": [0.0] * n, "counts": [0] * n}

    def _resize(self, state, n):
        fresh = self._new_state(n)
        if state is not None:
            # carry balance history when a FIXED group changes size (the
            # incremental pick() API contract; the middleware path keys
            # groups by replica membership, so it starts fresh instead):
            # new replicas start at the current minimum so they pick up
            # work immediately without a thundering herd
            old_n = state["n"]
            base_l = min(state["loads"]) if old_n else 0.0
            base_c = min(state["counts"]) if old_n else 0
            for k in range(n):
                fresh["loads"][k] = state["loads"][k] if k < old_n else base_l
                fresh["counts"][k] = (state["counts"][k] if k < old_n
                                      else base_c)
        return fresh

    def _pick(self, state, cost, queue_depths):
        loads, counts = state["loads"], state["counts"]
        j = min(range(state["n"]), key=lambda k: (loads[k], counts[k]))
        loads[j] += cost
        counts[j] += 1
        return j

    def _batch_order(self, requests, cost):
        # LPT: place the most expensive requests first
        return sorted(range(len(requests)), key=lambda i: -cost(requests[i]))


class LeastLoadedRouter(TokenAwareBalancedRouter):
    """Queue-depth-aware: prefer the replica with the shallowest live queue
    (outstanding requests), breaking ties by cumulative token load.  Falls
    back to token-aware balancing when no depths are observable (batch
    mode, or endpoints without stats)."""

    def _pick(self, state, cost, queue_depths):
        n = state["n"]
        if queue_depths is not None and len(queue_depths) == n:
            loads, counts = state["loads"], state["counts"]
            j = min(range(n),
                    key=lambda k: (queue_depths[k], loads[k], counts[k]))
            loads[j] += cost
            counts[j] += 1
            return j
        return super()._pick(state, cost, queue_depths)


ROUTERS = {
    "random": RandomRouter,
    "round_robin": RoundRobinRouter,
    "balanced": TokenAwareBalancedRouter,
    "least_loaded": LeastLoadedRouter,
}


def make_router(kind: str, **kw) -> Router:
    return ROUTERS[kind](**kw)
