"""Inference request routing across service replicas (Exp 4, Fig 5d).

Two APIs on every router:

  * ``assign(requests, n_instances, cost)`` — batch: split a known request
    set into per-instance index lists (offline benchmarks, launchers).
  * ``route(env, ctx)`` — incremental: route ONE ``InferenceRequest``
    envelope as it arrives given a ``RouteContext`` (candidate count,
    balance group, live queue depths, stable member identities, sticky
    namespace); this is what the middleware dispatch path uses.  State is
    kept per ``ctx.group`` (one group per replicated service) so a single
    shared router instance balances each replica set independently.
    ``pick(cost, n_instances=..., ...)`` remains as a deprecation shim
    over ``route`` for callers of the old keyword surface.

Routers also own per-tenant token-bucket ADMISSION (``TenantThrottle``):
``configure_tenants`` arms a cost-units/s rate per tenant (with burst)
and ``admit(env, cost)`` gates a request before any placement state is
touched — the first stage of multi-tenant QoS isolation.

``RandomRouter`` assigns uniformly at random; ``RoundRobinRouter`` cycles;
the paper's ``TokenAwareBalancedRouter`` greedily equalizes BOTH request
count and estimated input-token volume per instance (longest-processing-
time-first bin packing in batch mode), which suppresses stragglers under
heterogeneous prompt costs; ``LeastLoadedRouter`` additionally reads live
per-replica queue depths so slow or backed-up replicas shed load.

``PrefixAffinityRouter`` adds KV-cache awareness on top of least-loaded:
requests carrying the same ``affinity_key`` (a hash of a bounded prompt
prefix, see ``request_signature``) stick to the replica that served the
key before — the replica whose KV cache already holds the shared prefix —
spilling to the least-loaded replica only when the sticky one is backed
up past ``spill_factor``.  This is the vLLM-prefix-caching / SGLang-
RadixAttention scheduling insight: affinity beats pure balance once the
serving side can reuse prefill work (see ``repro.serving.engine``).

``RadixAffinityRouter`` replaces the fixed-length hash with true radix
longest-prefix-match over the raw token prefix (``request_prefix``):
sessions whose turns diverge *after* the hashed window still route to
their warmest replica, an overloaded sticky replica sheds to the replica
holding the **second-longest** matching prefix (not blindly to
least-loaded), and per-replica residency summaries gossiped by the
replica set (``update_residency``) ground those decisions in what each
replica's KV cache actually holds.  See ``repro.core.prefix`` for the
unified residency architecture.

Sticky state (the affinity maps / radix indices) lives in a store keyed
separately from per-membership balance state: callers that pass stable
``members`` identities and an ``affinity_group`` (see
``ReplicaSet.route``) keep session assignments across replica-set
membership changes, so an autoscale or crash re-homes only the sessions
whose replica actually left.
"""
from __future__ import annotations

import hashlib
import random
import threading
import time
from collections import OrderedDict
from typing import Any, Callable, Optional, Sequence

from .prefix import RadixIndex
from .request import InferenceRequest, RouteContext


def default_cost(request) -> float:
    """Estimated cost of one request: its token count when discernible.
    Dict payloads are costed by their prompt alone — a dict's key count
    says nothing about the work it requests."""
    if isinstance(request, dict):
        prompt = request.get("prompt")
        if prompt is not None and hasattr(prompt, "__len__"):
            return float(len(prompt))
        return 1.0
    if hasattr(request, "__len__"):
        return float(len(request))
    return 1.0


def request_model(request) -> Optional[str]:
    """Model tag of one request: multi-model services route a payload only
    among the replicas of its model group.  Dict payloads are tagged by
    ``payload["model"]``; anything else is untagged (None) and routes to
    the service's default group."""
    if isinstance(request, dict):
        model = request.get("model")
        if model is not None:
            return str(model)
    return None


def request_signature(request, prefix_len: int = 32) -> Optional[int]:
    """Affinity key for one request: a stable hash of its bounded prompt
    prefix.  Requests sharing the first ``prefix_len`` prompt tokens (or
    characters) map to the same key, so a prefix-affinity router can pin
    them to the replica whose KV cache already holds that prefix.  Dict
    payloads are keyed by ``payload["prompt"]``; requests with no
    discernible prompt return ``None`` (no affinity — route by load).
    """
    prompt = request.get("prompt") if isinstance(request, dict) else request
    if prompt is None or prefix_len <= 0:
        return None
    if isinstance(prompt, (str, bytes)):
        prefix = prompt[:prefix_len]
    else:
        try:
            prefix = tuple(prompt[:prefix_len])
        except TypeError:  # not sliceable (int uid, object payload, ...)
            return None
        try:
            # canonicalize integer token ids: the hash must not depend on
            # the element type (python int vs numpy scalar) or on numpy's
            # repr, or value-equal turns of one session would key apart
            prefix = tuple(x.__index__() for x in prefix)
        except (AttributeError, TypeError):
            pass  # non-integer elements: hash their repr as-is
    # blake2b, not hash(): stable across processes/PYTHONHASHSEED so
    # offline traces and live routing agree on session identity
    digest = hashlib.blake2b(repr(prefix).encode(), digest_size=8)
    return int.from_bytes(digest.digest(), "big")


def request_prefix(request, max_len: int = 128) -> Optional[tuple]:
    """Raw bounded prompt prefix of one request, as a canonical tuple —
    the radix router's affinity key.  Unlike ``request_signature`` this is
    lossless up to ``max_len``, so longest-prefix-match can see WHERE two
    sessions diverge instead of collapsing them to equal/unequal hashes.
    Dict payloads are keyed by ``payload["prompt"]``; requests with no
    sliceable prompt return ``None`` (no affinity — route by load)."""
    prompt = request.get("prompt") if isinstance(request, dict) else request
    if prompt is None or max_len <= 0:
        return None
    if isinstance(prompt, (str, bytes)):
        return tuple(prompt[:max_len]) or None
    try:
        prefix = tuple(prompt[:max_len])
    except TypeError:  # not sliceable (int uid, object payload, ...)
        return None
    try:
        # same integer canonicalization as request_signature: value-equal
        # token ids must compare equal whatever their element type
        prefix = tuple(x.__index__() for x in prefix)
    except (AttributeError, TypeError):
        pass  # non-integer elements: match by their own equality
    return prefix or None


class TenantThrottle:
    """Per-tenant token-bucket admission control.

    Each tenant accrues ``rate`` cost units per second (its own override
    from ``rates`` when present, else the default), up to a bucket depth
    of ``rate * burst_s``.  A request of cost ``c`` is admitted iff the
    bucket holds ``min(c, depth)`` tokens — the clamp keeps a single
    request costlier than the whole burst admittable at full bucket
    instead of starving its tenant forever.

    ``rate=None`` means unlimited (tenants without an override are not
    throttled); ``rate <= 0`` means deny everything for that tenant (a
    hard off-switch).  Untenanted requests are never throttled — they
    have no bucket to charge.  Denials are counted per tenant for the
    replica set's ``per_tenant`` stats."""

    def __init__(self, rate: Optional[float] = None,
                 rates: Optional[dict] = None, burst_s: float = 2.0,
                 clock: Callable[[], float] = time.monotonic):
        self.rate = rate
        self.rates = dict(rates or {})
        self.burst_s = max(burst_s, 1e-9)
        self._clock = clock
        self._lock = threading.Lock()
        self._buckets: dict = {}  # tenant -> [tokens, last_refill]
        self.denied: dict = {}  # tenant -> denial count

    def rate_for(self, tenant) -> Optional[float]:
        return self.rates.get(tenant, self.rate)

    def admit(self, tenant, cost: float = 1.0) -> bool:
        if tenant is None:
            return True
        rate = self.rate_for(tenant)
        if rate is None:
            return True
        with self._lock:
            if rate <= 0:
                self.denied[tenant] = self.denied.get(tenant, 0) + 1
                return False
            depth = rate * self.burst_s
            now = self._clock()
            tokens, last = self._buckets.get(tenant, (depth, now))
            tokens = min(depth, tokens + (now - last) * rate)
            need = min(max(cost, 0.0), depth)
            if tokens >= need:
                self._buckets[tenant] = (tokens - need, now)
                return True
            self._buckets[tenant] = (tokens, now)
            self.denied[tenant] = self.denied.get(tenant, 0) + 1
            return False

    def denials(self) -> dict:
        with self._lock:
            return dict(self.denied)


class Router:
    """Base router: per-group incremental state + a generic batch assign.

    Subclasses implement ``_new_state(n)`` and ``_pick(state, cost,
    queue_depths)``; ``pick`` handles locking, group bookkeeping, and
    resizing state when a replica set grows or shrinks (autoscaling).
    Affinity-aware subclasses override ``_pick_affinity`` instead, which
    additionally sees the request's ``affinity_key`` and may report how
    the pick was made through the ``info`` out-dict.
    """

    uses_affinity = False  # True -> callers should compute signature()
    uses_residency = False  # True -> callers should gossip residency
    #                         summaries via update_residency()

    def __init__(self):
        self._lock = threading.Lock()
        self._groups: dict[str, Any] = {}
        # sticky/affinity state, keyed SEPARATELY from balance state: a
        # caller that keys ``group`` by membership (so positional load
        # history resets on churn) can still pass a stable
        # ``affinity_group`` so session assignments survive membership
        # changes (LRU-bounded like _groups)
        self._affinity: "OrderedDict[Any, dict]" = OrderedDict()
        self._throttle: Optional[TenantThrottle] = None

    def signature(self, request) -> Optional[Any]:
        """Affinity key for ``request``; None for affinity-blind routers
        (so callers can pass ``signature(payload)`` unconditionally)."""
        return None

    # -- per-tenant admission -----------------------------------------------
    def configure_tenants(self, rate: Optional[float] = None,
                          rates: Optional[dict] = None,
                          burst_s: float = 2.0,
                          clock: Callable[[], float] = time.monotonic):
        """Arm per-tenant token-bucket admission (``TenantThrottle``).
        ``rate`` is the default cost-units/s per tenant (None = tenants
        without an override are unlimited); ``rates`` overrides per
        tenant; ``burst_s`` sizes the bucket in seconds at the rate."""
        self._throttle = TenantThrottle(rate=rate, rates=rates,
                                        burst_s=burst_s, clock=clock)

    def admit(self, env: InferenceRequest, cost: float = 1.0) -> bool:
        """Token-bucket admission for one envelope; True when no throttle
        is configured or the tenant's bucket covers the cost.  Callers
        check this BEFORE ``route()`` so a denied request never perturbs
        placement state."""
        if self._throttle is None:
            return True
        return self._throttle.admit(env.tenant, cost)

    def admission_denials(self) -> dict:
        """Per-tenant denial counts (empty when no throttle is armed)."""
        return self._throttle.denials() if self._throttle else {}

    # -- incremental API ----------------------------------------------------
    def route(self, env: InferenceRequest, ctx: RouteContext,
              cost: Optional[float] = None) -> int:
        """Route one envelope given its candidate-set context; returns a
        replica index into the candidates.

        ``env.affinity`` (see ``request_signature``/``request_prefix``;
        derived from ``env.payload`` via ``signature()`` when unset) lets
        sticky routers pin requests sharing a prompt prefix to one
        replica; ``ctx.info``, if given, is filled with ``{"affinity":
        "hit"|"miss"|"spill"}`` so the caller can account KV-reuse
        without a second lookup.

        ``ctx.members`` names the current candidates with STABLE
        identities (e.g. replica indices that are never reused); sticky
        routers store assignments against those identities, so a
        membership change re-homes only sessions whose member actually
        left.  Defaults to positions ``0..n-1``.  ``ctx.affinity_group``
        keys the sticky state (defaults to ``ctx.group``); pass something
        stable across membership changes to carry assignments through
        autoscale/crash churn.

        ``cost`` defaults to ``default_cost(env.payload)``.
        """
        n_instances = ctx.n_instances
        if n_instances <= 0:
            raise ValueError("n_instances must be >= 1")
        members = ctx.members
        if members is not None and len(members) != n_instances:
            raise ValueError("members must have n_instances entries")
        if cost is None:
            cost = default_cost(env.payload)
        affinity_key = env.affinity
        if affinity_key is None and self.uses_affinity \
                and env.payload is not None:
            affinity_key = self.signature(env.payload)
        if n_instances == 1 and (affinity_key is None
                                 or not self.uses_affinity):
            return 0  # trivial: skip state bookkeeping entirely
        # keyed picks on an affinity router take the full path even at
        # n=1, so first contact still counts as a miss and hit rates stay
        # comparable across replica counts
        group, info = ctx.group, ctx.info
        with self._lock:
            state = self._groups.pop(group, None)
            if state is None or state["n"] != n_instances:
                state = self._resize(state, n_instances)
                if len(self._groups) >= 512:  # LRU-evict a group:
                    # membership-keyed groups (see ReplicaSet.route) churn
                    # under autoscaling and would otherwise grow unbounded
                    self._groups.pop(next(iter(self._groups)))
            # pop + reinsert keeps insertion order = recency order, so
            # the eviction above drops the least-recently-USED group
            self._groups[group] = state
            astate = None
            if self.uses_affinity:
                astate = self._affinity_state(
                    group if ctx.affinity_group is None
                    else ctx.affinity_group)
            mem = tuple(members) if members is not None \
                else tuple(range(n_instances))
            idx = self._pick_affinity(state, cost, ctx.queue_depths,
                                      affinity_key, info,
                                      astate=astate, members=mem)
        return idx

    def pick(self, cost: float = 1.0, *, n_instances: int,
             group: str = "default",
             queue_depths: Optional[Sequence[float]] = None,
             affinity_key: Optional[Any] = None,
             info: Optional[dict] = None,
             members: Optional[Sequence] = None,
             affinity_group: Optional[Any] = None) -> int:
        """Deprecated keyword-surface shim over ``route(env, ctx)``.

        Kept for callers of the pre-envelope API; new code should build
        an ``InferenceRequest`` (or let ``ReplicaSet.request`` wrap the
        payload) and pass a ``RouteContext``."""
        env = InferenceRequest(payload=None, affinity=affinity_key)
        ctx = RouteContext(n_instances=n_instances, group=group,
                           queue_depths=queue_depths, members=members,
                           affinity_group=affinity_group, info=info)
        return self.route(env, ctx, cost=cost)

    def _affinity_state(self, key) -> dict:
        """Get-or-create the sticky state for one affinity group (caller
        holds the lock)."""
        astate = self._affinity.pop(key, None)
        if astate is None:
            astate = self._new_affinity_state()
            while len(self._affinity) >= 512:
                self._affinity.popitem(last=False)
        self._affinity[key] = astate
        return astate

    def update_residency(self, affinity_group, member, seqs: Sequence):
        """Feed one member's resident prefix sequences (replica-set
        gossip); affinity-blind routers ignore it."""

    def note_residency(self, affinity_group, member, seq: Sequence):
        """Merge ONE resident sequence into ``member``'s gossiped
        residency without replacing the rest — the disagg handoff path's
        proactive re-home (the importer now holds the migrated blocks,
        and waiting for the next full gossip pull would leave a staleness
        window where follow-up turns route to the emptied exporter).
        Affinity-blind routers ignore it."""

    def update_headroom(self, affinity_group, member, free: int,
                        capacity: int):
        """Feed one member's physical KV headroom (free / total blocks,
        replica-set gossip); routers without headroom awareness ignore
        it."""

    def forget_member(self, affinity_group, member):
        """Drop all sticky state pointing at ``member`` (it left the
        replica set for good); affinity-blind routers ignore it."""

    def reset(self, group: str = "default", affinity_group=None):
        """Drop one group's balance state and its sticky state.  Callers
        that route with a distinct ``affinity_group`` (see
        ``ReplicaSet.route``) must pass it too — sticky state lives under
        that key, not under ``group``."""
        with self._lock:
            self._groups.pop(group, None)
            self._affinity.pop(
                group if affinity_group is None else affinity_group, None)

    # -- batch API ----------------------------------------------------------
    def _batch_order(self, requests: Sequence, cost: Callable):
        """Iteration order for batch assign; subclasses may reorder."""
        return range(len(requests))

    def assign(self, requests: Sequence, n_instances: int,
               cost: Optional[Callable] = None) -> list:
        """Return per-instance request index lists."""
        cost = cost or default_cost
        out: list = [[] for _ in range(n_instances)]
        group = object()  # private throwaway group for this batch
        for i in self._batch_order(requests, cost):
            out[self.pick(cost(requests[i]), n_instances=n_instances,
                          group=group)].append(i)
        self.reset(group)
        return out

    # -- subclass hooks -----------------------------------------------------
    def _new_state(self, n: int) -> dict:
        return {"n": n}

    def _new_affinity_state(self) -> dict:
        return {}

    def _resize(self, state: Optional[dict], n: int) -> dict:
        """Default: start fresh when the replica count changes."""
        return self._new_state(n)

    def _overloaded(self, idx: int,
                    queue_depths: Optional[Sequence[float]]) -> bool:
        """Spill signal shared by the sticky routers: a replica whose live
        queue depth exceeds ``spill_factor * (min_depth + 1)`` sheds."""
        factor = getattr(self, "spill_factor", 0.0)
        if queue_depths is None or factor <= 0:
            return False  # no live load signal: stickiness wins
        return queue_depths[idx] > factor * (min(queue_depths) + 1.0)

    def _pick_affinity(self, state: dict, cost: float,
                       queue_depths: Optional[Sequence[float]],
                       affinity_key: Optional[Any],
                       info: Optional[dict], *, astate: Optional[dict],
                       members: tuple) -> int:
        """Affinity-blind default: ignore the key, delegate to ``_pick``."""
        return self._pick(state, cost, queue_depths)

    def _pick(self, state: dict, cost: float,
              queue_depths: Optional[Sequence[float]]) -> int:
        raise NotImplementedError


class RandomRouter(Router):
    def __init__(self, seed: int = 0):
        super().__init__()
        self.rng = random.Random(seed)

    def _pick(self, state, cost, queue_depths):
        return self.rng.randrange(state["n"])


class RoundRobinRouter(Router):
    def _new_state(self, n):
        return {"n": n, "i": 0}

    def _resize(self, state, n):
        fresh = self._new_state(n)
        if state is not None:  # keep cycling through the new size
            fresh["i"] = state["i"] % n
        return fresh

    def _pick(self, state, cost, queue_depths):
        idx = state["i"] % state["n"]
        state["i"] = idx + 1
        return idx


class TokenAwareBalancedRouter(Router):
    """Greedy balance of BOTH cumulative token load and request count: each
    request goes to the instance with minimum (load, count).  Batch mode is
    LPT: sort by estimated token cost descending first."""

    def _new_state(self, n):
        return {"n": n, "loads": [0.0] * n, "counts": [0] * n}

    def _resize(self, state, n):
        fresh = self._new_state(n)
        if state is not None:
            # carry balance history when a FIXED group changes size (the
            # incremental pick() API contract; the middleware path keys
            # groups by replica membership, so it starts fresh instead):
            # new replicas start at the current minimum so they pick up
            # work immediately without a thundering herd
            old_n = state["n"]
            base_l = min(state["loads"]) if old_n else 0.0
            base_c = min(state["counts"]) if old_n else 0
            for k in range(n):
                fresh["loads"][k] = state["loads"][k] if k < old_n else base_l
                fresh["counts"][k] = (state["counts"][k] if k < old_n
                                      else base_c)
        return fresh

    def _pick(self, state, cost, queue_depths):
        loads, counts = state["loads"], state["counts"]
        j = min(range(state["n"]), key=lambda k: (loads[k], counts[k]))
        loads[j] += cost
        counts[j] += 1
        return j

    def _batch_order(self, requests, cost):
        # LPT: place the most expensive requests first
        return sorted(range(len(requests)), key=lambda i: -cost(requests[i]))


class LeastLoadedRouter(TokenAwareBalancedRouter):
    """Queue-depth-aware: prefer the replica with the shallowest live queue
    (outstanding requests), breaking ties by cumulative token load.  Falls
    back to token-aware balancing when no depths are observable (batch
    mode, or endpoints without stats)."""

    def _pick(self, state, cost, queue_depths):
        n = state["n"]
        if queue_depths is not None and len(queue_depths) == n:
            loads, counts = state["loads"], state["counts"]
            j = min(range(n),
                    key=lambda k: (queue_depths[k], loads[k], counts[k]))
            loads[j] += cost
            counts[j] += 1
            return j
        return super()._pick(state, cost, queue_depths)


class PrefixAffinityRouter(LeastLoadedRouter):
    """Sticky-session routing keyed by prompt-prefix hash (KV-cache reuse).

    Per affinity group, a bounded LRU map ``affinity_key -> member`` pins
    a session (all requests sharing a prompt prefix) to one replica, so
    the serving engine behind it can skip prefill for the resident prefix.
    Unkeyed requests and first-seen keys fall through to the least-loaded
    policy; a sticky replica whose live queue depth exceeds
    ``spill_factor * (min_depth + 1)`` sheds the request (and re-homes the
    session) rather than letting affinity defeat load balance.  Sticky
    entries name stable member identities, so membership changes (an
    autoscale shrink, a crash) re-home only the sessions whose member
    actually left the candidate set.
    """

    uses_affinity = True

    def __init__(self, prefix_len: int = 32, spill_factor: float = 2.0,
                 map_capacity: int = 4096):
        super().__init__()
        self.prefix_len = prefix_len
        self.spill_factor = spill_factor
        self.map_capacity = map_capacity

    def signature(self, request) -> Optional[int]:
        return request_signature(request, prefix_len=self.prefix_len)

    def _new_affinity_state(self):
        return {"amap": OrderedDict()}  # affinity_key -> member id (LRU)

    def forget_member(self, affinity_group, member):
        with self._lock:
            astate = self._affinity.get(affinity_group)
            if astate is None:
                return
            amap = astate["amap"]
            for k in [k for k, v in amap.items() if v == member]:
                del amap[k]

    def _pick_affinity(self, state, cost, queue_depths, affinity_key, info,
                       *, astate, members):
        if affinity_key is None:
            return self._pick(state, cost, queue_depths)
        amap = astate["amap"]
        sticky = amap.get(affinity_key)
        pos = members.index(sticky) if sticky in members else None
        if pos is not None:
            if not self._overloaded(pos, queue_depths):
                amap.move_to_end(affinity_key)
                # charge the balance history the fallback policy reads, so
                # sticky traffic still counts as load on its home replica
                state["loads"][pos] += cost
                state["counts"][pos] += 1
                if info is not None:
                    info["affinity"] = "hit"
                return pos
            if info is not None:
                info["affinity"] = "spill"
        elif info is not None:
            info["affinity"] = "miss"
        idx = self._pick(state, cost, queue_depths)
        amap[affinity_key] = members[idx]  # (re-)home the session here
        amap.move_to_end(affinity_key)
        while len(amap) > self.map_capacity:
            amap.popitem(last=False)
        return idx


class RadixAffinityRouter(LeastLoadedRouter):
    """Radix longest-prefix-match routing (the SGLang RadixAttention
    scheduling insight, applied at the router layer).

    Per affinity group, TWO ``RadixIndex`` structures over raw token
    prefixes (``request_prefix``, lossless up to ``max_prefix`` tokens):

      * ``sessions`` — observed prompt prefix -> member that served it
        (assignment memory, replacing the hashed LRU map).  Because the
        match is longest-common-prefix, a session whose turns diverge
        after any fixed hash window still finds its warmest replica, and
        two sessions sharing only a system-prompt stem are distinguished
        by their own turns.
      * ``residency`` — prefixes each member's KV cache actually holds,
        gossiped by the replica set (``update_residency``) from the
        engines' residency summaries.

    A pick routes to the member with the deepest match of at least
    ``min_match`` tokens (ties prefer the shallower queue); when that
    member is overloaded (same ``spill_factor`` rule as
    ``PrefixAffinityRouter``) it sheds to the member holding the
    *second-longest* matching prefix — prefix-aware spill — and only
    falls back to least-loaded when no other member knows the prefix.
    Assignments name stable member identities, so membership churn
    re-homes only sessions homed on a departed member.

    Residency matches are additionally weighed by PHYSICAL headroom
    (``update_headroom``, gossiped from the paged engines' free/total
    block gauges): a member whose free-block fraction is below
    ``headroom_watermark`` ranks after every non-starved match, so a
    deep prefix match on a memory-starved replica — one about to evict
    the very residency being matched — no longer beats a shallow match
    (or an empty replica) with room to grow.
    """

    uses_affinity = True
    uses_residency = True

    def __init__(self, max_prefix: int = 128, min_match: int = 8,
                 spill_factor: float = 2.0, map_capacity: int = 4096,
                 headroom_watermark: float = 0.1):
        super().__init__()
        self.max_prefix = max_prefix
        self.min_match = max(1, min_match)
        self.spill_factor = spill_factor
        self.map_capacity = map_capacity
        self.headroom_watermark = headroom_watermark

    def signature(self, request) -> Optional[tuple]:
        return request_prefix(request, max_len=self.max_prefix)

    def _new_affinity_state(self):
        return {"sessions": RadixIndex(capacity=self.map_capacity),
                "residency": RadixIndex(capacity=self.map_capacity),
                "headroom": {}}  # member -> (free_blocks, total_blocks)

    def update_residency(self, affinity_group, member, seqs):
        """Replace ``member``'s gossiped residency with ``seqs`` (its
        engine's current resident prefix sequences)."""
        with self._lock:
            astate = self._affinity_state(affinity_group)
            res = astate["residency"]
            res.remove_value(member)
            # cap is a runaway guard only: normal payloads are bounded by
            # the engine's slot count (and the index's own LRU capacity)
            for s in list(seqs)[:1024]:
                res.insert(tuple(s)[:self.max_prefix], member)

    def note_residency(self, affinity_group, member, seq):
        """Merge one sequence into ``member``'s residency (handoff
        re-home): unlike ``update_residency`` this does NOT drop the
        member's other gossiped prefixes."""
        seq = tuple(seq)[:self.max_prefix]
        if not seq:
            return
        with self._lock:
            astate = self._affinity_state(affinity_group)
            astate["residency"].insert(seq, member)

    def update_headroom(self, affinity_group, member, free, capacity):
        """Replace ``member``'s gossiped physical headroom (free / total
        KV blocks of its paged engine)."""
        with self._lock:
            astate = self._affinity_state(affinity_group)
            astate.setdefault("headroom", {})[member] = (free, capacity)

    def forget_member(self, affinity_group, member):
        with self._lock:
            astate = self._affinity.get(affinity_group)
            if astate is None:
                return
            astate["sessions"].remove_value(member)
            astate["residency"].remove_value(member)
            astate.get("headroom", {}).pop(member, None)

    def _starved(self, astate, member) -> bool:
        """True when the member's gossiped free-block fraction is below
        the watermark — its next admissions will evict residency, so its
        prefix matches should not win placement.  Members with no
        gossiped headroom (slot-pool engines, pre-first-gossip) are never
        starved."""
        hr = astate.get("headroom", {}).get(member)
        if hr is None:
            return False
        free, capacity = hr
        return capacity > 0 and free < self.headroom_watermark * capacity

    def _pick_affinity(self, state, cost, queue_depths, affinity_key, info,
                       *, astate, members):
        if not isinstance(affinity_key, tuple) or not affinity_key:
            return self._pick(state, cost, queue_depths)
        seq = affinity_key[:self.max_prefix]
        # best common-prefix length per member, across BOTH assignment
        # memory and gossiped residency (one O(len(seq)) descent each)
        depth = astate["sessions"].match_lengths(seq)
        for v, d in astate["residency"].match_lengths(seq).items():
            if d > depth.get(v, 0):
                depth[v] = d
        pos = {m: i for i, m in enumerate(members)}
        ranked = [(self._starved(astate, m), d, pos[m])
                  for m, d in depth.items()
                  if d >= self.min_match and m in pos]
        # deepest match first; equal depths (e.g. several members holding
        # the same shared stem) prefer the shallower live queue; matches
        # on memory-starved members rank after EVERY non-starved match,
        # however shallow — their engine is about to evict the matched
        # residency anyway, so the prefill saving is illusory
        ranked.sort(key=lambda t: (
            t[0], -t[1],
            queue_depths[t[2]] if queue_depths is not None else 0.0))
        eligible = [t for t in ranked if not t[0]]
        starved_max = max((d for s, d, _i in ranked if s), default=-1)
        outcome = "miss"
        idx = None
        for _s, d, i in eligible:
            if not self._overloaded(i, queue_depths):
                idx = i
                if outcome == "miss":
                    # landing on a shallower match than a starved member's
                    # deeper one is a headroom spill, not a plain hit
                    outcome = "hit" if d >= starved_max else "spill"
                break
            outcome = "spill"  # matching member overloaded: try the next-
            #                    longest matching prefix holder
        if idx is None and eligible and queue_depths is not None and \
                self.spill_factor > 0 and \
                queue_depths[eligible[0][2]] <= 2 * self.spill_factor * (
                    min(queue_depths) + 1.0):
            # every prefix holder is past the eager threshold, but going
            # COLD re-pays the whole prefill — stay with the deepest
            # non-starved match until pressure doubles the spill threshold
            # (two-tier spill: warm->warm moves are cheap, warm->cold
            # moves are not)
            idx = eligible[0][2]
            outcome = "hit" if eligible[0][1] >= starved_max else "spill"
        if idx is None:
            if ranked:
                outcome = "spill"  # every match starved or overloaded
            idx = self._pick(state, cost, queue_depths)  # charges balance
        else:
            state["loads"][idx] += cost
            state["counts"][idx] += 1
        if info is not None:
            info["affinity"] = outcome
        # remember where this (possibly grown) prefix landed; compaction
        # inside RadixIndex replaces the session's shorter earlier turns
        astate["sessions"].insert(seq, members[idx])
        return idx


ROUTERS = {
    "random": RandomRouter,
    "round_robin": RoundRobinRouter,
    "balanced": TokenAwareBalancedRouter,
    "least_loaded": LeastLoadedRouter,
    "prefix_affinity": PrefixAffinityRouter,
    "radix_affinity": RadixAffinityRouter,
}


def make_router(kind: str, **kw) -> Router:
    return ROUTERS[kind](**kw)


def router_from_policy(policy) -> Router:
    """Build the policy's router, threading through its affinity knobs."""
    kind = getattr(policy, "routing", None) or "round_robin"
    kw = {}
    if kind == "prefix_affinity":
        kw = {
            "prefix_len": getattr(policy, "affinity_prefix_len", 32),
            "spill_factor": getattr(policy, "affinity_spill_factor", 2.0),
        }
    elif kind == "radix_affinity":
        kw = {
            "max_prefix": getattr(policy, "affinity_max_prefix", 128),
            "min_match": getattr(policy, "affinity_min_match", 8),
            "spill_factor": getattr(policy, "affinity_spill_factor", 2.0),
            "headroom_watermark": getattr(
                policy, "affinity_headroom_watermark", 0.1),
        }
    r = make_router(kind, **kw)
    rate = getattr(policy, "tenant_rate", None)
    rates = getattr(policy, "tenant_rates", None)
    if rate is not None or rates:
        r.configure_tenants(rate=rate, rates=rates,
                            burst_s=getattr(policy, "tenant_burst_s", 2.0))
    return r
