"""Services as first-class, replicated workflow entities (§III-B, Fig 5d).

A ``ServiceDescription`` declares a factory for a *servicer* — anything with
``submit(payload) -> uid`` / ``step() -> [(uid, result)]`` (pumped, e.g. a
continuous-batching engine) or just ``handle(payload) -> result`` (sync RPC)
— plus how many replicas to run.  The ``ServiceManager`` owns a *replica
set* per service name: per-replica ``ServiceInstance`` + ``ServiceEndpoint``,
aggregated stats, per-replica restart-on-crash (exponential backoff via
``restart_backoff_s``/``restart_backoff_max_s``, giving up after
``restart_max_attempts`` consecutive crashes so a persistently broken
servicer degrades the set instead of hot-looping), and (optionally)
queue-depth driven autoscaling within policy bounds.  Requests fan out
across replicas through the shared router (see ``repro.core.router``);
with ``routing="prefix_affinity"`` / ``"radix_affinity"`` each request's
prompt-prefix signature pins sessions to their cache-warm replica, and the
outcome is accounted per endpoint as ``prefix_hits``/``prefix_misses`` in
``stats()``.

Cross-layer residency (see ``repro.core.prefix``): routes pass each
replica's STABLE identity (``replica_idx``, never reused) plus a stable
affinity group to the router, so sticky assignments survive membership
churn — after an autoscale or crash only sessions homed on the departed
replica re-home.  The stats tick (and every ``residency_sync_every``-th
route) collects per-replica residency summaries from servicers that
expose ``residency_summary()`` and gossips them to the router, grounding
prefix-aware spill in what each replica's KV cache actually holds.  A
replica that exhausts its restart budget is declared dead, counted in
``stats()["dead_replicas"]``, and after ``dead_replica_grace_s`` folded
out of the set with its stats merged into the aggregate.

Multi-model services (§III, Fig 5: heterogeneous AI workloads in ONE job
allocation): a ``ServiceDescription`` may declare several ``ModelGroup``s
— one replica set then serves several model configs.  Each replica is
tagged with its group, a request's ``model`` tag (payload ``{"model":
...}``) narrows routing to that group's replicas BEFORE any
affinity/least-loaded logic runs (sticky state is keyed per group, so
per-model affinity falls out), ``stats()["per_group"]`` breaks out
requests/hits/latency/claims per model, and ``scale_to(n, group=)`` /
``scale_groups(targets)`` scale one group at a time — ``scale_groups``
applies shrinks first, so the ``weighted_capacity`` autoscaler's
rebalances (retire a replica from an over-provisioned group to admit one
for an SLO-violating group) stay capacity-neutral inside a full
partition.

Resource claims (§III-C: one ledger for tasks AND services): when the
manager is given the middleware's partition ``Allocation``s, every replica
spawn first books ``ServiceDescription.requirements`` as a concrete
``Claim`` (node/core/gpu ids) against the set's partition, held until the
replica retires.  Scale-up is therefore *admission-controlled*: a full
partition denies the claim and the set degrades gracefully — a
``SCALE_DENIED`` event plus the ``stats()["admission_denied"]`` counter,
never an exception — instead of scaling past physical capacity.  The same
claims surface in ``Rhapsody.utilization()``, so services and tasks are
finally visible on one ledger.  With ``ExecutionPolicy.warmup`` a new
replica also completes a warm-up prime (``servicer.warmup()``: compile + a
token of decode) before ``ready`` is set — the router never routes to a
cold replica, so autoscale-up stops adding tail latency.  Autoscaling
itself is pluggable (``repro.core.autoscale``): queue-depth (default) or
p95-latency-SLO policies, both bounded by ``Allocation.free_capacity()``.
"""
from __future__ import annotations

import dataclasses
import itertools
import queue
import threading
import time
from typing import Any, Callable, Optional

from .autoscale import LatencyWindow, autoscaler_from_policy, percentile
from .request import AdmissionDenied, InferenceRequest, RouteContext
from .router import Router, default_cost, router_from_policy
from .task import ResourceRequirements


@dataclasses.dataclass
class ModelGroup:
    """One model config served inside a multi-model replica set.

    A ``ServiceDescription`` may declare several of these (``models=[...]``)
    behind ONE service name: each replica is tagged with the group it hosts,
    requests carry a ``model`` tag (payload ``{"model": ...}`` or
    ``request(..., model=...)``) and are routed only among that group's
    replicas, and capacity is shared — every group's replicas claim from the
    same partition ledger, with ``weight`` naming the group's entitlement to
    it (initial replica split, and who donates first when the
    ``weighted_capacity`` autoscaler rebalances).
    """

    name: str
    factory: Optional[Callable[[], Any]] = None  # None -> desc.factory
    weight: float = 1.0  # share of the set's capacity this group is
    #                      entitled to, relative to its siblings
    replicas: Optional[int] = None  # initial count; None -> weighted share
    #                                 of ServiceDescription.replicas
    slo_p95_ms: Optional[float] = None  # per-group SLO target; None ->
    #                                     ExecutionPolicy.slo_p95_ms
    requirements: Optional[ResourceRequirements] = None  # per-replica
    #                                 claim shape; None -> desc.requirements
    role: str = "serve"  # | "draft" | "prefill" | "decode".
    #   "draft": a speculative-decoding draft group.  Draft groups share
    #   their target group's affinity namespace under residency-aware
    #   routers (both legs of one prompt pin to the same radix key,
    #   keeping both KV stems warm), and the weighted_capacity autoscaler
    #   scales their entitlement by the set's measured acceptance rate —
    #   a low-acceptance workload shrinks the draft toward min_replicas
    #   instead of burning cores.
    #   "prefill"/"decode": disaggregated serving pools for ONE model.
    #   New prompts route to the prefill group (large chunked-prefill
    #   budget, no decode interleave); on first token the sequence
    #   migrates to the paired decode group via a paged-KV handoff
    #   (engine.export_sequence -> engine.import_sequence), orchestrated
    #   by the set (see ``ReplicaSet._handoff``).  The prefill group's
    #   SLO is a TTFT target, the decode group's an ITL target — the
    #   weighted_capacity autoscaler reads the matching per-phase latency
    #   window for each (see ``latency_p95(phase=...)``).
    paired_with: Optional[str] = None  # draft role: target group sharing
    #   the affinity namespace; None -> the first serve-role group.
    #   prefill role: the decode group sequences hand off to; None -> the
    #   first decode-role group
    min_replicas: Optional[int] = None  # per-group autoscale floor; None
    #   -> 1 (every model keeps a replica).  An EXPLICIT 0 allows the
    #   rebalancer to retire the group entirely (spec-decode off)
    max_replicas: Optional[int] = None  # per-group autoscale ceiling;
    #   None -> bounded only by the set total / ledger
    borrow_limit: Optional[int] = None  # burst-borrow cap: how many
    #   replicas BELOW its weight-anchored entitlement this group may be
    #   shrunk when acting as a donor in a weighted_capacity rebalance.
    #   None -> unbounded (donate down to min_replicas); 0 -> never
    #   donate below entitlement


@dataclasses.dataclass
class ServiceDescription:
    name: str
    factory: Optional[Callable[[], Any]] = None  # builds one servicer
    #   (called per replica); optional when every ModelGroup in ``models``
    #   brings its own factory
    requirements: ResourceRequirements = dataclasses.field(
        default_factory=ResourceRequirements)  # claimed PER REPLICA
    ready_timeout: float = 30.0
    partition: Optional[str] = None
    replicas: Optional[int] = None  # None -> ExecutionPolicy.replicas
    warmup: Optional[bool] = None  # None -> ExecutionPolicy.warmup
    models: Optional[list] = None  # [ModelGroup, ...]: serve several model
    #                                configs from ONE replica set (None ->
    #                                a single implicit "default" group)


def weighted_split(total: int, weights: dict) -> dict:
    """Split ``total`` replicas across groups proportionally to weight
    (largest-remainder rounding), guaranteeing every group at least 1 —
    a model with no replica cannot serve at all."""
    names = list(weights)
    w = {g: max(0.0, float(weights[g])) for g in names}
    total_w = sum(w.values())
    if total_w <= 0:
        w = {g: 1.0 for g in names}
        total_w = float(len(names))
    out = {g: 1 for g in names}
    rem = total - len(names)
    if rem <= 0:
        return out
    exact = {g: rem * w[g] / total_w for g in names}
    for g in names:
        out[g] += int(exact[g])
    left = rem - sum(int(exact[g]) for g in names)
    # leftover replicas go to the largest fractional remainders, ties in
    # declaration order (deterministic across runs)
    for g in sorted(names, key=lambda g: -(exact[g] - int(exact[g])))[:left]:
        out[g] += 1
    return out


_STAT_KEYS = ("requests", "completed", "errors", "cost",
              "prefix_hits", "prefix_misses")


def _merge_tenant_stats(snaps, folded, denied) -> dict:
    """Merge per-endpoint tenant counters (``snaps``: list of
    {tenant: {requests, completed, errors}}), folded retired aggregates
    and router-bucket denial counts into one per-tenant view."""
    per_tenant: dict = {t: dict(v) for t, v in folded.items()}
    for snap in snaps:
        for t, ts in snap.items():
            tt = per_tenant.setdefault(
                t, {"requests": 0, "completed": 0, "errors": 0})
            for k in ("requests", "completed", "errors"):
                tt[k] = tt.get(k, 0) + ts.get(k, 0)
    for t, n in denied.items():
        tt = per_tenant.setdefault(
            t, {"requests": 0, "completed": 0, "errors": 0})
        tt["admission_denied"] = tt.get("admission_denied", 0) + n
    return per_tenant


class _Future:
    __slots__ = ("_event", "_result", "_error", "_callbacks")

    def __init__(self):
        self._event = threading.Event()
        self._result = None
        self._error = None
        self._callbacks: list = []

    def add_done_callback(self, cb: Callable):
        """Run ``cb(self)`` when the future resolves (immediately if it
        already has) — the handoff orchestration chains the decode leg's
        future into the one the original caller holds this way.  Callback
        errors are swallowed: a misbehaving observer must not poison the
        resolve path."""
        if self._event.is_set():
            try:
                cb(self)
            except Exception:
                pass
            return
        self._callbacks.append(cb)
        if self._event.is_set():  # resolved while appending: fire now
            self._fire_callbacks()

    def _fire_callbacks(self):
        cbs, self._callbacks = self._callbacks, []
        for cb in cbs:
            try:
                cb(self)
            except Exception:
                pass

    def set_result(self, r):
        self._result = r
        self._event.set()
        self._fire_callbacks()

    def set_error(self, e):
        self._error = e
        self._event.set()
        self._fire_callbacks()

    def result(self, timeout: Optional[float] = None):
        if not self._event.wait(timeout):
            raise TimeoutError("service request timed out")
        if self._error is not None:
            raise self._error
        return self._result

    def done(self) -> bool:
        return self._event.is_set()


class ServiceEndpoint:
    """Client-visible handle for ONE replica; requests are async futures."""

    def __init__(self, name: str, replica_idx: int = 0,
                 group: str = "default"):
        self.name = name
        self.replica_idx = replica_idx
        self.group = group  # model group this replica hosts (multi-model
        #                     sets route a request only within its group)
        self.requests: "queue.Queue" = queue.Queue()
        self.ready = threading.Event()
        self.stats = {"requests": 0, "completed": 0, "errors": 0,
                      "cost": 0.0,  # routed token-cost (load imbalance)
                      # sticky-routing outcomes (prefix_affinity): a hit
                      # means this replica was the request's cache-warm home
                      "prefix_hits": 0, "prefix_misses": 0}
        self._stats_lock = threading.Lock()
        self.retired = False  # set when scaled away / replaced
        self.on_retired: Optional[Callable] = None  # drains my queue
        self.claim = None  # resources.Claim held while this replica lives
        #                    (None when the manager has no allocations)
        self.latency = LatencyWindow()  # end-to-end request latencies —
        #                    the SLO autoscaler's per-endpoint signal
        # per-phase windows fed from result dicts that carry the engine's
        # first_token_at stamps: ttft for prefill(/unified) replicas, itl
        # (mean inter-token gap per request) for decode(/unified) ones —
        # the per-role SLO signals of disaggregated serving
        self.ttft = LatencyWindow()
        self.itl = LatencyWindow()
        # multi-tenant QoS accounting: per-tenant request counters and
        # per-priority-class end-to-end latency windows (the isolation
        # signal — "is the high class's p95 flat while low saturates")
        self.tenant_stats: dict = {}  # tenant -> {requests/completed/errors}
        self.class_latency: dict = {}  # qos class -> LatencyWindow

    def bump(self, key: str, by: int = 1, tenant: Optional[str] = None):
        # stats feed depth(), which drives routing and autoscaling — a
        # lost += under concurrent clients would skew a control signal
        with self._stats_lock:
            self.stats[key] += by
            if tenant is not None:
                ts = self.tenant_stats.setdefault(
                    tenant, {"requests": 0, "completed": 0, "errors": 0})
                if key in ts:
                    ts[key] += by

    def observe_latency(self, seconds: float,
                        qos_class: Optional[str] = None):
        self.latency.observe(seconds)
        if qos_class is not None:
            win = self.class_latency.get(qos_class)
            if win is None:
                win = self.class_latency.setdefault(qos_class,
                                                    LatencyWindow())
            win.observe(seconds)

    def request(self, payload, **meta) -> _Future:
        """Legacy keyword surface: wraps the payload into an
        ``InferenceRequest`` (lifting the pre-envelope ``_t0``/``_model``
        meta side-channels onto it) and enqueues.  New code builds the
        envelope itself and calls ``request_env``."""
        t0 = meta.pop("_t0", None)
        model = meta.pop("_model", None)
        env = InferenceRequest.wrap(payload, model=model, meta=meta)
        if t0 is not None:
            env.submitted_at = t0
        return self.request_env(env)

    def request_env(self, env: InferenceRequest) -> _Future:
        """Enqueue one envelope on this replica.  ``env.submitted_at``
        was stamped when the envelope was first built, so replays,
        reroutes and handoffs all observe true end-to-end latency."""
        fut = _Future()
        self.bump("requests", tenant=env.tenant)
        self.requests.put((env, fut))
        # closes the route()/retire race: if this endpoint was retired
        # between the route decision and the put, hand the queue (which
        # now holds this request) to the replica set for rerouting
        if self.retired and self.on_retired is not None:
            self.on_retired(self)
        return fut

    def depth(self) -> int:
        """Outstanding requests (queued + in service) — the live load signal
        the least-loaded router and the autoscaler consume."""
        s = self.stats
        return max(0, s["requests"] - s["completed"] - s["errors"])


class ServiceInstance(threading.Thread):
    """Drives one servicer replica: admits endpoint requests, pumps,
    resolves."""

    def __init__(self, desc: ServiceDescription, endpoint: ServiceEndpoint,
                 on_exit: Optional[Callable] = None, warmup: bool = False,
                 residency_listener: Optional[Callable] = None,
                 factory: Optional[Callable] = None):
        super().__init__(
            name=f"service-{desc.name}[{endpoint.replica_idx}]", daemon=True)
        self.desc = desc
        self.endpoint = endpoint
        self.factory = factory or desc.factory  # a multi-model set passes
        #                                         the replica's GROUP factory
        self.alive = True
        self.last_beat = time.perf_counter()
        self.ready_at: Optional[float] = None  # when this instance came up
        self.servicer = None
        self._pending: dict = {}
        self._on_exit = on_exit
        self._warmup = warmup
        self._residency_listener = residency_listener
        self._drain = False
        self.error: Optional[BaseException] = None
        # disaggregated serving: the replica set installs this on
        # prefill-role replicas.  A servicer result dict carrying a
        # "handoff_export" payload (an exported sequence) is diverted
        # here — the hook re-dispatches the decode leg and chains the
        # futures — instead of resolving the caller's future with a
        # half-finished generation.
        self.on_handoff: Optional[Callable] = None

    def run(self):
        try:
            self.servicer = self.factory()
            if self._residency_listener is not None and \
                    hasattr(self.servicer, "set_residency_listener"):
                # gossip push channel: the engine notifies on KV eviction
                # so the router's residency view refreshes immediately
                self.servicer.set_residency_listener(self._residency_listener)
            if hasattr(self.servicer, "setup"):
                self.servicer.setup()
            if self._warmup and hasattr(self.servicer, "warmup"):
                # prime (compile + a token of decode) BEFORE ready: the
                # router never sees a cold replica, so autoscale-up does
                # not add first-request tail latency.  A warm-up crash is
                # a factory crash: _await_ready bails out early on it.
                self.servicer.warmup()
            self.endpoint.ready.set()
            self.ready_at = time.perf_counter()
            pumped = hasattr(self.servicer, "step")
            while self.alive or (self._drain and self._pending):
                self.last_beat = time.perf_counter()
                moved = self._admit() if self.alive else False
                if pumped:
                    if self._pending:
                        for uid, result in self.servicer.step() or []:
                            self._resolve(uid, result)
                        self._drain_finished()
                    elif not moved:
                        time.sleep(1e-4)
                elif not moved:
                    time.sleep(1e-4)
        except BaseException as e:  # noqa: BLE001
            self.error = e
            self.endpoint.ready.clear()
            # preemption-safe: replay in-flight requests on the relaunched
            # instance (bounded by env.replays), else fail their futures
            for uid, (fut, env) in self._pending.items():
                if env.replays < 2:
                    env.replays += 1
                    self.endpoint.requests.put((env, fut))
                else:
                    fut.set_error(e)
                    self.endpoint.bump("errors", tenant=env.tenant)
            # same post-put re-check as request(): if this endpoint was
            # retired while we crashed, hand the replays to the reroute
            if self.endpoint.retired and self.endpoint.on_retired:
                self.endpoint.on_retired(self.endpoint)
        finally:
            if self.error is None:
                # non-drain stop with work still in flight: fail those
                # futures now instead of letting clients hit their own
                # (much longer) timeouts
                for uid, (fut, env) in self._pending.items():
                    if not fut.done():
                        fut.set_error(RuntimeError(
                            f"service {self.desc.name} stopped"))
                        self.endpoint.bump("errors", tenant=env.tenant)
                self._pending.clear()
            if hasattr(self.servicer, "teardown") and self.servicer is not None:
                try:
                    self.servicer.teardown()
                except Exception:
                    pass
            if self._on_exit:
                self._on_exit(self)

    # -- internals ----------------------------------------------------------
    def _admit(self) -> bool:
        moved = False
        for _ in range(64):
            try:
                env, fut = self.endpoint.requests.get_nowait()
            except queue.Empty:
                break
            moved = True
            kw = env.servicer_kwargs()
            if hasattr(self.servicer, "submit"):
                if getattr(self.servicer, "accepts_envelope", False):
                    # envelope-aware servicers (LLMServicer) get the full
                    # record (tenant/priority/handoff); plain test
                    # servicers keep the bare payload + public meta
                    kw["envelope"] = env
                try:
                    uid = self.servicer.submit(env.payload, **kw)
                except BaseException as e:  # noqa: BLE001
                    # crash mid-submit: requeue THIS request for replay on
                    # the relaunched instance before propagating
                    if env.replays < 2:
                        env.replays += 1
                        self.endpoint.requests.put((env, fut))
                    else:
                        fut.set_error(e)
                        self.endpoint.bump("errors", tenant=env.tenant)
                    raise
                self._pending[uid] = (fut, env)
            else:  # sync RPC servicer (same public-meta kwargs as submit)
                try:
                    fut.set_result(self.servicer.handle(env.payload, **kw))
                    self.endpoint.bump("completed", tenant=env.tenant)
                    self._observe(env)
                except BaseException as e:  # noqa: BLE001
                    fut.set_error(e)
                    self.endpoint.bump("errors", tenant=env.tenant)
        return moved

    def _observe(self, env: InferenceRequest):
        if env.submitted_at is not None:
            self.endpoint.observe_latency(
                time.perf_counter() - env.submitted_at,
                qos_class=env.priority)

    def _resolve(self, uid, result):
        entry = self._pending.pop(uid, None)
        if entry is None:
            return
        fut, env = entry
        if isinstance(result, dict):
            self._observe_phases(result)
            if result.get("handoff_export") is not None \
                    and self.on_handoff is not None:
                # prefill leg done: this replica's work is complete (count
                # it) but the REQUEST is not — divert to the handoff hook,
                # which dispatches the decode leg and resolves the caller's
                # future when that leg finishes
                self.endpoint.bump("completed", tenant=env.tenant)
                self._observe(env)
                try:
                    self.on_handoff(fut, result, env)
                except BaseException as e:  # noqa: BLE001
                    fut.set_error(e)
                    self.endpoint.bump("errors", tenant=env.tenant)
                return
        fut.set_result(result)
        self.endpoint.bump("completed", tenant=env.tenant)
        self._observe(env)

    def _observe_phases(self, result: dict):
        """Feed the endpoint's per-phase latency windows from a result
        dict.  TTFT is observed where it was MEASURED: a decode-side final
        result of a handed-off sequence carries the prefill replica's
        ttft_s for the client, flagged ``handoff`` — the prefill endpoint
        already observed it, so it is skipped here (phase-pure windows)."""
        t = result.get("ttft_s")
        if t is not None and not result.get("handoff"):
            self.endpoint.ttft.observe(t)
        i = result.get("itl_s")
        if i is not None:
            self.endpoint.itl.observe(i)

    def _drain_finished(self):
        if hasattr(self.servicer, "drain"):
            for uid, result in self.servicer.drain() or []:
                self._resolve(uid, result)

    def stop(self, drain: bool = False):
        self._drain = drain
        self.alive = False


def _await_ready(inst: ServiceInstance, timeout: float) -> bool:
    """Wait for a replica to come ready, bailing out as soon as its
    factory crashes instead of burning the whole timeout."""
    deadline = time.perf_counter() + timeout
    while time.perf_counter() < deadline:
        if inst.endpoint.ready.wait(0.05):
            return True
        if inst.error is not None and not inst.is_alive():
            return False
    return inst.endpoint.ready.is_set()


_replica_set_seq = itertools.count()  # unique per-set id for router group
#                                       keys (id(self) could be reused by
#                                       the allocator after a stop/relaunch)


class ReplicaSet:
    """All replicas behind one service name — the unit of scaling.

    Exposes the same ``request()`` surface a single endpoint used to, but
    routes each request to a replica through the manager's shared router,
    so existing callers transparently load-balance.
    """

    def __init__(self, desc: ServiceDescription, manager: "ServiceManager"):
        self.desc = desc
        self.manager = manager
        # the partition ledger this set's replicas claim resources from
        # (None when the manager was built without allocations: claims and
        # admission control are skipped, the pre-claim behavior)
        self.allocation = manager.allocation_for(desc)
        self._warmup = (desc.warmup if desc.warmup is not None
                        else bool(getattr(manager.policy, "warmup", False)))
        # model groups served by this ONE set (multi-model services): a
        # plain single-model description gets one implicit "default" group,
        # so every internal path is uniformly per-group
        self.model_groups: dict = {}
        if desc.models:
            for mg in desc.models:
                if mg.name in self.model_groups:
                    raise ValueError(
                        f"service {desc.name}: duplicate model group "
                        f"{mg.name!r}")
                if (mg.factory or desc.factory) is None:
                    raise ValueError(
                        f"service {desc.name}: model group {mg.name!r} "
                        f"has no factory (and no service-level default)")
                self.model_groups[mg.name] = mg
        elif desc.factory is None:
            raise ValueError(f"service {desc.name}: factory is required "
                             f"when no model groups are declared")
        else:
            self.model_groups["default"] = ModelGroup(
                name="default", factory=desc.factory,
                replicas=desc.replicas, requirements=desc.requirements)
        self._default_group = next(iter(self.model_groups))
        self.endpoints: list[ServiceEndpoint] = []
        self.instances: list[ServiceInstance] = []
        # endpoints retired by scale-down, kept live for stats() so
        # aggregates survive shrinks (and late drains still count);
        # bounded: older ones are folded into _retired_agg once their
        # drains have long finished (autoscale oscillation must not leak)
        self._retired: list[ServiceEndpoint] = []
        self._retired_agg = {k: 0 for k in _STAT_KEYS}
        self._retired_agg_groups: dict = {}  # group -> same shape, so the
        #                                      per_group stats survive folds
        self._retired_agg_tenants: dict = {}  # tenant -> {requests,
        #                     completed, errors}: folded endpoints'
        #                     tenant_stats, so per_tenant survives folds
        self._tenant_denied: dict = {}  # tenant -> request admissions the
        #                     router's token bucket refused (pre-placement)
        self._scaling = False  # an async autoscale grow/shrink in flight
        self._scale_lock = threading.Lock()  # serializes scale_to callers
        self._gen = 0  # bumped on every membership change so recurring
        #                memberships never resume stale router history
        self._next_idx = 0  # monotonic replica_idx allocator
        self._uid = next(_replica_set_seq)
        self._crash_history: dict[int, dict] = {}  # replica_idx -> backoff
        self._route_count = 0  # drives the periodic residency gossip pull
        self._sync_inflight = False  # at most one async gossip pull at once
        self._gossip_lock = threading.Lock()  # orders gossip pulls vs
        #                     forget_member so an in-flight pull can't
        #                     re-insert a reaped replica's residency
        self._dead_count = 0  # replicas declared dead (operator-visible)
        self._dead_pending: list = []  # (declared_at, endpoint) to fold
        self._admission_denied = 0  # replica spawns denied by the ledger
        self._denied_episode = False  # one SCALE_DENIED event per episode
        #                               (cleared when capacity frees up)
        self._closed = False
        self._successor: Optional["ReplicaSet"] = None  # set on re-launch
        self._lock = threading.RLock()

    # -- client surface -----------------------------------------------------
    @property
    def name(self) -> str:
        return self.desc.name

    @property
    def n_replicas(self) -> int:
        return len(self.endpoints)

    @property
    def n_live(self) -> int:
        """Replicas actually able to serve (or come back): excludes ones
        retired in place, e.g. after exhausting their restart budget.  The
        autoscaler bounds-checks against THIS count, so a dead replica
        doesn't permanently consume configured capacity."""
        with self._lock:
            return sum(1 for ep in self.endpoints if not ep.retired)

    # -- model groups -------------------------------------------------------
    @property
    def multi_model(self) -> bool:
        return bool(self.desc.models)

    def group_names(self) -> list:
        return list(self.model_groups)

    def group_weight(self, group: str) -> float:
        return max(0.0, float(self.model_groups[group].weight))

    def group_slo_ms(self, group: str) -> float:
        """The group's p95 SLO target: its own, else the policy default."""
        slo = self.model_groups[group].slo_p95_ms
        if slo is None:
            slo = getattr(self.manager.policy, "slo_p95_ms", 250.0)
        return float(slo)

    def group_role(self, group: str) -> str:
        return self.model_groups[group].role

    def group_bounds(self, group: str) -> tuple:
        """Per-group autoscale bounds ``(min, max)``: min defaults to 1
        (every model keeps a replica); an explicit ``min_replicas=0``
        allows scale-to-zero; max is None when unbounded."""
        mg = self.model_groups[group]
        gmin = 1 if mg.min_replicas is None else max(0, mg.min_replicas)
        gmax = mg.max_replicas
        if gmax is not None:
            gmax = max(gmin, gmax)
        return gmin, gmax

    def _affinity_alias(self, group: str) -> str:
        """Affinity-namespace alias: a draft-role group shares its target
        group's namespace (``paired_with``, else the first serve-role
        group), so the draft and target legs of one prompt pin to the
        same radix key and residency view — replica indices are unique
        set-wide, so both groups' members coexist in one index and each
        leg still only picks among its own group's candidates."""
        mg = self.model_groups.get(group)
        if mg is None or mg.role != "draft":
            return group
        if mg.paired_with is not None and mg.paired_with in self.model_groups:
            return mg.paired_with
        for g, other in self.model_groups.items():
            if other.role != "draft":
                return g
        return group

    def _decode_pair(self, group: str) -> Optional[str]:
        """The decode-role group a prefill group hands sequences to:
        ``paired_with`` when declared, else the first decode-role group.
        None when the set has no decode pool (the prefill result is then
        served to completion as-is)."""
        mg = self.model_groups.get(group)
        if mg is None or mg.role != "prefill":
            return None
        if mg.paired_with is not None \
                and mg.paired_with in self.model_groups:
            return mg.paired_with
        for g, other in self.model_groups.items():
            if other.role == "decode":
                return g
        return None

    def _handoff(self, src_group: str, fut: _Future, result: dict,
                 env: InferenceRequest):
        """Disaggregated-serving migration: a prefill replica finished a
        sequence's prompt (and produced its first token) — dispatch the
        exported paged-KV payload to the paired decode group and chain
        that leg's future into the one the original caller holds.

        Runs on the prefill replica's instance thread (from ``_resolve``);
        route()/request() are thread-safe.  The decode leg's envelope
        carries the ORIGINAL ``submitted_at`` (and tenant/priority) so
        the decode endpoint's end-to-end window covers the WHOLE request,
        and the importer's residency is gossiped to the router
        immediately — follow-up turns with the same prefix route warm to
        the new holder instead of the (now empty) prefill replica."""
        payload = result.pop("handoff_export", None)
        dec = self._decode_pair(src_group)
        if payload is None or dec is None:
            # no decode pool configured: the prefill leg's result is final
            fut.set_result(result)
            return
        req_payload = {"prompt": list(payload["prompt"])}
        router = self.manager.router
        env2 = InferenceRequest(
            payload=req_payload, model=dec, tenant=env.tenant,
            priority=env.priority, deadline_s=env.deadline_s,
            handoff=payload,
            submitted_at=(env.submitted_at
                          if env.submitted_at is not None
                          else time.perf_counter()),
            affinity=router.signature(req_payload))
        try:
            # affinity accounting stays off: the prefill route already
            # counted this request's outcome (same rule as reroutes)
            ep = self.route(env2, router, account_affinity=False)
        except KeyError as e:
            fut.set_error(RuntimeError(
                f"service {self.name}: decode group {dec!r} has no live "
                f"replicas for handoff ({e})"))
            return
        f2 = ep.request_env(env2)
        if getattr(router, "uses_residency", False):
            # proactive re-home: the exported blocks now live on the
            # importer — tell the router NOW instead of waiting for the
            # next gossip pull
            max_len = getattr(self.manager.policy,
                              "affinity_max_prefix", 128)
            seq = (list(payload.get("prompt") or [])
                   + list(payload.get("output") or []))[:max_len]
            if seq:
                router.note_residency(
                    (self.name, self._uid, self._affinity_alias(dec)),
                    ep.replica_idx, seq)

        def chain(done: _Future):
            try:
                fut.set_result(done.result(0))
            except BaseException as e:  # noqa: BLE001
                fut.set_error(e)

        f2.add_done_callback(chain)

    def handoff_totals(self) -> dict:
        """Set-wide disaggregation counters summed over live replicas
        whose servicers track them: ``exports`` (prefill side),
        ``imports`` and ``recomputes`` (decode side, recompute = the
        reservation-gated import was denied and the sequence re-entered
        via the normal prompt path)."""
        with self._lock:
            pairs = [(ep, inst) for ep, inst
                     in zip(self.endpoints, self.instances)
                     if not ep.retired]
        out = {"exports": 0, "imports": 0, "recomputes": 0}
        for ep, inst in pairs:
            fn = getattr(getattr(inst, "servicer", None),
                         "handoff_stats", None)
            if fn is None:
                continue
            try:
                hs = fn()
            except Exception:
                continue  # crashed mid-read: next tick retries
            if hs:
                for k in out:
                    out[k] += int(hs.get(k, 0))
        return out

    def spec_totals(self) -> tuple:
        """Set-wide speculative-decoding counters ``(proposed, accepted)``
        summed over live replicas whose servicers run a spec-decode
        session — the acceptance signal the ``weighted_capacity``
        autoscaler scales draft-group entitlements by."""
        with self._lock:
            pairs = [(ep, inst) for ep, inst
                     in zip(self.endpoints, self.instances)
                     if not ep.retired]
        proposed = accepted = 0
        for ep, inst in pairs:
            fn = getattr(getattr(inst, "servicer", None), "spec_stats", None)
            if fn is None:
                continue
            try:
                ss = fn()
            except Exception:
                continue  # crashed mid-read: next tick retries
            if ss:
                proposed += int(ss.get("proposed", 0))
                accepted += int(ss.get("accepted", 0))
        return proposed, accepted

    def _group_requirements(self, group: str) -> ResourceRequirements:
        return self.model_groups[group].requirements or self.desc.requirements

    def _group_factory(self, group: str) -> Callable:
        return self.model_groups[group].factory or self.desc.factory

    def _resolve_group(self, model: Optional[str]) -> str:
        """Model tag -> group name; untagged requests go to the FIRST
        declared group, unknown tags on a multi-model set are a routing
        error.  Single-model sets IGNORE the tag: a payload carrying
        {"model": "llama-7b"} routed fine before groups existed (the key
        passed through to the servicer), and must keep doing so."""
        if model is None or not self.multi_model:
            return self._default_group
        if model not in self.model_groups:
            raise KeyError(
                f"service {self.name} serves no model {model!r} "
                f"(has {sorted(self.model_groups)})")
        return model

    def n_live_group(self, group: str) -> int:
        with self._lock:
            return sum(1 for ep in self.endpoints
                       if ep.group == group and not ep.retired)

    def group_counts(self) -> dict:
        """Live replica count per model group (the rebalancer's view)."""
        with self._lock:
            out = {g: 0 for g in self.model_groups}
            for ep in self.endpoints:
                if not ep.retired:
                    out[ep.group] = out.get(ep.group, 0) + 1
        return out

    def initial_group_counts(self) -> dict:
        """Replicas to launch per group: explicit ``ModelGroup.replicas``
        first, the rest split the remaining ``ServiceDescription.replicas``
        (or the policy default) proportionally to weight, >= 1 each."""
        pol_default = max(1, getattr(self.manager.policy, "replicas", 1) or 1)
        total = max(1, self.desc.replicas or pol_default)
        counts = {g: max(1, mg.replicas)
                  for g, mg in self.model_groups.items()
                  if mg.replicas is not None}
        rest = [g for g in self.model_groups if g not in counts]
        if rest:
            budget = max(len(rest), total - sum(counts.values()))
            counts.update(weighted_split(
                budget, {g: self.model_groups[g].weight for g in rest}))
        return {g: counts[g] for g in self.model_groups}  # declaration order

    def request(self, payload, model: Optional[str] = None,
                tenant: Optional[str] = None,
                priority: Optional[str] = None,
                deadline_s: Optional[float] = None, **meta) -> _Future:
        """Submit one request: wraps bare payloads into an
        ``InferenceRequest`` (the normalization adapter — existing
        callers keep working unchanged), admits it through the router's
        per-tenant token bucket, routes it within its model group, and
        enqueues the envelope on the chosen replica.  A denied admission
        resolves the future with ``AdmissionDenied`` immediately — rate
        limiting is backpressure to the CLIENT, never queued load."""
        router = self.manager.router
        env = InferenceRequest.wrap(payload, model=model, tenant=tenant,
                                    priority=priority,
                                    deadline_s=deadline_s, meta=meta)
        cost = default_cost(env.payload)
        if not router.admit(env, cost):
            self.note_tenant_denied(env.tenant)
            fut = _Future()
            fut.set_error(AdmissionDenied(env.tenant))
            return fut
        ep = self.route(env, router, cost=cost)
        return ep.request_env(env)

    def note_tenant_denied(self, tenant: Optional[str]):
        """Count one router-bucket admission denial against ``tenant``
        (surfaced per tenant in ``stats()['per_tenant']``)."""
        with self._lock:
            self._tenant_denied[tenant] = \
                self._tenant_denied.get(tenant, 0) + 1

    def tenant_usage(self) -> dict:
        """Lightweight per-tenant accounting snapshot — same shape as
        ``stats()['per_tenant']`` but without the full stats tick (no
        gossip pull, no dead-replica reap)."""
        with self._lock:
            snaps = [{t: dict(ts) for t, ts in ep.tenant_stats.items()}
                     for ep in self.endpoints + self._retired]
            folded = {t: dict(v)
                      for t, v in self._retired_agg_tenants.items()}
            denied = dict(self._tenant_denied)
        return _merge_tenant_stats(snaps, folded, denied)

    def route(self, env: InferenceRequest, router: Router,
              cost: Optional[float] = None,
              account_affinity: bool = True) -> ServiceEndpoint:
        """Pick the replica endpoint for one envelope.

        ``env.affinity`` (derived from the payload by the router when
        unset) makes sticky routers pin same-prefix requests to one
        replica; the outcome is accounted on the chosen endpoint as
        ``prefix_hits``/``prefix_misses`` unless ``account_affinity`` is
        False (reroutes: the original route already counted this
        request's outcome, counting the second hop too would break
        hits+misses == keyed requests).

        ``env.model`` (see ``InferenceRequest.wrap``) narrows the
        candidates to ONE model group's replicas before any
        affinity/least-loaded logic runs — multi-model sets never route
        a request to a wrong-model replica.  Untagged requests go to the
        first declared group; unknown tags raise ``KeyError`` (a routing
        error, not a silent misroute).

        Only READY replicas are candidates: a freshly spawned replica is
        in ``endpoints`` before its factory finishes, and routing to it
        would queue work nothing admits yet."""
        gsel = self._resolve_group(env.model)
        if cost is None:
            cost = default_cost(env.payload)
        with self._lock:
            pairs = [(ep, inst) for ep, inst
                     in zip(self.endpoints, self.instances)
                     if ep.group == gsel]
            eps = [ep for ep, _ in pairs
                   if ep.ready.is_set() and not ep.retired]
            self._route_count += 1  # under the lock: lost increments
            route_count = self._route_count  # would starve gossip ticks
            if not eps:
                # none ready yet (launch/relaunch window): queue on a
                # replica that is still coming up. A crashed replica
                # counts only when restarts are enabled (its endpoint
                # survives the relaunch and the queue is served then);
                # otherwise the request would sit on a dead queue forever
                restart = getattr(self.manager.policy,
                                  "restart_failed_services", False)
                eps = [ep for ep, inst in pairs
                       if not ep.retired and (inst.error is None or restart)]
            successor = self._successor
        if not eps:
            if successor is not None:  # name was re-launched; follow it
                return successor.route(env, router, cost=cost,
                                       account_affinity=account_affinity)
            raise KeyError(f"service {self.name} has no live replicas"
                           + (f" for model {gsel!r}" if self.multi_model
                              else ""))
        # periodically gossip replica residency summaries to the router so
        # prefix-aware spill sees fresh caches (stats() also syncs); the
        # pull runs on a background thread — snapshotting every engine's
        # index must not add inline latency to the unlucky Nth request
        if getattr(router, "uses_residency", False):
            every = getattr(self.manager.policy, "residency_sync_every", 32)
            if every and every > 0 and route_count % every == 0:
                self._sync_residency_async()
        # key BALANCE state by generation + candidate MEMBERSHIP, not just
        # the name: positions in eps shift as replicas crash/recover, and
        # reusing positional load history across different subsets (or a
        # recurring subset from before a membership change) would charge
        # one replica's history to another.  Sticky state instead keys on
        # the stable (name, uid) affinity group with stable replica_idx
        # member identities, so session assignments survive membership
        # churn and only sessions homed on a departed replica re-home.
        # Both keys also carry the MODEL GROUP, so each model balances and
        # sticks independently — per-group affinity falls out of the keying
        # (two models sharing a token prefix never share a session home).
        members = tuple(ep.replica_idx for ep in eps)
        group = (self.name, self._uid, self._gen, gsel) + members
        info: dict = {}
        # residency-aware routers get the PAIR namespace: a draft-role
        # group's sticky/residency state keys under its target group, so
        # the draft and target legs of one prompt share a radix key (the
        # radix indices hold many members per prefix, and each leg only
        # picks among its own group's candidates).  Hash-map affinity
        # routers keep per-group namespaces — one key -> one member there,
        # and two legs would evict each other's assignment every request.
        gaff = (self._affinity_alias(gsel)
                if getattr(router, "uses_residency", False) else gsel)
        ctx = RouteContext(n_instances=len(eps), group=group,
                           queue_depths=[ep.depth() for ep in eps],
                           members=members,
                           affinity_group=(self.name, self._uid, gaff),
                           info=info)
        idx = router.route(env, ctx, cost=cost)
        eps[idx].bump("cost", cost)
        if account_affinity:
            affinity = info.get("affinity")
            if affinity == "hit":
                eps[idx].bump("prefix_hits")
            elif affinity is not None:  # miss or spill: prefix not reused
                eps[idx].bump("prefix_misses")
        return eps[idx]

    def ready(self) -> bool:
        with self._lock:
            eps = list(self.endpoints)
        return bool(eps) and all(ep.ready.is_set() for ep in eps)

    def stats(self) -> dict:
        """Aggregate request stats plus the per-replica breakdown.  This is
        the stats tick: it also gossips residency summaries to the router
        and folds any dead replica whose grace period expired."""
        self.reap_dead()
        self._sync_residency()
        with self._lock:
            eps = list(self.endpoints)
            insts = list(self.instances)
            per = [dict(ep.stats) for ep in eps]
            retired_pairs = [(ep.group, dict(ep.stats))
                             for ep in self._retired]
            folded = dict(self._retired_agg)
            folded_groups = {g: dict(v)
                             for g, v in self._retired_agg_groups.items()}
            tenant_snaps = [{t: dict(ts)
                             for t, ts in ep.tenant_stats.items()}
                            for ep in eps + self._retired]
            folded_tenants = {t: dict(v)
                              for t, v in self._retired_agg_tenants.items()}
            tenant_denied = dict(self._tenant_denied)
            dead = self._dead_count
            denied = self._admission_denied
        retired = [p for _, p in retired_pairs]
        # live paged-pool gauges per replica (free/total/reserved/shared
        # blocks, CoW copies, evictions): the physical-memory view the
        # per-group aggregation and headroom-aware routing build on.
        # Slot-pool engines (and replicas still starting up) report None.
        block_tel: dict = {}  # replica_idx -> telemetry dict
        spec_tel: dict = {}  # replica_idx -> spec-decode session counters
        handoff_tel: dict = {}  # replica_idx -> disagg handoff counters
        qos_tel: dict = {}  # replica_idx -> WFQ/preemption counters
        for ep, inst in zip(eps, insts):
            if ep.retired:
                continue
            fn = getattr(getattr(inst, "servicer", None),
                         "block_telemetry", None)
            if fn is not None:
                try:
                    tel = fn()
                except Exception:
                    tel = None  # crashed mid-read: next stats tick retries
                if tel:
                    block_tel[ep.replica_idx] = tel
            sfn = getattr(getattr(inst, "servicer", None),
                          "spec_stats", None)
            if sfn is not None:
                try:
                    ss = sfn()
                except Exception:
                    ss = None
                if ss:
                    spec_tel[ep.replica_idx] = ss
            hfn = getattr(getattr(inst, "servicer", None),
                          "handoff_stats", None)
            if hfn is not None:
                try:
                    hs = hfn()
                except Exception:
                    hs = None
                if hs:
                    handoff_tel[ep.replica_idx] = hs
            qfn = getattr(getattr(inst, "servicer", None),
                          "qos_stats", None)
            if qfn is not None:
                try:
                    qs = qfn()
                except Exception:
                    qs = None
                if qs:
                    qos_tel[ep.replica_idx] = qs
        all_samples: list = []
        ep_samples: dict = {}  # replica_idx -> latency snapshot (reused by
        #                        the per-group aggregation below)
        ep_ttft: dict = {}  # replica_idx -> per-phase snapshots, same reuse
        ep_itl: dict = {}
        for ep, p in zip(eps, per):
            samples = ep.latency.samples()
            ep_samples[ep.replica_idx] = samples
            ep_ttft[ep.replica_idx] = ep.ttft.samples()
            ep_itl[ep.replica_idx] = ep.itl.samples()
            p95 = percentile(samples, 0.95)
            p["group"] = ep.group
            p["latency_p95_ms"] = None if p95 is None else p95 * 1e3
            p["latency_histogram"] = ep.latency.histogram(samples=samples)
            tp = percentile(ep_ttft[ep.replica_idx], 0.95)
            ip = percentile(ep_itl[ep.replica_idx], 0.95)
            p["ttft_p95_ms"] = None if tp is None else tp * 1e3
            p["itl_p95_ms"] = None if ip is None else ip * 1e3
            p["block_telemetry"] = block_tel.get(ep.replica_idx)
            if not ep.retired:
                all_samples.extend(samples)
        agg = {k: folded[k] + sum(p[k] for p in per)
               + sum(p[k] for p in retired)
               for k in _STAT_KEYS}
        agg["replicas"] = len(per)
        agg["dead_replicas"] = dead  # lifetime count of replicas that
        #                              exhausted their restart budget (or
        #                              crashed with restarts disabled)
        agg["admission_denied"] = denied  # replica admissions the ledger
        #                                   refused: every denied spawn,
        #                                   plus one per sustained
        #                                   autoscaler denial episode
        p95 = percentile(all_samples, 0.95)
        agg["latency_p95_ms"] = None if p95 is None else p95 * 1e3
        agg["per_replica"] = per
        # per-tenant accounting: live + retired + folded endpoint counters
        # plus router-bucket denials — the QoS bench's conservation check
        # (requests == completed + errors per tenant) reads THIS
        agg["per_tenant"] = _merge_tenant_stats(tenant_snaps,
                                                folded_tenants,
                                                tenant_denied)
        # WFQ/preemption counters summed over the qos-armed replicas (the
        # QoS bench asserts preemptions == resumes off THIS); None when no
        # replica has a scheduler armed
        if qos_tel:
            agg["qos"] = {k: sum(int(q.get(k, 0))
                                 for q in qos_tel.values())
                          for k in ("preempted", "engine_preemptions",
                                    "engine_preempt_resumes")}
            agg["qos"]["reporting_replicas"] = len(qos_tel)
        else:
            agg["qos"] = None
        # per-model-group view: endpoints, request/hit accounting, latency
        # windows, and live ledger claims — the multi-model operator (and
        # the weighted-capacity rebalancer's bench validation) reads THIS
        per_group: dict = {}
        for g in self.model_groups:
            gl = [(ep, p) for ep, p in zip(eps, per) if ep.group == g]
            gr = [p for gp, p in retired_pairs if gp == g]
            gf = folded_groups.get(g, {k: 0 for k in _STAT_KEYS})
            gs = {k: gf[k] + sum(p[k] for _, p in gl) + sum(p[k] for p in gr)
                  for k in _STAT_KEYS}
            live = [ep for ep, _ in gl if not ep.retired]
            gs["replicas"] = len(live)
            gs["endpoints"] = [ep.replica_idx for ep in live]
            gs["weight"] = self.group_weight(g)
            gs["slo_p95_ms"] = self.group_slo_ms(g)
            gsamples: list = []
            gttft: list = []
            gitl: list = []
            for ep in live:
                gsamples.extend(ep_samples.get(ep.replica_idx, ()))
                gttft.extend(ep_ttft.get(ep.replica_idx, ()))
                gitl.extend(ep_itl.get(ep.replica_idx, ()))
            p95g = percentile(gsamples, 0.95)
            gs["latency_p95_ms"] = None if p95g is None else p95g * 1e3
            # per-phase p95s: the disagg autoscaler's per-role signals
            # (TTFT for prefill groups, ITL for decode groups); unified
            # groups report both from the same replicas
            tp = percentile(gttft, 0.95)
            ip = percentile(gitl, 0.95)
            gs["ttft_p95_ms"] = None if tp is None else tp * 1e3
            gs["itl_p95_ms"] = None if ip is None else ip * 1e3
            # disaggregation counters: exports on the prefill side,
            # imports/recomputes on the decode side
            ghand = [handoff_tel[ep.replica_idx] for ep in live
                     if ep.replica_idx in handoff_tel]
            for k in ("exports", "imports", "recomputes"):
                gs["handoff_" + k] = sum(int(h.get(k, 0)) for h in ghand)
            claims = [ep.claim for ep in live if ep.claim is not None]
            gs["cores"] = sum(c.n_cores for c in claims)
            gs["gpus"] = sum(c.n_gpus for c in claims)
            gtel = [block_tel[ep.replica_idx] for ep in live
                    if ep.replica_idx in block_tel]
            if gtel:
                summed = {k: sum(t.get(k, 0) for t in gtel)
                          for k in ("free_blocks", "total_blocks",
                                    "reserved_blocks", "shared_blocks",
                                    "cow_copies", "evicted_residencies")}
                summed["reporting_replicas"] = len(gtel)
                gs["block_telemetry"] = summed
            else:  # no paged replicas in the group (slot pool / starting)
                gs["block_telemetry"] = None
            # speculative-decoding counters: a group's own sessions'
            # proposed/accepted (the target group hosts the sessions —
            # its servicers embed the draft engine), plus the group role
            gs["role"] = self.group_role(g)
            gspec = [spec_tel[ep.replica_idx] for ep in live
                     if ep.replica_idx in spec_tel]
            gs["proposed"] = sum(int(s.get("proposed", 0)) for s in gspec)
            gs["accepted"] = sum(int(s.get("accepted", 0)) for s in gspec)
            gs["acceptance_rate"] = (gs["accepted"] / gs["proposed"]
                                     if gs["proposed"] else None)
            per_group[g] = gs
        agg["per_group"] = per_group
        # a draft-role group runs no sessions itself (the target group's
        # servicers do); surface the SET-WIDE acceptance on it so the
        # signal that scales its entitlement is observable where the
        # operator looks for it
        tot_p = sum(int(s.get("proposed", 0)) for s in spec_tel.values())
        tot_a = sum(int(s.get("accepted", 0)) for s in spec_tel.values())
        for g, gs in per_group.items():
            if gs["role"] == "draft" and not gs["proposed"]:
                gs["acceptance_rate"] = (tot_a / tot_p) if tot_p else None
        return agg

    def latency_p95(self, window_s: Optional[float] = None,
                    started_after: Optional[float] = None,
                    group: Optional[str] = None,
                    phase: Optional[str] = None,
                    tenant_class: Optional[str] = None) -> Optional[float]:
        """p95 end-to-end latency (seconds) across live replicas, the SLO
        autoscaler's signal; optionally windowed, restricted to requests
        *started* after a given perf_counter instant, and/or to one model
        group's replicas (the per-group rebalancer's signal).

        ``phase`` selects a per-phase window instead of end-to-end:
        ``"ttft"`` (time-to-first-token, a prefill-group's SLO) or
        ``"itl"`` (mean inter-token latency per request, a decode-group's
        SLO).  ``tenant_class`` restricts the end-to-end window to one
        QoS priority class (``policy.qos_protected_class`` isolation
        signal); returns None when no replica has samples for it."""
        if phase not in (None, "ttft", "itl"):
            raise ValueError(f"unknown latency phase {phase!r} "
                             f"(expected None, 'ttft' or 'itl')")
        if tenant_class is not None and phase is not None:
            raise ValueError("tenant_class and phase are exclusive "
                             "(per-class windows are end-to-end only)")
        with self._lock:
            eps = [ep for ep in self.endpoints if not ep.retired
                   and (group is None or ep.group == group)]
        samples: list = []
        for ep in eps:
            if tenant_class is not None:
                win = ep.class_latency.get(tenant_class)
                if win is None:
                    continue
            else:
                win = (ep.latency if phase is None
                       else ep.ttft if phase == "ttft" else ep.itl)
            samples.extend(win.samples(window_s, started_after))
        return percentile(samples, 0.95)

    def group_borrow_limit(self, group: str) -> Optional[int]:
        """The group's burst-borrow cap (``ModelGroup.borrow_limit``):
        how far below its weight-anchored entitlement a donor may be
        shrunk; None -> unbounded."""
        bl = self.model_groups[group].borrow_limit
        return None if bl is None else max(0, int(bl))

    def claimed(self, group: Optional[str] = None) -> dict:
        """Live resources this set's replicas hold on the shared ledger,
        optionally for one model group only."""
        with self._lock:
            claims = [ep.claim for ep in self.endpoints
                      if ep.claim is not None
                      and (group is None or ep.group == group)]
        return {"cores": sum(c.n_cores for c in claims),
                "gpus": sum(c.n_gpus for c in claims),
                "replicas": sum(1 for c in claims if not c.released)}

    def claimed_by_group(self) -> dict:
        """Per-model-group slice of ``claimed()`` — what each model costs
        on the shared ledger right now."""
        return {g: self.claimed(group=g) for g in self.model_groups}

    def capacity_headroom(self, group: Optional[str] = None) -> Optional[int]:
        """How many MORE replicas of this shape (the named group's, else
        the service default) the partition can admit right now; None when
        the set has no allocation (unbounded)."""
        if self.allocation is None:
            return None
        req = (self._group_requirements(group) if group is not None
               else self.desc.requirements)
        return self.allocation.fits(req.ranks, req.cores_per_rank,
                                    req.gpus_per_rank)

    def _note_admission_denied(self, where: str = "spawn",
                               once_per_episode: bool = False):
        """Record a denied replica admission: bump the operator counter
        and emit SCALE_DENIED once per denial episode (re-armed when a
        claim succeeds or capacity is released back).  The autoscaler tick
        passes ``once_per_episode=True`` — it re-evaluates every interval,
        and counting each tick would inflate one sustained denial into
        thousands; spawn-level denials always count."""
        with self._lock:
            first = not self._denied_episode
            if once_per_episode and not first:
                return
            self._admission_denied += 1
            self._denied_episode = True
        if first and self.manager.events:
            self.manager.events.emit(self.name, "SCALE_DENIED", "service",
                                     f"partition_full:{where}")

    def _sync_residency_async(self):
        """Run one residency gossip pull off the routing path; coalesces
        with a pull already in flight."""
        with self._lock:
            if self._sync_inflight or self._closed:
                return
            self._sync_inflight = True

        def work():
            try:
                self._sync_residency()
            finally:
                self._sync_inflight = False

        threading.Thread(target=work, name=f"residency-{self.name}",
                         daemon=True).start()

    def _sync_residency(self):
        """Collect per-replica residency summaries from servicers that
        expose them and feed the router's residency index (no-op for
        routers that don't consume gossip and for summary-less
        servicers)."""
        router = self.manager.router
        if not getattr(router, "uses_residency", False):
            return  # nobody consumes the gossip: skip the collection cost
        # gossip at the router's own match fidelity: truncating below the
        # sessions index's max_prefix would silently cap residency matches
        max_len = getattr(self.manager.policy, "affinity_max_prefix", 128)
        with self._gossip_lock:  # a retire's forget_member (see
            # _fold_retired) waits for this pull, so a member reaped AFTER
            # the snapshot below is forgotten AFTER its last update here
            with self._lock:
                pairs = [(ep, inst) for ep, inst
                         in zip(self.endpoints, self.instances)
                         if not ep.retired and ep.ready.is_set()]
            for ep, inst in pairs:
                fn = getattr(inst.servicer, "residency_summary", None)
                if fn is None:
                    continue
                try:
                    try:
                        seqs = fn(max_len=max_len)
                    except TypeError:  # fixed-fidelity servicer summary
                        seqs = fn()
                except Exception:
                    continue  # crashed mid-snapshot: next tick retries
                # draft-role groups gossip into their PAIR namespace (see
                # route()): the shared radix index is what lets a target
                # leg see which replica holds the draft's warm stem
                gkey = (self.name, self._uid, self._affinity_alias(ep.group))
                router.update_residency(gkey, ep.replica_idx, seqs)
                # piggyback physical headroom on the same gossip tick so
                # residency matches are weighed by free-block pressure
                tel_fn = getattr(inst.servicer, "block_telemetry", None)
                if tel_fn is None:
                    continue
                try:
                    tel = tel_fn()
                except Exception:
                    continue
                if tel:
                    router.update_headroom(
                        gkey, ep.replica_idx,
                        tel["free_blocks"], tel["total_blocks"])

    def mean_depth(self, group: Optional[str] = None) -> float:
        with self._lock:
            # a replica declared dead (restart budget exhausted -> retired
            # in place) serves nothing: averaging in its empty queue would
            # dilute the autoscaler's scale-up signal
            eps = [ep for ep in self.endpoints if not ep.retired
                   and (group is None or ep.group == group)]
        if not eps:
            return 0.0
        return sum(ep.depth() for ep in eps) / len(eps)

    # -- lifecycle (driven by the manager) ----------------------------------
    def _spawn(self, group: Optional[str] = None
               ) -> Optional[ServiceInstance]:
        """Create + start one replica of ``group`` (default: the first
        declared model group); caller waits for readiness.
        Returns None if the set was closed (shutdown raced a grow) OR the
        partition allocation denied the replica's resource claim
        (admission control: the set degrades, with a SCALE_DENIED event
        and the ``admission_denied`` stat, instead of overbooking).
        Replica indices are monotonic so identities stay unambiguous
        even after a middle replica is shrunk away."""
        gname = group if group is not None else self._default_group
        with self._lock:
            if self._closed:
                return None
        claim = None
        if self.allocation is not None:
            owner = (f"service:{self.desc.name}/{gname}" if self.multi_model
                     else f"service:{self.desc.name}")
            claim = self.allocation.claim(
                self._group_requirements(gname), owner=owner)
            if claim is None:
                self._note_admission_denied()
                return None
        with self._lock:
            if self._closed:  # closed while we were claiming
                if claim is not None:
                    claim.release()
                return None
            self._denied_episode = False  # capacity exists again
            ep = ServiceEndpoint(self.desc.name, self._next_idx,
                                 group=gname)
            ep.claim = claim
            self._next_idx += 1
            inst = ServiceInstance(self.desc, ep,
                                   on_exit=self.manager._handle_exit,
                                   warmup=self._warmup,
                                   residency_listener=self._on_engine_evict,
                                   factory=self._group_factory(gname))
            if self.group_role(gname) == "prefill":
                inst.on_handoff = (lambda fut, result, env, _g=gname:
                                   self._handoff(_g, fut, result, env))
            self.endpoints.append(ep)
            self.instances.append(inst)
            self._gen += 1
        inst.start()
        return inst

    def _on_engine_evict(self):
        """Residency gossip PUSH: an engine dropped resident KV — refresh
        the router's view now (async, coalesced) instead of leaving a
        staleness window until the next pull tick."""
        if getattr(self.manager.router, "uses_residency", False):
            self._sync_residency_async()

    def _release_claim(self, ep: ServiceEndpoint):
        """Return a retired replica's resources to the ledger (idempotent:
        retire paths may race)."""
        claim = getattr(ep, "claim", None)
        if claim is not None and claim.release():
            with self._lock:
                self._denied_episode = False  # capacity freed: re-arm the
                #                               SCALE_DENIED episode event

    def _reclaim(self):
        """Best-effort re-book claims for live replicas.  Used when a
        blue/green relaunch released this set's claims to admit a
        successor that then FAILED: the old replicas keep serving, so
        their cores must go back on the ledger.  A claim that no longer
        fits (a task grabbed the cores meanwhile) stays unbooked — the
        replica serves under-accounted rather than being killed."""
        if self.allocation is None:
            return
        with self._lock:
            eps = [ep for ep in self.endpoints if not ep.retired]
        for ep in eps:
            claim = getattr(ep, "claim", None)
            if claim is not None and not claim.released:
                continue
            fresh = self.allocation.claim(
                self._group_requirements(ep.group),
                owner=f"service:{self.desc.name}")
            if fresh is None:
                continue
            # a concurrent retire (autoscale shrink, reap, stop) may have
            # removed this endpoint between the snapshot and here; a claim
            # attached now would never be released again.  Membership is
            # mutated under the lock, so re-check before attaching.
            with self._lock:
                attach = ep in self.endpoints and not ep.retired
                if attach:
                    ep.claim = fresh
            if not attach:
                fresh.release()

    def _relaunch(self, dead: ServiceInstance):
        """Restart ONE crashed replica on its existing endpoint (whose queue
        holds the replayed in-flight requests) without disturbing siblings.
        The replica's resource claim survives the relaunch — same replica,
        same booked cores."""
        with self._lock:
            try:
                idx = self.instances.index(dead)
            except ValueError:  # already replaced or scaled away
                return
            inst = ServiceInstance(self.desc, dead.endpoint,
                                   on_exit=self.manager._handle_exit,
                                   warmup=self._warmup,
                                   residency_listener=self._on_engine_evict,
                                   factory=self._group_factory(
                                       dead.endpoint.group))
            if self.group_role(dead.endpoint.group) == "prefill":
                inst.on_handoff = (
                    lambda fut, result, env, _g=dead.endpoint.group:
                    self._handoff(_g, fut, result, env))
            self.instances[idx] = inst
            self._gen += 1  # recovered replica starts with fresh history
        inst.start()
        router = self.manager.router
        if getattr(router, "uses_residency", False):
            # the relaunched servicer starts with an EMPTY cache: drop the
            # pre-crash gossiped residency so prefix-aware picks stop
            # chasing a cache that no longer exists.  Sticky assignments
            # stay — the session must re-warm somewhere, and its home is
            # as good a place as any.
            with self._gossip_lock:
                router.update_residency(
                    (self.name, self._uid, dead.endpoint.group),
                    dead.endpoint.replica_idx, [])
        _await_ready(inst, self.desc.ready_timeout)

    def _restart_backoff(self, inst: ServiceInstance) -> tuple[float, bool]:
        """Exponential-backoff bookkeeping for one crashed replica.

        Returns ``(delay_s, give_up)``: how long to wait before relaunching
        on the replica's existing endpoint, and whether the replica has
        exhausted its ``restart_max_attempts`` budget and should be declared
        dead instead (the set degrades rather than hot-looping a replica
        whose factory/servicer crashes persistently).  A replica that
        SERVED healthily (came ready, then ran) for 4x the backoff ceiling
        before this crash earns a fresh budget — wall time between crashes
        doesn't count, or a factory that burns seconds initializing before
        dying would reset its own budget every cycle.
        """
        pol = self.manager.policy
        base = max(0.0, getattr(pol, "restart_backoff_s", 0.05))
        cap = max(base, getattr(pol, "restart_backoff_max_s", 2.0))
        max_attempts = getattr(pol, "restart_max_attempts", 6)
        now = time.perf_counter()
        with self._lock:
            hist = self._crash_history.setdefault(
                inst.endpoint.replica_idx, {"attempts": 0})
            if hist["attempts"] and inst.ready_at is not None \
                    and now - inst.ready_at > 4 * cap:
                hist["attempts"] = 0  # recovered: crashes are not consecutive
            hist["attempts"] += 1
            if max_attempts and max_attempts > 0 and \
                    hist["attempts"] > max_attempts:
                return 0.0, True
            return min(cap, base * 2 ** (hist["attempts"] - 1)), False

    def scale_to(self, n: int, ready_timeout: Optional[float] = None,
                 group: Optional[str] = None):
        """Grow or shrink to ``n`` replicas; shrink re-routes queued work.
        Multi-model sets scale ONE group at a time (``group=`` required —
        a bare total is ambiguous across models); single-model sets keep
        the original signature."""
        if group is None:
            if self.multi_model:
                raise ValueError(
                    f"service {self.name} is multi-model: scale_to needs "
                    f"group= (one of {sorted(self.model_groups)})")
            group = self._default_group
        elif group not in self.model_groups:
            raise KeyError(f"service {self.name} has no model group "
                           f"{group!r}")
        with self._scale_lock:  # concurrent callers (user + autoscaler)
            self._scale_group_locked(group, n, ready_timeout)

    def scale_groups(self, targets: dict,
                     ready_timeout: Optional[float] = None):
        """Apply per-group LIVE replica targets in ONE scaling action,
        shrinks first by default: a rebalance inside a full partition
        retires the donor group's replica (releasing its claim) before
        the growing group claims — capacity-neutral moves need no free
        headroom.

        WARM HANDOFF: when the partition has enough free headroom to
        admit every grow WITHOUT the donors' released claims, the order
        flips to grows-first — the growing group's replica spawns, warms
        up and joins routing BEFORE the donor drains (a bounded
        claim-overlap window), so a rebalance stops costing tail latency
        on the growing group.  Inside a full partition the order stays
        shrink-first (the grow could not be admitted anyway).

        Targets count live replicas (what ``group_counts()`` and the
        ``weighted_capacity`` scaler see), so a replica declared dead but
        still visible in the set during its grace window does not make a
        replacement grow silently no-op; the membership-level target is
        the live target plus any such corpses (which the shrink path
        retires FIRST, being the least healthy)."""
        for g in targets:
            if g not in self.model_groups:
                raise KeyError(f"service {self.name} has no model group "
                               f"{g!r}")
        with self._scale_lock:
            raw = {g: 0 for g in targets}
            live = {g: 0 for g in targets}
            with self._lock:
                for ep in self.endpoints:
                    if ep.group in raw:
                        raw[ep.group] += 1
                        if not ep.retired:
                            live[ep.group] += 1
            adj = {g: targets[g] + (raw[g] - live[g]) for g in targets}
            grow_amt = {g: adj[g] - raw[g] for g in targets
                        if adj[g] > raw[g]}
            warm = bool(grow_amt)
            total_grow = sum(grow_amt.values())
            for g in grow_amt:
                # conservative: each growing group's shape must fit the
                # WHOLE grow count in free headroom (shapes are uniform
                # in the common case; mixed shapes only over-require)
                hr = self.capacity_headroom(g)
                if hr is not None and hr < total_grow:
                    warm = False
                    break
            if warm:
                order = sorted(targets, key=lambda g: adj[g] < raw[g])
            else:
                order = sorted(targets, key=lambda g: adj[g] >= raw[g])
            for g in order:
                self._scale_group_locked(g, adj[g], ready_timeout)

    def _scale_group_locked(self, gname: str, n: int,
                            ready_timeout: Optional[float]):
        gmin, gmax = self.group_bounds(gname)
        n = max(gmin, n)  # default floor 1; an explicit min_replicas=0
        #                   lets a draft group scale all the way off
        if gmax is not None:
            n = min(n, gmax)
        timeout = (self.desc.ready_timeout if ready_timeout is None
                   else ready_timeout)

        def group_size():
            with self._lock:
                return sum(1 for ep in self.endpoints if ep.group == gname)

        if group_size() < n and not self._closed:
            # spawn all missing replicas first so factories initialize in
            # parallel (same pattern as launch()), then await readiness
            # against a shared deadline
            spawned = [self._spawn(gname) for _ in range(n - group_size())]
            deadline = time.perf_counter() + timeout
            for inst in spawned:
                if inst is None:  # set closed while growing
                    continue
                remaining = max(0.0, deadline - time.perf_counter())
                if _await_ready(inst, remaining):
                    continue
                # unready replica must not stay in the routing set — yank
                # it back out and reroute anything that slipped onto its
                # queue (an autoscale grow degrades to fewer replicas
                # instead of failing)
                with self._lock:
                    popped = inst in self.instances
                    if popped:
                        idx = self.instances.index(inst)
                        self.instances.pop(idx)
                        self.endpoints.pop(idx)
                if popped:
                    inst.endpoint.on_retired = self._reroute
                    inst.endpoint.retired = True
                    inst.stop()
                    self._reroute(inst.endpoint)
                    self._release_claim(inst.endpoint)
                # not popped: the replica crashed and _relaunch already
                # replaced it on the same endpoint — leave that recovery
                # alone (do NOT retire the endpoint out from under it)
        removed: list[tuple[ServiceInstance, ServiceEndpoint]] = []
        with self._lock:
            while True:
                gidx = [i for i, ep in enumerate(self.endpoints)
                        if ep.group == gname]
                if len(gidx) <= n:
                    break
                # retire the least healthy GROUP replica first (crashed,
                # then unready, then highest index) — shrinking must never
                # take a healthy replica while leaving a dead one behind
                idx = min(gidx,
                          key=lambda i: (self.instances[i].error is None,
                                         self.endpoints[i].ready.is_set(),
                                         -i))
                removed.append((self.instances.pop(idx),
                                self.endpoints.pop(idx)))
            if removed:
                self._gen += 1
        for inst, ep in removed:
            # retire BEFORE stopping: a racing route()->request() that
            # already chose this endpoint will see the flag after its put
            # and trigger the reroute itself
            ep.on_retired = self._reroute
            ep.retired = True
            inst.stop(drain=True)  # finish in-flight work, admit no more
        for inst, ep in removed:
            try:
                inst.join(timeout=timeout)
            except RuntimeError:
                pass  # registered by _relaunch but not yet started
            self._reroute(ep)
            # keep the retired endpoint for stats(): a drain that outlives
            # the join timeout still lands its completions somewhere visible
            self._fold_retired([ep])

    def _reroute(self, ep: ServiceEndpoint):
        """Move requests still queued on a retired endpoint to live ones."""
        while True:
            try:
                env, fut = ep.requests.get_nowait()
            except queue.Empty:
                return
            cost = default_cost(env.payload)
            # the request is leaving this endpoint: un-count it so the
            # retired replica's folded stats don't double-count it with
            # the target's own increment (route() re-adds cost there)
            ep.bump("requests", -1, tenant=env.tenant)
            ep.bump("cost", -cost)
            router = self.manager.router
            try:
                # sticky keys still steer the reroute, but the affinity
                # outcome is NOT re-counted: the original route() already
                # accounted this request.  ``env.model`` keeps the
                # reroute inside the SAME model group.
                target = self.route(env, router, cost=cost,
                                    account_affinity=False)
            except KeyError:
                # keep the request accounted where it died so stats()
                # still balances (requests = completed + errors + depth)
                ep.bump("requests", 1, tenant=env.tenant)
                ep.bump("cost", cost)
                ep.bump("errors", tenant=env.tenant)
                fut.set_error(RuntimeError(
                    f"service {self.name} scaled to zero"))
                continue
            target.bump("requests", tenant=env.tenant)
            target.requests.put((env, fut))
            # same post-put re-check as request(): the target may have
            # been retired between route() and the put
            if target.retired and target.on_retired is not None:
                target.on_retired(target)

    def _retire_all(self, drain: bool, sink: Callable, join_timeout: float):
        """Shared teardown: close the set, retire every endpoint (so a
        racing post-put re-check routes to ``sink``), stop + join the
        instances, then drain each queue into ``sink``."""
        with self._lock:
            self._closed = True  # a racing scale_to grow must not respawn
            instances = list(self.instances)
            endpoints = list(self.endpoints)
            self.instances.clear()
            self.endpoints.clear()
        for ep in endpoints:
            ep.on_retired = sink
            ep.retired = True
        for inst in instances:
            inst.stop(drain=drain)
        for inst in instances:
            try:
                inst.join(timeout=join_timeout)
            except RuntimeError:
                pass  # registered by _relaunch but not yet started
        for ep in endpoints:
            sink(ep)
        # preserve served-request history on the old handle, same as the
        # scale-down path does
        self._fold_retired(endpoints)

    def _fold_retired(self, endpoints):
        """Track retired endpoints for stats(), folding the oldest (whose
        drains have long finished) into a flat aggregate so churn stays
        bounded.  Retired replicas also hand their resource claims back to
        the partition ledger here (idempotent; dead replicas already
        released at declare time)."""
        for ep in endpoints:
            self._release_claim(ep)
        with self._lock:
            self._retired.extend(endpoints)
            for ep in endpoints:  # replica_idx is never reused: drop its
                #                   backoff bookkeeping with the endpoint
                self._crash_history.pop(ep.replica_idx, None)
            while len(self._retired) > 8:
                if self._retired[0].depth() > 0:
                    break  # drain still landing completions; keep it live
                old = self._retired.pop(0)
                gagg = self._retired_agg_groups.setdefault(
                    old.group, {k: 0 for k in _STAT_KEYS})
                for k in self._retired_agg:
                    self._retired_agg[k] += old.stats[k]
                    gagg[k] += old.stats[k]
                for t, ts in old.tenant_stats.items():
                    tagg = self._retired_agg_tenants.setdefault(
                        t, {"requests": 0, "completed": 0, "errors": 0})
                    for k in tagg:
                        tagg[k] += ts.get(k, 0)
        with self._gossip_lock:  # after any in-flight gossip pull, so a
            # pull that snapshotted these endpoints can't resurrect them
            for ep in endpoints:
                # the replica is gone for good: sticky sessions homed on
                # it must re-home, and its gossiped residency is stale.
                # Forget under both the plain and (for draft groups) the
                # pair-aliased namespace — sticky state lives under the
                # plain key on hash-affinity routers and under the alias
                # on residency-aware ones, and forgetting is idempotent
                keys = {ep.group, self._affinity_alias(ep.group)}
                for g in keys:
                    self.manager.router.forget_member(
                        (self.name, self._uid, g), ep.replica_idx)

    def _declare_dead(self, inst: ServiceInstance):
        """Mark one replica permanently dead (restart budget exhausted, or
        restarts disabled): fail its queued futures, count it for
        operators, and schedule the grace-period fold that removes it from
        the set with its stats merged into the aggregate."""
        ep = inst.endpoint
        ep.on_retired = self._fail_queue
        ep.retired = True
        self._fail_queue(ep)
        # a permanently dead replica serves nothing: free its claim NOW so
        # a replacement scale-up can be admitted (n_live already excludes
        # it from the autoscaler's configured-capacity bound)
        self._release_claim(ep)
        grace = getattr(self.manager.policy, "dead_replica_grace_s", 2.0)
        with self._lock:
            if self._closed:
                return
            self._dead_count += 1
            if grace is None or grace < 0:
                return  # operator opted to keep the corpse visible forever
            self._dead_pending.append((time.perf_counter() + grace, ep))
        timer = threading.Timer(max(grace, 0.0) + 1e-3, self.reap_dead)
        timer.daemon = True
        timer.start()

    def reap_dead(self):
        """Fold replicas declared dead whose grace period has expired:
        remove them from the routing membership (bumping the generation)
        and merge their stats into the retired aggregate.  Idempotent;
        also called on every stats tick."""
        now = time.perf_counter()
        # membership change: serialize vs scaling — but never BLOCK a
        # stats tick behind a slow in-flight scale; retry shortly instead
        if not self._scale_lock.acquire(blocking=False):
            with self._lock:
                pending = bool(self._dead_pending) and not self._closed
            if pending:
                timer = threading.Timer(0.1, self.reap_dead)
                timer.daemon = True
                timer.start()
            return
        try:
            folded: list[ServiceEndpoint] = []
            with self._lock:
                if self._closed:
                    self._dead_pending.clear()
                    return
                for item in list(self._dead_pending):
                    due, ep = item
                    if now < due:
                        continue
                    self._dead_pending.remove(item)
                    try:
                        i = self.endpoints.index(ep)
                    except ValueError:
                        continue  # already swept by a scale-down
                    self.endpoints.pop(i)
                    self.instances.pop(i)
                    self._gen += 1
                    folded.append(ep)
        finally:
            self._scale_lock.release()
        for ep in folded:
            self._fold_retired([ep])

    def _stop_all(self, join_timeout: float = 2.0):
        # queued futures fail fast instead of hanging to client timeouts
        self._retire_all(False, self._fail_queue, join_timeout)

    def _fail_queue(self, ep: ServiceEndpoint):
        err = RuntimeError(f"service {self.name} stopped")
        while True:
            try:
                env, fut = ep.requests.get_nowait()
            except queue.Empty:
                return
            fut.set_error(err)
            ep.bump("errors", tenant=env.tenant)

    def _drain_into(self, other: "ReplicaSet", join_timeout: float = 5.0):
        """Retire this whole set, moving queued work to ``other`` — used
        when a service name is re-launched so outstanding futures are
        served by the new replicas instead of hanging."""
        with self._lock:
            self._successor = other  # stale handles keep routing
        self._retire_all(True, other._reroute, join_timeout)


class ServiceManager:
    """Launch / discover / monitor / restart / scale replicated services."""

    def __init__(self, policy=None, event_log=None,
                 router: Optional[Router] = None,
                 allocations: Optional[dict] = None):
        self.policy = policy
        self.events = event_log
        self.replica_sets: dict[str, ReplicaSet] = {}
        self.router = router or router_from_policy(policy)
        # named partition Allocations (the middleware's ledger).  When
        # given, every replica spawn claims its ServiceDescription
        # requirements here — admission-controlled scaling; when absent
        # (standalone manager), claims are skipped entirely.
        self.allocations: dict = allocations or {}
        self.autoscaler = (autoscaler_from_policy(policy)
                           if policy is not None else None)
        self._lock = threading.Lock()
        self._autoscale_thread: Optional[threading.Thread] = None
        self._autoscale_stop = threading.Event()

    def allocation_for(self, desc: ServiceDescription):
        """Partition ledger a service's replicas claim from (same
        resolution order as task dispatch): its pinned partition, the
        policy default, else the first allocation.  None when the manager
        has no allocations."""
        if not self.allocations:
            return None
        part = desc.partition or getattr(self.policy, "default_partition",
                                         None)
        if part and part in self.allocations:
            return self.allocations[part]
        return next(iter(self.allocations.values()))

    def claimed(self) -> dict:
        """Per-partition resources currently claimed by service replicas:
        {partition: {"cores", "gpus", "replicas", "models": {...},
        "services": {name: ...}}} — the services half of the shared ledger
        that ``Rhapsody.utilization()`` reports.  Each service entry (and
        the partition-level ``models`` rollup) breaks the claims out per
        model group, so a multi-model set's ledger cost is visible per
        model, not just per service."""
        out: dict = {}
        for name, rs in list(self.replica_sets.items()):
            if rs.allocation is None:
                continue
            c = rs.claimed()
            c["groups"] = rs.claimed_by_group()
            agg = out.setdefault(rs.allocation.name,
                                 {"cores": 0, "gpus": 0, "replicas": 0,
                                  "models": {}, "services": {}})
            agg["cores"] += c["cores"]
            agg["gpus"] += c["gpus"]
            agg["replicas"] += c["replicas"]
            for g, gc in c["groups"].items():
                m = agg["models"].setdefault(
                    g, {"cores": 0, "gpus": 0, "replicas": 0})
                for k in m:
                    m[k] += gc[k]
            agg["services"][name] = c
        return out

    # -- back-compat views --------------------------------------------------
    @property
    def instances(self) -> dict:
        """name -> primary (replica 0) instance, as before replication."""
        out = {}
        for name, rs in list(self.replica_sets.items()):  # snapshot vs
            insts = list(rs.instances)  # concurrent launch/stop
            if insts:
                out[name] = insts[0]
        return out

    @property
    def endpoints(self) -> dict:
        """name -> replica set (request()-compatible with the old endpoint)."""
        return dict(self.replica_sets)

    # -- lifecycle ----------------------------------------------------------
    def launch(self, desc: ServiceDescription) -> ReplicaSet:
        with self._lock:
            predecessor = self.replica_sets.get(desc.name)
        if predecessor is not None:
            # blue/green relaunch of a live name: the predecessor hands its
            # claims back NOW so the successor can be admitted on the same
            # capacity (otherwise a full partition would deny every spawn
            # and a partial one would silently downsize the service).  The
            # old replicas keep serving claim-less only for the bounded
            # window until _drain_into below retires them.
            for ep in list(predecessor.endpoints):
                predecessor._release_claim(ep)
        rs = ReplicaSet(desc, self)
        deadline = time.perf_counter() + desc.ready_timeout
        try:
            # spawn all replicas first so factories initialize in parallel
            # (each is its own thread); THEN wait — the shared deadline is
            # per set, not per serially-started replica.  A spawn denied by
            # the partition ledger comes back None: the launch degrades to
            # the admitted count (event already emitted) as long as at
            # least one replica fits.  Multi-model sets spawn each group's
            # initial count (explicit or weight-proportional, >= 1 each).
            insts = [rs._spawn(g)
                     for g, c in rs.initial_group_counts().items()
                     for _ in range(c)]
            spawned = [inst for inst in insts if inst is not None]
            if not spawned:
                raise RuntimeError(
                    f"service {desc.name}: no replica admitted — "
                    f"partition "
                    f"{rs.allocation.name if rs.allocation else '?'} "
                    f"cannot fit {desc.requirements}")
            for inst in spawned:
                remaining = deadline - time.perf_counter()
                if not _await_ready(inst, max(0.0, remaining)):
                    err = inst.error
                    raise TimeoutError(
                        f"service {desc.name} replica "
                        f"{inst.endpoint.replica_idx} not ready"
                        + (f" (factory failed: {err!r})" if err else ""))
        except BaseException:
            # the set was never registered, so nothing could have routed
            # to it — tear it down; a live old set keeps serving untouched
            # (and gets the claims it lent the failed successor re-booked,
            # or admission control would silently lapse for its cores)
            rs._stop_all()
            if predecessor is not None:
                predecessor._reclaim()
            raise
        # register only once fully ready: during the spawn window the old
        # set (if any) keeps serving, and dispatch never sees a set whose
        # endpoints nothing admits yet
        with self._lock:
            old = self.replica_sets.get(desc.name)
            self.replica_sets[desc.name] = rs
        if old is not None:
            # re-launch of a live name: finish the old set's in-flight
            # work and hand its queued requests to the new replicas
            old._drain_into(rs)
        if self.events:
            self.events.emit(desc.name, "RUNNING", "service", "service_up")
        self._maybe_start_autoscaler()
        return rs

    def get(self, name: str) -> ReplicaSet:
        rs = self.replica_sets.get(name)
        if rs is None:
            raise KeyError(f"unknown service {name}")
        return rs

    def list(self, verbose: bool = False):
        """name -> 'ready' (all replicas up) | 'degraded' (some up, e.g.
        mid scale-up warm-up or crash-restart) | 'down' (none serving).
        With ``verbose=True`` each value is a dict that also carries the
        replica count and the operator-visible ``dead_replicas`` tally
        (replicas that exhausted their restart budget and were — or are
        about to be — folded out of the set)."""
        out = {}
        for n, rs in list(self.replica_sets.items()):  # snapshot: launch()
            # on another thread may insert while we iterate
            if rs.ready():
                status = "ready"
            elif any(ep.ready.is_set() for ep in list(rs.endpoints)):
                status = "degraded"
            else:
                status = "down"
            if verbose:
                out[n] = {"status": status, "replicas": rs.n_replicas,
                          "live": rs.n_live,
                          "dead_replicas": rs._dead_count}
            else:
                out[n] = status
        return out

    def stats(self, name: str) -> dict:
        return self.get(name).stats()

    def stop(self, name: str):
        with self._lock:
            rs = self.replica_sets.pop(name, None)
        if rs is not None:
            rs._stop_all()
        if self.events:
            self.events.emit(name, "DONE", "service", "service_down")

    def stop_all(self):
        self._autoscale_stop.set()
        with self._lock:
            scaler = self._autoscale_thread
            self._autoscale_thread = None  # a later launch() may start a new one
        if scaler is not None:
            scaler.join(timeout=2.0)
        for name in list(self.replica_sets):
            self.stop(name)

    def _handle_exit(self, inst: ServiceInstance):
        if inst.error is None or not inst.alive:
            return  # clean shutdown (stop/scale-down)
        if self.events:
            self.events.emit(inst.desc.name, "FAILED", "service",
                             "service_crash")
        rs = self.replica_sets.get(inst.desc.name)
        if rs is None:
            return
        if self.policy is not None and getattr(
                self.policy, "restart_failed_services", False):
            delay, give_up = rs._restart_backoff(inst)
            if not give_up:
                if delay > 0:
                    # runs on the dying replica's own thread, so the wait
                    # stalls nobody else; siblings keep serving and the
                    # router skips this (not-ready) endpoint meanwhile
                    time.sleep(delay)
                try:
                    rs._relaunch(inst)
                except Exception:
                    pass
                return
            # budget exhausted: a persistently crashing replica must not
            # hot-loop.  Declare it dead (set degrades; route() skips it)
            # and fail its queued futures instead of abandoning them.
            if self.events:
                self.events.emit(inst.desc.name, "FAILED", "service",
                                 "restart_exhausted")
        # no restart is coming: nothing will ever drain this dead
        # replica's queue (including crash-replayed in-flight requests),
        # so fail those futures now instead of letting clients hang to
        # their own timeouts; after dead_replica_grace_s the corpse is
        # folded out of the set with its stats merged into the aggregate
        rs._declare_dead(inst)

    # -- autoscaling --------------------------------------------------------
    def _maybe_start_autoscaler(self):
        pol = self.policy
        if pol is None or not getattr(pol, "autoscale", False):
            return
        with self._lock:
            if self._autoscale_thread is not None:
                return
            self._autoscale_stop.clear()
            self._autoscale_thread = threading.Thread(
                target=self._autoscale_loop, name="service-autoscaler",
                daemon=True)
            self._autoscale_thread.start()

    def _autoscale_loop(self):
        """Pluggable-policy control loop (``repro.core.autoscale``): each
        tick asks the configured ``Autoscaler`` for every set's desired
        size, bounds scale-up by the partition ledger
        (``Allocation.fits``), and applies the change asynchronously.
        Bounded by [autoscale_min_replicas, autoscale_max_replicas] inside
        the policy, and by physical free capacity here."""
        pol = self.policy
        scaler = self.autoscaler
        while not self._autoscale_stop.wait(pol.autoscale_interval_s):
            try:
                self._autoscale_tick(scaler)
            except Exception as e:
                # one bad tick (e.g. a scale racing shutdown) must not
                # kill autoscaling for the rest of the process — but a
                # persistently failing tick must be visible to operators
                if self.events:
                    self.events.emit("autoscaler", "FAILED", "service",
                                     f"tick_error={e!r}")

    def _autoscale_tick(self, scaler):
        scaler.prune(set(self.replica_sets))
        for name, rs in list(self.replica_sets.items()):
            if rs._scaling:  # previous grow/shrink still in flight
                continue
            group_fn = getattr(scaler, "desired_groups", None)
            if group_fn is not None:
                # per-group policy (weighted_capacity): one dict of group
                # targets per tick, applied as a single rebalance action
                targets = group_fn(name, rs)
                if targets:
                    self._scale_groups_async(name, rs, targets)
                continue
            if rs.multi_model:
                continue  # a set-level target is ambiguous across model
                #           groups; only per-group scalers may steer these
            n = rs.n_replicas
            target = scaler.desired(name, rs)
            if target is None:
                continue
            target = max(1, target)
            if target > n:
                # admission control: never target more replicas than the
                # partition can physically claim.  A fully clamped grow is
                # a DENIAL (event + stat on the set), not an exception.
                headroom = rs.capacity_headroom()
                if headroom is not None:
                    target = min(target, n + headroom)
                if target <= n:
                    rs._note_admission_denied("autoscale",
                                              once_per_episode=True)
                    continue
                self._scale_async(name, rs, n, target, "SCALE_UP")
            elif target < n:
                self._scale_async(name, rs, n, target, "SCALE_DOWN")

    def _scale_async(self, name, rs, n_before, n_target, tag):
        """Run one scaling action off the control loop: a slow replica
        factory must not stall sampling for every other service.  The
        in-flight flag is cleared on EVERY exit path (including a scale_to
        error or a thread that never started), so a denied or failed grow
        can never wedge autoscaling for this set."""
        rs._scaling = True

        def work():
            try:
                rs.scale_to(n_target)
                # emit what actually happened: a grow can degrade if the
                # new replica misses its ready timeout or is denied
                # admission by the partition ledger
                if self.events and rs.n_replicas != n_before:
                    self.events.emit(name, tag, "service",
                                     f"replicas={rs.n_replicas}")
            except Exception as e:
                if self.events:
                    self.events.emit(name, "FAILED", "service",
                                     f"scale_error={e!r}")
            finally:
                # stamp the action COMPLETION (not initiation): a slow grow
                # (factory + warm-up) must not let latency served under the
                # old replica count pass the SLO scaler's post-action
                # filter and trigger an oscillating second correction
                if self.autoscaler is not None:
                    self.autoscaler.note_scaled(name)
                rs._scaling = False

        t = threading.Thread(target=work, name=f"scale-{name}", daemon=True)
        try:
            t.start()
        except BaseException:
            rs._scaling = False
            raise

    def _scale_groups_async(self, name, rs, targets: dict):
        """Apply one per-group rebalance off the control loop (same
        in-flight discipline as ``_scale_async``); emits SCALE_REBALANCE
        with the counts that actually materialized — a grow half can still
        degrade on a denied claim or a missed ready timeout."""
        rs._scaling = True
        before = rs.group_counts()

        def work():
            try:
                rs.scale_groups(targets)
                after = rs.group_counts()
                if self.events and after != before:
                    self.events.emit(
                        name, "SCALE_REBALANCE", "service",
                        "groups=" + ",".join(f"{g}:{c}"
                                             for g, c in after.items()))
            except Exception as e:
                if self.events:
                    self.events.emit(name, "FAILED", "service",
                                     f"rebalance_error={e!r}")
            finally:
                if self.autoscaler is not None:
                    self.autoscaler.note_scaled(name)
                rs._scaling = False

        t = threading.Thread(target=work, name=f"rebalance-{name}",
                             daemon=True)
        try:
            t.start()
        except BaseException:
            rs._scaling = False
            raise
