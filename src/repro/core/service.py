"""Services as first-class workflow entities (§III-B).

A ``ServiceDescription`` declares a factory for a *servicer* — anything with
``submit(payload) -> uid`` / ``step() -> [(uid, result)]`` (pumped, e.g. a
continuous-batching engine) or just ``handle(payload) -> result`` (sync RPC).
The ``ServiceManager`` owns the lifecycle: launch, readiness, endpoint
registration/discovery, heartbeat, and restart-on-failure.
"""
from __future__ import annotations

import dataclasses
import itertools
import queue
import threading
import time
from typing import Any, Callable, Optional

from .task import ResourceRequirements


@dataclasses.dataclass
class ServiceDescription:
    name: str
    factory: Callable[[], Any]  # builds the servicer
    requirements: ResourceRequirements = dataclasses.field(
        default_factory=ResourceRequirements)
    ready_timeout: float = 30.0
    partition: Optional[str] = None


class _Future:
    __slots__ = ("_event", "_result", "_error")

    def __init__(self):
        self._event = threading.Event()
        self._result = None
        self._error = None

    def set_result(self, r):
        self._result = r
        self._event.set()

    def set_error(self, e):
        self._error = e
        self._event.set()

    def result(self, timeout: Optional[float] = None):
        if not self._event.wait(timeout):
            raise TimeoutError("service request timed out")
        if self._error is not None:
            raise self._error
        return self._result

    def done(self) -> bool:
        return self._event.is_set()


class ServiceEndpoint:
    """Client-visible handle; requests are async futures."""

    def __init__(self, name: str):
        self.name = name
        self.requests: "queue.Queue" = queue.Queue()
        self.ready = threading.Event()
        self.stats = {"requests": 0, "completed": 0, "errors": 0}

    def request(self, payload, **meta) -> _Future:
        fut = _Future()
        self.stats["requests"] += 1
        self.requests.put((payload, meta, fut))
        return fut


class ServiceInstance(threading.Thread):
    """Drives one servicer: admits endpoint requests, pumps, resolves."""

    def __init__(self, desc: ServiceDescription, endpoint: ServiceEndpoint,
                 on_exit: Optional[Callable] = None):
        super().__init__(name=f"service-{desc.name}", daemon=True)
        self.desc = desc
        self.endpoint = endpoint
        self.alive = True
        self.last_beat = time.perf_counter()
        self.servicer = None
        self._pending: dict = {}
        self._on_exit = on_exit
        self.error: Optional[BaseException] = None

    def run(self):
        try:
            self.servicer = self.desc.factory()
            if hasattr(self.servicer, "setup"):
                self.servicer.setup()
            self.endpoint.ready.set()
            pumped = hasattr(self.servicer, "step")
            while self.alive:
                self.last_beat = time.perf_counter()
                moved = self._admit()
                if pumped:
                    if self._pending:
                        for uid, result in self.servicer.step() or []:
                            self._resolve(uid, result)
                        self._drain_finished()
                    elif not moved:
                        time.sleep(1e-4)
                elif not moved:
                    time.sleep(1e-4)
        except BaseException as e:  # noqa: BLE001
            self.error = e
            self.endpoint.ready.clear()
            # preemption-safe: replay in-flight requests on the relaunched
            # instance (bounded by _replays), else fail their futures
            for uid, (fut, payload, meta) in self._pending.items():
                replays = meta.get("_replays", 0)
                if replays < 2:
                    meta = dict(meta, _replays=replays + 1)
                    self.endpoint.requests.put((payload, meta, fut))
                else:
                    fut.set_error(e)
        finally:
            if hasattr(self.servicer, "teardown") and self.servicer is not None:
                try:
                    self.servicer.teardown()
                except Exception:
                    pass
            if self._on_exit:
                self._on_exit(self)

    # -- internals ----------------------------------------------------------
    def _admit(self) -> bool:
        moved = False
        for _ in range(64):
            try:
                payload, meta, fut = self.endpoint.requests.get_nowait()
            except queue.Empty:
                break
            moved = True
            if hasattr(self.servicer, "submit"):
                kw = {k: v for k, v in meta.items()
                      if not k.startswith("_")}
                try:
                    uid = self.servicer.submit(payload, **kw)
                except BaseException as e:  # noqa: BLE001
                    # crash mid-submit: requeue THIS request for replay on
                    # the relaunched instance before propagating
                    replays = meta.get("_replays", 0)
                    if replays < 2:
                        self.endpoint.requests.put(
                            (payload, dict(meta, _replays=replays + 1), fut))
                    else:
                        fut.set_error(e)
                    raise
                self._pending[uid] = (fut, payload, meta)
            else:  # sync RPC servicer
                try:
                    fut.set_result(self.servicer.handle(payload, **meta))
                    self.endpoint.stats["completed"] += 1
                except BaseException as e:  # noqa: BLE001
                    fut.set_error(e)
                    self.endpoint.stats["errors"] += 1
        return moved

    def _resolve(self, uid, result):
        entry = self._pending.pop(uid, None)
        if entry is not None:
            entry[0].set_result(result)
            self.endpoint.stats["completed"] += 1

    def _drain_finished(self):
        if hasattr(self.servicer, "drain"):
            for uid, result in self.servicer.drain() or []:
                self._resolve(uid, result)

    def stop(self):
        self.alive = False


class ServiceManager:
    """Launch / discover / monitor / restart services."""

    def __init__(self, policy=None, event_log=None):
        self.policy = policy
        self.events = event_log
        self.instances: dict[str, ServiceInstance] = {}
        self.endpoints: dict[str, ServiceEndpoint] = {}
        self._lock = threading.Lock()

    def launch(self, desc: ServiceDescription) -> ServiceEndpoint:
        with self._lock:
            ep = self.endpoints.get(desc.name) or ServiceEndpoint(desc.name)
            self.endpoints[desc.name] = ep
            inst = ServiceInstance(desc, ep, on_exit=self._handle_exit)
            self.instances[desc.name] = inst
            inst.start()
        if not ep.ready.wait(desc.ready_timeout):
            raise TimeoutError(f"service {desc.name} not ready")
        if self.events:
            self.events.emit(desc.name, "RUNNING", "service", "service_up")
        return ep

    def get(self, name: str) -> ServiceEndpoint:
        ep = self.endpoints.get(name)
        if ep is None:
            raise KeyError(f"unknown service {name}")
        return ep

    def list(self):
        return {n: ("ready" if ep.ready.is_set() else "down")
                for n, ep in self.endpoints.items()}

    def stop(self, name: str):
        inst = self.instances.pop(name, None)
        if inst:
            inst.stop()
            inst.join(timeout=2.0)
        if self.events:
            self.events.emit(name, "DONE", "service", "service_down")

    def stop_all(self):
        for name in list(self.instances):
            self.stop(name)

    def _handle_exit(self, inst: ServiceInstance):
        if inst.error is None or not inst.alive:
            return  # clean shutdown
        if self.events:
            self.events.emit(inst.desc.name, "FAILED", "service",
                             "service_crash")
        if self.policy is not None and getattr(
                self.policy, "restart_failed_services", False):
            try:
                self.launch(inst.desc)
            except Exception:
                pass
