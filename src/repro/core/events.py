"""Event tracing + the paper's evaluation metrics.

Events are appended lock-free-ish (list.append is atomic under the GIL) as
``(timestamp, uid, state, task_type, tag)`` tuples.  From a trace we compute:

  * heterogeneity width HW(t) — number of DISTINCT task types running
    concurrently (Exp 2, Fig 4),
  * throughput (tasks/s) and per-task overhead (Exp 1, Fig 3),
  * agent decision rate vs AI-HPC realization rate ARR (Exp 6, Fig 7),
  * utilization timelines.
"""
from __future__ import annotations

import bisect
import time
from collections import defaultdict
from typing import Any, Optional


class EventLog:
    def __init__(self):
        self.events: list = []  # (ts, uid, state, task_type, tag)
        self.t0 = time.perf_counter()

    def emit(self, uid: str, state: str, task_type: str = "", tag: str = ""):
        self.events.append((time.perf_counter(), uid, state, task_type, tag))

    def clear(self):
        self.events.clear()
        self.t0 = time.perf_counter()

    # ------------------------------------------------------------------
    # Derived metrics
    # ------------------------------------------------------------------
    def intervals(self):
        """[(start, end, uid, task_type)] for tasks that ran."""
        start: dict = {}
        out = []
        for ts, uid, state, ttype, _ in self.events:
            if state == "RUNNING":
                start[uid] = (ts, ttype)
            elif state in ("DONE", "FAILED", "CANCELED") and uid in start:
                s, tt = start.pop(uid)
                out.append((s, ts, uid, tt))
        return out

    def heterogeneity_width(self, resolution: float = 0.01):
        """[(t, HW)] sampled timeline of distinct concurrent task types."""
        iv = self.intervals()
        if not iv:
            return []
        points = []
        for s, e, _, tt in iv:
            points.append((s, 1, tt))
            points.append((e, -1, tt))
        points.sort()
        counts: dict = defaultdict(int)
        timeline = []
        for ts, delta, tt in points:
            counts[tt] += delta
            if counts[tt] == 0:
                del counts[tt]
            timeline.append((ts - self.t0, len(counts)))
        # downsample to resolution
        out = []
        last_t = None
        for t, hw in timeline:
            if last_t is None or t - last_t >= resolution:
                out.append((t, hw))
                last_t = t
            else:
                out[-1] = (out[-1][0], max(out[-1][1], hw))
        return out

    def peak_hw(self) -> int:
        tl = self.heterogeneity_width()
        return max((hw for _, hw in tl), default=0)

    def throughput(self, state: str = "DONE") -> float:
        ts = [e[0] for e in self.events if e[2] == state]
        if len(ts) < 2:
            return 0.0
        return len(ts) / max(1e-9, max(ts) - min(ts))

    def windowed_rate(self, state: str, window: float = 1.0,
                      tag: Optional[str] = None):
        """[(t, events/s)] sliding-window rate for a state transition."""
        ts = sorted(e[0] - self.t0 for e in self.events
                    if e[2] == state and (tag is None or e[4] == tag))
        if not ts:
            return []
        out = []
        t = ts[0]
        end = ts[-1]
        while t <= end + window:
            lo = bisect.bisect_left(ts, t - window)
            hi = bisect.bisect_right(ts, t)
            out.append((t, (hi - lo) / window))
            t += window / 4
        return out

    def realization_lag(self, decision_tag: str = "decision",
                        realize_state: str = "RUNNING") -> list:
        """Per-event lag between agent decisions and HPC task starts."""
        decisions = sorted(e[0] for e in self.events if e[4] == decision_tag)
        starts = sorted(e[0] for e in self.events if e[2] == realize_state)
        lags = []
        di = 0
        for s in starts:
            while di < len(decisions) - 1 and decisions[di + 1] <= s:
                di += 1
            if decisions and decisions[di] <= s:
                lags.append(s - decisions[di])
        return lags
