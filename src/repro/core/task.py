"""Task abstraction: the paper's uniform middleware-level representation.

One description type covers the four task categories of §III-B:
  * EXECUTABLE — multi-rank compute payloads (MPI-simulation analogue),
  * FUNCTION   — language-level functions (fine-grained tasks),
  * SERVICE    — long-running services (inference engines, stores),
  * COUPLED    — tightly coupled AI-HPC tasks exchanging data in a loop,
  * INFERENCE  — client-side requests against a SERVICE endpoint.

Tasks carry declarative resource requirements (ranks x cores x gpus) and
dependencies; the middleware owns scheduling/dispatch/lifecycle uniformly.
"""
from __future__ import annotations

import dataclasses
import enum
import itertools
import time
from typing import Any, Callable, Optional


class TaskKind(enum.Enum):
    EXECUTABLE = "executable"
    FUNCTION = "function"
    SERVICE = "service"
    COUPLED = "coupled"
    INFERENCE = "inference"


class TaskState(enum.Enum):
    NEW = "NEW"
    WAITING = "WAITING"  # unresolved dependencies
    READY = "READY"
    SCHEDULED = "SCHEDULED"
    RUNNING = "RUNNING"
    DONE = "DONE"
    FAILED = "FAILED"
    CANCELED = "CANCELED"

    @property
    def terminal(self) -> bool:
        return self in (TaskState.DONE, TaskState.FAILED, TaskState.CANCELED)


_uid_counter = itertools.count()


def _next_uid(prefix: str) -> str:
    return f"{prefix}.{next(_uid_counter):08d}"


@dataclasses.dataclass
class ResourceRequirements:
    ranks: int = 1
    cores_per_rank: int = 1
    gpus_per_rank: int = 0

    @property
    def cores(self) -> int:
        return self.ranks * self.cores_per_rank

    @property
    def gpus(self) -> int:
        return self.ranks * self.gpus_per_rank


@dataclasses.dataclass
class TaskDescription:
    """Declarative task submission record (backend-agnostic)."""

    kind: TaskKind = TaskKind.FUNCTION
    fn: Optional[Callable] = None  # FUNCTION / COUPLED / EXECUTABLE payload
    args: tuple = ()
    kwargs: dict = dataclasses.field(default_factory=dict)
    requirements: ResourceRequirements = dataclasses.field(
        default_factory=ResourceRequirements)
    dependencies: list = dataclasses.field(default_factory=list)  # uids
    task_type: str = "function"  # heterogeneity label (HW metric)
    service: Optional[str] = None  # INFERENCE: target service name
    payload: Any = None  # INFERENCE: request payload
    partition: Optional[str] = None  # pin to a named partition
    uid: Optional[str] = None
    max_retries: int = 0
    metadata: dict = dataclasses.field(default_factory=dict)

    def __post_init__(self):
        if self.uid is None:
            self.uid = _next_uid("task")


@dataclasses.dataclass
class Task:
    """Runtime record tracked by the middleware."""

    desc: TaskDescription
    state: TaskState = TaskState.NEW
    result: Any = None
    error: Optional[BaseException] = None
    unresolved: int = 0
    dependents: list = dataclasses.field(default_factory=list)
    placement: Any = None  # binding produced by the resource mapper
    retries: int = 0
    submitted_at: float = 0.0
    started_at: float = 0.0
    finished_at: float = 0.0

    @property
    def uid(self) -> str:
        return self.desc.uid

    @property
    def duration(self) -> float:
        if self.finished_at and self.started_at:
            return self.finished_at - self.started_at
        return 0.0
