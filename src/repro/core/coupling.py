"""Coupling data plane (Exp 5): in-memory vs filesystem exchange.

``InMemoryStore`` is the SmartRedis/Dragon-channel analogue (per-"node"
dict-backed KV store with PUT/GET latency tracing); ``FileSystemStore`` is
the RAM-disk baseline the paper compares against.  Both move real ndarray
payloads so the benchmark measures genuine serialization/copy costs.
"""
from __future__ import annotations

import os
import pickle
import shutil
import tempfile
import threading
import time
from typing import Any, Optional

import numpy as np


class StoreStats:
    __slots__ = ("put_times", "get_times", "put_bytes", "get_bytes")

    def __init__(self):
        self.put_times: list = []
        self.get_times: list = []
        self.put_bytes = 0
        self.get_bytes = 0

    def summary(self) -> dict:
        def avg(xs):
            return sum(xs) / len(xs) if xs else 0.0

        return {
            "puts": len(self.put_times),
            "gets": len(self.get_times),
            "avg_put_ms": 1e3 * avg(self.put_times),
            "avg_get_ms": 1e3 * avg(self.get_times),
            "put_bytes": self.put_bytes,
            "get_bytes": self.get_bytes,
        }


class DataStore:
    """API shared by both coupling mechanisms."""

    def put(self, key: str, value) -> None:
        raise NotImplementedError

    def get(self, key: str, *, timeout: float = 10.0):
        raise NotImplementedError

    def delete(self, key: str) -> None:
        raise NotImplementedError

    def close(self) -> None:
        pass


class InMemoryStore(DataStore):
    """Node-local shared-memory exchange (SmartRedis analogue)."""

    def __init__(self, node_id: int = 0):
        self.node_id = node_id
        self._data: dict = {}
        self._cond = threading.Condition()
        self.stats = StoreStats()

    def put(self, key, value):
        t0 = time.perf_counter()
        if isinstance(value, np.ndarray):
            payload = value.copy()  # ownership transfer (no aliasing races)
            nbytes = payload.nbytes
        else:
            payload = value
            nbytes = len(pickle.dumps(value, protocol=5))
        with self._cond:
            self._data[key] = payload
            self._cond.notify_all()
        self.stats.put_times.append(time.perf_counter() - t0)
        self.stats.put_bytes += nbytes

    def get(self, key, *, timeout: float = 10.0):
        t0 = time.perf_counter()
        with self._cond:
            ok = self._cond.wait_for(lambda: key in self._data, timeout)
            if not ok:
                raise KeyError(f"timeout waiting for {key}")
            value = self._data[key]
        nbytes = (value.nbytes if isinstance(value, np.ndarray)
                  else len(pickle.dumps(value, protocol=5)))
        self.stats.get_times.append(time.perf_counter() - t0)
        self.stats.get_bytes += nbytes
        return value

    def delete(self, key):
        with self._cond:
            self._data.pop(key, None)


class FileSystemStore(DataStore):
    """File-based exchange (RAM-disk baseline). Uses /dev/shm when present."""

    def __init__(self, node_id: int = 0, root: Optional[str] = None):
        base = root or ("/dev/shm" if os.path.isdir("/dev/shm")
                        else tempfile.gettempdir())
        self.dir = tempfile.mkdtemp(prefix=f"rhapsody_fs_{node_id}_", dir=base)
        self.stats = StoreStats()

    def _path(self, key: str) -> str:
        return os.path.join(self.dir, key.replace("/", "_") + ".npy")

    def put(self, key, value):
        t0 = time.perf_counter()
        path = self._path(key)
        tmp = path + ".tmp"
        if isinstance(value, np.ndarray):
            np.save(tmp + ".npy", value)
            os.replace(tmp + ".npy", path)
            nbytes = value.nbytes
        else:
            with open(tmp, "wb") as f:
                pickle.dump(value, f, protocol=5)
            os.replace(tmp, path)
            nbytes = os.path.getsize(path)
        self.stats.put_times.append(time.perf_counter() - t0)
        self.stats.put_bytes += nbytes

    def get(self, key, *, timeout: float = 10.0):
        t0 = time.perf_counter()
        path = self._path(key)
        deadline = t0 + timeout
        while not os.path.exists(path):
            if time.perf_counter() > deadline:
                raise KeyError(f"timeout waiting for {key}")
            time.sleep(1e-4)
        try:
            value = np.load(path)
        except (ValueError, pickle.UnpicklingError):
            with open(path, "rb") as f:
                value = pickle.load(f)
        self.stats.get_times.append(time.perf_counter() - t0)
        # mirror put's accounting (ndarray: raw bytes; pickle: file
        # size) so pickled payloads no longer read as zero bytes
        self.stats.get_bytes += (value.nbytes
                                 if isinstance(value, np.ndarray)
                                 else os.path.getsize(path))
        return value

    def delete(self, key):
        try:
            os.remove(self._path(key))
        except OSError:
            pass

    def close(self):
        shutil.rmtree(self.dir, ignore_errors=True)


def make_store(kind: str, node_id: int = 0) -> DataStore:
    if kind == "memory":
        return InMemoryStore(node_id)
    if kind == "filesystem":
        return FileSystemStore(node_id)
    raise ValueError(f"unknown store kind {kind!r}")
