"""Agentic AI-HPC control loop (Exp 6, Fig 7).

An ``Agent`` repeatedly (1) issues an inference request to a middleware
service (the decision), (2) realizes the decision as HPC task submissions,
(3) observes results and decides again — with feedback: high realization
backlog moderates the decision rate (the emergent behavior the paper
observes).  Decision events are tagged in the event log so the benchmark can
compute decision rate vs ARR and their lag.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from typing import Any, Callable, Optional

from .middleware import Rhapsody
from .task import TaskDescription, TaskKind, ResourceRequirements


@dataclasses.dataclass
class AgentConfig:
    name: str = "agent"
    service: str = "llm"
    n_decisions: int = 10
    tasks_per_decision: int = 2
    decision_payload: Callable[[int], Any] = lambda i: [1, 2, 3]
    make_task: Optional[Callable[[int, int], TaskDescription]] = None
    backlog_limit: int = 16  # feedback: pause deciding when backlog high
    think_time: float = 0.0


class Agent(threading.Thread):
    """One autonomous agent driving decisions -> HPC realizations."""

    def __init__(self, rhapsody: Rhapsody, cfg: AgentConfig):
        super().__init__(name=f"agent-{cfg.name}", daemon=True)
        self.rh = rhapsody
        self.cfg = cfg
        self.submitted: list = []
        self.decisions = 0
        self.error: Optional[BaseException] = None

    def run(self):
        try:
            ep = self.rh.get_service(self.cfg.service)
            for i in range(self.cfg.n_decisions):
                # feedback loop: wait while too many realized tasks pending
                while self._backlog() > self.cfg.backlog_limit:
                    time.sleep(0.001)
                fut = ep.request(self.cfg.decision_payload(i))
                result = fut.result(timeout=60.0)
                self.decisions += 1
                self.rh.events.emit(f"{self.cfg.name}.d{i}", "DECISION",
                                    "agent", "decision")
                descs = []
                for j in range(self.cfg.tasks_per_decision):
                    if self.cfg.make_task is not None:
                        descs.append(self.cfg.make_task(i, j))
                    else:
                        from repro.substrate.simulation import noop

                        descs.append(TaskDescription(
                            kind=TaskKind.FUNCTION, fn=noop,
                            task_type="agent_tool",
                        ))
                self.submitted.extend(self.rh.submit(descs))
                if self.cfg.think_time:
                    time.sleep(self.cfg.think_time)
        except BaseException as e:  # noqa: BLE001
            self.error = e

    def _backlog(self) -> int:
        n = 0
        for uid in self.submitted[-64:]:
            if not self.rh.tasks[uid].state.terminal:
                n += 1
        return n


def run_agent_population(rhapsody: Rhapsody, configs) -> dict:
    agents = [Agent(rhapsody, c) for c in configs]
    for a in agents:
        a.start()
    for a in agents:
        a.join()
    uids = [u for a in agents for u in a.submitted]
    rhapsody.wait(uids)
    return {
        "agents": len(agents),
        "decisions": sum(a.decisions for a in agents),
        "tasks": len(uids),
        "errors": [repr(a.error) for a in agents if a.error],
    }
