"""Agentic AI-HPC control loop (Exp 6, Fig 7).

An ``Agent`` repeatedly (1) issues an inference request to a middleware
service (the decision), (2) realizes the decision as HPC task submissions,
(3) observes results and decides again — with feedback: high realization
backlog moderates the decision rate (the emergent behavior the paper
observes).  Decision events are tagged in the event log so the benchmark can
compute decision rate vs ARR and their lag.

Agents carry a QoS identity: ``AgentConfig.tenant`` / ``priority`` ride
every decision request as first-class ``InferenceRequest`` fields, so a
population mixing priority classes exercises the multi-tenant admission,
weighted-fair queueing, and preemption path end to end.  Per-decision
latencies are recorded (``Agent.latencies``) for the QoS bench's p95s.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from typing import Any, Callable, Optional

from .middleware import Rhapsody
from .task import TaskDescription, TaskKind, ResourceRequirements


@dataclasses.dataclass
class AgentConfig:
    name: str = "agent"
    service: str = "llm"
    n_decisions: int = 10
    tasks_per_decision: int = 2
    decision_payload: Callable[[int], Any] = lambda i: [1, 2, 3]
    make_task: Optional[Callable[[int, int], TaskDescription]] = None
    backlog_limit: int = 16  # feedback: pause deciding when backlog high
    think_time: float = 0.0
    tenant: Optional[str] = None  # QoS identity on every decision request
    priority: Optional[str] = None  # priority class (None -> "normal")
    pipeline_depth: int = 1  # decisions kept in flight concurrently (>1:
    #                          agent issues its next request before the
    #                          previous resolves — concurrent tool calls)


class Agent(threading.Thread):
    """One autonomous agent driving decisions -> HPC realizations."""

    def __init__(self, rhapsody: Rhapsody, cfg: AgentConfig):
        super().__init__(name=f"agent-{cfg.name}", daemon=True)
        self.rh = rhapsody
        self.cfg = cfg
        self.submitted: list = []
        self.decisions = 0
        self.latencies: list = []  # per-decision end-to-end seconds
        self.errors = 0  # decision requests that failed (e.g. denied)
        self.error: Optional[BaseException] = None
        self._pending: set = set()  # submitted-but-not-terminal task uids

    def run(self):
        try:
            ep = self.rh.get_service(self.cfg.service)
            inflight: list = []  # (decision index, submit time, future)
            for i in range(self.cfg.n_decisions):
                # feedback loop: wait while too many realized tasks pending
                while self._backlog() > self.cfg.backlog_limit:
                    time.sleep(0.001)
                t0 = time.perf_counter()
                fut = ep.request(self.cfg.decision_payload(i),
                                 tenant=self.cfg.tenant,
                                 priority=self.cfg.priority)
                inflight.append((i, t0, fut))
                # pipelined decisions: only block once the window is full
                # (depth 1 is the classic decide -> realize -> decide loop)
                while len(inflight) >= max(1, self.cfg.pipeline_depth):
                    self._realize(*inflight.pop(0))
                if self.cfg.think_time:
                    time.sleep(self.cfg.think_time)
            while inflight:  # drain the tail of the pipeline
                self._realize(*inflight.pop(0))
        except BaseException as e:  # noqa: BLE001
            self.error = e

    def _realize(self, i: int, t0: float, fut):
        """Resolve one decision and realize it as HPC task submissions."""
        try:
            fut.result(timeout=60.0)
        except Exception:
            self.errors += 1
            return  # a denied/failed decision costs the slot
        self.latencies.append(time.perf_counter() - t0)
        self.decisions += 1
        self.rh.events.emit(f"{self.cfg.name}.d{i}", "DECISION",
                            "agent", "decision")
        descs = []
        for j in range(self.cfg.tasks_per_decision):
            if self.cfg.make_task is not None:
                descs.append(self.cfg.make_task(i, j))
            else:
                from repro.substrate.simulation import noop

                descs.append(TaskDescription(
                    kind=TaskKind.FUNCTION, fn=noop,
                    task_type="agent_tool",
                ))
        uids = self.rh.submit(descs)
        self.submitted.extend(uids)
        self._pending.update(uids)

    def _backlog(self) -> int:
        """Outstanding realized tasks.  Tracked incrementally: terminal
        uids leave the pending set for good, so the cost is O(pending),
        not O(history) — and unlike the old last-64 window, a long-lived
        agent can never outrun its own backlog accounting."""
        done = [uid for uid in self._pending
                if self.rh.tasks[uid].state.terminal]
        self._pending.difference_update(done)
        return len(self._pending)


def run_agent_population(rhapsody: Rhapsody, configs) -> dict:
    agents = [Agent(rhapsody, c) for c in configs]
    for a in agents:
        a.start()
    for a in agents:
        a.join()
    uids = [u for a in agents for u in a.submitted]
    rhapsody.wait(uids)
    by_class: dict = {}
    for a in agents:
        by_class.setdefault(a.cfg.priority or "normal",
                            []).extend(a.latencies)
    return {
        "agents": len(agents),
        "decisions": sum(a.decisions for a in agents),
        "tasks": len(uids),
        "decision_errors": sum(a.errors for a in agents),
        "latencies": [lat for a in agents for lat in a.latencies],
        "latencies_by_class": by_class,
        "errors": [repr(a.error) for a in agents if a.error],
    }
