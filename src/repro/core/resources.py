"""Declarative resources, partitions, and the resource mapper (§III-C).

``ResourceDescription`` declares what the middleware may use; ``Allocation``
tracks free cores/gpus per node with O(1) freelists; ``ResourceMapper`` binds
task requirements (ranks x cores x gpus) to concrete node/core/gpu ids.
Allocations can be partitioned into disjoint node sets, each servable by a
different backend (e.g. MPI partition + function-task partition).

The claim API is what makes tasks and *services* share one ledger, the
paper's §III-C premise that every workload category runs inside one job
allocation under uniform resource abstractions: a long-running entity (a
service replica) calls ``Allocation.claim(requirements)`` and holds the
returned ``Claim`` — concrete node/core/gpu ids booked against the same
free lists transient tasks map through — until it retires and releases it.
``free_capacity()`` / ``fits()`` let admission control (the replica-set
autoscaler) bound scale-up decisions by what is physically left instead of
scaling past the allocation.  Packing is first-fit by default; best-fit
(tightest node that still fits, minimizing stranded fragments) is available
per allocation or per call.
"""
from __future__ import annotations

import dataclasses
import threading
from typing import Any, Iterable, Optional, Union


@dataclasses.dataclass(frozen=True)
class ResourceDescription:
    nodes: int = 1
    cores_per_node: int = 8
    gpus_per_node: int = 0

    @property
    def total_cores(self) -> int:
        return self.nodes * self.cores_per_node

    @property
    def total_gpus(self) -> int:
        return self.nodes * self.gpus_per_node


@dataclasses.dataclass
class Placement:
    """Concrete binding: rank -> (node, cores, gpus)."""

    ranks: list  # [(node_id, (core ids...), (gpu ids...)), ...]

    @property
    def nodes(self):
        return sorted({r[0] for r in self.ranks})

    @property
    def n_cores(self) -> int:
        return sum(len(r[1]) for r in self.ranks)

    @property
    def n_gpus(self) -> int:
        return sum(len(r[2]) for r in self.ranks)


class Claim:
    """A held reservation: a ``Placement`` plus the allocation it came from.

    Unlike a task's placement (released by the middleware on completion), a
    claim is owned by a long-running entity — a service replica — and stays
    booked until ``release()``.  Release is idempotent: retire paths can
    race (scale-down vs reap vs shutdown) without double-freeing cores.
    """

    __slots__ = ("placement", "allocation", "owner", "_released", "_lock")

    def __init__(self, placement: Placement, allocation: "Allocation",
                 owner: str = ""):
        self.placement = placement
        self.allocation = allocation
        self.owner = owner
        self._released = False
        self._lock = threading.Lock()

    @property
    def n_cores(self) -> int:
        return 0 if self._released else self.placement.n_cores

    @property
    def n_gpus(self) -> int:
        return 0 if self._released else self.placement.n_gpus

    @property
    def released(self) -> bool:
        return self._released

    def release(self) -> bool:
        """Return the claimed cores/gpus to the allocation; True only for
        the call that actually freed them."""
        with self._lock:
            if self._released:
                return False
            self._released = True
        self.allocation.release(self.placement)
        return True

    def __repr__(self):
        state = "released" if self._released else (
            f"{self.placement.n_cores}c/{self.placement.n_gpus}g"
            f"@nodes{self.placement.nodes}")
        return f"Claim({self.owner or 'anon'}: {state})"


class NodeState:
    __slots__ = ("node_id", "free_cores", "free_gpus")

    def __init__(self, node_id: int, cores: int, gpus: int):
        self.node_id = node_id
        self.free_cores = list(range(cores))
        self.free_gpus = list(range(gpus))


class Allocation:
    """Mutable free-resource view over a ResourceDescription (or subset)."""

    def __init__(self, desc: ResourceDescription, node_ids=None,
                 name: str = "default", strategy: str = "first_fit"):
        self.desc = desc
        self.name = name
        if strategy not in ("first_fit", "best_fit"):
            raise ValueError(f"unknown packing strategy {strategy!r}")
        self.strategy = strategy
        ids = list(node_ids) if node_ids is not None else list(range(desc.nodes))
        self.nodes = {i: NodeState(i, desc.cores_per_node, desc.gpus_per_node)
                      for i in ids}
        self._lock = threading.Lock()
        self.used_cores = 0
        self.used_gpus = 0

    # -- capacity ---------------------------------------------------------
    @property
    def total_cores(self) -> int:
        return len(self.nodes) * self.desc.cores_per_node

    @property
    def total_gpus(self) -> int:
        return len(self.nodes) * self.desc.gpus_per_node

    def utilization(self) -> dict:
        return {
            "cores": self.used_cores / max(1, self.total_cores),
            "gpus": self.used_gpus / max(1, self.total_gpus),
        }

    def free_capacity(self) -> dict:
        """What is left to claim right now: total free cores/gpus plus the
        largest node-local contiguous chunk of each (a rank's cores are
        node-local, so the *shape* of the leftovers bounds admission, not
        just the sum)."""
        with self._lock:
            cores = [len(n.free_cores) for n in self.nodes.values()]
            gpus = [len(n.free_gpus) for n in self.nodes.values()]
        return {
            "cores": sum(cores),
            "gpus": sum(gpus),
            "max_cores_per_node": max(cores, default=0),
            "max_gpus_per_node": max(gpus, default=0),
            "nodes": len(cores),
        }

    def fits(self, ranks: int, cores_per_rank: int,
             gpus_per_rank: int = 0) -> int:
        """How many MORE placements of this shape fit right now, without
        booking anything (the autoscaler's admission bound)."""
        if ranks <= 0:
            return 0
        cores_per_rank = max(0, cores_per_rank)
        gpus_per_rank = max(0, gpus_per_rank)
        if cores_per_rank == 0 and gpus_per_rank == 0:
            return 1 << 30  # zero-footprint shape: admission never binds
        # rank slots of one shape are interchangeable across placements,
        # so the count is just total node-local rank capacity // ranks —
        # O(nodes), not a placement-by-placement simulation (this runs on
        # every autoscaler grow tick)
        slots = 0
        with self._lock:
            for n in self.nodes.values():
                per_node = []
                if cores_per_rank:
                    per_node.append(len(n.free_cores) // cores_per_rank)
                if gpus_per_rank:
                    per_node.append(len(n.free_gpus) // gpus_per_rank)
                slots += min(per_node)
        return slots // ranks

    # -- mapping ------------------------------------------------------------
    def _pick_node(self, cores_per_rank: int, gpus_per_rank: int,
                   strategy: str) -> Optional[NodeState]:
        """Node for one rank.  ``first_fit`` scans in id order; ``best_fit``
        picks the eligible node with the fewest leftover cores (then gpus),
        so small claims pack into already-fragmented nodes and big ranks
        keep finding whole ones."""
        if strategy == "best_fit":
            best = None
            for node in self.nodes.values():
                if (len(node.free_cores) >= cores_per_rank
                        and len(node.free_gpus) >= gpus_per_rank):
                    key = (len(node.free_cores) - cores_per_rank,
                           len(node.free_gpus) - gpus_per_rank)
                    if best is None or key < best[0]:
                        best = (key, node)
            return best[1] if best else None
        for node in self.nodes.values():
            if (len(node.free_cores) >= cores_per_rank
                    and len(node.free_gpus) >= gpus_per_rank):
                return node
        return None

    def try_map(self, ranks: int, cores_per_rank: int,
                gpus_per_rank: int, strategy: Optional[str] = None
                ) -> Optional[Placement]:
        """Rank placement (each rank's cores/gpus are node-local); rolls
        back fully on failure.  ``strategy`` overrides the allocation's
        default packing for this call."""
        strategy = strategy or self.strategy
        # a 0-core (gpu-only) or 0-gpu rank books nothing of that kind:
        # [-0:] would silently grab a node's ENTIRE free list
        cores_per_rank = max(0, cores_per_rank)
        gpus_per_rank = max(0, gpus_per_rank)
        with self._lock:
            bound = []
            for _ in range(ranks):
                node = self._pick_node(cores_per_rank, gpus_per_rank,
                                       strategy)
                if node is None:
                    # roll back partial binding
                    for (nid, cores, gpus) in bound:
                        n = self.nodes[nid]
                        n.free_cores.extend(cores)
                        n.free_gpus.extend(gpus)
                    return None
                cores = tuple(node.free_cores[-cores_per_rank:]) \
                    if cores_per_rank else ()
                if cores_per_rank:
                    del node.free_cores[-cores_per_rank:]
                gpus = tuple(node.free_gpus[-gpus_per_rank:]) \
                    if gpus_per_rank else ()
                if gpus_per_rank:
                    del node.free_gpus[-gpus_per_rank:]
                bound.append((node.node_id, cores, gpus))
            self.used_cores += ranks * cores_per_rank
            self.used_gpus += ranks * gpus_per_rank
            return Placement(bound)

    def claim(self, requirements, owner: str = "",
              strategy: Optional[str] = None) -> Optional[Claim]:
        """Book ``requirements`` (anything with ranks/cores_per_rank/
        gpus_per_rank) as a held ``Claim``; None when the allocation cannot
        fit it — the caller degrades (admission denied), it does not crash.
        """
        placement = self.try_map(requirements.ranks,
                                 requirements.cores_per_rank,
                                 requirements.gpus_per_rank,
                                 strategy=strategy)
        if placement is None:
            return None
        return Claim(placement, self, owner=owner)

    def release(self, placement: Placement):
        with self._lock:
            for (nid, cores, gpus) in placement.ranks:
                node = self.nodes[nid]
                node.free_cores.extend(cores)
                node.free_gpus.extend(gpus)
                self.used_cores -= len(cores)
                self.used_gpus -= len(gpus)

    # -- elasticity -----------------------------------------------------------
    def add_nodes(self, n: int):
        """Grow the allocation (elastic scale-up)."""
        start = max(self.nodes) + 1 if self.nodes else 0
        for i in range(start, start + n):
            self.nodes[i] = NodeState(i, self.desc.cores_per_node,
                                      self.desc.gpus_per_node)

    def drain_node(self, node_id: int) -> bool:
        """Remove a node if idle (elastic scale-down / failure simulation)."""
        node = self.nodes.get(node_id)
        if node is None:
            return False
        if (len(node.free_cores) < self.desc.cores_per_node
                or len(node.free_gpus) < self.desc.gpus_per_node):
            return False
        del self.nodes[node_id]
        return True


def partition(desc: ResourceDescription,
              sizes: Union[dict, Iterable],
              strategy: str = "first_fit") -> dict:
    """Split a resource description into named disjoint node partitions.

    ``sizes`` maps partition name -> either a node COUNT (taken from the
    lowest remaining ids, in declaration order) or an explicit iterable of
    node ids.  One entry may be named ``"*"``: it absorbs every node left
    over after all the named partitions, so a demo config that under-counts
    no longer silently strands capacity.  A sequence of ``(name, spec)``
    pairs is also accepted; duplicate names, overlapping or out-of-range
    explicit ids, and over-subscription all raise instead of silently
    mis-partitioning.
    """
    items = list(sizes.items()) if isinstance(sizes, dict) else list(sizes)
    seen: set = set()
    for name, _ in items:
        if name in seen:
            raise ValueError(f"duplicate partition name {name!r}")
        seen.add(name)
    if sum(1 for name, _ in items if name == "*") > 1:
        raise ValueError('at most one "*" remainder partition allowed')

    remaining = list(range(desc.nodes))
    assigned: dict = {}  # name -> node id list
    # explicit id lists first: counts and "*" draw from what is left
    for name, spec in items:
        if name == "*" or isinstance(spec, int):
            continue
        ids = sorted(int(i) for i in spec)
        for i in ids:
            if i < 0 or i >= desc.nodes:
                raise ValueError(
                    f"partition {name!r} names node {i} outside "
                    f"0..{desc.nodes - 1}")
        if len(set(ids)) != len(ids):
            raise ValueError(f"partition {name!r} repeats node ids")
        taken = set(remaining)
        overlap = [i for i in ids if i not in taken]
        if overlap:
            raise ValueError(
                f"partition {name!r} overlaps nodes {overlap} already "
                f"assigned to another partition")
        ids_set = set(ids)
        remaining = [i for i in remaining if i not in ids_set]
        assigned[name] = ids
    for name, spec in items:
        if name == "*" or not isinstance(spec, int):
            continue
        if spec < 0:
            raise ValueError(f"partition {name!r} has negative size {spec}")
        if spec > len(remaining):
            raise ValueError(
                f"partition {name!r} needs {spec} nodes but only "
                f"{len(remaining)} of {desc.nodes} remain")
        assigned[name] = remaining[:spec]
        remaining = remaining[spec:]
    for name, _ in items:
        if name == "*":
            if not remaining:
                raise ValueError(
                    '"*" remainder partition would be empty: every node '
                    "is already assigned")
            assigned[name] = remaining
            remaining = []
    # preserve declaration order in the returned dict
    return {name: Allocation(desc, assigned[name], name=name,
                             strategy=strategy)
            for name, _ in items}
