"""Declarative resources, partitions, and the resource mapper (§III-C).

``ResourceDescription`` declares what the middleware may use; ``Allocation``
tracks free cores/gpus per node with O(1) freelists; ``ResourceMapper`` binds
task requirements (ranks x cores x gpus) to concrete node/core/gpu ids.
Allocations can be partitioned into disjoint node sets, each servable by a
different backend (e.g. MPI partition + function-task partition).
"""
from __future__ import annotations

import dataclasses
import threading
from typing import Any, Optional


@dataclasses.dataclass(frozen=True)
class ResourceDescription:
    nodes: int = 1
    cores_per_node: int = 8
    gpus_per_node: int = 0

    @property
    def total_cores(self) -> int:
        return self.nodes * self.cores_per_node

    @property
    def total_gpus(self) -> int:
        return self.nodes * self.gpus_per_node


@dataclasses.dataclass
class Placement:
    """Concrete binding: rank -> (node, cores, gpus)."""

    ranks: list  # [(node_id, (core ids...), (gpu ids...)), ...]

    @property
    def nodes(self):
        return sorted({r[0] for r in self.ranks})


class NodeState:
    __slots__ = ("node_id", "free_cores", "free_gpus")

    def __init__(self, node_id: int, cores: int, gpus: int):
        self.node_id = node_id
        self.free_cores = list(range(cores))
        self.free_gpus = list(range(gpus))


class Allocation:
    """Mutable free-resource view over a ResourceDescription (or subset)."""

    def __init__(self, desc: ResourceDescription, node_ids=None,
                 name: str = "default"):
        self.desc = desc
        self.name = name
        ids = list(node_ids) if node_ids is not None else list(range(desc.nodes))
        self.nodes = {i: NodeState(i, desc.cores_per_node, desc.gpus_per_node)
                      for i in ids}
        self._lock = threading.Lock()
        self.used_cores = 0
        self.used_gpus = 0

    # -- capacity ---------------------------------------------------------
    @property
    def total_cores(self) -> int:
        return len(self.nodes) * self.desc.cores_per_node

    @property
    def total_gpus(self) -> int:
        return len(self.nodes) * self.desc.gpus_per_node

    def utilization(self) -> dict:
        return {
            "cores": self.used_cores / max(1, self.total_cores),
            "gpus": self.used_gpus / max(1, self.total_gpus),
        }

    # -- mapping ------------------------------------------------------------
    def try_map(self, ranks: int, cores_per_rank: int,
                gpus_per_rank: int) -> Optional[Placement]:
        """First-fit rank placement; each rank's cores/gpus are node-local."""
        with self._lock:
            bound = []
            touched = []
            for _ in range(ranks):
                placed = False
                for node in self.nodes.values():
                    if (len(node.free_cores) >= cores_per_rank
                            and len(node.free_gpus) >= gpus_per_rank):
                        cores = tuple(node.free_cores[-cores_per_rank:])
                        del node.free_cores[-cores_per_rank:]
                        gpus = tuple(node.free_gpus[-gpus_per_rank:]) \
                            if gpus_per_rank else ()
                        if gpus_per_rank:
                            del node.free_gpus[-gpus_per_rank:]
                        bound.append((node.node_id, cores, gpus))
                        touched.append(node)
                        placed = True
                        break
                if not placed:
                    # roll back partial binding
                    for (nid, cores, gpus) in bound:
                        n = self.nodes[nid]
                        n.free_cores.extend(cores)
                        n.free_gpus.extend(gpus)
                    return None
            self.used_cores += ranks * cores_per_rank
            self.used_gpus += ranks * gpus_per_rank
            return Placement(bound)

    def release(self, placement: Placement):
        with self._lock:
            for (nid, cores, gpus) in placement.ranks:
                node = self.nodes[nid]
                node.free_cores.extend(cores)
                node.free_gpus.extend(gpus)
                self.used_cores -= len(cores)
                self.used_gpus -= len(gpus)

    # -- elasticity -----------------------------------------------------------
    def add_nodes(self, n: int):
        """Grow the allocation (elastic scale-up)."""
        start = max(self.nodes) + 1 if self.nodes else 0
        for i in range(start, start + n):
            self.nodes[i] = NodeState(i, self.desc.cores_per_node,
                                      self.desc.gpus_per_node)

    def drain_node(self, node_id: int) -> bool:
        """Remove a node if idle (elastic scale-down / failure simulation)."""
        node = self.nodes.get(node_id)
        if node is None:
            return False
        if (len(node.free_cores) < self.desc.cores_per_node
                or len(node.free_gpus) < self.desc.gpus_per_node):
            return False
        del self.nodes[node_id]
        return True


def partition(desc: ResourceDescription, sizes: dict) -> dict:
    """Split a resource description into named disjoint node partitions.

    sizes: {"mpi": 12, "functions": 4} (node counts; must sum <= desc.nodes).
    """
    total = sum(sizes.values())
    if total > desc.nodes:
        raise ValueError(f"partitions need {total} nodes > {desc.nodes}")
    out = {}
    cursor = 0
    for name, n in sizes.items():
        out[name] = Allocation(desc, range(cursor, cursor + n), name=name)
        cursor += n
    return out
