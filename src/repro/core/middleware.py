"""RHAPSODY middleware: the central orchestrator (§III-A/B/C).

Interprets task/resource descriptions under an ExecutionPolicy, resolves
dependencies, maps tasks to resources (with intentional logical
oversubscription + backfilling), dispatches to backends, manages service
lifecycles, and tracks every state transition in the event log.

Single dispatcher thread; completions arrive on backend worker threads and
are folded back through ``_complete``.  The hot path (no-op FUNCTION task)
costs a few tens of microseconds — the Exp-1 scaling benchmark measures it.
"""
from __future__ import annotations

import statistics
import threading
import time
from collections import deque
from typing import Any, Iterable, Optional, Sequence, Union

from .events import EventLog
from .policy import ExecutionPolicy
from .resources import Allocation, ResourceDescription, partition
from .request import AdmissionDenied, InferenceRequest
from .router import default_cost, router_from_policy
from .service import ServiceDescription, ServiceManager
from .task import Task, TaskDescription, TaskKind, TaskState


class Rhapsody:
    """The middleware facade (public API layer of Fig. 1)."""

    def __init__(self,
                 resources: Union[ResourceDescription, dict, None] = None,
                 policy: Optional[ExecutionPolicy] = None,
                 backends: Optional[dict] = None,
                 partitions: Optional[dict] = None,
                 n_workers: int = 4):
        from repro.backends.local import PoolBackend  # avoid import cycle

        self.policy = policy or ExecutionPolicy()
        self.events = EventLog()
        resources = resources or ResourceDescription(nodes=1, cores_per_node=8)
        strategy = getattr(self.policy, "placement", "first_fit")
        if partitions:
            self.allocations = partition(resources, partitions,
                                         strategy=strategy)
        else:
            self.allocations = {"default": Allocation(resources,
                                                      strategy=strategy)}
        self.backends: dict = backends or {
            "pool": PoolBackend(n_workers=n_workers)
        }
        for b in self.backends.values():
            b.start(self._backend_complete)
            if hasattr(b, "on_start"):
                b.on_start = self._backend_start
        self.router = router_from_policy(self.policy)
        # services share the task allocations: every replica claims its
        # ServiceDescription.requirements from its partition's ledger
        self.services = ServiceManager(self.policy, self.events,
                                       router=self.router,
                                       allocations=self.allocations)

        self.tasks: dict[str, Task] = {}
        self.ready: deque[Task] = deque()
        self._lock = threading.RLock()
        self._done_cond = threading.Condition(self._lock)
        self._wake = threading.Event()
        self._alive = True
        self._durations: dict[str, list] = {}
        self._inflight = 0
        self._dispatcher = threading.Thread(target=self._dispatch_loop,
                                            name="rhapsody-dispatcher",
                                            daemon=True)
        self._dispatcher.start()

    # ------------------------------------------------------------------
    # Public API: tasks
    # ------------------------------------------------------------------
    def submit(self, descs: Union[TaskDescription, Sequence[TaskDescription]]
               ) -> list:
        """Submit task descriptions; returns their uids."""
        if isinstance(descs, TaskDescription):
            descs = [descs]
        uids = []
        with self._lock:
            new_ready = 0
            for desc in descs:
                task = Task(desc, submitted_at=time.perf_counter())
                self.tasks[desc.uid] = task
                uids.append(desc.uid)
                unresolved = 0
                for dep in desc.dependencies:
                    dep_task = self.tasks.get(dep)
                    if dep_task is None:
                        raise KeyError(f"unknown dependency {dep}")
                    if not dep_task.state.terminal:
                        dep_task.dependents.append(task)
                        unresolved += 1
                task.unresolved = unresolved
                if unresolved:
                    task.state = TaskState.WAITING
                else:
                    task.state = TaskState.READY
                    self.ready.append(task)
                    new_ready += 1
                self._inflight += 1
            if new_ready:
                self._wake.set()
        return uids

    def wait(self, uids: Optional[Iterable[str]] = None,
             timeout: Optional[float] = None) -> bool:
        """Block until the given tasks (or all) are terminal."""
        deadline = None if timeout is None else time.perf_counter() + timeout
        with self._done_cond:
            while True:
                if uids is None:
                    pending = self._inflight
                else:
                    pending = sum(
                        0 if self.tasks[u].state.terminal else 1
                        for u in uids)
                if pending == 0:
                    return True
                remaining = None
                if deadline is not None:
                    remaining = deadline - time.perf_counter()
                    if remaining <= 0:
                        return False
                self._done_cond.wait(timeout=remaining if remaining else 0.25)

    def result(self, uid: str):
        task = self.tasks[uid]
        if task.state == TaskState.FAILED:
            raise task.error
        if not task.state.terminal:
            raise TimeoutError(
                f"task {uid} not finished (state={task.state.value}); "
                f"wait() for it before reading its result")
        return task.result

    def state(self, uid: str) -> TaskState:
        return self.tasks[uid].state

    # ------------------------------------------------------------------
    # Public API: services
    # ------------------------------------------------------------------
    def add_service(self, desc: ServiceDescription):
        return self.services.launch(desc)

    def get_service(self, name: str):
        return self.services.get(name)

    # ------------------------------------------------------------------
    # Public API: lifecycle / introspection
    # ------------------------------------------------------------------
    def utilization(self) -> dict:
        """Per-partition utilization of the SHARED ledger: the core/gpu
        fractions cover tasks and service replicas alike (§III-C), the
        ``service_*`` keys break out what live replica claims hold, and
        ``service_models`` slices those claims per model group — so a
        multi-model set's per-model footprint is first-class on the one
        ledger, next to the tasks it coexists with.  ``tenants`` rolls
        up per-tenant request accounting (requests/completed/errors and
        router-bucket ``admission_denied``) across every service whose
        replicas claim from that partition."""
        claimed = self.services.claimed()
        tenants: dict = {name: {} for name in self.allocations}
        for rs in list(self.services.replica_sets.values()):
            pname = next((n for n, a in self.allocations.items()
                          if a is rs.allocation), None)
            if pname is None:
                continue
            for t, ts in rs.tenant_usage().items():
                tt = tenants[pname].setdefault(t, {})
                for k, v in ts.items():
                    tt[k] = tt.get(k, 0) + v
        out = {}
        for name, alloc in self.allocations.items():
            u = alloc.utilization()
            svc = claimed.get(name, {})
            u["service_cores"] = svc.get("cores", 0)
            u["service_gpus"] = svc.get("gpus", 0)
            u["service_replicas"] = svc.get("replicas", 0)
            u["service_models"] = svc.get("models", {})
            u["tenants"] = tenants.get(name, {})
            u["free"] = alloc.free_capacity()
            out[name] = u
        return out

    def close(self):
        self._alive = False
        self._wake.set()
        self.services.stop_all()
        for b in self.backends.values():
            b.shutdown(wait=False)

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()

    # ------------------------------------------------------------------
    # Dispatch
    # ------------------------------------------------------------------
    def _allocation_for(self, task: Task) -> Allocation:
        part = task.desc.partition or self.policy.default_partition
        if part and part in self.allocations:
            return self.allocations[part]
        return next(iter(self.allocations.values()))

    def _backend_for(self, task: Task):
        part = task.desc.partition
        if part and part in self.backends:
            return self.backends[part]
        return next(iter(self.backends.values()))

    def _dispatch_loop(self):
        while self._alive:
            dispatched = self._dispatch_some()
            if not dispatched:
                self._wake.wait(timeout=0.002)
                self._wake.clear()
                if self.policy.straggler_factor > 0:
                    self._check_stragglers()

    def _dispatch_some(self) -> int:
        n = 0
        with self._lock:
            if not self.ready:
                return 0
            window = (len(self.ready) if not self.policy.backfill
                      else min(len(self.ready), self.policy.backfill_window))
            blocked: list = []
            while self.ready and window > 0:
                task = self.ready.popleft()
                window -= 1
                if task.desc.kind == TaskKind.INFERENCE:
                    # zero-footprint: the request's compute is charged to
                    # the SERVICE replica's claim on the same ledger —
                    # booking a core here would throttle the very
                    # partition the task is merely waiting on (a full
                    # partition of replicas used to starve its own
                    # clients)
                    task.state = TaskState.SCHEDULED
                    self._start_task(task)
                    n += 1
                    continue
                req = task.desc.requirements
                alloc = self._allocation_for(task)
                placement = alloc.try_map(req.ranks, req.cores_per_rank,
                                          req.gpus_per_rank)
                if placement is None:
                    blocked.append(task)
                    if not self.policy.backfill:
                        break
                    continue
                task.placement = placement
                task.state = TaskState.SCHEDULED
                self._start_task(task)
                n += 1
            for t in reversed(blocked):
                self.ready.appendleft(t)
        return n

    def _start_task(self, task: Task):
        desc = task.desc
        if desc.kind == TaskKind.INFERENCE:
            self._dispatch_inference(task)
            return
        backend = self._backend_for(task)
        task.state = TaskState.RUNNING
        task.started_at = time.perf_counter()
        self.events.emit(task.uid, "RUNNING", desc.task_type)
        backend.submit(task)

    def _dispatch_inference(self, task: Task):
        desc = task.desc
        # the task's payload + metadata become one InferenceRequest
        # envelope: ``wrap`` lifts the {"model": ...} tag and any
        # tenant/priority/deadline_s metadata onto first-class fields,
        # so QoS identity rides the task into the serving layer.
        env = InferenceRequest.wrap(desc.payload, meta=dict(desc.metadata))
        cost = default_cost(env.payload)
        try:
            replica_set = self.services.get(desc.service)
            if not self.router.admit(env, cost=cost):
                # rate limiting is backpressure to the CLIENT: the task
                # fails immediately instead of queueing over-quota load
                replica_set.note_tenant_denied(env.tenant)
                raise AdmissionDenied(env.tenant)
            # the load-balancing spine: every INFERENCE task picks its
            # replica through the policy router (token-cost + queue-depth
            # aware), not a fixed endpoint; under prefix_affinity routing
            # the payload's prompt-prefix signature makes same-session
            # requests stick to their cache-warm replica.  An envelope
            # with ``model`` set routes only among that model group's
            # replicas (multi-model services); an unknown tag fails the
            # task like an unknown service would.
            endpoint = replica_set.route(env, self.router, cost=cost)
        except (KeyError, AdmissionDenied) as e:
            self._complete(task, None, e)
            return
        task.state = TaskState.RUNNING
        task.started_at = time.perf_counter()
        self.events.emit(task.uid, "RUNNING", desc.task_type,
                         f"replica={endpoint.replica_idx}")
        fut = endpoint.request_env(env)
        timeout = self.policy.inference_timeout_s

        def waiter():
            try:
                self._complete(task, fut.result(timeout=timeout), None)
            except BaseException as e:  # noqa: BLE001
                self._complete(task, None, e)

        threading.Thread(target=waiter, daemon=True).start()

    # ------------------------------------------------------------------
    # Completion path
    # ------------------------------------------------------------------
    def _backend_start(self, task: Task):
        pass  # RUNNING already emitted at submit (cheap path)

    def _backend_complete(self, task: Task, result, error):
        self._complete(task, result, error)

    def _complete(self, task: Task, result, error):
        with self._lock:
            if task.state.terminal:  # duplicate (straggler twin) finished
                return
            task.finished_at = time.perf_counter()
            limit = task.desc.max_retries or self.policy.max_retries
            if error is not None and task.retries < limit:
                task.retries += 1
                self.events.emit(task.uid, "RETRY", task.desc.task_type)
                if task.placement is not None:
                    self._allocation_for(task).release(task.placement)
                    task.placement = None
                task.state = TaskState.READY
                self.ready.append(task)
                self._wake.set()
                return
            self._finalize(task, result, error)
            # first-completion-wins: a straggler twin resolves its original
            orig_uid = task.desc.metadata.get("_resolve")
            if orig_uid:
                orig = self.tasks.get(orig_uid)
                if orig is not None and not orig.state.terminal:
                    orig.finished_at = time.perf_counter()
                    self._finalize(orig, result, error)
            self._done_cond.notify_all()

    def _finalize(self, task: Task, result, error):
        """Terminal-state bookkeeping; caller holds the lock."""
        task.result = result
        task.error = error
        task.state = (TaskState.FAILED if error is not None
                      else TaskState.DONE)
        self.events.emit(task.uid, task.state.value, task.desc.task_type)
        if task.placement is not None:
            self._allocation_for(task).release(task.placement)
            task.placement = None
        self._durations.setdefault(task.desc.task_type, []).append(
            task.duration)
        self._inflight -= 1
        woke = False
        for dep in task.dependents:
            dep.unresolved -= 1
            if dep.unresolved == 0 and dep.state == TaskState.WAITING:
                dep.state = TaskState.READY
                self.ready.append(dep)
                woke = True
        if woke or self.ready:
            self._wake.set()

    # ------------------------------------------------------------------
    # Straggler mitigation (policy.straggler_factor > 0)
    # ------------------------------------------------------------------
    def _check_stragglers(self):
        now = time.perf_counter()
        with self._lock:
            # snapshot: issuing a twin inserts into self.tasks mid-scan
            for task in list(self.tasks.values()):
                if task.state != TaskState.RUNNING:
                    continue
                if task.desc.metadata.get("_straggler_twin"):
                    continue
                hist = self._durations.get(task.desc.task_type, [])
                if len(hist) < self.policy.straggler_min_samples:
                    continue
                med = statistics.median(hist)
                if now - task.started_at < self.policy.straggler_factor * med:
                    continue
                if task.desc.metadata.get("_dup_issued"):
                    continue
                task.desc.metadata["_dup_issued"] = True
                # full copy of the description (minus dependencies, which
                # the running original already resolved): dropping fields
                # like partition/service/payload would let a twin run on
                # the wrong partition or lose its inference target
                clone = TaskDescription(
                    kind=task.desc.kind, fn=task.desc.fn,
                    args=task.desc.args, kwargs=task.desc.kwargs,
                    requirements=task.desc.requirements,
                    task_type=task.desc.task_type,
                    service=task.desc.service,
                    payload=task.desc.payload,
                    partition=task.desc.partition,
                    max_retries=task.desc.max_retries,
                    metadata={**task.desc.metadata,
                              "_straggler_twin": True,
                              "_original": task.uid},
                )
                clone.metadata["_resolve"] = task.uid
                twin = Task(clone, submitted_at=now)
                twin.state = TaskState.READY
                self.tasks[clone.uid] = twin
                self._inflight += 1
                self.events.emit(clone.uid, "DUPLICATED",
                                 task.desc.task_type)
                self.ready.append(twin)
                self._wake.set()
