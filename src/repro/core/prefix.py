"""Unified radix-tree prefix residency (SGLang RadixAttention insight).

One data structure — ``RadixIndex``, a compressed token-sequence trie with
LRU-bounded entries — backs every layer that reasons about "who already
holds this prefix":

  * **Engine** (``repro.serving.engine``): the per-engine index maps each
    freed slot's resident token sequence (value = slot id).  Admission asks
    ``match_lengths(prompt)`` once — O(len(prompt)) — and resumes the slot
    with the deepest usable common prefix, including *partial* matches
    where a branching turn shares a stem but diverges mid-sequence (the
    slot rewinds to the divergence point instead of missing entirely).
    ``summary()`` exports the resident sequences as the replica's
    residency summary.

  * **ReplicaSet** (``repro.core.service``): on its stats tick it collects
    each replica's residency summary from the servicer and feeds it to the
    shared router via ``Router.update_residency`` — the cross-replica
    prefix-map gossip that keeps routing decisions grounded in what each
    replica's KV cache actually holds.

  * **Router** (``repro.core.router.RadixAffinityRouter``): two indices per
    replica set — session assignments (prompt prefix -> replica id,
    replacing the hashed-LRU sticky map) and gossiped residency — answer
    longest-prefix-match routing.  Sessions whose turns diverge after a
    fixed hash window still route to their warmest replica, and an
    overloaded sticky replica sheds to the replica holding the
    *second-longest* matching prefix rather than blindly to least-loaded.

Data flow: engine residency -> replica-set stats tick -> router residency
index -> routing decision -> engine partial resume.  Values are opaque
identifiers (slot ids in the engine, stable replica ids in the router)
that survive replica-set membership churn, so only sessions homed on a
dead replica re-home after an autoscale or crash.

The structure is a classic compressed radix tree: edges carry token-tuple
labels, terminal nodes carry (value -> entry) sets, and every node keeps a
refcount of the values present in its subtree so longest-match queries can
report the best common-prefix length *per value* in a single O(len(seq))
descent.  Entries are LRU-tracked globally; inserting a sequence that
extends an existing same-value entry on its path replaces (compacts) the
shorter one.
"""
from __future__ import annotations

import itertools
import threading
from collections import OrderedDict
from typing import Any, Iterable, Optional


def _lcp_len(label: tuple, seq: tuple, offset: int) -> int:
    """Length of the common prefix of ``label`` and ``seq[offset:]``."""
    n = min(len(label), len(seq) - offset)
    k = 0
    while k < n and label[k] == seq[offset + k]:
        k += 1
    return k


class _Node:
    __slots__ = ("edges", "entries", "vals")

    def __init__(self):
        self.edges: dict = {}  # first token -> (label tuple, child _Node)
        self.entries: dict = {}  # value -> None (ordered set of terminals)
        self.vals: dict = {}  # value -> entry refcount within this subtree


class RadixIndex:
    """LRU-bounded radix tree over token sequences with per-value queries.

    Thread-safe: every public operation takes an internal lock, so a
    replica set may snapshot an engine's residency summary while the
    engine thread keeps inserting (and a shared router may serve picks
    while residency gossip lands).
    """

    def __init__(self, capacity: int = 0):
        self.capacity = capacity  # max entries; 0 -> unbounded
        self.root = _Node()
        self._lock = threading.Lock()
        # (value, id(terminal node)) -> (seq, value, node); insertion order
        # is recency order (refreshed on re-insert)
        self._entries: "OrderedDict[tuple, tuple]" = OrderedDict()
        self._by_value: dict = {}  # value -> set of entry keys
        self._touch: dict = {}  # value -> last-insert tick (recency)
        self._clock = itertools.count()

    # -- mutation -----------------------------------------------------------
    def insert(self, seq: Iterable, value: Any) -> bool:
        """Associate ``value`` with token sequence ``seq``.

        A same-value entry that is a strict prefix of ``seq`` is removed
        (compaction: the longer sequence subsumes it — the growing-session
        pattern).  Returns False for empty sequences.
        """
        seq = tuple(seq)
        if not seq:
            return False
        with self._lock:
            node, depth = self.root, 0
            path = [self.root]
            subsumed = []
            while depth < len(seq):
                if value in node.entries:
                    subsumed.append((value, id(node)))
                edge = node.edges.get(seq[depth])
                if edge is None:
                    child = _Node()
                    node.edges[seq[depth]] = (seq[depth:], child)
                    node, depth = child, len(seq)
                    path.append(node)
                    break
                label, child = edge
                k = _lcp_len(label, seq, depth)
                if k == len(label):
                    node, depth = child, depth + k
                    path.append(node)
                    continue
                # split the edge at k
                mid = _Node()
                mid.vals = dict(child.vals)
                mid.edges[label[k]] = (label[k:], child)
                node.edges[seq[depth]] = (label[:k], mid)
                depth += k
                path.append(mid)
                if depth == len(seq):
                    node = mid
                    break
                leaf = _Node()
                mid.edges[seq[depth]] = (seq[depth:], leaf)
                node, depth = leaf, len(seq)
                path.append(node)
                break
            key = (value, id(node))
            if value in node.entries:
                self._entries.move_to_end(key)
            else:
                node.entries[value] = None
                for nd in path:
                    nd.vals[value] = nd.vals.get(value, 0) + 1
                self._entries[key] = (seq, value, node)
                self._by_value.setdefault(value, set()).add(key)
            self._touch[value] = next(self._clock)
            for old in subsumed:
                if old != key and old in self._entries:
                    self._remove_entry(old)
            while self.capacity and len(self._entries) > self.capacity:
                oldest = next(iter(self._entries))
                if oldest == key:  # never evict what was just inserted
                    break
                self._remove_entry(oldest)
            return True

    def remove(self, seq: Iterable, value: Any) -> bool:
        """Remove the exact (seq, value) entry; True if it existed."""
        seq = tuple(seq)
        with self._lock:
            for key in self._by_value.get(value, set()):
                if self._entries[key][0] == seq:
                    self._remove_entry(key)
                    return True
        return False

    def remove_value(self, value: Any) -> int:
        """Drop every entry carrying ``value`` (slot recycled / replica
        left the set).  Returns how many entries were removed."""
        with self._lock:
            keys = list(self._by_value.get(value, ()))
            for key in keys:
                self._remove_entry(key)
            self._touch.pop(value, None)
            return len(keys)

    def evict_lru(self) -> Optional[tuple]:
        """Remove the least-recently-inserted entry; returns (seq, value)."""
        with self._lock:
            if not self._entries:
                return None
            key = next(iter(self._entries))
            seq, value, _ = self._entries[key]
            self._remove_entry(key)
            return seq, value

    def clear(self):
        with self._lock:
            self.root = _Node()
            self._entries.clear()
            self._by_value.clear()
            self._touch.clear()

    # -- queries ------------------------------------------------------------
    def longest_match(self, seq: Iterable) -> tuple:
        """(length, value) of the longest common prefix between ``seq`` and
        any stored sequence; (0, None) when nothing shares a first token.
        Ties prefer the most recently inserted value."""
        seq = tuple(seq)
        with self._lock:
            node, depth = self.root, 0
            while depth < len(seq):
                edge = node.edges.get(seq[depth])
                if edge is None:
                    break
                label, child = edge
                k = _lcp_len(label, seq, depth)
                node, depth = child, depth + k
                if k < len(label):
                    break
            if depth == 0 or not node.vals:
                return 0, None
            best = max(node.vals, key=lambda v: self._touch.get(v, -1))
            return depth, best

    def match_lengths(self, seq: Iterable) -> dict:
        """Best common-prefix length per stored value, in one descent:
        ``{value: lcp}`` covering every value in the index (0 when the
        value shares nothing with ``seq``)."""
        seq = tuple(seq)
        out: dict = {}
        with self._lock:
            for v in self.root.vals:
                out[v] = 0
            node, depth = self.root, 0
            while depth < len(seq):
                edge = node.edges.get(seq[depth])
                if edge is None:
                    break
                label, child = edge
                k = _lcp_len(label, seq, depth)
                d = depth + k
                for v in child.vals:
                    out[v] = d
                if k < len(label):
                    break
                node, depth = child, d
        return out

    def summary(self, max_entries: int = 64, max_len: int = 128) -> list:
        """Compact residency summary: the most recently inserted sequences
        (newest first), each truncated to ``max_len`` tokens — the payload
        a replica gossips to the router."""
        with self._lock:
            out = []
            for seq, _value, _node in reversed(self._entries.values()):
                out.append(list(seq[:max_len]))
                if len(out) >= max_entries:
                    break
            return out

    def values(self) -> set:
        with self._lock:
            return set(self._by_value)

    def __len__(self) -> int:
        return len(self._entries)

    def __contains__(self, value) -> bool:
        return value in self._by_value

    # -- internals ----------------------------------------------------------
    def _remove_entry(self, key):
        """Remove one entry and restore the tree invariants (refcounts,
        empty-node pruning, single-edge merge).  Caller holds the lock."""
        seq, value, node = self._entries.pop(key)
        keys = self._by_value.get(value)
        if keys is not None:
            keys.discard(key)
            if not keys:
                del self._by_value[value]
        # re-walk the exact path (splits preserve token boundaries)
        path = [(self.root, 0)]
        cur, depth = self.root, 0
        while depth < len(seq):
            label, child = cur.edges[seq[depth]]
            depth += len(label)
            cur = child
            path.append((cur, depth))
        del node.entries[value]
        for nd, _ in path:
            c = nd.vals.get(value, 0) - 1
            if c <= 0:
                nd.vals.pop(value, None)
            else:
                nd.vals[value] = c
        # prune empties / merge pass-through nodes bottom-up
        for i in range(len(path) - 1, 0, -1):
            nd, _ = path[i]
            parent, pdepth = path[i - 1]
            if nd.entries:
                break
            tok = seq[pdepth]
            plabel = parent.edges[tok][0]
            if not nd.edges:
                del parent.edges[tok]
                continue  # parent may now be prunable too
            if len(nd.edges) == 1:
                (clabel, gchild), = nd.edges.values()
                parent.edges[tok] = (plabel + clabel, gchild)
            break
