"""RHAPSODY middleware core: tasks, services, resources, policies, coupling."""
from .middleware import Rhapsody
from .policy import ExecutionPolicy
from .resources import Allocation, Placement, ResourceDescription, partition
from .service import ReplicaSet, ServiceDescription, ServiceEndpoint
from .task import (ResourceRequirements, Task, TaskDescription, TaskKind,
                   TaskState)

__all__ = [
    "Rhapsody", "ExecutionPolicy", "ResourceDescription", "Allocation",
    "Placement", "partition", "ReplicaSet", "ServiceDescription",
    "ServiceEndpoint",
    "TaskDescription", "TaskKind", "TaskState", "Task",
    "ResourceRequirements",
]
