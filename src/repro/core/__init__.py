"""RHAPSODY middleware core: tasks, services, resources, policies, coupling."""
from .autoscale import (AUTOSCALERS, Autoscaler, LatencySLOAutoscaler,
                        LatencyWindow, QueueDepthAutoscaler,
                        WeightedCapacityAutoscaler, autoscaler_from_policy)
from .middleware import Rhapsody
from .policy import ExecutionPolicy
from .request import (AdmissionDenied, InferenceRequest, RouteContext,
                      DEFAULT_CLASS_WEIGHTS)
from .resources import (Allocation, Claim, Placement, ResourceDescription,
                        partition)
from .service import (ModelGroup, ReplicaSet, ServiceDescription,
                      ServiceEndpoint, weighted_split)
from .task import (ResourceRequirements, Task, TaskDescription, TaskKind,
                   TaskState)

__all__ = [
    "Rhapsody", "ExecutionPolicy", "ResourceDescription", "Allocation",
    "Claim", "Placement", "partition", "ReplicaSet", "ServiceDescription",
    "ServiceEndpoint", "ModelGroup", "weighted_split",
    "AUTOSCALERS", "Autoscaler", "QueueDepthAutoscaler",
    "LatencySLOAutoscaler", "WeightedCapacityAutoscaler", "LatencyWindow",
    "autoscaler_from_policy",
    "TaskDescription", "TaskKind", "TaskState", "Task",
    "ResourceRequirements",
    "InferenceRequest", "RouteContext", "AdmissionDenied",
    "DEFAULT_CLASS_WEIGHTS",
]
