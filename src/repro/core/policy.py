"""Execution policies: high-level constraints guiding task->resource mapping."""
from __future__ import annotations

import dataclasses
from typing import Optional


@dataclasses.dataclass
class ExecutionPolicy:
    # scheduling
    oversubscription: float = 4.0  # ready tasks kept per free core (backfill)
    backfill: bool = True  # smaller tasks may jump blocked head-of-line tasks
    backfill_window: int = 64  # how deep into the ready queue backfill looks
    # placement
    default_partition: Optional[str] = None
    colocate_coupled: bool = True  # coupled pairs pinned to the same node
    # routing (inference)
    routing: str = "balanced"  # random | round_robin | balanced
    # fault tolerance
    max_retries: int = 1
    straggler_factor: float = 0.0  # >0: duplicate tasks slower than
    #                                factor x median runtime (first wins)
    straggler_min_samples: int = 10
    # services
    service_ready_timeout: float = 30.0
    service_heartbeat: float = 5.0
    restart_failed_services: bool = True
