"""Execution policies: high-level constraints guiding task->resource mapping."""
from __future__ import annotations

import dataclasses
from typing import Optional


@dataclasses.dataclass
class ExecutionPolicy:
    # scheduling
    oversubscription: float = 4.0  # ready tasks kept per free core (backfill)
    backfill: bool = True  # smaller tasks may jump blocked head-of-line tasks
    backfill_window: int = 64  # how deep into the ready queue backfill looks
    # placement
    default_partition: Optional[str] = None
    colocate_coupled: bool = True  # coupled pairs pinned to the same node
    placement: str = "first_fit"  # | "best_fit": how task placements and
    #                               service replica claims pack onto nodes
    # routing (inference)
    routing: str = "balanced"  # random | round_robin | balanced |
    #                            least_loaded | prefix_affinity |
    #                            radix_affinity
    affinity_prefix_len: int = 32  # prompt tokens/chars hashed into the
    #                                sticky key (prefix_affinity routing)
    affinity_spill_factor: float = 2.0  # sticky replica sheds when its
    #                                     queue depth exceeds
    #                                     factor * (min depth + 1); <=0
    #                                     disables spilling entirely
    affinity_max_prefix: int = 128  # radix_affinity: prompt tokens kept
    #                                 (lossless) in the session/residency
    #                                 radix indices
    affinity_min_match: int = 8  # radix_affinity: shortest common prefix
    #                              that counts as a match (shorter ones
    #                              route by load, not stickiness)
    affinity_headroom_watermark: float = 0.1  # radix_affinity: a member
    #                              whose gossiped free-block fraction
    #                              falls below this ranks after every
    #                              non-starved prefix match (its engine
    #                              is about to evict the matched
    #                              residency); <=0 disables headroom
    #                              weighting
    residency_sync_every: int = 32  # routed requests between residency
    #                                 gossip pulls from the replicas'
    #                                 engines (0 disables the periodic
    #                                 pull; stats() always syncs)
    # services: replication + autoscaling
    replicas: int = 1  # default replica count when a ServiceDescription
    #                    leaves ``replicas`` unset
    autoscale: bool = False  # grow/shrink replica sets (see `autoscaler`)
    autoscaler: str = "queue_depth"  # | "latency_slo" |
    #                  "weighted_capacity" (repro.core.autoscale; the last
    #                  one drives multi-model sets: per-group SLO control
    #                  with weight-anchored, capacity-neutral rebalancing)
    autoscale_min_replicas: int = 1
    autoscale_max_replicas: int = 4
    autoscale_high_depth: float = 4.0  # mean outstanding reqs/replica to grow
    autoscale_low_depth: float = 0.5  # ... below which we shrink
    autoscale_interval_s: float = 0.05  # sampling period
    autoscale_sustain: int = 3  # consecutive hot/cold samples before acting
    autoscale_sustain_up: Optional[int] = None  # override grow sustain
    #                       (latency_slo defaults to 1: breached SLOs are
    #                       acted on fast)
    autoscale_sustain_down: Optional[int] = None  # override shrink sustain
    #                       (latency_slo defaults to 3x autoscale_sustain:
    #                       slow, deliberate cool-down)
    slo_p95_ms: float = 250.0  # latency_slo: p95 end-to-end target
    slo_window_s: float = 5.0  # latency_slo: latency sample window
    slo_down_factor: float = 0.5  # latency_slo: shrink only when p95 is
    #                               under factor * slo (and queues shallow)
    # speculative decoding (weighted_capacity draft-group entitlements):
    # a draft-role ModelGroup's weight is scaled by the set's measured
    # acceptance rate, and once enough proposals are observed a rate
    # below the floor force-shrinks the group toward its min_replicas —
    # spec-decode turns off gracefully instead of burning cores
    spec_min_acceptance: float = 0.3  # acceptance floor for draft groups
    spec_min_proposed: int = 256  # proposals to observe before judging
    # multi-tenant QoS (see repro.core.request / repro.serving.qos)
    qos_class_weights: Optional[dict] = None  # priority-class -> weighted-
    #                     fair share (None: DEFAULT_CLASS_WEIGHTS high=4
    #                     normal=2 low=1); drives per-replica WFQ ordering
    #                     and decode preemption
    qos_protected_class: Optional[str] = None  # weighted_capacity judges a
    #                     group's SLO on this class's p95 when samples
    #                     exist (isolation signal: scale for the class the
    #                     SLO protects, not the saturating bulk traffic)
    qos_preempt: bool = True  # WFQ may preempt decoding sequences of
    #                     lighter classes (retire paged KV to residency,
    #                     resume token-identically) to admit a heavier
    #                     class's queued request
    tenant_rate: Optional[float] = None  # per-tenant admission rate
    #                     (cost units/s; None = unlimited) enforced by a
    #                     router token bucket BEFORE placement
    tenant_burst_s: float = 2.0  # bucket depth in seconds at the rate
    tenant_rates: Optional[dict] = None  # per-tenant rate overrides
    warmup: bool = False  # prime new replicas (servicer.warmup(): compile
    #                       + a token of decode) before the router sees them
    # fault tolerance
    max_retries: int = 1
    straggler_factor: float = 0.0  # >0: duplicate tasks slower than
    #                                factor x median runtime (first wins)
    straggler_min_samples: int = 10
    # services
    inference_timeout_s: float = 1200.0  # per-INFERENCE-task result wait
    service_ready_timeout: float = 30.0
    service_heartbeat: float = 5.0
    restart_failed_services: bool = True
    restart_backoff_s: float = 0.05  # first relaunch delay after a crash;
    #                                  doubles per consecutive crash
    restart_backoff_max_s: float = 2.0  # exponential backoff ceiling; a
    #                                     replica healthy for 4x this long
    #                                     earns a fresh restart budget
    restart_max_attempts: int = 6  # consecutive crash-relaunches before a
    #                                replica is declared dead (degraded
    #                                set); <=0 means retry forever
    dead_replica_grace_s: float = 2.0  # how long a declared-dead replica
    #                                    stays visible (degraded) before it
    #                                    is folded out of the set with its
    #                                    stats merged into the aggregate;
    #                                    <0 keeps the corpse forever
