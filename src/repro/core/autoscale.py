"""Pluggable replica autoscaling policies (§III-C: services and tasks
co-scheduled inside one job allocation).

The ``ServiceManager`` control loop no longer hard-codes queue-depth
scaling: it asks an ``Autoscaler`` for each replica set's desired size and
only then applies *admission control* — the target is bounded by what the
set's partition ``Allocation`` can still physically claim
(``Allocation.fits``), so "scale up" can be denied (event + stat, never an
exception) but can never overbook the ledger shared with tasks.

Three policies ship:

  * ``QueueDepthAutoscaler`` — the original behavior: grow when mean
    outstanding requests per live replica stays above
    ``autoscale_high_depth`` for ``autoscale_sustain_up`` consecutive
    ticks, shrink below ``autoscale_low_depth`` for
    ``autoscale_sustain_down`` ticks.
  * ``LatencySLOAutoscaler`` — targets a p95 end-to-end latency
    (``slo_p95_ms``) computed from the per-endpoint latency windows the
    replica set aggregates in ``stats()``.  Hysteresis is *asymmetric*:
    scale-up triggers after ``autoscale_sustain_up`` (default 1 — a
    violated SLO is acted on fast), scale-down needs the p95 to sit below
    ``slo_down_factor * slo`` AND the queues to be shallow for
    ``autoscale_sustain_down`` (default ``3 * autoscale_sustain``) ticks.
    Only samples from requests *started after the last scaling action*
    count, so latency accumulated under the old replica count cannot
    trigger a second, oscillating correction.
  * ``WeightedCapacityAutoscaler`` — multi-model replica sets: runs the
    SLO logic per model group (each against its own ``slo_p95_ms``),
    anchors each group's share of the partition to ``ModelGroup.weight``,
    and when a violating group cannot grow (set at max, or no ledger
    headroom) *rebalances* — retires a replica from the most
    over-entitled non-violating group to admit one for the violator,
    capacity-neutral under the single shared ``Allocation``.

All are bounded by ``[autoscale_min_replicas, autoscale_max_replicas]``
and, through the manager, by ``Allocation.free_capacity()``.
"""
from __future__ import annotations

import math
import threading
import time
from collections import deque
from typing import Optional, Sequence


def percentile(samples: Sequence[float], q: float) -> Optional[float]:
    """Nearest-rank percentile (q in [0, 1]); None on empty input."""
    if not samples:
        return None
    xs = sorted(samples)
    idx = min(len(xs) - 1, max(0, math.ceil(q * len(xs)) - 1))
    return xs[idx]


class LatencyWindow:
    """Bounded sliding window of request latencies (one per endpoint).

    Each observation is ``(completed_at, seconds)``; queries can restrict
    to a recent wall-clock window and/or to samples whose request *started*
    (``completed_at - seconds``) after a given instant — the SLO
    autoscaler uses the latter to ignore latency incurred under a previous
    replica count.  ``histogram()`` exposes log2-ms buckets for operators.
    """

    def __init__(self, maxlen: int = 512):
        self._samples: deque = deque(maxlen=maxlen)
        self._lock = threading.Lock()
        self.count = 0  # lifetime observations (window-independent)

    def observe(self, seconds: float, now: Optional[float] = None):
        now = time.perf_counter() if now is None else now
        with self._lock:
            self._samples.append((now, float(seconds)))
            self.count += 1

    def samples(self, window_s: Optional[float] = None,
                started_after: Optional[float] = None,
                now: Optional[float] = None) -> list:
        now = time.perf_counter() if now is None else now
        with self._lock:
            snap = list(self._samples)
        out = []
        for t, dt in snap:
            if window_s is not None and now - t > window_s:
                continue
            if started_after is not None and t - dt < started_after:
                continue
            out.append(dt)
        return out

    def p95(self, window_s: Optional[float] = None,
            started_after: Optional[float] = None) -> Optional[float]:
        return percentile(self.samples(window_s, started_after), 0.95)

    def histogram(self, window_s: Optional[float] = None,
                  samples: Optional[list] = None) -> dict:
        """Log2 millisecond buckets: {"<=1ms": n, "<=2ms": n, ...}.  Pass
        ``samples`` (an earlier ``samples()`` result) to reuse a snapshot
        instead of copying the deque again."""
        out: dict = {}
        for dt in (self.samples(window_s) if samples is None else samples):
            ms = dt * 1e3
            edge = 1 << max(0, math.ceil(math.log2(max(ms, 1e-3))))
            out[f"<={edge}ms"] = out.get(f"<={edge}ms", 0) + 1
        return out


class Autoscaler:
    """Base policy: per-service sustain counters + bounds bookkeeping.

    Subclasses implement ``_direction(name, rs) -> int`` returning +1
    (wants to grow), -1 (wants to shrink), or 0; the base class applies the
    asymmetric sustain hysteresis and the [min, max] replica bounds.  The
    manager applies capacity bounds on top (see ``ServiceManager``).
    """

    def __init__(self, policy):
        self.policy = policy
        self._hot: dict = {}
        self._cold: dict = {}
        self._last_action: dict = {}  # name -> perf_counter of last scale

    # -- knobs ---------------------------------------------------------------
    @property
    def sustain_up(self) -> int:
        v = getattr(self.policy, "autoscale_sustain_up", None)
        return v if v and v > 0 else self._default_sustain_up()

    @property
    def sustain_down(self) -> int:
        v = getattr(self.policy, "autoscale_sustain_down", None)
        return v if v and v > 0 else self._default_sustain_down()

    def _default_sustain_up(self) -> int:
        return max(1, getattr(self.policy, "autoscale_sustain", 3))

    def _default_sustain_down(self) -> int:
        return max(1, getattr(self.policy, "autoscale_sustain", 3))

    # -- manager surface -----------------------------------------------------
    def prune(self, live_names):
        """Drop counters for service names that no longer exist."""
        for d in (self._hot, self._cold, self._last_action):
            for k in [k for k in d if k not in live_names]:
                del d[k]

    def note_scaled(self, name: str):
        """The manager issued a scaling action for ``name``: restart the
        hysteresis and remember when, so signal predating the action is
        discounted."""
        self._hot[name] = 0
        self._cold[name] = 0
        self._last_action[name] = time.perf_counter()

    def desired(self, name: str, rs) -> Optional[int]:
        """Target replica count for one tick, or None for no change."""
        pol = self.policy
        live = rs.n_live
        direction = self._direction(name, rs)
        if direction > 0 and live < pol.autoscale_max_replicas:
            self._hot[name] = self._hot.get(name, 0) + 1
            self._cold[name] = 0
            if self._hot[name] >= self.sustain_up:
                self._hot[name] = 0
                return rs.n_replicas + 1
        elif direction < 0 and live > pol.autoscale_min_replicas:
            self._cold[name] = self._cold.get(name, 0) + 1
            self._hot[name] = 0
            if self._cold[name] >= self.sustain_down:
                self._cold[name] = 0
                return rs.n_replicas - 1
        else:
            self._hot[name] = 0
            self._cold[name] = 0
        return None

    # -- subclass hook -------------------------------------------------------
    def _direction(self, name: str, rs) -> int:
        raise NotImplementedError


class QueueDepthAutoscaler(Autoscaler):
    """Grow when the mean live queue depth per replica stays high, shrink
    when it stays low — the original symmetric-sustain policy."""

    def _direction(self, name, rs) -> int:
        depth = rs.mean_depth()
        if depth > self.policy.autoscale_high_depth:
            return 1
        if depth < self.policy.autoscale_low_depth:
            return -1
        return 0


class LatencySLOAutoscaler(Autoscaler):
    """Hold a p95 end-to-end latency target (``slo_p95_ms``).

    Scale up fast when the windowed p95 of requests started since the last
    scaling action breaches the SLO; scale down slowly — only when p95 is
    comfortably under (``slo_down_factor``) AND queues are shallow, both
    sustained.  No fresh signal (an idle service) counts toward shrink.
    """

    def _default_sustain_up(self) -> int:
        return 1  # a breached SLO is acted on at the next tick

    def _default_sustain_down(self) -> int:
        return 3 * max(1, getattr(self.policy, "autoscale_sustain", 3))

    def _direction(self, name, rs) -> int:
        pol = self.policy
        slo_s = getattr(pol, "slo_p95_ms", 250.0) / 1e3
        window = getattr(pol, "slo_window_s", 5.0)
        down = getattr(pol, "slo_down_factor", 0.5)
        p95 = rs.latency_p95(window_s=window,
                             started_after=self._last_action.get(name))
        if p95 is None:
            # distinguish the two no-fresh-signal cases (the loaded steady
            # state paid a single latency_p95 above; this second, wider
            # query only runs on the quiet paths):
            if rs.latency_p95(window_s=window) is None:
                # nothing completed recently at all: a genuinely idle set
                # with shallow queues may cool down
                return -1 if rs.mean_depth() < pol.autoscale_low_depth else 0
            # recent traffic, but every sample predates the last scaling
            # action: judging it would oscillate — wait for fresh signal
            return 0
        if p95 > slo_s:
            return 1
        if p95 < down * slo_s and rs.mean_depth() < pol.autoscale_low_depth:
            return -1
        return 0


class WeightedCapacityAutoscaler(LatencySLOAutoscaler):
    """Per-model-group SLO autoscaling with weighted entitlements and
    capacity-neutral rebalancing (multi-model replica sets).

    Each model group runs the ``LatencySLOAutoscaler`` control logic
    against ITS OWN latency windows and SLO target
    (``ModelGroup.slo_p95_ms``, falling back to ``policy.slo_p95_ms``),
    with per-group sustain counters.  A group's share of the partition is
    anchored to its ``weight``: when a violating group wants a replica but
    the set is at ``autoscale_max_replicas`` or the partition has no free
    headroom for its shape, the scaler *rebalances* — it retires one
    replica from a donor group (not itself violating, holding more than
    one replica, preferring the group furthest ABOVE its weighted share,
    then the coldest) so the violating group can be admitted on the freed
    capacity.  Every group keeps at least its ``ModelGroup.min_replicas``
    floor (default 1 — a model with no replica cannot serve; an explicit
    0 allows scale-to-zero) and never exceeds its ``max_replicas``
    ceiling; plain grows/shrinks remain bounded by
    ``autoscale_max_replicas`` (total across groups) and the ledger.

    Speculative decoding closes the loop on draft-role groups
    (``ModelGroup.role == "draft"``): the set-wide acceptance rate
    (``ReplicaSet.spec_totals()``) scales the draft's effective weight —
    a draft whose proposals are mostly rejected becomes the most
    over-entitled donor — and once ``spec_min_proposed`` proposals have
    been observed, a rate below ``spec_min_acceptance`` force-shrinks the
    group one replica per tick (no sustain) toward its floor: spec-decode
    turns itself off gracefully instead of burning cores.

    Disaggregated serving closes the loop on prefill/decode-role groups:
    a prefill group's direction is judged against its TTFT window and a
    decode group's against its ITL window (``latency_p95(phase=...)``),
    so the prefill:decode ratio tracks the traffic mix (long-prompt vs
    chatty) instead of one blended end-to-end number.  Donor picks honor
    ``ModelGroup.borrow_limit``: a donor is never taken more than its
    limit below its weight-anchored entitlement.

    The manager consumes this policy through ``desired_groups(name, rs)``
    — one dict of per-group targets per tick, applied shrink-first so a
    rebalance inside a full partition never needs transient headroom
    (grows-first — warm handoff — when the partition has free headroom
    for every grow; see ``ReplicaSet.scale_groups``).
    Single-group sets degenerate to plain per-set SLO scaling.
    """

    def prune(self, live_names):
        # counters are keyed (service, group): prune on the service half
        for d in (self._hot, self._cold, self._last_action):
            for k in [k for k in d
                      if (k[0] if isinstance(k, tuple) else k)
                      not in live_names]:
                del d[k]

    def note_scaled(self, name: str):
        # one scaling ACTION restarts every group's hysteresis for the
        # service: the applied targets changed the whole set's signal
        for d in (self._hot, self._cold):
            for k in list(d):
                if (k[0] if isinstance(k, tuple) else k) == name:
                    d[k] = 0
        self._last_action[name] = time.perf_counter()

    def _group_phase(self, rs, group: str) -> Optional[str]:
        """Which latency window prices this group's SLO: disaggregated
        prefill groups are judged on TTFT, decode groups on ITL, every
        other role on end-to-end latency (None)."""
        role_fn = getattr(rs, "group_role", None)
        role = role_fn(group) if role_fn else "serve"
        return {"prefill": "ttft", "decode": "itl"}.get(role)

    def _group_direction(self, name: str, rs, group: str) -> int:
        """The LatencySLOAutoscaler direction logic, per model group.
        Prefill/decode-role groups read their per-phase window (TTFT /
        ITL) instead of end-to-end latency, so each pool's SLO violation
        grows it independently.

        With ``policy.qos_protected_class`` set, the group is judged on
        that priority class's end-to-end p95 whenever such samples exist
        — the isolation signal: capacity follows the class the SLO
        protects, not the saturating bulk traffic — falling back to the
        usual phase/end-to-end window when the class is quiet."""
        pol = self.policy
        slo_s = rs.group_slo_ms(group) / 1e3
        window = getattr(pol, "slo_window_s", 5.0)
        down = getattr(pol, "slo_down_factor", 0.5)
        phase = self._group_phase(rs, group)
        kw = {} if phase is None else {"phase": phase}
        cls = getattr(pol, "qos_protected_class", None)
        if cls is not None and phase is None:
            ckw = {"tenant_class": cls}
            if rs.latency_p95(window_s=window, group=group,
                              **ckw) is not None:
                kw = ckw  # class samples exist: judge on the class
        p95 = rs.latency_p95(window_s=window,
                             started_after=self._last_action.get(name),
                             group=group, **kw)
        if p95 is None:
            if rs.latency_p95(window_s=window, group=group, **kw) is None:
                # genuinely idle group with shallow queues may cool down
                return (-1 if rs.mean_depth(group=group)
                        < pol.autoscale_low_depth else 0)
            return 0  # only stale (pre-action) samples: wait, don't judge
        if p95 > slo_s:
            return 1
        if p95 < down * slo_s and \
                rs.mean_depth(group=group) < pol.autoscale_low_depth:
            return -1
        return 0

    def _pick_donor(self, grower: str, targets: dict, dirs: dict,
                    weights: dict, growers, bounds=None,
                    borrows=None) -> Optional[str]:
        """Group to retire a replica from so ``grower`` can be admitted:
        not itself wanting to grow, above its per-group floor (default
        1), preferring the largest surplus over its weighted share and
        then the coldest direction.  None when nobody can donate.

        ``borrows`` (group -> ``ModelGroup.borrow_limit`` or None) caps
        how far BELOW its weight-anchored entitlement a donor may be
        taken: a group with ``borrow_limit=b`` never donates below
        ``ceil(entitlement) - b`` replicas — a sustained burst on one
        group borrows bounded capacity instead of hollowing its siblings
        out to their absolute floors."""
        total = sum(targets.values())
        total_w = sum(weights.values()) or float(len(weights))
        best = None
        for g, n in targets.items():
            floor = (bounds or {}).get(g, (1, None))[0]
            ent = total * weights[g] / total_w
            borrow = (borrows or {}).get(g)
            if borrow is not None:
                floor = max(floor, math.ceil(ent) - borrow)
            if g == grower or g in growers or n <= floor:
                continue
            if dirs.get(g, 0) > 0:
                continue  # donating from a violating group helps nobody
            surplus = n - ent
            key = (surplus, -dirs.get(g, 0))
            if best is None or key > best[0]:
                best = (key, g)
        return best[1] if best else None

    def desired_groups(self, name: str, rs) -> Optional[dict]:
        """Per-group replica targets for one tick, or None for no change.
        ``rs`` is a ``ReplicaSet`` (or anything exposing the group surface:
        ``group_counts``/``group_weight``/``group_slo_ms``/
        ``latency_p95``/``mean_depth``/``capacity_headroom``)."""
        pol = self.policy
        counts = rs.group_counts()
        if not counts:
            return None
        role_fn = getattr(rs, "group_role", None)
        roles = {g: (role_fn(g) if role_fn else "serve") for g in counts}
        bounds_fn = getattr(rs, "group_bounds", None)
        bounds = {g: (bounds_fn(g) if bounds_fn else (1, None))
                  for g in counts}
        borrow_fn = getattr(rs, "group_borrow_limit", None)
        borrows = ({g: borrow_fn(g) for g in counts} if borrow_fn
                   else None)
        # speculative-decoding feedback: the set-wide acceptance rate
        # (accepted / proposed across every spec session) prices a
        # draft-role group's entitlement.  Below the floor — once enough
        # proposals have been observed to judge — the draft force-shrinks
        # toward its min_replicas (no sustain: a collapsed acceptance is
        # as decisive as a breached SLO), turning spec-decode off
        # gracefully instead of burning cores on rejected proposals.
        acceptance = None
        if any(r == "draft" for r in roles.values()) \
                and hasattr(rs, "spec_totals"):
            proposed, accepted = rs.spec_totals()
            if proposed >= max(1, getattr(pol, "spec_min_proposed", 256)):
                acceptance = accepted / proposed
        min_acc = getattr(pol, "spec_min_acceptance", 0.3)
        forced = set()
        dirs = {}
        for g in counts:
            d = self._group_direction(name, rs, g)
            if roles[g] == "draft" and acceptance is not None:
                if acceptance < min_acc:
                    d = -1
                    if counts[g] > bounds[g][0]:
                        forced.add(g)
                elif d < 0:
                    d = 0  # a paying draft group is not idle overhead:
                    #        its work shows up as the target's latency
            key = (name, g)
            if d > 0:
                self._hot[key] = self._hot.get(key, 0) + 1
                self._cold[key] = 0
            elif d < 0:
                self._cold[key] = self._cold.get(key, 0) + 1
                self._hot[key] = 0
            else:
                self._hot[key] = 0
                self._cold[key] = 0
            dirs[g] = d
        growers = [g for g in counts if dirs[g] > 0
                   and self._hot.get((name, g), 0) >= self.sustain_up]
        shrinkers = [g for g in counts if dirs[g] < 0
                     and (g in forced
                          or self._cold.get((name, g), 0)
                          >= self.sustain_down)]
        targets = dict(counts)
        weights = {g: max(0.0, rs.group_weight(g)) for g in counts}
        if acceptance is not None:
            for g in counts:  # entitlement scales with measured usefulness
                if roles[g] == "draft":
                    weights[g] *= acceptance
        for g in growers:
            gmax = bounds[g][1]
            if gmax is not None and targets[g] >= gmax:
                continue  # pinned by the operator's per-group ceiling
            donor = None
            headroom = rs.capacity_headroom(group=g)
            at_max = sum(targets.values()) >= pol.autoscale_max_replicas
            if at_max or (headroom is not None and headroom < 1):
                donor = self._pick_donor(g, targets, dirs, weights, growers,
                                         bounds=bounds, borrows=borrows)
                if donor is None:
                    # nothing to retire and nothing free: a sustained
                    # denial episode, visible on the set's stats
                    if hasattr(rs, "_note_admission_denied"):
                        rs._note_admission_denied("rebalance",
                                                  once_per_episode=True)
                    continue
                targets[donor] -= 1
                self._cold[(name, donor)] = 0
            targets[g] += 1
            self._hot[(name, g)] = 0
        min_total = max(1, getattr(pol, "autoscale_min_replicas", 1))
        for g in shrinkers:
            if targets[g] != counts[g]:
                continue  # already donated (or grew) this tick
            if targets[g] <= bounds[g][0]:
                continue  # per-group floor (default: every model keeps
                #           at least one replica; an explicit
                #           min_replicas=0 lets a draft scale off)
            if sum(targets.values()) <= min_total:
                continue  # the SET total honors autoscale_min_replicas,
                #           same floor the per-set policies enforce
            targets[g] -= 1
            self._cold[(name, g)] = 0
        return targets if targets != counts else None


AUTOSCALERS = {
    "queue_depth": QueueDepthAutoscaler,
    "latency_slo": LatencySLOAutoscaler,
    "weighted_capacity": WeightedCapacityAutoscaler,
}


def autoscaler_from_policy(policy) -> Autoscaler:
    kind = getattr(policy, "autoscaler", None) or "queue_depth"
    try:
        cls = AUTOSCALERS[kind]
    except KeyError:
        raise ValueError(
            f"unknown autoscaler {kind!r}; one of {sorted(AUTOSCALERS)}")
    return cls(policy)
