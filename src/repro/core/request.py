"""First-class inference request envelope + routing context.

``InferenceRequest`` is the one record that travels from the client
surface (``ReplicaSet.request`` / the middleware's INFERENCE dispatch)
through routing, the endpoint queue, and into the servicer.  It replaces
the magic payload keys and meta side-channels that had grown organically:

  * ``{"model": ...}`` payload tag        -> ``env.model``
  * ``meta["_model"]`` reroute hint       -> ``env.model``
  * ``meta["_t0"]`` latency stamp         -> ``env.submitted_at``
  * ``{"_import": ...}`` handoff payload  -> ``env.handoff``
  * ``meta["_replays"]`` crash counter    -> ``env.replays``

and adds the multi-tenant QoS fields the serving stack rides on:
``tenant`` (the accounting/admission identity), ``priority`` (the QoS
class: weighted-fair share + preemption order + per-class SLO windows)
and ``deadline_s`` (a client latency budget carried for schedulers).

Bare payloads keep working: ``InferenceRequest.wrap`` is the one
normalization adapter — the ONLY place the legacy ``{"model": ...}``
payload key is still interpreted — so every internal path deals in
envelopes only.

``RouteContext`` bundles the per-pick candidate-set arguments that
``Router.pick()`` had accreted as keywords (``n_instances``, ``group``,
``queue_depths``, ``members``, ``affinity_group``, ``info``); the router
API is now ``route(env, ctx)`` with ``pick()`` kept as a deprecation
shim.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Optional, Sequence

#: the default QoS class of requests that declare none.  Class weights
#: (see ``ExecutionPolicy.qos_class_weights``) give "high" a larger
#: weighted-fair share than "normal" than "low"; unknown classes weigh 1.
DEFAULT_PRIORITY = "normal"

DEFAULT_CLASS_WEIGHTS = {"high": 4.0, "normal": 2.0, "low": 1.0}


class AdmissionDenied(RuntimeError):
    """A tenant's token-bucket admission refused this request (rate
    limit exceeded).  Carried to the client through the request future;
    counted per tenant on the replica set."""

    def __init__(self, tenant: Optional[str], message: str = ""):
        super().__init__(message or f"tenant {tenant!r} over admission "
                                    f"rate limit")
        self.tenant = tenant


@dataclasses.dataclass
class InferenceRequest:
    """One inference request, end to end.

    ``payload`` is what the servicer consumes (dict/list/str, unchanged);
    everything else is routing/accounting/QoS state that used to hide in
    payload keys and private meta entries.  ``meta`` carries remaining
    caller keywords through to the servicer (non-underscore keys only,
    same contract as before).
    """

    payload: Any = None
    model: Optional[str] = None  # model-group tag (multi-model routing)
    tenant: Optional[str] = None  # accounting + admission identity
    priority: str = DEFAULT_PRIORITY  # QoS class: "high"/"normal"/"low"
    deadline_s: Optional[float] = None  # client latency budget (seconds)
    affinity: Any = None  # router affinity key (signature/prefix); None
    #                       -> the router derives one from the payload
    handoff: Optional[dict] = None  # exported paged-KV payload (disagg
    #                                 decode leg); replaces "_import"
    submitted_at: Optional[float] = None  # perf_counter stamp; set once
    #                                       and carried through replays/
    #                                       reroutes/handoffs so latency
    #                                       windows see end-to-end time
    replays: int = 0  # crash-replay budget consumed (was meta _replays)
    meta: dict = dataclasses.field(default_factory=dict)

    def __post_init__(self):
        if self.submitted_at is None:
            self.submitted_at = time.perf_counter()
        if not self.priority:
            self.priority = DEFAULT_PRIORITY

    @classmethod
    def wrap(cls, payload, *, model: Optional[str] = None,
             tenant: Optional[str] = None, priority: Optional[str] = None,
             deadline_s: Optional[float] = None, affinity: Any = None,
             meta: Optional[dict] = None) -> "InferenceRequest":
        """Normalization adapter: turn a bare payload (or an existing
        envelope) into an ``InferenceRequest``.

        This is the ONE back-compat site where the legacy conventions are
        still honored: a dict payload's ``{"model": ...}`` tag becomes
        ``env.model`` (the key stays in the payload — single-model
        servicers historically saw it and must keep doing so), and
        ``tenant``/``priority``/``deadline_s`` keys in ``meta`` (e.g.
        task metadata) are lifted onto the envelope.  Explicit keyword
        arguments win over both."""
        if isinstance(payload, cls):
            env = payload
            if meta:
                env.meta.update(meta)
            if model is not None:
                env.model = model
            if tenant is not None:
                env.tenant = tenant
            if priority is not None:
                env.priority = priority
            if deadline_s is not None:
                env.deadline_s = deadline_s
            if affinity is not None:
                env.affinity = affinity
            return env
        meta = dict(meta or {})
        if tenant is None:
            tenant = meta.pop("tenant", None)
        else:
            meta.pop("tenant", None)
        if priority is None:
            priority = meta.pop("priority", None)
        else:
            meta.pop("priority", None)
        if deadline_s is None:
            deadline_s = meta.pop("deadline_s", None)
        else:
            meta.pop("deadline_s", None)
        if model is None and isinstance(payload, dict):
            tag = payload.get("model")
            if tag is not None:
                model = str(tag)
        return cls(payload=payload, model=model, tenant=tenant,
                   priority=priority or DEFAULT_PRIORITY,
                   deadline_s=deadline_s, affinity=affinity, meta=meta)

    def servicer_kwargs(self) -> dict:
        """The keyword arguments forwarded to the servicer: public meta
        keys only (underscore-prefixed entries are private to the
        service layer, the same filter ``ServiceInstance`` always
        applied)."""
        return {k: v for k, v in self.meta.items()
                if not k.startswith("_")}


@dataclasses.dataclass
class RouteContext:
    """Candidate-set context for one routing decision.

    Collapses the keyword surface ``Router.pick()`` had grown: the
    balance-state key (``group``), the live candidates and their stable
    identities (``n_instances``/``members``/``queue_depths``), the
    sticky-state namespace (``affinity_group``), and the outcome
    out-dict (``info``, filled with ``{"affinity": "hit"|"miss"|
    "spill"}`` by sticky routers)."""

    n_instances: int
    group: Any = "default"
    queue_depths: Optional[Sequence[float]] = None
    members: Optional[Sequence] = None
    affinity_group: Optional[Any] = None
    info: Optional[dict] = None
