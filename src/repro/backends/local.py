"""Pool backend — the Dragon-runtime analogue.

Multi-worker executor with per-worker deques + work stealing, matching
Dragon's lightweight-worker/distributed-queue execution model (§III-D) at
single-host scale.  Multi-rank EXECUTABLE tasks run their payload once with a
``rank_count``/placement context (the MPI-launch analogue); worker failure is
injectable for the fault-tolerance tests.
"""
from __future__ import annotations

import itertools
import queue
import random
import threading
import time
from collections import deque
from typing import Any, Callable, Optional

from repro.core.task import Task, TaskKind
from .base import Backend, BackendCapabilities


class _Worker(threading.Thread):
    def __init__(self, backend: "PoolBackend", wid: int):
        super().__init__(name=f"rhapsody-worker-{wid}", daemon=True)
        self.backend = backend
        self.wid = wid
        self.queue: deque = deque()
        self.lock = threading.Lock()
        self.alive = True
        self.busy = False
        self.executed = 0

    def push(self, task: Task):
        with self.lock:
            self.queue.append(task)
        self.backend._wake.set()

    def pop(self) -> Optional[Task]:
        with self.lock:
            return self.queue.popleft() if self.queue else None

    def steal(self) -> Optional[Task]:
        with self.lock:
            return self.queue.pop() if self.queue else None

    def run(self):
        b = self.backend
        while self.alive:
            task = self.pop()
            if task is None:
                # work stealing: grab from the busiest sibling
                victim = max(b.workers, key=lambda w: len(w.queue),
                             default=None)
                if victim is not None and victim is not self:
                    task = victim.steal()
            if task is None:
                b._wake.wait(timeout=0.001)
                b._wake.clear()
                continue
            if not self.alive:  # killed while holding a task -> requeue
                b._requeue(task)
                break
            self.busy = True
            self._execute(task)
            self.busy = False
            self.executed += 1

    def _execute(self, task: Task):
        b = self.backend
        try:
            desc = task.desc
            if desc.fn is None:
                result = None
            elif desc.kind == TaskKind.EXECUTABLE and desc.requirements.ranks > 1:
                result = desc.fn(*desc.args, _ranks=desc.requirements.ranks,
                                 _placement=task.placement, **desc.kwargs)
            else:
                result = desc.fn(*desc.args, **desc.kwargs)
            b._on_complete(task, result, None)
        except BaseException as e:  # noqa: BLE001 — report to middleware
            b._on_complete(task, None, e)


class PoolBackend(Backend):
    name = "pool"

    def __init__(self, n_workers: int = 4, seed: int = 0):
        self.n_workers = n_workers
        self.workers: list[_Worker] = []
        self._rr = itertools.count()
        self._wake = threading.Event()
        self._on_complete_cb = None
        self.rng = random.Random(seed)

    # -- Backend API --------------------------------------------------------
    def start(self, on_complete):
        self._on_complete_cb = on_complete
        self.workers = [_Worker(self, i) for i in range(self.n_workers)]
        for w in self.workers:
            w.start()
        return self

    def submit(self, task: Task):
        # least-loaded of two random choices (power of two)
        if len(self.workers) == 1:
            self.workers[0].push(task)
            return
        a, b = self.rng.sample(self.workers, 2)
        (a if len(a.queue) <= len(b.queue) else b).push(task)

    def capabilities(self):
        return BackendCapabilities(
            kinds=(TaskKind.FUNCTION, TaskKind.EXECUTABLE, TaskKind.COUPLED),
            max_concurrency=self.n_workers,
        )

    def shutdown(self, wait=True):
        for w in self.workers:
            w.alive = False
        self._wake.set()
        if wait:
            for w in self.workers:
                w.join(timeout=1.0)

    def stats(self):
        return {
            "workers": len(self.workers),
            "executed": sum(w.executed for w in self.workers),
            "queued": sum(len(w.queue) for w in self.workers),
        }

    # -- internals ------------------------------------------------------------
    def _on_complete(self, task, result, error):
        self._on_complete_cb(task, result, error)

    def _requeue(self, task: Task):
        live = [w for w in self.workers if w.alive]
        if live:
            self.rng.choice(live).push(task)
        else:
            self._on_complete_cb(task, None,
                                 RuntimeError("no live workers"))

    # -- failure injection (tests / fault-tolerance benchmarks) --------------
    def kill_worker(self, wid: int) -> list:
        """Kill a worker; returns the tasks stranded in its queue."""
        w = self.workers[wid]
        w.alive = False
        stranded = []
        with w.lock:
            while w.queue:
                stranded.append(w.queue.popleft())
        self.workers = [x for x in self.workers if x.wid != wid]
        self._wake.set()
        return stranded

    def add_workers(self, n: int):
        start = (max((w.wid for w in self.workers), default=-1)) + 1
        for i in range(start, start + n):
            w = _Worker(self, i)
            self.workers.append(w)
            w.start()
