"""Backend ABC: concrete execution mechanisms composed by the middleware."""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

from repro.core.task import Task


@dataclasses.dataclass
class BackendCapabilities:
    kinds: tuple  # TaskKind values this backend executes
    max_concurrency: int = 0  # 0 = unbounded
    supports_mpi: bool = False
    supports_gpu: bool = False


class Backend:
    """Executes tasks; reports completion via the middleware callback."""

    name = "backend"

    def start(self, on_complete: Callable[[Task, Any, Optional[BaseException]], None]):
        raise NotImplementedError

    def submit(self, task: Task) -> None:
        raise NotImplementedError

    def cancel(self, task: Task) -> bool:
        return False

    def capabilities(self) -> BackendCapabilities:
        raise NotImplementedError

    def shutdown(self, wait: bool = True) -> None:
        raise NotImplementedError

    # introspection used by benchmarks
    def stats(self) -> dict:
        return {}
