"""JAX compute backend: executes jitted array payloads on local devices.

The middleware composes this *alongside* the pool backend (the paper's
central claim: multiple runtimes coexist in one allocation, each serving the
partition it's suited for).  Payloads are ``fn(*args)`` returning jax arrays;
the backend jit-caches by function identity, runs on a dedicated executor
thread (keeping device work off middleware worker threads), and blocks until
results are materialized so task completion means data-ready.
"""
from __future__ import annotations

import queue
import threading
from typing import Any, Callable, Optional

import jax

from repro.core.task import Task, TaskKind
from .base import Backend, BackendCapabilities


class JaxBackend(Backend):
    name = "jax"

    def __init__(self, *, jit_payloads: bool = True):
        self.jit_payloads = jit_payloads
        self._jit_cache: dict = {}
        self._queue: "queue.Queue" = queue.Queue()
        self._on_complete = None
        self._alive = True
        self._thread: Optional[threading.Thread] = None
        self.executed = 0

    # -- Backend API --------------------------------------------------------
    def start(self, on_complete):
        self._on_complete = on_complete
        self._thread = threading.Thread(target=self._loop,
                                        name="jax-backend", daemon=True)
        self._thread.start()
        return self

    def submit(self, task: Task):
        self._queue.put(task)

    def capabilities(self):
        return BackendCapabilities(
            kinds=(TaskKind.FUNCTION, TaskKind.EXECUTABLE, TaskKind.COUPLED),
            max_concurrency=1,  # one device stream
            supports_gpu=True,
        )

    def shutdown(self, wait=True):
        self._alive = False
        self._queue.put(None)
        if wait and self._thread is not None:
            self._thread.join(timeout=2.0)

    def stats(self):
        return {"executed": self.executed, "queued": self._queue.qsize(),
                "jit_cache": len(self._jit_cache)}

    # -- internals ------------------------------------------------------------
    def _loop(self):
        while self._alive:
            task = self._queue.get()
            if task is None:
                break
            try:
                fn = task.desc.fn
                if self.jit_payloads and not task.desc.kwargs:
                    key = id(fn)
                    if key not in self._jit_cache:
                        self._jit_cache[key] = jax.jit(fn)
                    fn = self._jit_cache[key]
                result = fn(*task.desc.args, **task.desc.kwargs)
                result = jax.block_until_ready(result)
                self.executed += 1
                self._on_complete(task, result, None)
            except BaseException as e:  # noqa: BLE001
                self._on_complete(task, None, e)
