"""Decoder-only and encoder-decoder transformer LMs.

Covers the dense archs (qwen1.5-0.5b, qwen3-8b, llama3.2-3b, nemotron-4-340b),
the MoE archs (via ``repro.models.moe`` FFN plug-in), whisper-small (enc-dec)
and internvl2-1b (vision-prefix LM).

Stack layout: an optional short list of "pre" blocks (e.g. deepseek's first
dense layer) followed by a homogeneous stack of blocks applied with
``jax.lax.scan`` over stacked params — HLO size and remat-checkpointed memory
stay O(one layer) regardless of depth.
"""
from __future__ import annotations

import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp

from . import attention as attn
from . import moe as moe_lib
from . import nn
from .config import ModelConfig


# ---------------------------------------------------------------------------
# Remat policies
# ---------------------------------------------------------------------------


def remat_wrap(fn, cfg: ModelConfig):
    if cfg.remat == "none":
        return fn
    if cfg.remat == "dots":
        policy = jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims
        return jax.checkpoint(fn, policy=policy)
    return jax.checkpoint(fn)  # "full": save only layer boundaries


# ---------------------------------------------------------------------------
# Block init / apply
# ---------------------------------------------------------------------------


def block_init(key, cfg: ModelConfig, *, layer_idx: int = 0,
               cross: bool = False):
    ks = jax.random.split(key, 4)
    dt = cfg.pdtype
    p = {
        "ln_attn": nn.rmsnorm_init(cfg.d_model, dtype=dt),
        "attn": attn.attention_init(ks[0], cfg),
        "ln_mlp": nn.rmsnorm_init(cfg.d_model, dtype=dt),
    }
    use_moe = cfg.is_moe and layer_idx >= cfg.first_dense_layers
    if use_moe:
        p["moe"] = moe_lib.moe_init(ks[1], cfg)
    else:
        ff = cfg.dense_ff or cfg.d_ff
        p["mlp"] = nn.mlp_init(ks[1], cfg.d_model, ff, gated=cfg.gated_mlp,
                               dtype=dt)
    if cross:
        p["ln_cross"] = nn.rmsnorm_init(cfg.d_model, dtype=dt)
        p["cross"] = attn.attention_init(ks[2], cfg, cross=True)
    return p


def _sp_on(cfg, mesh, x):
    return (cfg.seq_shard_activations and mesh is not None
            and "model" in mesh.axis_names
            and x.ndim == 3 and x.shape[1] % mesh.shape["model"] == 0)


def _gather_seq(x, cfg, mesh):
    """Megatron-SP: gather the seq-sharded residual before a block (bf16)."""
    if not _sp_on(cfg, mesh, x):
        return x
    return nn.constrain(x, mesh, nn.batch_pspec(mesh, x.shape[0]))


def _ffn(p, x, cfg: ModelConfig, mesh, decode):
    sp = _sp_on(cfg, mesh, x)
    h = nn.rmsnorm_apply(p["ln_mlp"], _gather_seq(x, cfg, mesh), cfg.norm_eps)
    if "moe" in p:
        h, aux = moe_lib.moe_apply(p["moe"], h, cfg, mesh=mesh, decode=decode)
        if sp:
            from jax.sharding import PartitionSpec as P

            h = nn.constrain(
                h, mesh, P(nn.batch_pspec(mesh, x.shape[0])[0], "model", None))
    else:
        h = nn.mlp_apply(p["mlp"], h, activation=cfg.activation,
                         compute_dtype=cfg.cdtype, mesh=mesh,
                         explicit_tp=cfg.explicit_tp, fsdp=cfg.fsdp_params,
                         seq_shard=sp)
        aux = jnp.zeros((), jnp.float32)
    return x + h, aux


def block_apply(p, x, cfg: ModelConfig, *, causal=True, positions=None,
                enc_out=None, mesh=None):
    """Full-sequence block forward.  Returns (y, aux_loss)."""
    sp = _sp_on(cfg, mesh, x)
    h = nn.rmsnorm_apply(p["ln_attn"], _gather_seq(x, cfg, mesh),
                         cfg.norm_eps)
    h = attn.attention_apply(p["attn"], h, cfg, causal=causal,
                             positions=positions,
                             rope=cfg.positions == "rope", mesh=mesh,
                             seq_shard=sp)
    x = x + h
    if "cross" in p and enc_out is not None:
        h = nn.rmsnorm_apply(p["ln_cross"], x, cfg.norm_eps)
        h = attn.attention_apply(p["cross"], h, cfg, causal=False,
                                 x_kv=enc_out, rope=False, mesh=mesh)
        x = x + h
    return _ffn(p, x, cfg, mesh, decode=False)


def block_prefill(p, x, cfg: ModelConfig, *, max_len: int, positions=None,
                  enc_out=None, mesh=None):
    """Prefill forward; returns (y, cache dict with padded KV)."""
    B, S, _ = x.shape
    h = nn.rmsnorm_apply(p["ln_attn"], x, cfg.norm_eps)
    h, (k, v) = attn.attention_prefill(p["attn"], h, cfg, positions=positions,
                                       mesh=mesh)
    pad = max_len - S
    cache = {
        "k": jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0))),
        "v": jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0))),
        "len": jnp.full((B,), S, jnp.int32),
    }
    x = x + h
    if "cross" in p and enc_out is not None:
        h = nn.rmsnorm_apply(p["ln_cross"], x, cfg.norm_eps)
        hq, (ck, cv) = _cross_prefill(p["cross"], h, enc_out, cfg)
        cache["cross_k"] = ck
        cache["cross_v"] = cv
        x = x + hq
    y, _ = _ffn(p, x, cfg, mesh, decode=True)
    return y, cache


def block_decode(p, x, cache, cfg: ModelConfig, *, mesh=None):
    """Single-token decode; cross-attn reads precomputed cross K/V."""
    h = nn.rmsnorm_apply(p["ln_attn"], x, cfg.norm_eps)
    h, ck, cv, clen = attn.attention_decode(
        p["attn"], h, cache["k"], cache["v"], cache["len"], cfg)
    cache = dict(cache, k=ck, v=cv, len=clen)
    x = x + h
    if "cross" in p and "cross_k" in cache:
        h = nn.rmsnorm_apply(p["ln_cross"], x, cfg.norm_eps)
        B = x.shape[0]
        zero = jnp.zeros((B, 1), jnp.int32)
        q, _, _ = attn._project_qkv(p["cross"], h, h, cfg, zero, zero,
                                    rope=False)
        kv_len = jnp.full((B,), cache["cross_k"].shape[1], jnp.int32)
        o = attn.decode_attention(q, cache["cross_k"], cache["cross_v"], kv_len)
        o = o.reshape(B, 1, cfg.padded_heads * cfg.head_dim)
        x = x + nn.linear_apply(p["cross"]["o"], o, cfg.cdtype)
    y, _ = _ffn(p, x, cfg, mesh, decode=True)
    return y, cache


def block_decode_paged(p, x, cache, block_tables, lens, write_phys,
                       write_off, cfg: ModelConfig, *, mesh=None):
    """Single-token decode against one layer's paged K/V store leaves.

    ``cache`` is the layer's slice of the paged store tree ({"k", "v",
    "len"} with block-paged k/v of shape [num_blocks, block_size, Hkv, D]);
    the "len" leaf is a template artifact — lengths live host-side in the
    engine and arrive as ``lens`` — so it passes through untouched.  Only
    dense/moe stacks run paged, so there is no cross-attention branch."""
    h = nn.rmsnorm_apply(p["ln_attn"], x, cfg.norm_eps)
    h, ck, cv = attn.attention_decode_paged(
        p["attn"], h, cache["k"], cache["v"], block_tables, lens,
        write_phys, write_off, cfg)
    cache = dict(cache, k=ck, v=cv)
    x = x + h
    y, _ = _ffn(p, x, cfg, mesh, decode=True)
    return y, cache


def block_extend(p, x, cache, cfg: ModelConfig, *, mesh=None):
    """Multi-token cache extension (chunked prefill): x [B,T,d] appended
    at cache positions len..len+T-1.  Cross-attn reads precomputed cross
    K/V, mirroring ``block_decode``."""
    h = nn.rmsnorm_apply(p["ln_attn"], x, cfg.norm_eps)
    h, ck, cv, clen = attn.attention_extend(
        p["attn"], h, cache["k"], cache["v"], cache["len"], cfg)
    cache = dict(cache, k=ck, v=cv, len=clen)
    x = x + h
    if "cross" in p and "cross_k" in cache:
        h = nn.rmsnorm_apply(p["ln_cross"], x, cfg.norm_eps)
        B, T, _ = x.shape
        zero = jnp.zeros((B, T), jnp.int32)
        q, _, _ = attn._project_qkv(p["cross"], h, h, cfg, zero, zero,
                                    rope=False)
        o = attn.full_attention(q, cache["cross_k"], cache["cross_v"],
                                causal=False)
        o = o.reshape(B, T, cfg.padded_heads * cfg.head_dim)
        x = x + nn.linear_apply(p["cross"]["o"], o, cfg.cdtype)
    y, _ = _ffn(p, x, cfg, mesh, decode=True)
    return y, cache


def _cross_prefill(p, x, enc_out, cfg):
    B, S, _ = x.shape
    q, k, v = attn._project_qkv(
        p, x, enc_out, cfg,
        jnp.arange(S)[None, :], jnp.arange(enc_out.shape[1])[None, :],
        rope=False)
    out = attn.full_attention(q, k, v, causal=False)
    out = out.reshape(B, S, cfg.padded_heads * cfg.head_dim)
    return nn.linear_apply(p["o"], out, cfg.cdtype), (k, v)


# ---------------------------------------------------------------------------
# LM init
# ---------------------------------------------------------------------------


def lm_init(key, cfg: ModelConfig):
    ks = jax.random.split(key, 6)
    dt = cfg.pdtype
    p: dict[str, Any] = {
        "embed": nn.embedding_init(ks[0], cfg.vocab, cfg.d_model, dtype=dt),
        "ln_f": nn.rmsnorm_init(cfg.d_model, dtype=dt),
    }
    n_dec = cfg.dec_layers or cfg.n_layers
    n_pre = cfg.first_dense_layers if cfg.is_moe else 0
    layer_keys = jax.random.split(ks[1], n_dec)
    pre = {
        f"layer_{i}": block_init(layer_keys[i], cfg, layer_idx=i,
                                 cross=cfg.cross_attention)
        for i in range(n_pre)
    }
    blocks = [
        block_init(layer_keys[i], cfg, layer_idx=i, cross=cfg.cross_attention)
        for i in range(n_pre, n_dec)
    ]
    if pre:
        p["pre"] = pre
    p["blocks"] = nn.stack_layers(blocks)
    if not cfg.tie_embeddings:
        p["unembed"] = nn.linear_init(ks[2], cfg.d_model, cfg.vocab,
                                      axes=("embed", "vocab"), dtype=dt)
    if cfg.family == "encdec":
        enc_keys = jax.random.split(ks[3], cfg.enc_layers)
        enc_blocks = [
            block_init(enc_keys[i], cfg, layer_idx=i, cross=False)
            for i in range(cfg.enc_layers)
        ]
        p["enc_blocks"] = nn.stack_layers(enc_blocks)
        p["enc_ln_f"] = nn.rmsnorm_init(cfg.d_model, dtype=dt)
    if cfg.positions == "learned":
        p["pos_embed"] = {
            "table": nn.Px(
                nn.normal_init(ks[4], (cfg.max_seq, cfg.d_model), dt, 0.01),
                ("pos", "embed"),
            )
        }
    return p


def _pre_names(p):
    if "pre" not in p:
        return []
    return sorted(p["pre"], key=lambda s: int(s.split("_")[1]))


# ---------------------------------------------------------------------------
# Forward (training / full sequence)
# ---------------------------------------------------------------------------


def _embed_tokens(p, tokens, cfg, *, prefix_embeds=None, mesh=None):
    x = nn.embedding_apply(p["embed"], tokens, cfg.cdtype, mesh=mesh)
    if prefix_embeds is not None:  # vlm: prepend vision patch embeddings
        x = jnp.concatenate([prefix_embeds.astype(x.dtype), x], axis=1)
    S = x.shape[1]
    positions = jnp.arange(S)[None, :]
    if cfg.positions == "learned":
        x = x + p["pos_embed"]["table"].astype(x.dtype)[:S][None]
    elif cfg.positions == "sinusoidal":
        x = x + nn.sinusoidal_positions(S, cfg.d_model).astype(x.dtype)[None]
    return x, positions


def _residual_spec(cfg, mesh, batch, seq):
    """Residual-stream sharding: batch over DP; + Megatron-SP over model
    on the sequence dim when ``seq_shard_activations`` (shrinks remat-saved
    activations by the TP degree; the gather back is bf16)."""
    from jax.sharding import PartitionSpec as P

    bspec = nn.batch_pspec(mesh, batch)
    if (cfg.seq_shard_activations and mesh is not None
            and "model" in mesh.axis_names
            and seq % mesh.shape["model"] == 0):
        return P(bspec[0], "model", None)
    return bspec


def _run_blocks(p, x, cfg: ModelConfig, *, positions=None, enc_out=None,
                mesh=None):
    body = functools.partial(block_apply, cfg=cfg, causal=True,
                             positions=positions, enc_out=enc_out, mesh=mesh)
    aspec = _residual_spec(cfg, mesh, x.shape[0], x.shape[1])
    aux = jnp.zeros((), jnp.float32)
    for name in _pre_names(p):
        fn = remat_wrap(lambda q, v: body(q, v), cfg)
        x, a = fn(p["pre"][name], nn.constrain(x, mesh, aspec))
        aux = aux + a

    def scan_body(carry, layer_params):
        x, aux = carry
        x = nn.constrain(x, mesh, aspec)
        y, a = body(layer_params, x)
        return (nn.constrain(y, mesh, aspec), aux + a), None

    scan_fn = remat_wrap(scan_body, cfg)
    (x, aux), _ = jax.lax.scan(scan_fn, (x, aux), p["blocks"])
    return x, aux


def _logits(p, x, cfg: ModelConfig):
    x = nn.rmsnorm_apply(p["ln_f"], x, cfg.norm_eps)
    if cfg.tie_embeddings:
        logits = nn.embedding_attend(p["embed"], x)
    else:
        logits = nn.linear_apply(p["unembed"], x, jnp.float32)
    if cfg.final_logit_softcap > 0:
        c = cfg.final_logit_softcap
        logits = jnp.tanh(logits / c) * c
    return logits


def encode(p, frame_embeds, cfg: ModelConfig, *, mesh=None):
    """Encoder stack over stubbed modality embeddings (whisper)."""
    x = frame_embeds.astype(cfg.cdtype)
    S = x.shape[1]
    x = x + nn.sinusoidal_positions(S, cfg.d_model).astype(x.dtype)[None]
    aspec = nn.batch_pspec(mesh, x.shape[0])

    def scan_body(x, layer_params):
        x = nn.constrain(x, mesh, aspec)
        y, _ = block_apply(layer_params, x, cfg, causal=False, mesh=mesh)
        return nn.constrain(y, mesh, aspec), None

    x, _ = jax.lax.scan(remat_wrap(scan_body, cfg), x, p["enc_blocks"])
    return nn.rmsnorm_apply(p["enc_ln_f"], x, cfg.norm_eps)


def forward(p, batch, cfg: ModelConfig, *, mesh=None):
    tokens = batch["tokens"]
    enc_out = (encode(p, batch["frame_embeds"], cfg, mesh=mesh)
               if cfg.family == "encdec" else None)
    prefix = batch.get("patch_embeds") if cfg.family == "vlm" else None
    x, positions = _embed_tokens(p, tokens, cfg, prefix_embeds=prefix,
                                 mesh=mesh)
    x = nn.constrain(x, mesh, nn.batch_pspec(mesh, x.shape[0]))
    x, aux = _run_blocks(p, x, cfg, positions=positions, enc_out=enc_out,
                         mesh=mesh)
    if prefix is not None:  # only score text positions
        x = x[:, prefix.shape[1]:]
    logits = _logits(p, x, cfg)
    if mesh is not None:
        from jax.sharding import PartitionSpec as P

        bspec = nn.batch_pspec(mesh, x.shape[0])
        logits = nn.constrain(
            logits, mesh,
            P(bspec[0], None, "model" if "model" in mesh.axis_names else None))
    return logits, aux


def _sharded_loglik(logits, targets, mesh, batch_size: int):
    """Per-token target log-likelihood with vocab sharded over "model".

    Runs inside shard_map so every vocab-shard computes its local max /
    sum-exp / target logit and combines with tiny [B,S] psums — no
    full-logits collectives, no one-hot materialization.
    """
    from jax.sharding import PartitionSpec as P

    bspec = nn.batch_pspec(mesh, batch_size, extra_dims=1)
    lspec = P(*bspec, "model")
    v_local = logits.shape[-1] // mesh.shape["model"]

    def local(lg, tg):
        j = jax.lax.axis_index("model")
        lg = lg.astype(jnp.float32)
        # stop_gradient BEFORE pmax: max-shift is gradient-invariant for
        # logsumexp, and pmax has no JVP rule (zero tangents bypass it)
        lmax = jax.lax.pmax(
            jax.lax.stop_gradient(jnp.max(lg, axis=-1)), "model")  # [B,S]
        sumexp = jnp.sum(jnp.exp(lg - lmax[..., None]), axis=-1)
        gsum = jax.lax.psum(sumexp, "model")
        local_t = tg - j * v_local
        in_range = (local_t >= 0) & (local_t < v_local)
        idx = jnp.clip(local_t, 0, v_local - 1)
        tl = jnp.take_along_axis(lg, idx[..., None], axis=-1)[..., 0]
        tl = jax.lax.psum(jnp.where(in_range, tl, 0.0), "model")
        return tl - lmax - jnp.log(gsum)

    return jax.shard_map(local, mesh=mesh,
                         in_specs=(lspec, P(*bspec)),
                         out_specs=P(*bspec))(logits, targets)


def _ce_from_logits(logits, batch, aux, cfg: ModelConfig, *, mesh=None):
    """Shared next-token CE loss used by every model family."""
    targets = batch["targets"]
    mask = batch.get("loss_mask")
    if mask is None:
        mask = jnp.ones_like(targets, jnp.float32)
    if (mesh is not None and "model" in mesh.axis_names
            and logits.shape[-1] % mesh.shape["model"] == 0):
        ll = _sharded_loglik(logits, targets, mesh, logits.shape[0])
    else:
        logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
        ll = jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
    denom = jnp.maximum(mask.sum(), 1.0)
    ce = -(ll * mask).sum() / denom
    total = ce + cfg.router_aux_weight * aux
    return total, {"ce": ce, "aux": aux, "tokens": mask.sum()}


def loss_fn(p, batch, cfg: ModelConfig, *, mesh=None):
    logits, aux = forward(p, batch, cfg, mesh=mesh)
    return _ce_from_logits(logits, batch, aux, cfg, mesh=mesh)


# ---------------------------------------------------------------------------
# Prefill / decode (serving)
# ---------------------------------------------------------------------------


def prefill(p, batch, cfg: ModelConfig, *, max_len: int, mesh=None,
            last_only: bool = True):
    """Prefill caches; returns (cache, logits).

    ``last_only=True`` -> logits [B, vocab] at the final position (dry-run /
    exact-length serving); ``False`` -> logits [B, S, vocab] so the engine can
    read the true last prompt position of right-padded bucketed prompts."""
    tokens = batch["tokens"]
    enc_out = (encode(p, batch["frame_embeds"], cfg, mesh=mesh)
               if cfg.family == "encdec" else None)
    prefix = batch.get("patch_embeds") if cfg.family == "vlm" else None
    x, positions = _embed_tokens(p, tokens, cfg, prefix_embeds=prefix,
                                 mesh=mesh)
    aspec = nn.batch_pspec(mesh, x.shape[0])
    x = nn.constrain(x, mesh, aspec)

    pre_cache = {}
    for name in _pre_names(p):
        x, c = block_prefill(p["pre"][name], x, cfg, max_len=max_len,
                             positions=positions, enc_out=enc_out, mesh=mesh)
        pre_cache[name] = c

    def scan_body(x, layer_params):
        x = nn.constrain(x, mesh, aspec)
        y, c = block_prefill(layer_params, x, cfg, max_len=max_len,
                             positions=positions, enc_out=enc_out, mesh=mesh)
        return nn.constrain(y, mesh, aspec), c

    x, scan_cache = jax.lax.scan(scan_body, x, p["blocks"])
    cache = {"scan": scan_cache}
    if pre_cache:
        cache["pre"] = pre_cache
    if last_only:
        logits = _logits(p, x[:, -1:, :], cfg)[:, 0]
    else:
        logits = _logits(p, x, cfg)
    return cache, logits


def extend_step(p, cache, tokens, cfg: ModelConfig, *, mesh=None):
    """Chunked cache extension; tokens [B, T] -> (cache, logits [B, T, vocab]).

    The T-token generalization of ``decode_step``: the chunk is written
    into the cache at positions len..len+T-1 and logits come back for
    every chunk position (the engine reads the last *real* one).  Feeding
    a prompt through successive extend calls produces the same cache and
    final-position logits as one full prefill, which is what lets the
    paged engine interleave long-prompt prefill with decode steps without
    perturbing outputs."""
    B, T = tokens.shape
    x = nn.embedding_apply(p["embed"], tokens, cfg.cdtype, mesh=mesh)
    if cfg.positions == "learned":
        lens = cache["scan"]["len"]  # [L, B]
        pos = lens[0][:, None] + jnp.arange(T)[None, :]  # [B, T]
        tab = p["pos_embed"]["table"].astype(x.dtype)
        x = x + jnp.take(tab, pos, axis=0)

    new_pre = {}
    for name in _pre_names(p):
        x, c = block_extend(p["pre"][name], x, cache["pre"][name], cfg,
                            mesh=mesh)
        new_pre[name] = c

    def scan_body(x, layer):
        layer_params, layer_cache = layer
        y, c = block_extend(layer_params, x, layer_cache, cfg, mesh=mesh)
        return y, c

    x, new_scan = jax.lax.scan(scan_body, x, (p["blocks"], cache["scan"]))
    new_cache = {"scan": new_scan}
    if new_pre:
        new_cache["pre"] = new_pre
    logits = _logits(p, x, cfg)
    return new_cache, logits


def decode_step(p, cache, tokens, cfg: ModelConfig, *, mesh=None):
    """One decode step; tokens [B] int32 -> (cache, logits [B, vocab])."""
    x = nn.embedding_apply(p["embed"], tokens[:, None], cfg.cdtype, mesh=mesh)
    if cfg.positions == "learned":
        # current position = cache length of first scanned layer
        lens = cache["scan"]["len"]  # [L, B]
        pos = lens[0]  # [B]
        tab = p["pos_embed"]["table"].astype(x.dtype)
        x = x + jnp.take(tab, pos, axis=0)[:, None, :]

    new_pre = {}
    for name in _pre_names(p):
        x, c = block_decode(p["pre"][name], x, cache["pre"][name], cfg,
                            mesh=mesh)
        new_pre[name] = c

    def scan_body(x, layer):
        layer_params, layer_cache = layer
        y, c = block_decode(layer_params, x, layer_cache, cfg, mesh=mesh)
        return y, c

    x, new_scan = jax.lax.scan(scan_body, x, (p["blocks"], cache["scan"]))
    new_cache = {"scan": new_scan}
    if new_pre:
        new_cache["pre"] = new_pre
    logits = _logits(p, x, cfg)[:, 0]
    return new_cache, logits


def paged_decode_step(p, store, block_tables, lens, tokens, write_phys,
                      write_off, cfg: ModelConfig, *, mesh=None):
    """One decode step directly on the block-paged physical store.

    The paged analogue of ``decode_step``: ``store`` is the engine's
    physical cache tree (k/v leaves [L, num_blocks, block_size, Hkv, D]),
    ``block_tables`` [B, max_blocks] maps each sequence's logical blocks
    to physical ones, ``lens`` [B] is each sequence's valid length before
    this token, and ``write_phys``/``write_off`` [B] name the single
    physical cell the new token's K/V is written into.  No contiguous
    [B, Smax] view is ever materialized — attention reads K/V through the
    block table (see ``attention_decode_paged``).  Returns
    (store, logits [B, vocab])."""
    x = nn.embedding_apply(p["embed"], tokens[:, None], cfg.cdtype, mesh=mesh)
    if cfg.positions == "learned":
        tab = p["pos_embed"]["table"].astype(x.dtype)
        x = x + jnp.take(tab, lens, axis=0)[:, None, :]

    new_pre = {}
    for name in _pre_names(p):
        x, c = block_decode_paged(p["pre"][name], x, store["pre"][name],
                                  block_tables, lens, write_phys, write_off,
                                  cfg, mesh=mesh)
        new_pre[name] = c

    def scan_body(x, layer):
        layer_params, layer_store = layer
        y, c = block_decode_paged(layer_params, x, layer_store,
                                  block_tables, lens, write_phys, write_off,
                                  cfg, mesh=mesh)
        return y, c

    x, new_scan = jax.lax.scan(scan_body, x, (p["blocks"], store["scan"]))
    new_store = {"scan": new_scan}
    if new_pre:
        new_store["pre"] = new_pre
    logits = _logits(p, x, cfg)[:, 0]
    return new_store, logits
