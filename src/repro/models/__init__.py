"""Model registry: a uniform API over all assigned architecture families."""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from . import mamba2, rwkv6
from . import transformer as tfm
from .config import ModelConfig


@dataclasses.dataclass(frozen=True)
class ModelApi:
    """Uniform model surface used by training, serving, and the dry-run."""

    init: Callable  # (key, cfg) -> Px tree
    loss: Callable  # (params, batch, cfg, *, mesh=None) -> (loss, metrics)
    forward: Callable  # (params, batch, cfg, *, mesh=None) -> (logits, aux)
    prefill: Callable  # (params, batch, cfg, *, max_len, mesh=None) -> (cache, logits)
    decode: Callable  # (params, cache, tokens, cfg, *, mesh=None) -> (cache, logits)
    # chunked cache extension (paged serving); None for state-carrying
    # families whose recurrent state has no per-position KV to extend
    extend: Optional[Callable] = None  # (params, cache, tokens [B,T], cfg, *, mesh=None) -> (cache, logits [B,T,V])
    # single-token decode directly on a block-paged physical store; None
    # for families without per-position KV (and unused by encdec/vlm,
    # whose cross/prefix handling the paged engine does not support)
    decode_paged: Optional[Callable] = None  # (params, store, block_tables, lens, tokens [B], write_phys, write_off, cfg, *, mesh=None) -> (store, logits [B,V])


def get_model(cfg: ModelConfig) -> ModelApi:
    if cfg.family == "hybrid":
        return ModelApi(
            init=mamba2.hybrid_init,
            loss=mamba2.hybrid_loss,
            forward=mamba2.hybrid_forward,
            prefill=mamba2.hybrid_prefill,
            decode=mamba2.hybrid_decode_step,
        )
    if cfg.family == "ssm":
        return ModelApi(
            init=rwkv6.rwkv_init,
            loss=rwkv6.rwkv_loss,
            forward=rwkv6.rwkv_forward,
            prefill=rwkv6.rwkv_prefill,
            decode=rwkv6.rwkv_decode_step,
        )
    # dense / moe / encdec / vlm all run through the transformer stack
    return ModelApi(
        init=tfm.lm_init,
        loss=tfm.loss_fn,
        forward=tfm.forward,
        prefill=tfm.prefill,
        decode=tfm.decode_step,
        extend=tfm.extend_step,
        decode_paged=tfm.paged_decode_step,
    )


# ---------------------------------------------------------------------------
# Synthetic batches (smoke tests / examples); frontends are stubs per spec
# ---------------------------------------------------------------------------


def make_batch(cfg: ModelConfig, batch: int, seq: int, key=None,
               *, frontend_len: Optional[int] = None) -> dict[str, Any]:
    """Random token batch with the right extra inputs per family.

    [audio]/[vlm] archs get stubbed frontend embeddings (the assignment says
    the modality frontend is a STUB providing precomputed frame/patch
    embeddings).
    """
    key = key if key is not None else jax.random.PRNGKey(0)
    k1, k2, k3 = jax.random.split(key, 3)
    tokens = jax.random.randint(k1, (batch, seq), 0, cfg.vocab, jnp.int32)
    out = {
        "tokens": tokens,
        "targets": jnp.roll(tokens, -1, axis=1),
        "loss_mask": jnp.ones((batch, seq), jnp.float32),
    }
    if cfg.family == "encdec":
        n = frontend_len if frontend_len is not None else seq
        out["frame_embeds"] = jax.random.normal(
            k2, (batch, n, cfg.d_model), jnp.float32) * 0.02
    if cfg.family == "vlm":
        n = frontend_len if frontend_len is not None else cfg.vision_tokens or 16
        out["patch_embeds"] = jax.random.normal(
            k3, (batch, n, cfg.d_model), jnp.float32) * 0.02
    return out
