"""Attention: GQA + RoPE (+ optional qk-norm / qkv-bias), three impls.

Implementations
  * ``full``     — materialized scores; fine for short sequences & smoke tests.
  * ``chunked``  — block-wise causal attention in pure jnp: python loop over
                   query blocks, each attending only to its prefix.  This keeps
                   HLO FLOPs at flash levels (lower triangle only) and bounds
                   live memory to one ``[B, H, block_q, kv_len]`` score tile —
                   it is both the long-context dry-run path and the oracle
                   shape for the Pallas flash kernel.
  * ``pallas``   — ``repro.kernels.flash_attention`` (TPU target; interpret
                   mode on CPU).

Decode attends one new token against a (possibly sequence-sharded) KV cache;
softmax over the sharded axis lowers to partial-reduce + all-reduce under
GSPMD, i.e. flash-decode semantics for free.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from . import nn
from .config import ModelConfig

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# Params
# ---------------------------------------------------------------------------


def attention_init(key, cfg: ModelConfig, *, cross: bool = False):
    d, hd = cfg.d_model, cfg.head_dim
    nh, nkv = cfg.n_heads, cfg.n_kv_heads
    nhp = cfg.padded_heads
    ks = jax.random.split(key, 6)
    dt = cfg.pdtype
    p = {
        "q": nn.linear_init(ks[0], d, nhp * hd, axes=("embed", "q_proj"),
                            dtype=dt, bias=cfg.qkv_bias, bias_axis="q_proj"),
        "k": nn.linear_init(ks[1], d, nkv * hd, axes=("embed", "kv_proj"),
                            dtype=dt, bias=cfg.qkv_bias, bias_axis="kv_proj"),
        "v": nn.linear_init(ks[2], d, nkv * hd, axes=("embed", "kv_proj"),
                            dtype=dt, bias=cfg.qkv_bias, bias_axis="kv_proj"),
        "o": nn.linear_init(ks[3], nhp * hd, d, axes=("q_proj", "embed"),
                            dtype=dt, stddev=1.0 / math.sqrt(nh * hd)),
    }
    if nhp != nh:
        # TP head padding: heads are laid out per kv-group [real..., pad...];
        # pad heads' o-rows are zeroed, so their contribution is exactly 0.
        mask = _pad_head_mask(cfg)  # [nhp] bool, True = real
        o = p["o"]["w"].value.reshape(nhp, hd, d)
        p["o"]["w"].value = (o * mask[:, None, None]).reshape(nhp * hd, d)
    if cfg.qk_norm:
        p["q_norm"] = nn.rmsnorm_init(hd, axis="head_dim", dtype=dt)
        p["k_norm"] = nn.rmsnorm_init(hd, axis="head_dim", dtype=dt)
    return p


def _pad_head_mask(cfg: ModelConfig):
    """[padded_heads] bool mask; heads grouped per kv head with pads last."""
    nkv = cfg.n_kv_heads
    g_real = cfg.n_heads // nkv
    g_pad = cfg.padded_heads // nkv
    m = jnp.zeros((nkv, g_pad), bool).at[:, :g_real].set(True)
    return m.reshape(-1)


# ---------------------------------------------------------------------------
# Projections
# ---------------------------------------------------------------------------


def _tp_ok(cfg: ModelConfig, mesh) -> bool:
    return (cfg.explicit_tp and mesh is not None
            and "model" in getattr(mesh, "axis_names", ())
            and cfg.padded_heads % mesh.shape["model"] == 0)


def _project_qkv(p, x, x_kv, cfg: ModelConfig, q_positions, kv_positions,
                 *, rope: bool, mesh=None):
    """Return q [B,S,Hq,D], k/v [B,Skv,Hkv,D]."""
    B, S, _ = x.shape
    Skv = x_kv.shape[1]
    cd = cfg.cdtype
    if _tp_ok(cfg, mesh):
        q = nn.linear_apply_tp(p["q"], x, "column", mesh, cd,
                               fsdp=cfg.fsdp_params)
    else:
        q = nn.linear_apply(p["q"], x, cd)
    q = q.reshape(B, S, cfg.padded_heads, cfg.head_dim)
    k = nn.linear_apply(p["k"], x_kv, cd).reshape(B, Skv, cfg.n_kv_heads, cfg.head_dim)
    v = nn.linear_apply(p["v"], x_kv, cd).reshape(B, Skv, cfg.n_kv_heads, cfg.head_dim)
    if cfg.qk_norm:
        q = nn.rmsnorm_apply(p["q_norm"], q, cfg.norm_eps)
        k = nn.rmsnorm_apply(p["k_norm"], k, cfg.norm_eps)
    if rope:
        q = nn.apply_rope(q, q_positions, cfg.rope_theta)
        k = nn.apply_rope(k, kv_positions, cfg.rope_theta)
    return q, k, v


def _repeat_kv(k, n_q):
    """GQA repeat-KV: [B,S,Hkv,D] -> [B,S,Hq,D].

    Keeps every attention einsum sharded uniformly on the (TP-sharded) q-head
    dim; the repeat is comm-free under GSPMD because the kv-head dim is
    replicated over the model axis.
    """
    B, S, Hkv, D = k.shape
    if Hkv == n_q:
        return k
    return jnp.repeat(k, n_q // Hkv, axis=2)


# ---------------------------------------------------------------------------
# Core attention math
# ---------------------------------------------------------------------------


def full_attention(q, k, v, *, causal: bool, q_offset: int = 0,
                   kv_mask: Optional[jnp.ndarray] = None):
    """Materialized-scores attention.

    q: [B,Sq,Hq,D]  k,v: [B,Sk,Hkv,D] with Hq % Hkv == 0.
    kv_mask: optional [B,Sk] validity mask.
    """
    B, Sq, Hq, D = q.shape
    Sk = k.shape[1]
    k = _repeat_kv(k, Hq)
    v = _repeat_kv(v, Hq)
    scale = 1.0 / math.sqrt(D)
    logits = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    if causal:
        qpos = q_offset + jnp.arange(Sq)
        kpos = jnp.arange(Sk)
        mask = qpos[:, None] >= kpos[None, :]
        logits = jnp.where(mask[None, None], logits, NEG_INF)
    if kv_mask is not None:
        logits = jnp.where(kv_mask[:, None, None, :], logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", probs.astype(v.dtype), v)
    return out


def chunked_causal_attention(q, k, v, *, block_q: int, block_k: int):
    """Block-wise causal attention: python loop over query blocks.

    Each query block i attends only to keys [0, (i+1)*block_q), so compiled
    FLOPs match causal flash attention (half of dense) and live memory is one
    score tile.  Differentiable (plain jnp ops throughout).
    """
    B, S, Hq, D = q.shape
    if S % block_q != 0:
        raise ValueError(f"seq {S} not divisible by block_q {block_q}")
    k = _repeat_kv(k, Hq)
    v = _repeat_kv(v, Hq)
    nq = S // block_q
    scale = 1.0 / math.sqrt(D)
    outs = []
    for i in range(nq):
        q_blk = jax.lax.slice_in_dim(q, i * block_q, (i + 1) * block_q, axis=1)
        kv_len = (i + 1) * block_q
        k_pre = jax.lax.slice_in_dim(k, 0, kv_len, axis=1)
        v_pre = jax.lax.slice_in_dim(v, 0, kv_len, axis=1)
        logits = jnp.einsum("bqhd,bkhd->bhqk", q_blk.astype(jnp.float32),
                            k_pre.astype(jnp.float32)) * scale
        # mask only the diagonal block's upper triangle
        qpos = i * block_q + jnp.arange(block_q)
        kpos = jnp.arange(kv_len)
        mask = qpos[:, None] >= kpos[None, :]
        logits = jnp.where(mask[None, None], logits, NEG_INF)
        probs = jax.nn.softmax(logits, axis=-1)
        out = jnp.einsum("bhqk,bkhd->bqhd", probs.astype(v.dtype), v_pre)
        outs.append(out)
    return jnp.concatenate(outs, axis=1)


def decode_attention(q, k_cache, v_cache, kv_length):
    """One-step decode: q [B,1,Hq,D] vs caches [B,Smax,Hkv,D].

    ``kv_length``: [B] number of valid cache entries (includes current token).
    """
    B, _, Hq, D = q.shape
    Smax = k_cache.shape[1]
    Hkv = k_cache.shape[2]
    # grouped (no repeat-KV): decode reads the cache once; the cache is
    # sequence-sharded at scale, so softmax over the sharded KV axis lowers to
    # partial-reduce + all-reduce (flash-decode semantics under GSPMD).
    # KV stays in its storage dtype: the einsums accumulate in f32 via
    # preferred_element_type WITHOUT materializing f32 copies of the cache
    # (which would triple the memory-bound decode's HBM traffic).
    qg = q.reshape(B, 1, Hkv, Hq // Hkv, D)
    scale = 1.0 / math.sqrt(D)
    logits = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k_cache,
                        preferred_element_type=jnp.float32) * scale
    valid = jnp.arange(Smax)[None, :] < kv_length[:, None]  # [B,Smax]
    logits = jnp.where(valid[:, None, None, None, :], logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", probs.astype(v_cache.dtype),
                     v_cache, preferred_element_type=jnp.float32)
    return out.reshape(B, 1, Hq, D).astype(q.dtype)


# ---------------------------------------------------------------------------
# Top-level apply (prefill / train forward)
# ---------------------------------------------------------------------------


def _pick_impl(cfg: ModelConfig, seq: int) -> str:
    if cfg.attention_impl != "auto":
        return cfg.attention_impl
    if cfg.use_pallas:
        return "pallas"
    return "chunked" if seq > 2048 else "full"


def _head_spec(cfg: ModelConfig, mesh, batch: int):
    """P(batch, None, "model", None) when q-heads divide the model axis."""
    if mesh is None or "model" not in mesh.axis_names:
        return None
    if cfg.padded_heads % mesh.shape["model"]:
        return None
    from repro.models import nn as _nn

    bspec = _nn.batch_pspec(mesh, batch, extra_dims=1)
    from jax.sharding import PartitionSpec as P

    return P(*bspec, "model", None)


def _constrain_heads(q, k, v, cfg, mesh):
    """Pin q and (repeated) k/v to head-sharded layouts so the blockwise
    attention loop never re-gathers KV per block (GSPMD propagation
    otherwise resolves the repeat ambiguously and inserts per-block
    all-gathers)."""
    spec = _head_spec(cfg, mesh, q.shape[0])
    if spec is None:
        return q, k, v
    from repro.models import nn as _nn

    q = _nn.constrain(q, mesh, spec)
    k = _nn.constrain(_repeat_kv(k, cfg.padded_heads), mesh, spec)
    v = _nn.constrain(_repeat_kv(v, cfg.padded_heads), mesh, spec)
    return q, k, v


def attention_apply(p, x, cfg: ModelConfig, *, causal=True, positions=None,
                    x_kv=None, kv_positions=None, rope=True, mesh=None,
                    seq_shard=False):
    """Self (or cross, via x_kv) attention over a full sequence."""
    B, S, _ = x.shape
    if positions is None:
        positions = jnp.arange(S)[None, :]
    x_kv = x if x_kv is None else x_kv
    if kv_positions is None:
        kv_positions = jnp.arange(x_kv.shape[1])[None, :]
    q, k, v = _project_qkv(p, x, x_kv, cfg, positions, kv_positions,
                           rope=rope, mesh=mesh)
    q, k, v = _constrain_heads(q, k, v, cfg, mesh)

    impl = _pick_impl(cfg, S)
    if impl == "pallas" and causal and x_kv is x:
        from repro.kernels.flash_attention import ops as fa_ops

        out = fa_ops.flash_attention(q, k, v, causal=True,
                                     block_q=cfg.attn_chunk_q,
                                     block_k=cfg.attn_chunk_k,
                                     interpret=not cfg.use_pallas or None)
    elif impl == "chunked" and causal and x_kv is x and S % cfg.attn_chunk_q == 0:
        out = chunked_causal_attention(q, k, v, block_q=cfg.attn_chunk_q,
                                       block_k=cfg.attn_chunk_k)
    else:
        out = full_attention(q, k, v, causal=causal and x_kv is x)
    out = out.reshape(B, S, cfg.padded_heads * cfg.head_dim)
    if _tp_ok(cfg, mesh):
        return nn.linear_apply_tp(p["o"], out, "row", mesh, cfg.cdtype,
                                  fsdp=cfg.fsdp_params, seq_shard=seq_shard)
    return nn.linear_apply(p["o"], out, cfg.cdtype)


def attention_prefill(p, x, cfg: ModelConfig, *, positions=None, mesh=None):
    """Prefill: forward + return (output, (k_cache_entries, v_cache_entries))."""
    B, S, _ = x.shape
    if positions is None:
        positions = jnp.arange(S)[None, :]
    q, k, v = _project_qkv(p, x, x, cfg, positions, positions,
                           rope=cfg.positions == "rope", mesh=mesh)
    qc, kc, vc = _constrain_heads(q, k, v, cfg, mesh)
    impl = _pick_impl(cfg, S)
    if impl == "chunked" and S % cfg.attn_chunk_q == 0:
        out = chunked_causal_attention(qc, kc, vc, block_q=cfg.attn_chunk_q,
                                       block_k=cfg.attn_chunk_k)
    else:
        out = full_attention(qc, kc, vc, causal=True)
    out = out.reshape(B, S, cfg.padded_heads * cfg.head_dim)
    if _tp_ok(cfg, mesh):
        return nn.linear_apply_tp(p["o"], out, "row", mesh, cfg.cdtype,
                                  fsdp=cfg.fsdp_params), (k, v)
    return nn.linear_apply(p["o"], out, cfg.cdtype), (k, v)


def attention_extend(p, x, cache_k, cache_v, kv_length, cfg: ModelConfig):
    """Multi-token cache extension (chunked prefill).

    x: [B,T,d] new tokens appended at positions kv_length..kv_length+T-1;
    cache_k/v: [B,Smax,Hkv,D]; kv_length: [B] valid entries *before* this
    chunk.  Returns (out [B,T,d], new_k, new_v, new_len).

    The T=chunk generalization of ``attention_decode``: the chunk's K/V
    are scattered into the cache at their absolute positions, then each
    chunk query attends to the cache prefix plus the chunk's own causal
    triangle.  The score math mirrors ``full_attention`` (f32 einsum,
    NEG_INF mask, softmax) so a prompt prefilled in chunks produces
    bit-identical KV and logits to a single full-sequence prefill —
    masked positions underflow to exactly 0.0 in the softmax, so the
    extra (masked) cache columns never perturb the f32 sums.
    """
    B, T, _ = x.shape
    Smax = cache_k.shape[1]
    pos = kv_length[:, None] + jnp.arange(T)[None, :]  # [B,T] abs positions
    q, k_new, v_new = _project_qkv(p, x, x, cfg, pos, pos,
                                   rope=cfg.positions == "rope")
    bidx = jnp.arange(B)[:, None]
    cache_k = cache_k.at[bidx, pos].set(k_new)
    cache_v = cache_v.at[bidx, pos].set(v_new)
    new_len = kv_length + T
    Hq = q.shape[2]
    k = _repeat_kv(cache_k, Hq)
    v = _repeat_kv(cache_v, Hq)
    scale = 1.0 / math.sqrt(cfg.head_dim)
    logits = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    # key j is valid for chunk query t iff j <= its absolute position
    mask = jnp.arange(Smax)[None, None, :] <= pos[:, :, None]  # [B,T,Smax]
    logits = jnp.where(mask[:, None], logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", probs.astype(v.dtype), v)
    out = out.reshape(B, T, cfg.padded_heads * cfg.head_dim)
    return nn.linear_apply(p["o"], out, cfg.cdtype), cache_k, cache_v, new_len


def attention_decode_paged(p, x, k_store, v_store, block_tables, kv_length,
                           write_phys, write_off, cfg: ModelConfig):
    """Single-token decode directly against a block-paged KV store.

    x: [B,1,d]; k_store/v_store: [num_blocks, block_size, Hkv, D] physical
    stores shared by every sequence; block_tables: [B, max_blocks] int32
    physical block ids per sequence; kv_length: [B] valid positions
    *before* this token; write_phys/write_off: [B] the (physical block,
    in-block offset) cell where this token's K/V lands (padded batch rows
    point at the null block (0, 0), where collisions are harmless).

    Unlike ``attention_decode`` this never materializes a contiguous
    [B, Smax] cache view: the new K/V row is written into ONLY its tail
    block, and attention reads K/V through the block table — via the
    scalar-prefetch Pallas kernel (``paged_decode_attention``) when
    ``cfg.use_pallas`` is on, so the gather happens at DMA issue time and
    per-token HBM traffic is O(blocks-touched) instead of O(Smax).  The
    CPU fallback gathers through the table in jnp (the
    ``paged_decode_ref`` oracle shape) and reuses ``decode_attention``,
    so greedy outputs are bit-identical to the slot path.

    Returns (out [B,1,d], k_store, v_store).
    """
    B = x.shape[0]
    pos = kv_length[:, None]  # [B,1] this token's absolute position
    q, k_new, v_new = _project_qkv(p, x, x, cfg, pos, pos,
                                   rope=cfg.positions == "rope")
    k_store = k_store.at[write_phys, write_off].set(
        k_new[:, 0].astype(k_store.dtype))
    v_store = v_store.at[write_phys, write_off].set(
        v_new[:, 0].astype(v_store.dtype))
    new_len = kv_length + 1
    if cfg.use_pallas or cfg.attention_impl == "pallas":
        from repro.kernels.decode_attention import ops as da_ops

        # interpret mode when forced onto the kernel without a TPU
        # (attention_impl="pallas" on CPU), mirroring attention_apply
        out = da_ops.paged_decode_attention(q, k_store, v_store,
                                            block_tables, new_len,
                                            interpret=not cfg.use_pallas)
    else:
        from repro.kernels.decode_attention.ref import gather_kv

        out = decode_attention(q, gather_kv(k_store, block_tables),
                               gather_kv(v_store, block_tables), new_len)
    out = out.reshape(B, 1, cfg.padded_heads * cfg.head_dim)
    return nn.linear_apply(p["o"], out, cfg.cdtype), k_store, v_store


def attention_decode(p, x, cache_k, cache_v, kv_length, cfg: ModelConfig):
    """Single-token decode step.

    x: [B,1,d]; cache_k/v: [B,Smax,Hkv,D]; kv_length: [B] valid entries
    *before* this token.  Returns (out [B,1,d], new_k, new_v, new_len).
    """
    B = x.shape[0]
    pos = kv_length[:, None]  # [B,1] this token's position
    q, k_new, v_new = _project_qkv(p, x, x, cfg, pos, pos,
                                   rope=cfg.positions == "rope")
    # write new kv at position kv_length (per batch element)
    idx = kv_length  # [B]
    bidx = jnp.arange(B)
    cache_k = cache_k.at[bidx, idx].set(k_new[:, 0])
    cache_v = cache_v.at[bidx, idx].set(v_new[:, 0])
    new_len = kv_length + 1
    out = decode_attention(q, cache_k, cache_v, new_len)
    out = out.reshape(B, 1, cfg.padded_heads * cfg.head_dim)
    return nn.linear_apply(p["o"], out, cfg.cdtype), cache_k, cache_v, new_len
