"""Functional neural-net layer library with logical-axis param metadata.

Params are plain pytrees (nested dicts of jnp arrays).  During init each leaf
is a :class:`Px` wrapper carrying the *logical axis names* of every dimension;
``split(tree)`` separates the value tree (fed to ``apply`` functions) from the
axes tree (mapped to a ``PartitionSpec`` tree by ``repro.launch.sharding``).

All layers are pure functions: ``<layer>_init(key, ...) -> Px tree`` and
``<layer>_apply(params, x, ...) -> y``.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

# ---------------------------------------------------------------------------
# Annotated params
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class Px:
    """A param leaf annotated with logical axis names (one per dim)."""

    value: jnp.ndarray
    axes: tuple

    def __post_init__(self):
        if len(self.axes) != self.value.ndim:
            raise ValueError(
                f"axes {self.axes} rank != value rank {self.value.shape}"
            )


def _is_px(x: Any) -> bool:
    return isinstance(x, Px)


def split(tree):
    """Split a Px tree into (values, axes) trees with identical structure."""
    values = jax.tree.map(lambda p: p.value, tree, is_leaf=_is_px)
    axes = jax.tree.map(lambda p: p.axes, tree, is_leaf=_is_px)
    return values, axes


def stack_layers(trees):
    """Stack a list of Px trees along a new leading 'layers' axis."""

    def _stack(*leaves):
        vals = jnp.stack([l.value for l in leaves])
        return Px(vals, ("layers",) + leaves[0].axes)

    return jax.tree.map(_stack, *trees, is_leaf=_is_px)


def constrain(x, mesh, spec):
    """with_sharding_constraint helper (no-op when mesh is None).

    Activation shardings are constrained explicitly at layer boundaries: pure
    GSPMD propagation may otherwise resolve the FSDP-weight(data) vs
    batch(data) einsum conflict by UNsharding the batch — replicating
    full-batch activations on every device.
    """
    if mesh is None:
        return x
    from jax.sharding import NamedSharding

    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def batch_pspec(mesh, batch_size: int, extra_dims: int = 2):
    """P(batch_axes, None, ...) if the batch divides the DP size, else P()."""
    from jax.sharding import PartitionSpec as P

    if mesh is None:
        return P()
    bt = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    if not bt:
        return P(*([None] * (1 + extra_dims)))
    import math

    dp = math.prod(mesh.shape[a] for a in bt)
    lead = bt if batch_size % dp == 0 else None
    return P(lead, *([None] * extra_dims))


def param_count(values_tree) -> int:
    return int(
        sum(np.prod(v.shape) for v in jax.tree.leaves(values_tree))
    )


def param_bytes(values_tree) -> int:
    return int(
        sum(np.prod(v.shape) * v.dtype.itemsize for v in jax.tree.leaves(values_tree))
    )


# ---------------------------------------------------------------------------
# Initializers
# ---------------------------------------------------------------------------


def normal_init(key, shape, dtype, stddev):
    return (jax.random.normal(key, shape) * stddev).astype(dtype)


def lecun_init(key, shape, dtype, fan_in):
    return normal_init(key, shape, dtype, 1.0 / math.sqrt(max(1, fan_in)))


# ---------------------------------------------------------------------------
# Core layers
# ---------------------------------------------------------------------------


def linear_init(key, d_in, d_out, *, axes, dtype=jnp.float32, bias=False,
                bias_axis=None, stddev=None):
    """Dense projection ``[d_in] -> [d_out]`` with logical axes for sharding."""
    w = Px(
        lecun_init(key, (d_in, d_out), dtype, d_in)
        if stddev is None
        else normal_init(key, (d_in, d_out), dtype, stddev),
        axes,
    )
    p = {"w": w}
    if bias:
        p["b"] = Px(jnp.zeros((d_out,), dtype), (bias_axis if bias_axis is not None else axes[-1],))
    return p


def linear_apply(p, x, compute_dtype=None):
    w = p["w"]
    if compute_dtype is not None:
        w = w.astype(compute_dtype)
        x = x.astype(compute_dtype)
    y = x @ w
    if "b" in p:
        b = p["b"].astype(y.dtype)
        y = y + b
    return y


def linear_apply_tp(p, x, mode: str, mesh, compute_dtype, *,
                    fsdp: bool = False, seq_shard: bool = False):
    """Explicit Megatron-style tensor-parallel linear via shard_map.

    GSPMD propagation places the TP partial-sum all-reduce on the f32 side of
    the convert that feeds the next norm (2x collective bytes) — this makes
    the collective explicit and bf16 in BOTH directions:

      * mode="column": w [d_in, out(model)]; x replicated over model ->
        y sharded on out.  Backward dx = psum(dy @ w^T) in compute dtype.
      * mode="row":    w [in(model), d_out]; x sharded on in ->
        y = psum(x @ w) in compute dtype.

    ``fsdp=True`` adds an explicit all-gather of the weight over "data"
    (ZeRO-3 gather, also in compute dtype).  Falls back to the plain matmul
    when the mesh/divisibility prerequisites don't hold.
    """
    from jax.sharding import PartitionSpec as P

    w = p["w"]
    if (mesh is None or "model" not in mesh.axis_names):
        return linear_apply(p, x, compute_dtype)
    msize = mesh.shape["model"]
    dsize = mesh.shape.get("data", 1) if fsdp else 1
    d_in, d_out = w.shape
    if mode == "column":
        if d_out % msize or (fsdp and d_in % dsize):
            return linear_apply(p, x, compute_dtype)
    else:
        if d_in % msize or (fsdp and d_out % dsize):
            return linear_apply(p, x, compute_dtype)
    cd = compute_dtype or x.dtype
    x = x.astype(cd)
    w = w.astype(cd)
    bias = p.get("b")
    bspec = batch_pspec(mesh, x.shape[0], extra_dims=x.ndim - 2)
    fs = "data" if fsdp else None

    if mode == "column":
        w_spec = P(fs, "model")
        in_specs = [P(*bspec, None), w_spec]
        out_spec = P(*bspec, "model")

        def local(xl, wl, *b):
            if fsdp:
                # barrier pins the gather to the bf16 value: XLA-CPU upcasts
                # bf16 dots to f32 and would otherwise hoist the convert
                # before the gather, doubling the measured collective bytes
                wl = jax.lax.optimization_barrier(
                    jax.lax.all_gather(wl, "data", axis=0, tiled=True))
            y = xl @ wl
            if b:
                y = y + b[0]
            return y

        args = [x, w]
        if bias is not None:
            in_specs.append(P("model"))
            args.append(bias.astype(cd))
    else:  # row
        w_spec = P("model", fs)
        in_specs = [P(*bspec, "model"), w_spec]
        # Megatron-SP: reduce-scatter the output over the sequence dim
        # (half the bytes of an all-reduce; the residual stays seq-sharded)
        use_sp = seq_shard and x.ndim == 3 and x.shape[1] % msize == 0
        out_spec = (P(bspec[0], "model", None) if use_sp
                    else P(*bspec, None))

        def local(xl, wl, *b):
            if fsdp:
                wl = jax.lax.optimization_barrier(
                    jax.lax.all_gather(wl, "data", axis=1, tiled=True))
            y = jax.lax.optimization_barrier((xl @ wl).astype(cd))
            if use_sp:
                y = jax.lax.psum_scatter(y, "model", scatter_dimension=1,
                                         tiled=True)
            else:
                y = jax.lax.psum(y, "model")
            if b:
                y = y + b[0]
            return y

        args = [x, w]
        if bias is not None:
            in_specs.append(P(None))
            args.append(bias.astype(cd))

    return jax.shard_map(local, mesh=mesh, in_specs=tuple(in_specs),
                         out_specs=out_spec)(*args)


def rmsnorm_init(d, *, axis="embed", dtype=jnp.float32):
    return {"scale": Px(jnp.ones((d,), dtype), (axis,))}


def rmsnorm_apply(p, x, eps=1e-6):
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    return (y * p["scale"].astype(jnp.float32)).astype(dt)


def layernorm_init(d, *, axis="embed", dtype=jnp.float32):
    return {
        "scale": Px(jnp.ones((d,), dtype), (axis,)),
        "bias": Px(jnp.zeros((d,), dtype), (axis,)),
    }


def layernorm_apply(p, x, eps=1e-5):
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    y = (x32 - mu) * jax.lax.rsqrt(var + eps)
    y = y * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)
    return y.astype(dt)


def embedding_init(key, vocab, d, *, dtype=jnp.float32):
    # Input tables use their own logical axes: the token gather must run over
    # an UNsharded vocab dim (a gather over a sharded dim forces SPMD full
    # rematerialization), so the table is sharded on the embed dim instead.
    return {
        "table": Px(normal_init(key, (vocab, d), dtype, 1.0),
                    ("tokens_vocab", "embed_g"))
    }


def embedding_apply(p, ids, compute_dtype=None, mesh=None):
    t = p["table"]
    if compute_dtype is not None:
        t = t.astype(compute_dtype)
    if mesh is not None and "model" in mesh.axis_names:
        # Token lookup via shard_map over the model axis: each shard takes
        # from its [vocab, embed/model] slice locally.  This sidesteps XLA
        # SPMD's gather partitioning entirely (which either fully
        # rematerializes the table or miscompiles under constraints).
        from jax.sharding import PartitionSpec as P

        tok_spec = batch_pspec(mesh, ids.shape[0], extra_dims=ids.ndim - 1)
        out_spec = P(*tok_spec, "model")

        def local(tt, ii):
            return jnp.take(tt, ii, axis=0)

        return jax.shard_map(local, mesh=mesh,
                             in_specs=(P(None, "model"), tok_spec),
                             out_specs=out_spec)(t, ids)
    return jnp.take(t, ids, axis=0)


def embedding_attend(p, x):
    """Tied readout: logits = x @ table.T (fp32 accumulation)."""
    t = p["table"].astype(jnp.float32)
    return x.astype(jnp.float32) @ t.T


# ---------------------------------------------------------------------------
# Activations
# ---------------------------------------------------------------------------


def squared_relu(x):
    r = jax.nn.relu(x)
    return r * r


ACTIVATIONS: dict[str, Callable] = {
    "silu": jax.nn.silu,
    "gelu": jax.nn.gelu,
    "relu": jax.nn.relu,
    "relu2": squared_relu,
}


# ---------------------------------------------------------------------------
# MLP (gated / non-gated)
# ---------------------------------------------------------------------------


def mlp_init(key, d_model, d_ff, *, gated=True, dtype=jnp.float32,
             in_axis="embed", ff_axis="mlp"):
    ks = jax.random.split(key, 3)
    p = {
        "up": linear_init(ks[0], d_model, d_ff, axes=(in_axis, ff_axis), dtype=dtype),
        "down": linear_init(ks[1], d_ff, d_model, axes=(ff_axis, in_axis), dtype=dtype),
    }
    if gated:
        p["gate"] = linear_init(ks[2], d_model, d_ff, axes=(in_axis, ff_axis), dtype=dtype)
    return p


def mlp_apply(p, x, *, activation="silu", compute_dtype=None, mesh=None,
              explicit_tp=False, fsdp=False, seq_shard=False):
    act = ACTIVATIONS[activation]
    tp = explicit_tp and mesh is not None and "model" in getattr(
        mesh, "axis_names", ())
    if tp:
        up = linear_apply_tp(p["up"], x, "column", mesh, compute_dtype,
                             fsdp=fsdp)
        if "gate" in p:
            gate = linear_apply_tp(p["gate"], x, "column", mesh,
                                   compute_dtype, fsdp=fsdp)
            h = act(gate) * up
        else:
            h = act(up)
        return linear_apply_tp(p["down"], h, "row", mesh, compute_dtype,
                               fsdp=fsdp, seq_shard=seq_shard)
    up = linear_apply(p["up"], x, compute_dtype)
    if "gate" in p:
        gate = linear_apply(p["gate"], x, compute_dtype)
        h = act(gate) * up
    else:
        h = act(up)
    return linear_apply(p["down"], h, compute_dtype)


# ---------------------------------------------------------------------------
# Rotary position embeddings
# ---------------------------------------------------------------------------


def rope_frequencies(head_dim: int, theta: float) -> jnp.ndarray:
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))


def apply_rope(x, positions, theta=1e4):
    """x: [..., S, H, D]; positions: broadcastable to [..., S]."""
    d = x.shape[-1]
    freqs = rope_frequencies(d, theta)  # [d/2]
    angles = positions[..., :, None].astype(jnp.float32) * freqs  # [..., S, d/2]
    cos = jnp.cos(angles)[..., :, None, :]  # [..., S, 1, d/2]
    sin = jnp.sin(angles)[..., :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    y1 = x1 * cos - x2 * sin
    y2 = x2 * cos + x1 * sin
    return jnp.concatenate([y1, y2], axis=-1).astype(x.dtype)


def sinusoidal_positions(n_pos: int, d: int) -> jnp.ndarray:
    pos = np.arange(n_pos)[:, None]
    dim = np.arange(d // 2)[None, :]
    angle = pos / np.power(10000.0, 2 * dim / d)
    out = np.concatenate([np.sin(angle), np.cos(angle)], axis=-1)
    return jnp.asarray(out, dtype=jnp.float32)
